"""L1 Pallas kernels: the scoring hot-spot of the paper.

Everything the paper's algorithms do against the database reduces to
scoring a block of feature rows against a parameter vector, optionally
fused with a masked (max, sum-exp, weighted-feature-sum) reduction:

* ``scores_block``  — tiled matvec ``(B, d) @ (d,) -> (B,)``; grid over
  row tiles so each tile's VMEM footprint is ``TILE × d`` floats.
* ``partition_block`` — fused masked scoring + streaming-partition
  fragment ``(max, Σ exp(s − max))`` of Algorithm 3; single pass, the
  scores never hit HBM.
* ``expect_block`` — additionally accumulates ``Σ exp(s − max)·v_r``
  (Algorithm 4's unnormalized feature expectation / the MLE gradient's
  model term).

TPU adaptation notes (DESIGN.md §Hardware-Adaptation): on a real TPU the
row tile sits in VMEM (TILE=256, d=64 ⇒ 64 KiB f32), θ is resident
across the grid, the ``(TILE, d) @ (d, 1)`` product maps onto the MXU,
and the fused reductions keep their accumulator in scratch across grid
steps. Here the kernels run with ``interpret=True`` (the CPU PJRT plugin
cannot execute Mosaic custom-calls), which exercises identical dataflow.

All kernels are shape-polymorphic in ``B`` and ``d`` at trace time but
are AOT-lowered for the fixed shapes recorded in ``artifacts/manifest.json``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height for the tiled scores kernel. 256 rows × d floats per
# VMEM tile; must divide the AOT block size.
TILE = 256

NEG = jnp.float32(-1e30)


# --------------------------------------------------------------------------
# scores: tiled matvec
# --------------------------------------------------------------------------

def _scores_kernel(v_ref, q_ref, o_ref):
    # (TILE, d) @ (d,) -> (TILE,)
    o_ref[...] = v_ref[...] @ q_ref[...]


def scores_block(v, q, tile=None):
    """Tiled Pallas matvec: scores of a row block.

    v: (B, d) f32, q: (d,) f32 -> (B,) f32.

    `tile` selects the row-tile height (default [`TILE`]). On TPU the
    VMEM-sized default is right; for the **CPU AOT schedule** the
    interpret-mode grid lowers to a serialized HLO while-loop whose
    per-iteration overhead dominates, so `aot.py` lowers with
    `tile = B` (one grid step — §Perf L2 iteration). Both schedules are
    numerically identical (tested).
    """
    b, d = v.shape
    tile = tile or TILE
    if b % tile == 0 and b >= tile:
        grid = (b // tile,)
        return pl.pallas_call(
            _scores_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile, d), lambda i: (i, 0)),
                pl.BlockSpec((d,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((b,), v.dtype),
            interpret=True,
        )(v, q)
    # ragged fallback: one whole-block tile
    return pl.pallas_call(
        _scores_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), v.dtype),
        interpret=True,
    )(v, q)


# --------------------------------------------------------------------------
# batched scores: one row block scored for a whole query batch
# --------------------------------------------------------------------------

def _scores_batch_kernel(v_ref, qs_ref, o_ref):
    # (TILE, d) @ (d, Q) -> stored query-major (Q, TILE): the row tile is
    # loaded once and reused across the whole query batch
    o_ref[...] = (v_ref[...] @ qs_ref[...].T).T


def scores_batch_block(v, qs, tile=None):
    """Batched tiled Pallas matvec: scores of a row block for Q queries.

    v: (B, d) f32, qs: (Q, d) f32 -> (Q, B) f32 (query-major — the
    layout of ``ScoreBackend::scores_batch`` on the rust side). Each row
    tile crosses HBM once per *batch* instead of once per query — the
    accelerator analogue of the native register-blocked multi-query
    kernels (same amortization the fast-scan PQ tiles give the CPU).
    """
    b, d = v.shape
    qn = qs.shape[0]
    tile = tile or TILE
    if b % tile == 0 and b >= tile:
        grid = (b // tile,)
        return pl.pallas_call(
            _scores_batch_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile, d), lambda i: (i, 0)),
                pl.BlockSpec((qn, d), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((qn, tile), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((qn, b), v.dtype),
            interpret=True,
        )(v, qs)
    # ragged fallback: one whole-block tile
    return pl.pallas_call(
        _scores_batch_kernel,
        out_shape=jax.ShapeDtypeStruct((qn, b), v.dtype),
        interpret=True,
    )(v, qs)


# --------------------------------------------------------------------------
# partition: fused masked (max, sumexp)
# --------------------------------------------------------------------------

def _partition_kernel(v_ref, q_ref, cnt_ref, m_ref, se_ref):
    s = v_ref[...] @ q_ref[...]
    cnt = cnt_ref[0]
    valid = jnp.arange(s.shape[0]) < cnt
    # literal sentinel (a module-level jnp constant would be captured and
    # rejected by pallas_call)
    s = jnp.where(valid, s, -1e30)
    m = jnp.max(s)
    se = jnp.sum(jnp.where(valid, jnp.exp(s - m), 0.0))
    m_ref[0] = m
    se_ref[0] = se


def partition_block(v, q, count):
    """Fused masked partition fragment.

    v: (B, d), q: (d,), count: () i32 -> (max (1,), sumexp (1,)).
    The whole block is one kernel invocation: scores stay in VMEM and are
    reduced in place (single pass over HBM-resident rows).
    """
    b, _d = v.shape
    cnt = jnp.reshape(count.astype(jnp.int32), (1,))
    m, se = pl.pallas_call(
        _partition_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), v.dtype),
            jax.ShapeDtypeStruct((1,), v.dtype),
        ),
        interpret=True,
    )(v, q, cnt)
    return m, se


def _partition_batch_kernel(v_ref, qs_ref, cnt_ref, m_ref, se_ref):
    s = qs_ref[...] @ v_ref[...].T  # (Q, B): rows cross VMEM once
    cnt = cnt_ref[0]
    valid = jnp.arange(s.shape[1]) < cnt
    s = jnp.where(valid[None, :], s, -1e30)
    m = jnp.max(s, axis=1)
    se = jnp.sum(jnp.where(valid[None, :], jnp.exp(s - m[:, None]), 0.0), axis=1)
    m_ref[...] = m
    se_ref[...] = se


def partition_batch_block(v, qs, count):
    """Fused masked partition fragments for a whole query batch.

    v: (B, d), qs: (Q, d), count: () i32 -> (max (Q,), sumexp (Q,)).
    One kernel invocation serves all Q queries' (max, Σexp) fragments —
    per-query results identical to ``partition_block`` per row of ``qs``.
    """
    qn = qs.shape[0]
    cnt = jnp.reshape(count.astype(jnp.int32), (1,))
    m, se = pl.pallas_call(
        _partition_batch_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((qn,), v.dtype),
            jax.ShapeDtypeStruct((qn,), v.dtype),
        ),
        interpret=True,
    )(v, qs, cnt)
    return m, se


# --------------------------------------------------------------------------
# expect: fused masked (max, sumexp, weighted feature sum)
# --------------------------------------------------------------------------

def _expect_kernel(v_ref, q_ref, cnt_ref, m_ref, se_ref, ws_ref):
    v = v_ref[...]
    s = v @ q_ref[...]
    cnt = cnt_ref[0]
    valid = jnp.arange(s.shape[0]) < cnt
    s = jnp.where(valid, s, -1e30)
    m = jnp.max(s)
    w = jnp.where(valid, jnp.exp(s - m), 0.0)
    m_ref[0] = m
    se_ref[0] = jnp.sum(w)
    ws_ref[...] = w @ v


def expect_block(v, q, count):
    """Fused masked expectation fragment.

    v: (B, d), q: (d,), count: () i32 ->
    (max (1,), sumexp (1,), wsum (d,)).
    """
    b, d = v.shape
    cnt = jnp.reshape(count.astype(jnp.int32), (1,))
    m, se, ws = pl.pallas_call(
        _expect_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), v.dtype),
            jax.ShapeDtypeStruct((1,), v.dtype),
            jax.ShapeDtypeStruct((d,), v.dtype),
        ),
        interpret=True,
    )(v, q, cnt)
    return m, se, ws


def _expect_batch_kernel(v_ref, qs_ref, cnt_ref, m_ref, se_ref, ws_ref):
    v = v_ref[...]
    s = qs_ref[...] @ v.T  # (Q, B)
    cnt = cnt_ref[0]
    valid = jnp.arange(s.shape[1]) < cnt
    s = jnp.where(valid[None, :], s, -1e30)
    m = jnp.max(s, axis=1)
    w = jnp.where(valid[None, :], jnp.exp(s - m[:, None]), 0.0)
    m_ref[...] = m
    se_ref[...] = jnp.sum(w, axis=1)
    ws_ref[...] = w @ v  # (Q, d)


def expect_batch_block(v, qs, count):
    """Fused masked expectation fragments for a whole query batch.

    v: (B, d), qs: (Q, d), count: () i32 ->
    (max (Q,), sumexp (Q,), wsum (Q, d)). Per-query results identical to
    ``expect_block`` per row of ``qs``.
    """
    qn = qs.shape[0]
    d = v.shape[1]
    cnt = jnp.reshape(count.astype(jnp.int32), (1,))
    m, se, ws = pl.pallas_call(
        _expect_batch_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((qn,), v.dtype),
            jax.ShapeDtypeStruct((qn,), v.dtype),
            jax.ShapeDtypeStruct((qn, d), v.dtype),
        ),
        interpret=True,
    )(v, qs, cnt)
    return m, se, ws


# --------------------------------------------------------------------------
# sq8 screen: exact integer u8-codes × i16-query dot
# --------------------------------------------------------------------------

def _sq8_screen_kernel(c_ref, q_ref, o_ref):
    o_ref[...] = c_ref[...].astype(jnp.int32) @ q_ref[...].astype(jnp.int32)


def sq8_screen_block(codes, q):
    """Integer SQ8 screening sums: u8 codes × i16 query -> i32 per row.

    codes: (B, d) u8, q: (d,) i16 -> (B,) i32. The per-block affine
    dequant (scale/offset) stays on the rust host exactly as the native
    integer kernels do it: this executable returns the *same exact
    integer sums* the native u8×i16 kernels accumulate, so a PJRT-served
    screen is bit-identical by construction. The i32 accumulator is
    exact for d·255·32767 < 2³¹ (d ≤ 257 — far above any compiled d).
    """
    b, _d = codes.shape
    return pl.pallas_call(
        _sq8_screen_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(codes, q)


@functools.lru_cache(maxsize=None)
def vmem_tile_bytes(d: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint estimate of one scores tile (DESIGN.md §Perf):
    row tile + resident query + output lane."""
    return TILE * d * dtype_bytes + d * dtype_bytes + TILE * dtype_bytes
