"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference implementation here written
with nothing but `jax.numpy` ops. pytest (with hypothesis sweeps) asserts
`assert_allclose(kernel(...), ref(...))` over shapes and inputs; the rust
layer additionally cross-checks the AOT artifacts against its own native
backend in `rust/tests/`.
"""

import jax.numpy as jnp

# large-negative sentinel for masked lanes (safe in f32: avoids inf - inf)
NEG = jnp.float32(-1e30)


def scores(v, q):
    """Scores of a row block: (B, d) @ (d,) -> (B,)."""
    return v @ q


def _masked_scores(v, q, count):
    s = v @ q
    idx = jnp.arange(v.shape[0])
    return jnp.where(idx < count, s, NEG)


def partition(v, q, count):
    """Masked streaming-partition fragment of a block.

    Returns (max, sumexp) with max over the first `count` rows and
    sumexp = sum(exp(s - max)) over those rows.
    """
    s = _masked_scores(v, q, count)
    m = jnp.max(s)
    se = jnp.sum(jnp.where(jnp.arange(v.shape[0]) < count, jnp.exp(s - m), 0.0))
    return m, se


def expect(v, q, count):
    """Masked expectation fragment: (max, sumexp, wsum) where
    wsum = sum_r exp(s_r - max) * v_r over the first `count` rows.
    """
    s = _masked_scores(v, q, count)
    m = jnp.max(s)
    valid = (jnp.arange(v.shape[0]) < count).astype(v.dtype)
    w = jnp.exp(s - m) * valid
    se = jnp.sum(w)
    wsum = w @ v
    return m, se, wsum


def log_partition_full(v, q):
    """Direct log-sum-exp over all rows (model-level oracle)."""
    s = v @ q
    m = jnp.max(s)
    return m + jnp.log(jnp.sum(jnp.exp(s - m)))


def feature_expectation_full(v, q):
    """Direct softmax-weighted feature mean (model-level oracle)."""
    s = v @ q
    w = jnp.exp(s - jnp.max(s))
    return (w @ v) / jnp.sum(w)
