"""L2: the log-linear model's compute graph, composed from the L1 Pallas
kernels.

The model is ``Pr(x; θ) ∝ exp(θ·φ(x))`` over a fixed feature database.
The rust coordinator (L3) drives three AOT entry points per (block, d)
shape — see ``aot.py``:

* ``scores(V, θ)``            — raw block scores (MIPS scans, tail scoring),
* ``partition(V, θ, count)``  — masked (max, Σexp) fragment (Algorithm 3),
* ``expect(V, θ, count)``     — + Σexp·φ fragment (Algorithm 4 / gradient).

Block fragments are merged on the rust side with the same max-shift
algebra (`linalg::MaxSumExp::merge`), so the full-database results are
independent of the blocking. The model-level helpers below implement the
whole-database compositions in JAX; they exist for testing that algebra
(kernel fragments → whole answer) and as documentation of the math.
"""

import jax
import jax.numpy as jnp

from compile.kernels import scores as K


def scores_entry(v, q):
    """AOT entry: block scores (CPU schedule — tile = whole block; the
    interpret-mode grid loop serializes on CPU, see kernels.scores)."""
    return (K.scores_block(v, q, tile=v.shape[0]),)


def scores_entry_tpu(v, q):
    """TPU-schedule variant: VMEM-sized row tiles (kept for parity tests
    and as the real-TPU lowering target)."""
    return (K.scores_block(v, q),)


def partition_entry(v, q, count):
    """AOT entry: masked partition fragment (max, sumexp)."""
    m, se = K.partition_block(v, q, count)
    return (m, se)


def expect_entry(v, q, count):
    """AOT entry: masked expectation fragment (max, sumexp, wsum)."""
    m, se, ws = K.expect_block(v, q, count)
    return (m, se, ws)


def scores_batch_entry(v, qs):
    """AOT entry: one row block scored for a Q-query batch, query-major
    (Q, B) — the layout of ``ScoreBackend::scores_batch`` on the rust
    side. Replaces the per-query executable loop for batched requests."""
    return (K.scores_batch_block(v, qs, tile=v.shape[0]),)


def partition_batch_entry(v, qs, count):
    """AOT entry: masked partition fragments for a Q-query batch."""
    m, se = K.partition_batch_block(v, qs, count)
    return (m, se)


def expect_batch_entry(v, qs, count):
    """AOT entry: masked expectation fragments for a Q-query batch."""
    m, se, ws = K.expect_batch_block(v, qs, count)
    return (m, se, ws)


def sq8_screen_entry(codes, q):
    """AOT entry: exact integer SQ8 screening sums (u8 codes × i16
    query); the affine dequant stays on the rust host for bit parity."""
    return (K.sq8_screen_block(codes, q),)


# --------------------------------------------------------------------------
# whole-database compositions (test/reference only; L3 does this in rust)
# --------------------------------------------------------------------------

def merge_fragments(ms, ses):
    """Merge (max, sumexp) fragments with the max-shift algebra."""
    ms = jnp.stack(ms)
    ses = jnp.stack(ses)
    m = jnp.max(ms)
    return m, jnp.sum(ses * jnp.exp(ms - m))


def log_partition_blocked(v, q, block):
    """log Z via block fragments — must equal the direct logsumexp."""
    n = v.shape[0]
    ms, ses = [], []
    for start in range(0, n, block):
        blk = v[start : start + block]
        pad = block - blk.shape[0]
        if pad:
            blk = jnp.pad(blk, ((0, pad), (0, 0)))
        m, se = K.partition_block(blk, q, jnp.int32(min(block, n - start)))
        ms.append(m[0])
        ses.append(se[0])
    m, se = merge_fragments(ms, ses)
    return m + jnp.log(se)


def feature_expectation_blocked(v, q, block):
    """E_θ[φ] via block fragments — must equal the direct softmax mean."""
    n, d = v.shape
    ms, ses, wss = [], [], []
    for start in range(0, n, block):
        blk = v[start : start + block]
        pad = block - blk.shape[0]
        if pad:
            blk = jnp.pad(blk, ((0, pad), (0, 0)))
        m, se, ws = K.expect_block(blk, q, jnp.int32(min(block, n - start)))
        ms.append(m[0])
        ses.append(se[0])
        wss.append(ws)
    mstack = jnp.stack(ms)
    m = jnp.max(mstack)
    scale = jnp.exp(mstack - m)
    se = jnp.sum(jnp.stack(ses) * scale)
    wsum = jnp.sum(jnp.stack(wss) * scale[:, None], axis=0)
    return wsum / se


def log_likelihood(v, q, data_ids):
    """Mean log-likelihood of a subset (θ-differentiable; the learning
    objective of §4.4). Gradient identity used by tests:
    ∇_θ logZ = E_θ[φ]."""
    mean_score = jnp.mean(v[data_ids] @ q)
    from compile.kernels import ref

    return mean_score - ref.log_partition_full(v, q)
