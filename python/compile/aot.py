"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the rust
runtime (L3).

Emits, for the configured (block, d):

    artifacts/scores_{B}x{d}.hlo.txt
    artifacts/partition_{B}x{d}.hlo.txt
    artifacts/expect_{B}x{d}.hlo.txt
    artifacts/scores_batch_{B}x{d}.hlo.txt     (Q-query batched variants)
    artifacts/partition_batch_{B}x{d}.hlo.txt
    artifacts/expect_batch_{B}x{d}.hlo.txt
    artifacts/sq8_screen_{B}x{d}.hlo.txt       (integer u8×i16 screen)
    artifacts/manifest.json

HLO *text* is the interchange format (NOT ``lowered.compile()`` /
serialized protos): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` rust crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONLY here, at build time. ``make artifacts`` re-runs this
when the compile-path sources change; the rust binary then serves every
request without touching Python.

Usage:
    python -m compile.aot --out-dir ../artifacts [--block 4096] [--dim 64]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entries(block: int, dim: int, qbatch: int = 8):
    """Lower the entry points for one (block, d) shape.

    Besides the three per-query entries, emits the Q-query batched
    variants (fixed ``qbatch`` group; rust pads short groups) and the
    integer SQ8 screening entry. The rust loader derives the group size
    from the ``scores_batch`` entry's input shapes, so older artifact
    sets without the batched entries keep working (per-query fallback).
    """
    f32 = jnp.float32
    i32 = jnp.int32
    v = jax.ShapeDtypeStruct((block, dim), f32)
    q = jax.ShapeDtypeStruct((dim,), f32)
    qs = jax.ShapeDtypeStruct((qbatch, dim), f32)
    cnt = jax.ShapeDtypeStruct((), i32)
    codes = jax.ShapeDtypeStruct((block, dim), jnp.uint8)
    qi16 = jax.ShapeDtypeStruct((dim,), jnp.int16)

    entries = [
        (
            "scores",
            jax.jit(model.scores_entry).lower(v, q),
            [[block, dim], [dim]],
            [[block]],
        ),
        (
            "partition",
            jax.jit(model.partition_entry).lower(v, q, cnt),
            [[block, dim], [dim], []],
            [[1], [1]],
        ),
        (
            "expect",
            jax.jit(model.expect_entry).lower(v, q, cnt),
            [[block, dim], [dim], []],
            [[1], [1], [dim]],
        ),
        (
            "scores_batch",
            jax.jit(model.scores_batch_entry).lower(v, qs),
            [[block, dim], [qbatch, dim]],
            [[qbatch, block]],
        ),
        (
            "partition_batch",
            jax.jit(model.partition_batch_entry).lower(v, qs, cnt),
            [[block, dim], [qbatch, dim], []],
            [[qbatch], [qbatch]],
        ),
        (
            "expect_batch",
            jax.jit(model.expect_batch_entry).lower(v, qs, cnt),
            [[block, dim], [qbatch, dim], []],
            [[qbatch], [qbatch], [qbatch, dim]],
        ),
        (
            "sq8_screen",
            jax.jit(model.sq8_screen_entry).lower(codes, qi16),
            [[block, dim], [dim]],
            [[block]],
        ),
    ]
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--block", type=int, default=4096, help="rows per executable call")
    ap.add_argument("--dim", type=int, default=64, help="feature dimension d")
    ap.add_argument(
        "--qbatch", type=int, default=8, help="queries per batched executable call"
    )
    # legacy single-file mode kept for the Makefile's convenience target
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    out_dir = out_dir or "."
    os.makedirs(out_dir, exist_ok=True)

    if args.block % 256 != 0:
        print(f"error: --block must be a multiple of the Pallas TILE (256)", file=sys.stderr)
        sys.exit(2)

    if args.qbatch < 1:
        print("error: --qbatch must be >= 1", file=sys.stderr)
        sys.exit(2)

    # "qbatch" is informational (the rust loader derives the group size
    # from the scores_batch entry's input shapes); extra keys are ignored
    # by older manifest parsers.
    manifest = {"block": args.block, "d": args.dim, "qbatch": args.qbatch, "entries": []}
    for name, lowered, inputs, outputs in lower_entries(args.block, args.dim, args.qbatch):
        text = to_hlo_text(lowered)
        fname = f"{name}_{args.block}x{args.dim}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
