"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts for the rust
runtime (L3).

Emits, for the configured (block, d):

    artifacts/scores_{B}x{d}.hlo.txt
    artifacts/partition_{B}x{d}.hlo.txt
    artifacts/expect_{B}x{d}.hlo.txt
    artifacts/manifest.json

HLO *text* is the interchange format (NOT ``lowered.compile()`` /
serialized protos): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` rust crate binds) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONLY here, at build time. ``make artifacts`` re-runs this
when the compile-path sources change; the rust binary then serves every
request without touching Python.

Usage:
    python -m compile.aot --out-dir ../artifacts [--block 4096] [--dim 64]
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entries(block: int, dim: int):
    """Lower the three entry points for one (block, d) shape."""
    f32 = jnp.float32
    i32 = jnp.int32
    v = jax.ShapeDtypeStruct((block, dim), f32)
    q = jax.ShapeDtypeStruct((dim,), f32)
    cnt = jax.ShapeDtypeStruct((), i32)

    entries = [
        (
            "scores",
            jax.jit(model.scores_entry).lower(v, q),
            [[block, dim], [dim]],
            [[block]],
        ),
        (
            "partition",
            jax.jit(model.partition_entry).lower(v, q, cnt),
            [[block, dim], [dim], []],
            [[1], [1]],
        ),
        (
            "expect",
            jax.jit(model.expect_entry).lower(v, q, cnt),
            [[block, dim], [dim], []],
            [[1], [1], [dim]],
        ),
    ]
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument("--block", type=int, default=4096, help="rows per executable call")
    ap.add_argument("--dim", type=int, default=64, help="feature dimension d")
    # legacy single-file mode kept for the Makefile's convenience target
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    out_dir = out_dir or "."
    os.makedirs(out_dir, exist_ok=True)

    if args.block % 256 != 0:
        print(f"error: --block must be a multiple of the Pallas TILE (256)", file=sys.stderr)
        sys.exit(2)

    manifest = {"block": args.block, "d": args.dim, "entries": []}
    for name, lowered, inputs, outputs in lower_entries(args.block, args.dim):
        text = to_hlo_text(lowered)
        fname = f"{name}_{args.block}x{args.dim}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {"name": name, "file": fname, "inputs": inputs, "outputs": outputs}
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
