"""L2 correctness: blocked-fragment compositions equal direct formulas,
and the gradient identity ∇_θ log Z = E_θ[φ] holds through the kernels.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def rand_db(n, d, scale=1.0):
    v = RNG.normal(size=(n, d)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    q = RNG.normal(size=(d,)).astype(np.float32) * scale
    return jnp.asarray(v), jnp.asarray(q)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=10, max_value=700),
    block=st.sampled_from([64, 128, 256]),
)
def test_blocked_log_partition_matches_direct(n, block):
    v, q = rand_db(n, 16, scale=10.0)
    got = float(model.log_partition_blocked(v, q, block))
    want = float(ref.log_partition_full(v, q))
    assert abs(got - want) < 1e-3, (got, want)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(min_value=10, max_value=500))
def test_blocked_feature_expectation_matches_direct(n):
    v, q = rand_db(n, 12, scale=5.0)
    got = np.asarray(model.feature_expectation_blocked(v, q, 128))
    want = np.asarray(ref.feature_expectation_full(v, q))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_blocking_invariance():
    # different block sizes must give the same answer (the merge algebra
    # the rust MaxSumExp::merge mirrors)
    v, q = rand_db(400, 8, scale=8.0)
    lz = [float(model.log_partition_blocked(v, q, b)) for b in (64, 128, 256)]
    for a in lz[1:]:
        assert abs(a - lz[0]) < 1e-4, lz


def test_gradient_identity():
    # ∇_θ logZ = E_θ[φ]: autodiff through the direct logZ must equal the
    # kernel-computed feature expectation
    v, q = rand_db(300, 10, scale=4.0)
    grad = jax.grad(lambda qq: ref.log_partition_full(v, qq))(q)
    expect = model.feature_expectation_blocked(v, q, 128)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(expect), rtol=1e-3, atol=1e-4)


def test_log_likelihood_gradient_direction():
    # one gradient-ascent step must increase the log-likelihood
    v, q = rand_db(200, 8)
    data_ids = jnp.asarray([3, 17, 42])
    ll = lambda qq: model.log_likelihood(v, qq, data_ids)
    g = jax.grad(ll)(q)
    assert float(ll(q + 0.1 * g)) > float(ll(q))


def test_entry_points_return_tuples():
    v, q = rand_db(256, 8)
    (s,) = model.scores_entry(v, q)
    assert s.shape == (256,)
    m, se = model.partition_entry(v, q, jnp.int32(256))
    assert m.shape == (1,) and se.shape == (1,)
    m, se, ws = model.expect_entry(v, q, jnp.int32(100))
    assert ws.shape == (8,)
