"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and inputs; fixed-seed numpy provides the data.
These tests are the build-time gate: `make artifacts` output is only
trusted because these pass.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import scores as K

RNG = np.random.default_rng(0)


def rand_block(b, d, scale=1.0):
    v = RNG.normal(size=(b, d)).astype(np.float32) * scale
    q = RNG.normal(size=(d,)).astype(np.float32) * scale
    return jnp.asarray(v), jnp.asarray(q)


# -------------------------------------------------------------------------
# scores kernel
# -------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    d=st.integers(min_value=1, max_value=96),
)
def test_scores_tiled_matches_ref(tiles, d):
    b = tiles * K.TILE
    v, q = rand_block(b, d)
    got = K.scores_block(v, q)
    want = ref.scores(v, q)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(min_value=1, max_value=300), d=st.integers(min_value=1, max_value=48))
def test_scores_ragged_fallback(b, d):
    v, q = rand_block(b, d)
    got = K.scores_block(v, q)
    np.testing.assert_allclose(got, ref.scores(v, q), rtol=1e-5, atol=1e-5)


def test_scores_large_magnitude():
    # temperature folding makes queries large (‖θ‖ ≈ 1/τ = 20)
    v, q = rand_block(K.TILE, 64, scale=1.0)
    q = q * 20.0
    got = K.scores_block(v, q)
    np.testing.assert_allclose(got, ref.scores(v, q), rtol=1e-4, atol=1e-3)


# -------------------------------------------------------------------------
# partition kernel (fused masked max/sumexp)
# -------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=257),
    d=st.integers(min_value=1, max_value=48),
    frac=st.floats(min_value=0.05, max_value=1.0),
)
def test_partition_masked_matches_ref(b, d, frac):
    v, q = rand_block(b, d)
    count = max(1, int(b * frac))
    m, se = K.partition_block(v, q, jnp.int32(count))
    rm, rse = ref.partition(v, q, jnp.int32(count))
    np.testing.assert_allclose(m[0], rm, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(se[0], rse, rtol=1e-5, atol=1e-5)


def test_partition_full_count_equals_logsumexp():
    v, q = rand_block(512, 32)
    m, se = K.partition_block(v, q, jnp.int32(512))
    log_z = float(m[0]) + float(jnp.log(se[0]))
    want = float(ref.log_partition_full(v, q))
    assert abs(log_z - want) < 1e-4


def test_partition_padding_rows_ignored():
    # the masked rows' content must not affect the fragment
    v, q = rand_block(128, 16)
    v2 = v.at[100:].set(1e4)  # garbage in the padding region
    m1, se1 = K.partition_block(v, q, jnp.int32(100))
    m2, se2 = K.partition_block(v2, q, jnp.int32(100))
    np.testing.assert_allclose(m1, m2)
    np.testing.assert_allclose(se1, se2)


def test_partition_count_one():
    v, q = rand_block(64, 8)
    m, se = K.partition_block(v, q, jnp.int32(1))
    np.testing.assert_allclose(m[0], (v @ q)[0], rtol=1e-6)
    np.testing.assert_allclose(se[0], 1.0, rtol=1e-6)


# -------------------------------------------------------------------------
# expect kernel (fused masked max/sumexp/weighted-feature-sum)
# -------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=200),
    d=st.integers(min_value=1, max_value=48),
    frac=st.floats(min_value=0.05, max_value=1.0),
)
def test_expect_masked_matches_ref(b, d, frac):
    v, q = rand_block(b, d)
    count = max(1, int(b * frac))
    m, se, ws = K.expect_block(v, q, jnp.int32(count))
    rm, rse, rws = ref.expect(v, q, jnp.int32(count))
    np.testing.assert_allclose(m[0], rm, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(se[0], rse, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ws, rws, rtol=1e-4, atol=1e-4)


def test_expect_full_equals_softmax_mean():
    v, q = rand_block(256, 24)
    m, se, ws = K.expect_block(v, q, jnp.int32(256))
    got = np.asarray(ws) / float(se[0])
    want = np.asarray(ref.feature_expectation_full(v, q))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_expect_padding_rows_ignored():
    v, q = rand_block(96, 12)
    v2 = v.at[80:].set(-777.0)
    out1 = K.expect_block(v, q, jnp.int32(80))
    out2 = K.expect_block(v2, q, jnp.int32(80))
    for a, b_ in zip(out1, out2):
        np.testing.assert_allclose(a, b_)


def test_vmem_tile_budget_documented():
    # DESIGN.md §Perf: the scores tile must fit comfortably in VMEM
    assert K.vmem_tile_bytes(64) < 128 * 1024
    assert K.vmem_tile_bytes(256) < 512 * 1024


def test_cpu_and_tpu_schedules_agree():
    # the whole-block CPU schedule and the VMEM-tiled TPU schedule must be
    # numerically identical (same kernel, different BlockSpec grids)
    v, q = rand_block(2 * K.TILE, 32)
    tiled = K.scores_block(v, q)
    whole = K.scores_block(v, q, tile=v.shape[0])
    np.testing.assert_allclose(tiled, whole, rtol=1e-6, atol=1e-6)


# -------------------------------------------------------------------------
# batched kernels (PR 10): one row block, a whole query group
# -------------------------------------------------------------------------

def rand_batch(b, d, qn, scale=1.0):
    v = RNG.normal(size=(b, d)).astype(np.float32) * scale
    qs = RNG.normal(size=(qn, d)).astype(np.float32) * scale
    return jnp.asarray(v), jnp.asarray(qs)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=300),
    d=st.integers(min_value=1, max_value=48),
    qn=st.integers(min_value=1, max_value=9),
)
def test_scores_batch_matches_per_query(b, d, qn):
    v, qs = rand_batch(b, d, qn)
    got = K.scores_batch_block(v, qs)
    assert got.shape == (qn, b)
    for j in range(qn):
        np.testing.assert_allclose(got[j], ref.scores(v, qs[j]), rtol=1e-5, atol=1e-5)


def test_scores_batch_tiled_matches_whole_block():
    # row-tiled grid (TPU shape) vs the one-step CPU AOT schedule
    v, qs = rand_batch(2 * K.TILE, 32, 8)
    tiled = K.scores_batch_block(v, qs)
    whole = K.scores_batch_block(v, qs, tile=v.shape[0])
    np.testing.assert_allclose(tiled, whole, rtol=1e-6, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=200),
    d=st.integers(min_value=1, max_value=48),
    qn=st.integers(min_value=1, max_value=9),
    frac=st.floats(min_value=0.05, max_value=1.0),
)
def test_partition_batch_matches_per_query(b, d, qn, frac):
    v, qs = rand_batch(b, d, qn)
    count = max(1, int(b * frac))
    m, se = K.partition_batch_block(v, qs, jnp.int32(count))
    for j in range(qn):
        rm, rse = ref.partition(v, qs[j], jnp.int32(count))
        np.testing.assert_allclose(m[j], rm, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(se[j], rse, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(min_value=2, max_value=200),
    d=st.integers(min_value=1, max_value=48),
    qn=st.integers(min_value=1, max_value=9),
    frac=st.floats(min_value=0.05, max_value=1.0),
)
def test_expect_batch_matches_per_query(b, d, qn, frac):
    v, qs = rand_batch(b, d, qn)
    count = max(1, int(b * frac))
    m, se, ws = K.expect_batch_block(v, qs, jnp.int32(count))
    assert ws.shape == (qn, d)
    for j in range(qn):
        rm, rse, rws = ref.expect(v, qs[j], jnp.int32(count))
        np.testing.assert_allclose(m[j], rm, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(se[j], rse, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(ws[j], rws, rtol=1e-4, atol=1e-4)


def test_batch_padding_rows_ignored():
    # masked rows' content must not affect any query's fragments
    v, qs = rand_batch(96, 12, 5)
    v2 = v.at[80:].set(-777.0)
    out1 = K.expect_batch_block(v, qs, jnp.int32(80))
    out2 = K.expect_batch_block(v2, qs, jnp.int32(80))
    for a, b_ in zip(out1, out2):
        np.testing.assert_allclose(a, b_)


def test_sq8_screen_exact_integer_sums():
    # the screen's contract is EXACT integer sums (dequant is host-side)
    codes = RNG.integers(0, 256, size=(200, 48), dtype=np.uint8)
    q = RNG.integers(-(2 ** 15), 2 ** 15, size=(48,), dtype=np.int16)
    got = K.sq8_screen_block(jnp.asarray(codes), jnp.asarray(q))
    assert got.dtype == jnp.int32
    want = codes.astype(np.int64) @ q.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got, dtype=np.int64), want)
