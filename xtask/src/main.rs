//! Repo-local dev tasks (`cargo xtask <cmd>`).
//!
//! `lint` is the only task so far: a textual policy checker for the
//! unsafe-code and determinism conventions documented in
//! rust/UNSAFE_POLICY.md. It is deliberately a line scanner, not a
//! parser — the rules are formatted-source conventions (rustfmt-shaped
//! code), and a scanner keeps the tool std-only so it runs offline and
//! compiles in under a second as the CI fast-fail step.
//!
//! Rules enforced over `rust/src/**/*.rs`:
//!
//! 1. every `unsafe {` block and `unsafe impl` must have a `SAFETY:`
//!    comment on the same line or within the preceding few lines;
//! 2. every `pub`/`pub(...)` `unsafe fn` must carry a `# Safety` doc
//!    section;
//! 3. `.lock().unwrap()` is banned — poisoned mutexes must recover via
//!    `.lock().unwrap_or_else(|p| p.into_inner())` (the PR-7 helpers);
//! 4. nondeterminism APIs (`SystemTime::now`, `thread_rng`) are banned
//!    outside `util/timing.rs` and `benches/` — seeded determinism is
//!    the repo's reproducibility contract;
//! 5. narrowing `as` casts are banned in the wire codecs
//!    (`remote/protocol.rs`, `store/format.rs`) outside test code —
//!    untrusted integers must go through checked conversions.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How many lines above an `unsafe` site may hold its `SAFETY:` comment.
/// Generous enough for a multi-line justification plus one code line
/// (e.g. a `let` binding the comment precedes), tight enough that a
/// stale comment three screens up does not count.
const SAFETY_LOOKBACK: usize = 10;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        Some(other) => {
            eprintln!("unknown xtask '{other}'\n");
            usage();
            ExitCode::FAILURE
        }
        None => {
            usage();
            ExitCode::FAILURE
        }
    }
}

fn usage() {
    eprintln!("usage: cargo xtask <command>\n\ncommands:\n  lint    check unsafe-code & determinism policy (rust/UNSAFE_POLICY.md)");
}

struct Violation {
    file: PathBuf,
    line: usize,
    rule: &'static str,
    message: String,
}

fn lint() -> ExitCode {
    let root = repo_root();
    let src = root.join("rust").join("src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("xtask lint: no .rs files found under {}", src.display());
        return ExitCode::FAILURE;
    }
    let mut violations = Vec::new();
    for f in &files {
        let text = match std::fs::read_to_string(f) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("xtask lint: cannot read {}: {e}", f.display());
                return ExitCode::FAILURE;
            }
        };
        lint_file(f, &text, &mut violations);
    }
    if violations.is_empty() {
        println!("xtask lint: {} files checked, 0 violations", files.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            println!(
                "{}:{}: [{}] {}",
                v.file.strip_prefix(&root).unwrap_or(&v.file).display(),
                v.line,
                v.rule,
                v.message
            );
        }
        println!(
            "xtask lint: {} files checked, {} violation(s) — see rust/UNSAFE_POLICY.md",
            files.len(),
            violations.len()
        );
        ExitCode::FAILURE
    }
}

/// The workspace root: walk up from CWD until Cargo.toml + rust/ exist
/// (cargo runs xtask with CWD at the workspace root, but be tolerant).
fn repo_root() -> PathBuf {
    let mut dir = std::env::current_dir().expect("cwd");
    loop {
        if dir.join("Cargo.toml").is_file() && dir.join("rust").join("src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            return std::env::current_dir().expect("cwd");
        }
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

/// The code part of a line: everything before the first `//` (naive —
/// a `//` inside a string literal would truncate early, which can only
/// under-report tokens in strings, never miss real code tokens, because
/// the scanned sources keep `//` out of string literals).
fn code_part(line: &str) -> &str {
    match line.find("//") {
        Some(i) => &line[..i],
        None => line,
    }
}

/// Whether `hay` contains `needle` bounded by non-identifier characters.
fn has_token(hay: &str, needle: &str) -> bool {
    let is_ident = |c: u8| c.is_ascii_alphanumeric() || c == b'_';
    let h = hay.as_bytes();
    let mut start = 0;
    while let Some(i) = hay[start..].find(needle) {
        let at = start + i;
        let before_ok = at == 0 || !is_ident(h[at - 1]);
        let end = at + needle.len();
        let after_ok = end >= h.len() || !is_ident(h[end]);
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn lint_file(path: &Path, text: &str, out: &mut Vec<Violation>) {
    let lines: Vec<&str> = text.lines().collect();
    let rel = path.to_string_lossy().replace('\\', "/");
    let is_wire_codec =
        rel.ends_with("src/remote/protocol.rs") || rel.ends_with("src/store/format.rs");
    let nondet_allowed = rel.ends_with("src/util/timing.rs");
    // test code starts at the first #[cfg(test)] — by repo convention the
    // test module is the tail of the file
    let test_start =
        lines.iter().position(|l| l.trim_start().starts_with("#[cfg(test)]")).unwrap_or(usize::MAX);

    for (idx, &line) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = code_part(line);
        let in_test = idx >= test_start;

        // rule 3: raw lock().unwrap() — everywhere, tests included (a
        // poisoned-mutex panic cascade in a test is still a flake)
        if code.contains(".lock().unwrap()") {
            out.push(Violation {
                file: path.to_path_buf(),
                line: lineno,
                rule: "lock-unwrap",
                message: "raw `.lock().unwrap()` — use the poison-recovering \
                          `.lock().unwrap_or_else(|p| p.into_inner())` pattern"
                    .into(),
            });
        }

        // rule 4: nondeterminism APIs
        if !nondet_allowed {
            for api in ["SystemTime::now", "thread_rng"] {
                if code.contains(api) {
                    out.push(Violation {
                        file: path.to_path_buf(),
                        line: lineno,
                        rule: "nondeterminism",
                        message: format!(
                            "`{api}` outside util/timing.rs — derive times/randomness \
                             from the seeded Pcg64 streams or util::timing"
                        ),
                    });
                }
            }
        }

        // rule 5: narrowing casts in the wire codecs
        if is_wire_codec && !in_test {
            for cast in [" as u8", " as u16", " as u32", " as i8", " as i16", " as i32",
                " as usize"]
            {
                if has_token(code, cast.trim_start()) && code.contains(cast) {
                    out.push(Violation {
                        file: path.to_path_buf(),
                        line: lineno,
                        rule: "truncating-cast",
                        message: format!(
                            "narrowing `{}` in a wire codec — use a checked conversion \
                             (`try_from`) so corrupt input errors instead of wrapping",
                            cast.trim_start()
                        ),
                    });
                }
            }
        }

        // rules 1 + 2: unsafe hygiene
        if !has_token(code, "unsafe") {
            continue;
        }
        let after = code[code.find("unsafe").expect("token present") + "unsafe".len()..].trim_start();
        if after.starts_with("fn") {
            // rule 2: pub unsafe fn needs # Safety docs; private unsafe
            // fns discharge their obligations at call sites (rule 1)
            if code.trim_start().starts_with("pub") {
                let mut has_safety_doc = false;
                let mut j = idx;
                while j > 0 {
                    j -= 1;
                    let t = lines[j].trim_start();
                    if t.starts_with("///") || t.starts_with("//") || t.starts_with("#[") {
                        if t.starts_with("///") && t.contains("# Safety") {
                            has_safety_doc = true;
                            break;
                        }
                    } else {
                        break;
                    }
                }
                if !has_safety_doc {
                    out.push(Violation {
                        file: path.to_path_buf(),
                        line: lineno,
                        rule: "missing-safety-doc",
                        message: "`pub unsafe fn` without a `# Safety` doc section".into(),
                    });
                }
            }
        } else {
            // rule 1: unsafe block / unsafe impl needs an adjacent SAFETY:
            let mut has_safety = line.contains("SAFETY:");
            if !has_safety {
                for j in idx.saturating_sub(SAFETY_LOOKBACK)..idx {
                    if lines[j].contains("SAFETY:") {
                        has_safety = true;
                        break;
                    }
                }
            }
            if !has_safety {
                let what = if after.starts_with("impl") { "unsafe impl" } else { "unsafe block" };
                out.push(Violation {
                    file: path.to_path_buf(),
                    line: lineno,
                    rule: "missing-safety-comment",
                    message: format!(
                        "{what} without a `SAFETY:` comment within {SAFETY_LOOKBACK} lines"
                    ),
                });
            }
        }
    }
}
