//! Figure 7: amortized cost incl. index build (paper: break-even ~8600 samples)
mod common;

fn main() {
    common::banner("bench_fig7_amortized", "Figure 7: amortized cost incl. index build (paper: break-even ~8600 samples)");
    let opts = common::bench_opts(60000, 8);
    gmips::eval::fig7::run(&opts);
}
