//! Table 1: sampling speedup + TV bound (paper: 4.65x/4.17x, TV ~1e-4)
mod common;

fn main() {
    common::banner("bench_table1_accuracy", "Table 1: sampling speedup + TV bound (paper: 4.65x/4.17x, TV ~1e-4)");
    let opts = common::bench_opts(60000, 12);
    gmips::eval::table1::run(&opts);
}
