//! Shared bench plumbing: the offline registry has no criterion, so each
//! bench is a `harness = false` binary that runs its eval driver at a
//! bench-friendly scale and prints the paper-style table.
//!
//! Scale knobs (env): GMIPS_BENCH_N (dataset size), GMIPS_BENCH_Q
//! (queries per config). Defaults keep each bench in the tens of seconds
//! on one core; `GMIPS_BENCH_N=1281167` reproduces paper scale.

use gmips::eval::EvalOpts;

#[allow(dead_code)]
pub fn bench_opts(default_n: usize, default_q: usize) -> EvalOpts {
    let n = std::env::var("GMIPS_BENCH_N")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_n);
    let queries = std::env::var("GMIPS_BENCH_Q")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_q);
    EvalOpts { n, queries, seed: 42, write_csv: true }
}

#[allow(dead_code)]
pub fn banner(name: &str, paper: &str) {
    println!("\n######################################################################");
    println!("# bench: {name}");
    println!("# paper reference: {paper}");
    println!("######################################################################");
}
