//! Hot-path microbenches (the §Perf deliverable): every stage of a
//! sampling/estimation query measured in isolation, so regressions are
//! attributable. Not a paper figure — this is the optimization harness.
//!
//! Stages: native block scoring, PJRT block scoring (when artifacts
//! exist), top-k collection, IVF probe, lazy tail draw, full Alg-1
//! sample, Alg-3 estimate.

mod common;

use gmips::config::Config;
use gmips::data;
use gmips::estimator::partition::PartitionEstimator;
use gmips::gumbel;
use gmips::mips::{self, MipsIndex};
use gmips::runtime::PjrtScorer;
use gmips::sampler::{lazy_gumbel::LazyGumbelSampler, Sampler};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::rng::Pcg64;
use gmips::util::timing::Bench;
use gmips::util::topk::TopK;
use rustc_hash::FxHashSet;
use std::sync::Arc;

fn main() {
    common::banner("bench_perf_hotpath", "§Perf: per-stage hot path microbenches");
    let opts = common::bench_opts(100_000, 8);
    let mut cfg = Config::preset("imagenet").unwrap();
    cfg.data.n = opts.n;
    cfg.data.d = 64;
    let d = cfg.data.d;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rng = Pcg64::new(1);
    let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);

    let bench = Bench::default();
    let mut results = Vec::new();

    // ---- native block scoring ------------------------------------------------
    let block = 4096.min(ds.n);
    let rows = &ds.data[..block * d];
    let mut out = vec![0f32; block];
    let s = bench.run("native scores 4096x64", || {
        NativeScorer.scores(std::hint::black_box(rows), d, &q, &mut out);
    });
    let gflops = (2.0 * block as f64 * d as f64) / s.mean_s / 1e9;
    results.push((s.clone(), format!("{gflops:.2} GFLOP/s")));

    // ---- PJRT block scoring (optional) ----------------------------------------
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match PjrtScorer::load("artifacts") {
            Ok(scorer) if scorer.d() == d => {
                let s = bench.run("pjrt scores 4096x64", || {
                    scorer.scores(std::hint::black_box(rows), d, &q, &mut out);
                });
                let gflops = (2.0 * block as f64 * d as f64) / s.mean_s / 1e9;
                results.push((s, format!("{gflops:.2} GFLOP/s")));
                let sc = Arc::new(scorer);
                let s = bench.run("pjrt fused partition 4096x64", || {
                    std::hint::black_box(sc.max_sumexp(rows, d, &q));
                });
                results.push((s, String::new()));
            }
            _ => println!("(skipping pjrt benches: artifacts missing or wrong d)"),
        }
    }

    // ---- top-k collection -----------------------------------------------------
    let scores: Vec<f32> = (0..ds.n).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0).collect();
    let k = cfg.sampler_k();
    let s = bench.run(&format!("topk k={k} over n={}", ds.n), || {
        let mut tk = TopK::new(k);
        tk.push_block(0, std::hint::black_box(&scores));
        std::hint::black_box(tk.into_sorted());
    });
    results.push((s, String::new()));

    // ---- IVF index probe --------------------------------------------------------
    let index: Arc<dyn MipsIndex> = {
        let mut icfg = cfg.index.clone();
        icfg.n_clusters = 0;
        icfg.n_probe = 0;
        icfg.kmeans_iters = 6;
        icfg.train_sample = 20_000.min(ds.n);
        mips::build_index(&ds, &icfg, backend.clone()).unwrap()
    };
    let s = bench.run("ivf top_k", || {
        std::hint::black_box(index.top_k(&q, k));
    });
    results.push((s, String::new()));

    // ---- lazy tail draw ---------------------------------------------------------
    let exclude: FxHashSet<u32> = (0..k as u32).collect();
    let b = gumbel::fixed_cutoff(ds.n, k);
    let s = bench.run("lazy tail draw (m≈k)", || {
        std::hint::black_box(gumbel::sample_tail(ds.n, &exclude, b, &mut rng));
    });
    results.push((s, String::new()));

    // ---- full Algorithm 1 sample --------------------------------------------------
    let sampler = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
    let s = bench.run("Alg1 sample (fresh θ)", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(sampler.sample(&theta, &mut rng));
    });
    results.push((s, String::new()));
    // amortized: one MIPS call, many draws
    let top = index.top_k(&q, k);
    let s = bench.run("Alg1 draw (reused top-k)", || {
        std::hint::black_box(sampler.sample_given_top(&top, &q, &mut rng));
    });
    results.push((s, String::new()));

    // ---- Algorithm 3 estimate ------------------------------------------------------
    let est = PartitionEstimator::new(ds.clone(), index, backend, k, k);
    let s = bench.run("Alg3 partition estimate", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(est.estimate(&theta, &mut rng));
    });
    results.push((s, String::new()));

    // ---- brute-force reference -------------------------------------------------------
    let exact = gmips::sampler::exact::ExactSampler::new(ds.clone(), Arc::new(NativeScorer));
    let s = bench.run("brute-force sample", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(exact.sample(&theta, &mut rng));
    });
    results.push((s, String::new()));

    println!("\n{:<34} {:>12} {:>10}  note", "stage", "mean", "iters");
    for (s, note) in &results {
        println!("{:<34} {:>12} {:>10}  {note}", s.name, s.human(), s.iters);
    }
}
