//! Hot-path microbenches (the §Perf deliverable): every stage of a
//! sampling/estimation query measured in isolation, so regressions are
//! attributable. Not a paper figure — this is the optimization harness.
//!
//! Stages: native single/batched block scoring, fused vs two-pass
//! `(max, Σexp)` reductions, fused expectation fragments, PJRT block
//! scoring (when artifacts exist), top-k collection, IVF probe
//! (single-query, 8 sequential queries, and one 8-query batch),
//! SQ8/SQ4/PQ quantized scans vs f32 scan (plus the end-to-end
//! two-stage/ladder brute top-k) and the register-blocked multi-query
//! integer kernel vs sequential single-query scoring
//! (`quant_batch_kernel_speedup`) on a ≥100k × 128 dataset, the PQ
//! fast-scan tile batched scan vs the plane-major batched LUT scan on
//! the same dataset (`pq_fastscan_speedup`), the PJRT batched
//! executable vs the per-query executable loop when artifacts exist
//! (`pjrt_batch_speedup`), sharded fan-out scan at 1/4/8
//! shards on the same dataset (`shard_scan_speedup`), sharded
//! Algorithm-4 expect-features vs monolithic on the same dataset
//! (`sharded_expect_speedup`), the obs metrics/trace instrumentation
//! overhead probe (`obs_overhead_pct`, target ≤2%), lazy tail draw,
//! full Alg-1 sample, Alg-3 estimate.
//!
//! Besides the banner table, results are written machine-readably to
//! `BENCH_perf_hotpath.json` (stage name, mean seconds, iters, GFLOP/s
//! where meaningful) so future PRs have a perf trajectory to regress
//! against.

mod common;

use gmips::config::Config;
use gmips::data;
use gmips::estimator::partition::PartitionEstimator;
use gmips::gumbel;
use gmips::linalg::{simd, MaxSumExp};
use gmips::mips::{self, MipsIndex};
use gmips::runtime::PjrtScorer;
use gmips::sampler::{lazy_gumbel::LazyGumbelSampler, Sampler};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::json::Json;
use gmips::util::rng::Pcg64;
use gmips::util::timing::{Bench, BenchStats};
use gmips::util::topk::TopK;
use rustc_hash::FxHashSet;
use std::sync::Arc;

struct Entry {
    stats: BenchStats,
    note: String,
    gflops: Option<f64>,
}

fn record(results: &mut Vec<Entry>, stats: BenchStats, flops_per_iter: Option<f64>) {
    let gflops = flops_per_iter.map(|f| f / stats.mean_s / 1e9);
    let note = gflops.map(|g| format!("{g:.2} GFLOP/s")).unwrap_or_default();
    results.push(Entry { stats, note, gflops });
}

fn main() {
    common::banner("bench_perf_hotpath", "§Perf: per-stage hot path microbenches");
    let opts = common::bench_opts(100_000, 8);
    let mut cfg = Config::preset("imagenet").unwrap();
    cfg.data.n = opts.n;
    cfg.data.d = 64;
    let d = cfg.data.d;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rng = Pcg64::new(1);
    let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);
    println!("simd kernel: {}", simd::kernel().name());

    let bench = Bench::default();
    let mut results: Vec<Entry> = Vec::new();

    // ---- native block scoring: single query, then 8-query batch ------------
    let block = 4096.min(ds.n);
    let rows = &ds.data[..block * d];
    let block_flops = 2.0 * block as f64 * d as f64;
    let mut out = vec![0f32; block];
    let s = bench.run("native scores 4096x64", || {
        NativeScorer.scores(std::hint::black_box(rows), d, &q, &mut out);
    });
    record(&mut results, s, Some(block_flops));

    const NQ: usize = 8;
    let qs_owned: Vec<Vec<f32>> = (0..NQ)
        .map(|_| data::random_theta(&ds, cfg.data.temperature, &mut rng))
        .collect();
    let mut qflat = vec![0f32; NQ * d];
    for (j, qj) in qs_owned.iter().enumerate() {
        qflat[j * d..(j + 1) * d].copy_from_slice(qj);
    }
    let mut out_multi = vec![0f32; NQ * block];
    let s = bench.run("native scores 4096x64 x8q sequential", || {
        for j in 0..NQ {
            NativeScorer.scores(
                std::hint::black_box(rows),
                d,
                &qflat[j * d..(j + 1) * d],
                &mut out_multi[j * block..(j + 1) * block],
            );
        }
    });
    record(&mut results, s, Some(block_flops * NQ as f64));
    let s = bench.run("native scores_batch 4096x64 x8q", || {
        NativeScorer.scores_batch(std::hint::black_box(rows), d, &qflat, NQ, &mut out_multi);
    });
    record(&mut results, s, Some(block_flops * NQ as f64));

    // ---- fused (max, Σexp) vs the seed two-pass shape ----------------------
    let s = bench.run("max_sumexp two-pass (seed shape)", || {
        // exactly the seed default: materialize scores, then scalar
        // f64 push_all as a second pass
        let n = rows.len() / d;
        let mut buf = vec![0f32; n];
        NativeScorer.scores(std::hint::black_box(rows), d, &q, &mut buf);
        let mut acc = MaxSumExp::default();
        acc.push_all(&buf);
        std::hint::black_box(acc);
    });
    let twopass_mean = s.mean_s;
    record(&mut results, s, Some(block_flops));
    let s = bench.run("max_sumexp fused (simd)", || {
        std::hint::black_box(NativeScorer.max_sumexp(std::hint::black_box(rows), d, &q));
    });
    let fused_mean = s.mean_s;
    record(&mut results, s, Some(block_flops));
    println!(
        "fused max_sumexp speedup vs seed two-pass: {:.2}x",
        twopass_mean / fused_mean
    );

    let s = bench.run("expect_fragment two-pass (seed shape)", || {
        let n = rows.len() / d;
        let mut buf = vec![0f32; n];
        NativeScorer.scores(std::hint::black_box(rows), d, &q, &mut buf);
        let mut acc = MaxSumExp::default();
        acc.push_all(&buf);
        let mut wsum = vec![0f32; d];
        for r in 0..n {
            let w = ((buf[r] as f64) - acc.max).exp() as f32;
            gmips::linalg::axpy(w, &rows[r * d..(r + 1) * d], &mut wsum);
        }
        std::hint::black_box((acc, wsum));
    });
    record(&mut results, s, Some(2.0 * block_flops));
    let s = bench.run("expect_fragment fused (simd)", || {
        std::hint::black_box(NativeScorer.expect_fragment(std::hint::black_box(rows), d, &q));
    });
    record(&mut results, s, Some(2.0 * block_flops));

    // ---- PJRT block scoring (optional) ----------------------------------------
    let mut pjrt_batch_speedup: Option<f64> = None;
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match PjrtScorer::load("artifacts") {
            Ok(scorer) if scorer.d() == d => {
                let s = bench.run("pjrt scores 4096x64", || {
                    scorer.scores(std::hint::black_box(rows), d, &q, &mut out);
                });
                record(&mut results, s, Some(block_flops));
                let sc = Arc::new(scorer);
                let s = bench.run("pjrt fused partition 4096x64", || {
                    std::hint::black_box(sc.max_sumexp(rows, d, &q));
                });
                record(&mut results, s, None);
                // batched executable vs the per-query executable loop:
                // with a `scores_batch` artifact each row block crosses
                // the device boundary once per 8-query group
                let s = bench.run("pjrt scores 4096x64 x8q sequential", || {
                    for j in 0..NQ {
                        let (qj, oj) = (
                            &qflat[j * d..(j + 1) * d],
                            &mut out_multi[j * block..(j + 1) * block],
                        );
                        sc.scores(std::hint::black_box(rows), d, qj, oj);
                    }
                });
                let seq_mean = s.mean_s;
                record(&mut results, s, Some(block_flops * NQ as f64));
                let s = bench.run("pjrt scores_batch 4096x64 x8q", || {
                    sc.scores_batch(std::hint::black_box(rows), d, &qflat, NQ, &mut out_multi);
                });
                let speedup = seq_mean / s.mean_s;
                pjrt_batch_speedup = Some(speedup);
                record(&mut results, s, Some(block_flops * NQ as f64));
                println!("pjrt 8-query batch speedup vs 8 sequential: {speedup:.2}x");
            }
            _ => println!("(skipping pjrt benches: artifacts missing/unloadable or wrong d)"),
        }
    }

    // ---- top-k collection -----------------------------------------------------
    let scores: Vec<f32> = (0..ds.n).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0).collect();
    let k = cfg.sampler_k();
    let s = bench.run(&format!("topk k={k} over n={}", ds.n), || {
        let mut tk = TopK::new(k);
        tk.push_block(0, std::hint::black_box(&scores));
        std::hint::black_box(tk.into_sorted());
    });
    record(&mut results, s, None);

    // ---- IVF index probe: single, 8 sequential, one 8-query batch --------------
    let index: Arc<dyn MipsIndex> = {
        let mut icfg = cfg.index.clone();
        icfg.n_clusters = 0;
        icfg.n_probe = 0;
        icfg.kmeans_iters = 6;
        icfg.train_sample = 20_000.min(ds.n);
        mips::build_index(&ds, &icfg, backend.clone()).unwrap()
    };
    let s = bench.run("ivf top_k", || {
        std::hint::black_box(index.top_k(&q, k));
    });
    record(&mut results, s, None);
    let qs_refs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
    let s = bench.run("ivf top_k x8q sequential", || {
        for qj in &qs_refs {
            std::hint::black_box(index.top_k(qj, k));
        }
    });
    let seq_mean = s.mean_s;
    record(&mut results, s, None);
    let s = bench.run("ivf top_k_batch 8q", || {
        std::hint::black_box(index.top_k_batch(&qs_refs, k));
    });
    let batch_mean = s.mean_s;
    record(&mut results, s, None);
    println!(
        "ivf 8-query batch speedup vs 8 sequential: {:.2}x",
        seq_mean / batch_mean
    );

    // ---- big-scan dataset shared by the quantized and sharding stages ----------
    // default floor 100k so the scans are memory-bound and the recorded
    // speedups meaningful; an explicit GMIPS_BENCH_N (CI smoke) wins so
    // the trajectory job stays cheap
    let qn = if std::env::var("GMIPS_BENCH_N").is_ok() {
        opts.n.max(4_096)
    } else {
        opts.n.max(100_000)
    };
    let qd = 128usize;
    let qds = {
        let mut qdata = cfg.data.clone();
        qdata.n = qn;
        qdata.d = qd;
        qdata.path = String::new();
        Arc::new(data::generate(&qdata))
    };
    let scan_flops_big = 2.0 * qn as f64 * qd as f64;

    // ---- SQ8 quantized scan vs f32 scan (≥100k × 128) --------------------------
    // acceptance: ≥2× pass-1 scan throughput; the two-stage brute top_k
    // below shows the end-to-end effect (screen + exact re-rank)
    let quant_speedup;
    let sq4_scan_speedup;
    let pq_scan_speedup;
    let quant_batch_kernel_speedup;
    let pq_fastscan_speedup;
    {
        use gmips::linalg::quant::{QuantQuery, QuantView};
        use gmips::mips::brute::BruteForce;
        let qv = QuantView::encode(&qds.data, qd, 64);
        let mut qrng = Pcg64::new(17);
        let theta = data::random_theta(&qds, cfg.data.temperature, &mut qrng);
        let qq = QuantQuery::encode(&theta);
        let scan_flops = scan_flops_big;
        let kq = (qn as f64).sqrt().round() as usize;
        let mut sbuf = vec![0f32; 4096];

        let s = bench.run(&format!("f32 scan+topk {qn}x{qd}"), || {
            let mut tk = TopK::new(kq);
            let mut start = 0;
            while start < qn {
                let end = (start + 4096).min(qn);
                let out = &mut sbuf[..end - start];
                NativeScorer.scores(
                    std::hint::black_box(&qds.data[start * qd..end * qd]),
                    qd,
                    &theta,
                    out,
                );
                tk.push_block(start as u32, out);
                start = end;
            }
            std::hint::black_box(tk.into_sorted());
        });
        let f32_mean = s.mean_s;
        record(&mut results, s, Some(scan_flops));

        let s = bench.run(&format!("sq8 quant scan+topk {qn}x{qd}"), || {
            let mut tk = TopK::new(kq);
            let mut start = 0;
            while start < qn {
                let end = (start + 4096).min(qn);
                let out = &mut sbuf[..end - start];
                qv.scores(start, end, std::hint::black_box(&qq), out);
                tk.push_block(start as u32, out);
                start = end;
            }
            std::hint::black_box(tk.into_sorted());
        });
        let quant_mean = s.mean_s;
        record(&mut results, s, Some(scan_flops));
        quant_speedup = f32_mean / quant_mean;
        println!("sq8 quantized scan speedup vs f32: {quant_speedup:.2}x");

        let bf = BruteForce::new(qds.clone(), Arc::new(NativeScorer));
        let s = bench.run(&format!("brute top_k f32 {qn}x{qd}"), || {
            std::hint::black_box(bf.top_k(&theta, kq));
        });
        record(&mut results, s, Some(scan_flops));
        let bq = BruteForce::new(qds.clone(), Arc::new(NativeScorer)).with_quant(64, 4);
        let s = bench.run(&format!("brute top_k sq8 two-stage {qn}x{qd}"), || {
            std::hint::black_box(bq.top_k(&theta, kq));
        });
        record(&mut results, s, Some(scan_flops));

        // ---- SQ4 + PQ screening tiers vs the same f32 scan (PR 5) ----------
        // acceptance: pass-1 bandwidth cuts beyond SQ8's 4× — SQ4 reads
        // ⅛, PQ(m=16,b=4) ~¹⁄₆₄ of the f32 bytes
        {
            use gmips::linalg::pq::PqView;
            use gmips::linalg::quant::Sq4View;
            let sq4 = Sq4View::encode(&qds.data, qd, 64);
            let s = bench.run(&format!("sq4 quant scan+topk {qn}x{qd}"), || {
                let mut tk = TopK::new(kq);
                let mut start = 0;
                while start < qn {
                    let end = (start + 4096).min(qn);
                    let out = &mut sbuf[..end - start];
                    sq4.scores(start, end, std::hint::black_box(&qq), out);
                    tk.push_block(start as u32, out);
                    start = end;
                }
                std::hint::black_box(tk.into_sorted());
            });
            sq4_scan_speedup = f32_mean / s.mean_s;
            record(&mut results, s, Some(scan_flops));
            println!("sq4 quantized scan speedup vs f32: {sq4_scan_speedup:.2}x");

            let pv = PqView::train(&qds.data, qd, qd / 8, 4, 4096, 8, 17);
            let lut = pv.encode_query(&theta);
            let s = bench.run(&format!("pq(m={},b=4) scan+topk {qn}x{qd}", qd / 8), || {
                let mut tk = TopK::new(kq);
                let mut start = 0;
                while start < qn {
                    let end = (start + 4096).min(qn);
                    let out = &mut sbuf[..end - start];
                    pv.scores(start, end, std::hint::black_box(&lut), out);
                    tk.push_block(start as u32, out);
                    start = end;
                }
                std::hint::black_box(tk.into_sorted());
            });
            pq_scan_speedup = f32_mean / s.mean_s;
            record(&mut results, s, Some(scan_flops));
            println!("pq quantized scan speedup vs f32: {pq_scan_speedup:.2}x");

            // end-to-end ladder scans (screen + certificate + exact re-rank)
            let mut tcfg = cfg.index.clone();
            tcfg.quant = gmips::config::QuantKind::Sq4;
            let b4 = BruteForce::new(qds.clone(), Arc::new(NativeScorer)).with_tier_cfg(&tcfg);
            let s = bench.run(&format!("brute top_k sq4 ladder {qn}x{qd}"), || {
                std::hint::black_box(b4.top_k(&theta, kq));
            });
            record(&mut results, s, Some(scan_flops));
            tcfg.quant = gmips::config::QuantKind::Pq;
            tcfg.pq_bits = 4;
            let bp = BruteForce::new(qds.clone(), Arc::new(NativeScorer)).with_tier_cfg(&tcfg);
            let s = bench.run(&format!("brute top_k pq ladder {qn}x{qd}"), || {
                std::hint::black_box(bp.top_k(&theta, kq));
            });
            record(&mut results, s, Some(scan_flops));
        }

        // ---- multi-query integer kernel: 8q sequential vs register-blocked -
        // acceptance: `scores_batch` streams each code block once per
        // batch instead of once per query (and re-pays the u8→i16
        // widening once per 4-query block)
        {
            let mut qrng2 = Pcg64::new(19);
            let qs_owned: Vec<Vec<f32>> = (0..NQ)
                .map(|_| data::random_theta(&qds, cfg.data.temperature, &mut qrng2))
                .collect();
            let qqs: Vec<gmips::linalg::quant::QuantQuery> =
                qs_owned.iter().map(|q| gmips::linalg::quant::QuantQuery::encode(q)).collect();
            let qq_refs: Vec<&gmips::linalg::quant::QuantQuery> = qqs.iter().collect();
            let qblock = 4096.min(qn);
            let mut out_multi = vec![0f32; NQ * qblock];
            let s = bench.run(&format!("sq8 scores x8q sequential {qblock}x{qd}"), || {
                for (j, qqj) in qqs.iter().enumerate() {
                    qv.scores(
                        0,
                        qblock,
                        std::hint::black_box(qqj),
                        &mut out_multi[j * qblock..(j + 1) * qblock],
                    );
                }
            });
            let seq_mean = s.mean_s;
            record(&mut results, s, Some(scan_flops / qn as f64 * qblock as f64 * NQ as f64));
            let s = bench.run(&format!("sq8 scores_batch x8q {qblock}x{qd}"), || {
                qv.scores_batch(0, qblock, std::hint::black_box(&qq_refs), &mut out_multi);
            });
            quant_batch_kernel_speedup = seq_mean / s.mean_s;
            record(&mut results, s, Some(scan_flops / qn as f64 * qblock as f64 * NQ as f64));
            println!(
                "sq8 multi-query kernel speedup vs 8 sequential: {quant_batch_kernel_speedup:.2}x"
            );
        }

        // ---- PQ fast-scan tiles: plane-major batched scan vs tile dispatch -
        // acceptance (PR 10): on 8-query batches the register-resident
        // 32-row nibble tiles (one shuffle per subspace serving a
        // 4-query block) must beat the plane-major batched LUT scan over
        // the full ≥100k × 128 dataset; dispatch is bit-identical by the
        // tiled-parity property tests, so only throughput is at stake
        {
            use gmips::linalg::pq::{PqLut, PqView};
            let pv = PqView::train(&qds.data, qd, qd / 8, 4, 4096, 8, 17);
            assert!(pv.serves_fastscan(NQ), "bench PQ view must carry fast-scan tiles");
            let mut qrng3 = Pcg64::new(31);
            let qs_owned: Vec<Vec<f32>> = (0..NQ)
                .map(|_| data::random_theta(&qds, cfg.data.temperature, &mut qrng3))
                .collect();
            let luts: Vec<PqLut> = qs_owned.iter().map(|t| pv.encode_query(t)).collect();
            let lut_refs: Vec<&PqLut> = luts.iter().collect();
            let mut out_multi = vec![0f32; NQ * 4096];
            let s = bench.run(&format!("pq plane scores_batch x8q {qn}x{qd}"), || {
                let mut start = 0;
                while start < qn {
                    let end = (start + 4096).min(qn);
                    let out = &mut out_multi[..NQ * (end - start)];
                    pv.scores_batch_plane(start, end, std::hint::black_box(&lut_refs), out);
                    start = end;
                }
            });
            let plane_mean = s.mean_s;
            record(&mut results, s, Some(scan_flops * NQ as f64));
            let s = bench.run(&format!("pq fastscan scores_batch x8q {qn}x{qd}"), || {
                let mut start = 0;
                while start < qn {
                    let end = (start + 4096).min(qn);
                    let out = &mut out_multi[..NQ * (end - start)];
                    pv.scores_batch(start, end, std::hint::black_box(&lut_refs), out);
                    start = end;
                }
            });
            pq_fastscan_speedup = plane_mean / s.mean_s;
            record(&mut results, s, Some(scan_flops * NQ as f64));
            println!("pq fast-scan batched speedup vs plane: {pq_fastscan_speedup:.2}x");
        }
    }

    // ---- obs overhead: metrics + trace checks on the screening hot loop --------
    // acceptance (PR 8): with the registry enabled, the per-block counter
    // adds and trace_active() checks the serving paths pay must cost ≤2%
    // over the identical uninstrumented scan
    let obs_overhead_pct;
    {
        let mut orng = Pcg64::new(41);
        let theta = data::random_theta(&qds, cfg.data.temperature, &mut orng);
        let kq = (qn as f64).sqrt().round() as usize;
        let mut sbuf = vec![0f32; 4096];
        gmips::obs::set_enabled(false);
        let s = bench.run(&format!("obs_overhead plain scan {qn}x{qd}"), || {
            let mut tk = TopK::new(kq);
            let mut start = 0;
            while start < qn {
                let end = (start + 4096).min(qn);
                let out = &mut sbuf[..end - start];
                NativeScorer.scores(
                    std::hint::black_box(&qds.data[start * qd..end * qd]),
                    qd,
                    &theta,
                    out,
                );
                tk.push_block(start as u32, out);
                start = end;
            }
            std::hint::black_box(tk.into_sorted());
        });
        let plain_mean = s.mean_s;
        record(&mut results, s, Some(scan_flops_big));

        gmips::obs::set_enabled(true);
        let obs = gmips::obs::registry();
        let s = bench.run(&format!("obs_overhead instrumented scan {qn}x{qd}"), || {
            let mut tk = TopK::new(kq);
            let mut start = 0;
            while start < qn {
                let end = (start + 4096).min(qn);
                let out = &mut sbuf[..end - start];
                NativeScorer.scores(
                    std::hint::black_box(&qds.data[start * qd..end * qd]),
                    qd,
                    &theta,
                    out,
                );
                obs.screen_rows_screened.add((end - start) as u64);
                if gmips::obs::trace_active() {
                    gmips::obs::trace_stage(gmips::obs::Stage::Screen, 0.0);
                }
                tk.push_block(start as u32, out);
                start = end;
            }
            obs.requests.inc();
            std::hint::black_box(tk.into_sorted());
        });
        gmips::obs::set_enabled(false);
        obs_overhead_pct = (s.mean_s - plain_mean) / plain_mean * 100.0;
        record(&mut results, s, Some(scan_flops_big));
        println!("obs instrumentation overhead: {obs_overhead_pct:.2}% (target ≤2%)");
    }

    // ---- sharded fan-out scan: 1 vs 4 vs 8 shards (≥100k × 128) ----------------
    // acceptance: the data-parallel fan-out must beat the monolithic scan
    // wall-clock; the baseline is a TRUE monolithic BruteForce scan (a
    // 1-shard ShardedIndex still pays fan-out/merge overhead, which the
    // N=1 stage below exposes separately) and
    // shard_scan_speedup = t(monolithic) / best t(4|8 shards)
    let shard_scan_speedup;
    {
        use gmips::mips::brute::BruteForce;
        use gmips::shard::ShardedIndex;
        let kq = (qn as f64).sqrt().round() as usize;
        let mut srng = Pcg64::new(23);
        let theta = data::random_theta(&qds, cfg.data.temperature, &mut srng);
        let mono = BruteForce::new(qds.clone(), backend.clone());
        let s = bench.run(&format!("monolithic brute top_k {qn}x{qd}"), || {
            std::hint::black_box(mono.top_k(&theta, kq));
        });
        let mono_mean = s.mean_s;
        record(&mut results, s, Some(scan_flops_big));
        let mut means = Vec::new();
        for shards in [1usize, 4, 8] {
            let mut icfg = cfg.index.clone();
            icfg.kind = gmips::config::IndexKind::Brute;
            icfg.shards = shards;
            let idx = ShardedIndex::build(&qds, &icfg, backend.clone()).unwrap();
            let s = bench.run(&format!("sharded brute top_k N={shards} {qn}x{qd}"), || {
                std::hint::black_box(idx.top_k(&theta, kq));
            });
            means.push(s.mean_s);
            record(&mut results, s, Some(scan_flops_big));
        }
        shard_scan_speedup = mono_mean / means[1].min(means[2]);
        println!(
            "sharded scan speedup vs monolithic: 1sh {:.2}x, 4sh {:.2}x, 8sh {:.2}x (recorded {:.2}x)",
            mono_mean / means[0],
            mono_mean / means[1],
            mono_mean / means[2],
            shard_scan_speedup
        );
    }

    // ---- sharded Algorithm 4: monolithic vs 4/8-shard fan-out (≥100k × 128) ----
    // acceptance: the per-shard decomposed expect-features (head fan-out
    // + keyed tails + weighted-LSE merge) must beat the monolithic
    // Algorithm 4 wall-clock on a scan-dominated dataset
    let sharded_expect_speedup;
    {
        use gmips::estimator::expectation::ExpectationEstimator;
        use gmips::mips::brute::BruteForce;
        use gmips::shard::{ShardedExpectationEstimator, ShardedIndex};
        let kq = (qn as f64).sqrt().round() as usize;
        let mut erng = Pcg64::new(29);
        let theta = data::random_theta(&qds, cfg.data.temperature, &mut erng);
        let mono_idx: Arc<dyn MipsIndex> =
            Arc::new(BruteForce::new(qds.clone(), backend.clone()));
        let mono_est =
            ExpectationEstimator::new(qds.clone(), mono_idx, backend.clone(), kq, kq);
        let s = bench.run(&format!("Alg4 expect_features monolithic {qn}x{qd}"), || {
            std::hint::black_box(mono_est.expect_features(&theta, &mut erng));
        });
        let mono_mean = s.mean_s;
        record(&mut results, s, None);
        let mut means = Vec::new();
        for shards in [4usize, 8] {
            let mut icfg = cfg.index.clone();
            icfg.kind = gmips::config::IndexKind::Brute;
            icfg.shards = shards;
            let idx = Arc::new(ShardedIndex::build(&qds, &icfg, backend.clone()).unwrap());
            let est =
                ShardedExpectationEstimator::new(qds.clone(), idx, backend.clone(), kq, kq, 31);
            let s = bench.run(
                &format!("Alg4 expect_features sharded N={shards} {qn}x{qd}"),
                || {
                    std::hint::black_box(est.expect_features(&theta));
                },
            );
            means.push(s.mean_s);
            record(&mut results, s, None);
        }
        sharded_expect_speedup = mono_mean / means[0].min(means[1]);
        println!(
            "sharded expect_features speedup vs monolithic: 4sh {:.2}x, 8sh {:.2}x (recorded {:.2}x)",
            mono_mean / means[0],
            mono_mean / means[1],
            sharded_expect_speedup
        );
    }

    // ---- lazy tail draw ---------------------------------------------------------
    let exclude: FxHashSet<u32> = (0..k as u32).collect();
    let b = gumbel::fixed_cutoff(ds.n, k);
    let s = bench.run("lazy tail draw (m≈k)", || {
        std::hint::black_box(gumbel::sample_tail(ds.n, &exclude, b, &mut rng));
    });
    record(&mut results, s, None);

    // ---- full Algorithm 1 sample --------------------------------------------------
    let sampler = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
    let s = bench.run("Alg1 sample (fresh θ)", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(sampler.sample(&theta, &mut rng));
    });
    record(&mut results, s, None);
    // amortized: one MIPS call, many draws
    let top = index.top_k(&q, k);
    let s = bench.run("Alg1 draw (reused top-k)", || {
        std::hint::black_box(sampler.sample_given_top(&top, &q, &mut rng));
    });
    record(&mut results, s, None);
    // batched: 8 θs share one batched retrieval
    let s = bench.run("Alg1 sample_batch 8q", || {
        std::hint::black_box(sampler.sample_batch(&qs_refs, &[1; NQ], &mut rng));
    });
    record(&mut results, s, None);

    // ---- Algorithm 3 estimate ------------------------------------------------------
    let est = PartitionEstimator::new(ds.clone(), index, backend, k, k);
    let s = bench.run("Alg3 partition estimate", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(est.estimate(&theta, &mut rng));
    });
    record(&mut results, s, None);
    let s = bench.run("Alg3 estimate_batch 8q", || {
        std::hint::black_box(est.estimate_batch(&qs_refs, &mut rng));
    });
    record(&mut results, s, None);

    // ---- brute-force reference -------------------------------------------------------
    let exact = gmips::sampler::exact::ExactSampler::new(ds.clone(), Arc::new(NativeScorer));
    let s = bench.run("brute-force sample", || {
        let theta = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        std::hint::black_box(exact.sample(&theta, &mut rng));
    });
    record(&mut results, s, None);

    println!("\n{:<38} {:>12} {:>10}  note", "stage", "mean", "iters");
    for e in &results {
        println!(
            "{:<38} {:>12} {:>10}  {}",
            e.stats.name,
            e.stats.human(),
            e.stats.iters,
            e.note
        );
    }

    // ---- machine-readable trajectory ------------------------------------------
    let stages: Vec<Json> = results
        .iter()
        .map(|e| {
            let mut kv = vec![
                ("stage", Json::str(e.stats.name.clone())),
                ("mean_s", Json::num(e.stats.mean_s)),
                ("iters", Json::num(e.stats.iters as f64)),
            ];
            if let Some(g) = e.gflops {
                kv.push(("gflops", Json::num(g)));
            }
            Json::obj(kv)
        })
        .collect();
    let mut top = vec![
        ("bench", Json::str("perf_hotpath")),
        ("kernel", Json::str(simd::kernel().name())),
        ("n", Json::num(ds.n as f64)),
        ("d", Json::num(d as f64)),
        ("batch_queries", Json::num(NQ as f64)),
        ("quant_scan_speedup", Json::num(quant_speedup)),
        ("sq4_scan_speedup", Json::num(sq4_scan_speedup)),
        ("pq_scan_speedup", Json::num(pq_scan_speedup)),
        ("quant_batch_kernel_speedup", Json::num(quant_batch_kernel_speedup)),
        ("pq_fastscan_speedup", Json::num(pq_fastscan_speedup)),
        ("obs_overhead_pct", Json::num(obs_overhead_pct)),
        ("shard_scan_speedup", Json::num(shard_scan_speedup)),
        ("sharded_expect_speedup", Json::num(sharded_expect_speedup)),
    ];
    if let Some(v) = pjrt_batch_speedup {
        top.push(("pjrt_batch_speedup", Json::num(v)));
    }
    top.push(("stages", Json::Arr(stages)));
    let doc = Json::obj(top);
    // temp-file + rename so a crash mid-write never leaves a truncated
    // JSON for downstream tooling to choke on
    match write_atomic("BENCH_perf_hotpath.json", doc.to_string().as_bytes()) {
        Ok(()) => println!("\nwrote BENCH_perf_hotpath.json"),
        Err(e) => eprintln!("could not write BENCH_perf_hotpath.json: {e}"),
    }
}

fn write_atomic(path: &str, bytes: &[u8]) -> std::io::Result<()> {
    use std::io::Write;
    let tmp = format!("{path}.tmp.{}", std::process::id());
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    let renamed = std::fs::rename(&tmp, path);
    if renamed.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    renamed
}
