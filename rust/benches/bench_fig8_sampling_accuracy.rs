//! Figure 8: histogram match + per-bin relative errors
mod common;

fn main() {
    common::banner("bench_fig8_sampling_accuracy", "Figure 8: histogram match + per-bin relative errors");
    let opts = common::bench_opts(12000, 6);
    gmips::eval::fig8::run(&opts);
}
