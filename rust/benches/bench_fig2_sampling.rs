//! Figure 2: per-query sampling time vs dataset size (paper: up to 5x at 1.28M)
mod common;

fn main() {
    common::banner("bench_fig2_sampling", "Figure 2: per-query sampling time vs dataset size (paper: up to 5x at 1.28M)");
    let opts = common::bench_opts(60000, 10);
    gmips::eval::fig2::run(&opts);
}
