//! Figure 4: partition estimate runtime-vs-error frontier
mod common;

fn main() {
    common::banner("bench_fig4_partition", "Figure 4: partition estimate runtime-vs-error frontier");
    let opts = common::bench_opts(40000, 8);
    gmips::eval::fig4::run(&opts);
}
