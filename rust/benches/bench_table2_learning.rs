//! Table 2 + Figure 5: learning by MLE — exact vs top-k vs ours
//! (paper: LL -3.170/-4.062/-3.175, speedup 1x/22.7x/9.6x).
mod common;

fn main() {
    common::banner(
        "bench_table2_learning",
        "Table 2/Fig 5: MLE learning, exact vs top-k vs ours",
    );
    let opts = common::bench_opts(30_000, 1);
    gmips::eval::table2::run(&opts);
}
