//! S4.2.2: random walk top-k overlap (paper: 73.6% between vs 69.3/72.9% within)
mod common;

fn main() {
    common::banner("bench_walk", "S4.2.2: random walk top-k overlap (paper: 73.6% between vs 69.3/72.9% within)");
    let opts = common::bench_opts(12000, 4);
    gmips::eval::walk_exp::run(&opts);
}
