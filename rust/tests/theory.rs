//! Theorem-level acceptance tests: each of the paper's formal claims gets
//! an empirical check at test scale, plus property tests (via the
//! in-crate `util::check` harness) on coordinator/index invariants.

use gmips::config::Config;
use gmips::data::{self, Dataset};
use gmips::estimator::expectation::{exact_feature_expectation, ExpectationEstimator};
use gmips::estimator::partition::{exact_log_partition, PartitionEstimator};
use gmips::gumbel;
use gmips::mips::{self, brute::BruteForce, MipsIndex};
use gmips::sampler::fixed_b::FixedBSampler;
use gmips::sampler::lazy_gumbel::LazyGumbelSampler;
use gmips::sampler::Sampler;
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::check::Checker;
use gmips::util::rng::Pcg64;
use gmips::util::topk::{topk_reference, TopK};
use rustc_hash::FxHashSet;
use std::sync::Arc;

fn setup(n: usize, d: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn MipsIndex>, Arc<dyn ScoreBackend>) {
    let ds = Arc::new(gmips::data::synth::imagenet_like(n, d, 20, 0.3, seed));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
    (ds, index, backend)
}

// ---------------------------------------------------------------------------
// Theorem 3.1 / 3.2 / 3.3 — sampling
// ---------------------------------------------------------------------------

#[test]
fn theorem_3_2_expected_m_bound_over_k_sweep() {
    // E[m] ≤ n/k for a sweep of k values (c = 0), across several θ
    let (ds, index, backend) = setup(4_000, 8, 1);
    let mut rng = Pcg64::new(2);
    for k in [15, 40, 63, 200] {
        let sampler = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
        let mut total_m = 0usize;
        let mut reps = 0usize;
        for _ in 0..4 {
            let q = data::random_theta(&ds, 0.1, &mut rng);
            for o in sampler.sample_many(&q, 100, &mut rng) {
                total_m += o.work.m;
                reps += 1;
            }
        }
        let mean = total_m as f64 / reps as f64;
        let bound = ds.n as f64 / k as f64;
        assert!(mean < 1.6 * bound + 2.0, "k={k}: E[m]={mean} bound={bound}");
    }
}

#[test]
fn theorem_3_3_failure_rate_respects_bound() {
    // With kl/n deliberately small, Algorithm 2 should fail occasionally —
    // but no more often than ~δ = exp(-kl/n). We detect failure by
    // comparing against a coupled exact run: instead, measure the rate of
    // tail-cutoff events where max_S (y+G) < B + S_max threshold proxy:
    // here we check the *distributional* consequence directly with GOF.
    let (ds, index, backend) = setup(500, 8, 3);
    // kl/n = 30·50/500 = 3 → δ ≈ 5%
    let sampler = FixedBSampler::new(ds.clone(), index, backend.clone(), 30, 50);
    let delta = sampler.failure_bound();
    assert!((delta - (-3.0f64).exp()).abs() < 1e-12);
    let exact = gmips::sampler::exact::ExactSampler::new(ds.clone(), backend);
    let mut rng = Pcg64::new(4);
    let q = data::random_theta(&ds, 0.3, &mut rng);
    let probs = exact.probabilities(&q);
    // even with 5% failure probability per draw, failures return *some*
    // top element, so TV distortion stays small; GOF with generous sigma
    let total = 20_000u64;
    let mut counts = vec![0u64; ds.n];
    for o in sampler.sample_many(&q, total as usize, &mut rng) {
        counts[o.id as usize] += 1;
    }
    assert!(gmips::util::stats::gof_ok(&counts, &probs, total, 8.0));
}

// ---------------------------------------------------------------------------
// Theorem 3.4 / 3.5 — estimators
// ---------------------------------------------------------------------------

#[test]
fn theorem_3_4_error_scales_with_inverse_sqrt_kl() {
    // doubling k·l should shrink the relative error ~√2: check the
    // monotone direction with averaged absolute errors
    let (ds, index, backend) = setup(2_000, 8, 5);
    let mut rng = Pcg64::new(6);
    let mut errs = Vec::new();
    for (k, l) in [(20, 20), (80, 80)] {
        let est = PartitionEstimator::new(ds.clone(), index.clone(), backend.clone(), k, l);
        let mut sum = 0.0;
        let trials = 40;
        for _ in 0..trials {
            let q = data::random_theta(&ds, 0.2, &mut rng);
            let want = exact_log_partition(&ds, backend.as_ref(), &q);
            let got = est.estimate(&q, &mut rng).log_z;
            sum += ((got - want).exp() - 1.0).abs();
        }
        errs.push(sum / trials as f64);
    }
    assert!(
        errs[1] < errs[0] * 0.75,
        "error should shrink with kl: {errs:?}"
    );
}

#[test]
fn theorem_3_5_error_scales_with_k() {
    let (ds, index, backend) = setup(1_500, 8, 7);
    let mut rng = Pcg64::new(8);
    let f = |id: u32| (id as f64 * 0.11).cos(); // |f| ≤ 1
    let mut errs = Vec::new();
    for (k, l) in [(15, 30), (150, 300)] {
        let est = ExpectationEstimator::new(ds.clone(), index.clone(), backend.clone(), k, l);
        let mut worst: f64 = 0.0;
        for _ in 0..10 {
            let q = data::random_theta(&ds, 0.2, &mut rng);
            let brute = BruteForce::new(ds.clone(), backend.clone());
            let mut all = vec![0f32; ds.n];
            brute.all_scores(&q, &mut all);
            let m = all.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let z: f64 = all.iter().map(|&y| ((y as f64) - m).exp()).sum();
            let want: f64 = all
                .iter()
                .enumerate()
                .map(|(i, &y)| ((y as f64) - m).exp() * f(i as u32))
                .sum::<f64>()
                / z;
            let (got, _) = est.expect_scalar(&q, &f, &mut rng);
            worst = worst.max((got - want).abs());
        }
        errs.push(worst);
    }
    assert!(errs[1] < errs[0], "worst additive error should shrink: {errs:?}");
    assert!(errs[1] < 0.1, "large-k error should be small: {errs:?}");
}

#[test]
fn gradient_estimate_is_unbiased_direction() {
    // Ê[φ] averaged over draws converges to E[φ] — the property that lets
    // SGD with Algorithm 4 track exact gradient ascent (Figure 5)
    let (ds, index, backend) = setup(1_200, 8, 9);
    let est = ExpectationEstimator::new(ds.clone(), index, backend.clone(), 60, 120);
    let mut rng = Pcg64::new(10);
    let q = data::random_theta(&ds, 0.1, &mut rng);
    let (want, _) = exact_feature_expectation(&ds, backend.as_ref(), &q);
    let reps = 60;
    let mut mean = vec![0f64; ds.d];
    for _ in 0..reps {
        let e = est.expect_features(&q, &mut rng);
        for j in 0..ds.d {
            mean[j] += e.mean[j] as f64 / reps as f64;
        }
    }
    for j in 0..ds.d {
        assert!(
            (mean[j] - want[j] as f64).abs() < 0.02,
            "coord {j}: {} vs {}",
            mean[j],
            want[j]
        );
    }
}

// ---------------------------------------------------------------------------
// Definition 3.1 — approximate top-k gap, and index invariants (property
// tests through the in-crate mini-proptest harness)
// ---------------------------------------------------------------------------

#[test]
fn property_topk_collector_matches_sort() {
    Checker::new(11).cases(150).check_vec_with_param(512, 64, |scores, k| {
        let mut tk = TopK::new(k);
        tk.push_block(0, scores);
        let got = tk.into_sorted();
        let want = topk_reference(scores, k);
        got.len() == want.len().min(scores.len())
            && got.iter().zip(&want).all(|(g, w)| g.score == w.score)
    });
}

#[test]
fn property_fixed_cutoff_monotone() {
    // larger l ⇒ lower cutoff B (more tail Gumbels pass)
    Checker::new(12).cases(100).check_u64(10_000, |l| {
        let n = 20_000;
        let l = (l as usize).clamp(1, n - 2);
        gumbel::fixed_cutoff(n, l) >= gumbel::fixed_cutoff(n, l + 1)
    });
}

#[test]
fn property_tail_prob_in_unit_interval() {
    Checker::new(13).cases(200).check_vec_f32(4, |xs| {
        let b = xs[0] as f64 * 10.0;
        let p = gumbel::tail_prob(b);
        (0.0..=1.0).contains(&p)
    });
}

#[test]
fn property_index_returns_sorted_unique_ids() {
    // routing invariant: every index's result is sorted desc and id-unique
    let (ds, _, backend) = setup(1_500, 8, 14);
    let mut cfg = Config::default().index;
    cfg.n_clusters = 30;
    cfg.n_probe = 6;
    cfg.kmeans_iters = 3;
    cfg.train_sample = 700;
    cfg.tables = 6;
    cfg.bits = 6;
    cfg.rungs = 5;
    let mut rng = Pcg64::new(15);
    for kind in [
        gmips::config::IndexKind::Brute,
        gmips::config::IndexKind::Ivf,
        gmips::config::IndexKind::Lsh,
        gmips::config::IndexKind::Tiered,
    ] {
        cfg.kind = kind;
        let idx = mips::build_index(&ds, &cfg, backend.clone()).unwrap();
        for _ in 0..5 {
            let q = data::random_theta(&ds, 0.1, &mut rng);
            let k = 1 + rng.next_below(100) as usize;
            let got = idx.top_k(&q, k);
            assert!(got.items.windows(2).all(|w| w[0].score >= w[1].score), "{kind:?}");
            let ids: FxHashSet<u32> = got.items.iter().map(|s| s.id).collect();
            assert_eq!(ids.len(), got.items.len(), "{kind:?} duplicated ids");
            assert!(got.items.iter().all(|s| (s.id as usize) < ds.n));
        }
    }
}

#[test]
fn property_lazy_tail_never_misses_top_of_s() {
    // state-machine invariant of Algorithm 1: the returned id always has
    // perturbed value ≥ the perturbed max of S (it IS the argmax of S∪T)
    let (ds, index, backend) = setup(800, 8, 16);
    let sampler = LazyGumbelSampler::new(ds.clone(), index.clone(), backend, 40, 0.0);
    let mut rng = Pcg64::new(17);
    for _ in 0..50 {
        let q = data::random_theta(&ds, 0.2, &mut rng);
        let o = sampler.sample(&q, &mut rng);
        assert!((o.id as usize) < ds.n);
        assert!(o.work.k == 40);
    }
}

// ---------------------------------------------------------------------------
// frozen-Gumbel comparison (§5): ours gives fresh samples, theirs doesn't
// ---------------------------------------------------------------------------

#[test]
fn fresh_vs_frozen_sample_diversity() {
    let (ds, index, backend) = setup(1_000, 8, 18);
    let ours = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), 60, 0.0);
    let mut icfg = Config::default().index;
    icfg.n_clusters = 20;
    icfg.n_probe = 5;
    icfg.kmeans_iters = 3;
    icfg.train_sample = 500;
    let frozen =
        gmips::sampler::frozen::FrozenGumbel::build(&ds, 8, &icfg, backend, 19).unwrap();
    let mut rng = Pcg64::new(20);
    let q = data::random_theta(&ds, 0.5, &mut rng); // flat-ish: many plausible states
    let distinct = |s: &dyn Sampler, rng: &mut Pcg64| -> usize {
        let ids: FxHashSet<u32> = (0..300).map(|_| s.sample(&q, rng).id).collect();
        ids.len()
    };
    let ours_distinct = distinct(&ours, &mut rng);
    let frozen_distinct = distinct(&frozen, &mut rng);
    assert!(
        ours_distinct > 4 * frozen_distinct,
        "fresh {ours_distinct} vs frozen {frozen_distinct}"
    );
}
