//! Cross-layer integration: AOT artifacts (L1 Pallas + L2 JAX → HLO text)
//! executed through the PJRT runtime must agree numerically with the
//! native Rust backend, and compose correctly under the samplers,
//! estimators, coordinator, and server.
//!
//! Requires `make artifacts` (the Makefile's `test` target guarantees it).

use gmips::config::{Config, IndexKind};
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::data::{self, Dataset};
use gmips::estimator::partition::{exact_log_partition, PartitionEstimator};
use gmips::linalg;
use gmips::mips::{self, brute::BruteForce, MipsIndex};
use gmips::runtime::PjrtScorer;
use gmips::sampler::lazy_gumbel::LazyGumbelSampler;
use gmips::sampler::Sampler;
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::rng::Pcg64;
use gmips::util::stats;
use std::sync::Arc;

const ARTIFACTS: &str = "artifacts";

fn artifacts_available() -> bool {
    std::path::Path::new(ARTIFACTS).join("manifest.json").exists()
}

fn pjrt() -> Option<Arc<PjrtScorer>> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    // builds without the `pjrt` feature get the stub scorer, whose load
    // always fails — degrade to a skip instead of panicking so default
    // `cargo test` passes even when artifacts/ happens to exist
    match PjrtScorer::load(ARTIFACTS) {
        Ok(scorer) => Some(Arc::new(scorer)),
        Err(e) => {
            eprintln!("SKIP: cannot load artifacts ({e})");
            None
        }
    }
}

fn testset(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
    Arc::new(gmips::data::synth::imagenet_like(n, d, 32, 0.3, seed))
}

#[test]
fn pjrt_scores_match_native() {
    let Some(scorer) = pjrt() else { return };
    let d = scorer.d();
    let ds = testset(10_000, d, 1);
    let mut rng = Pcg64::new(2);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    // full block, ragged block, tiny block
    for n in [scorer.block(), 1000, 3] {
        let rows = &ds.data[..n * d];
        let mut got = vec![0f32; n];
        scorer.scores(rows, d, &q, &mut got);
        let mut want = vec![0f32; n];
        NativeScorer.scores(rows, d, &q, &mut want);
        for i in 0..n {
            assert!(
                (got[i] - want[i]).abs() < 1e-2 + 1e-4 * want[i].abs(),
                "n={n} row {i}: pjrt {} native {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn pjrt_partition_fragment_matches_native() {
    let Some(scorer) = pjrt() else { return };
    let d = scorer.d();
    let ds = testset(9_000, d, 3);
    let mut rng = Pcg64::new(4);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    for n in [scorer.block(), 2500, 17] {
        let rows = &ds.data[..n * d];
        let got = scorer.max_sumexp(rows, d, &q);
        let want = NativeScorer.max_sumexp(rows, d, &q);
        assert!(
            (got.logsumexp() - want.logsumexp()).abs() < 1e-3,
            "n={n}: pjrt lse {} native {}",
            got.logsumexp(),
            want.logsumexp()
        );
        assert_eq!(got.count, want.count);
    }
}

#[test]
fn pjrt_expect_fragment_matches_native() {
    let Some(scorer) = pjrt() else { return };
    let d = scorer.d();
    let ds = testset(8_000, d, 5);
    let mut rng = Pcg64::new(6);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    for n in [scorer.block(), 1200] {
        let rows = &ds.data[..n * d];
        let (got_acc, got_ws) = scorer.expect_fragment(rows, d, &q);
        let (want_acc, want_ws) = NativeScorer.expect_fragment(rows, d, &q);
        assert!((got_acc.logsumexp() - want_acc.logsumexp()).abs() < 1e-3);
        for j in 0..d {
            let g = got_ws[j] as f64 / got_acc.sumexp;
            let w = want_ws[j] as f64 / want_acc.sumexp;
            assert!((g - w).abs() < 1e-3, "n={n} coord {j}: {g} vs {w}");
        }
    }
}

#[test]
fn sampling_through_pjrt_is_exact() {
    // end-to-end Alg 1 with the XLA scorer on the hot path: GOF against
    // the exact softmax computed natively
    let Some(scorer) = pjrt() else { return };
    let backend: Arc<dyn ScoreBackend> = scorer;
    let d = 64;
    let ds = testset(400, d, 7);
    let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
    let sampler = LazyGumbelSampler::new(ds.clone(), index, backend, 40, 0.0);
    let mut rng = Pcg64::new(8);
    let mut q = ds.row(5).to_vec();
    linalg::scale(&mut q, 4.0); // moderately peaked
    // exact probabilities via native backend
    let exact = gmips::sampler::exact::ExactSampler::new(ds.clone(), Arc::new(NativeScorer));
    let probs = exact.probabilities(&q);
    let total = 6_000u64;
    let mut counts = vec![0u64; ds.n];
    for o in sampler.sample_many(&q, total as usize, &mut rng) {
        counts[o.id as usize] += 1;
    }
    assert!(stats::gof_ok(&counts, &probs, total, 6.0), "PJRT-path GOF failed");
}

#[test]
fn partition_estimate_through_pjrt() {
    let Some(scorer) = pjrt() else { return };
    let backend: Arc<dyn ScoreBackend> = scorer;
    let ds = testset(12_000, 64, 9);
    let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
    let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 400, 400);
    let mut rng = Pcg64::new(10);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    let got = est.estimate(&q, &mut rng).log_z;
    let want = exact_log_partition(&ds, &NativeScorer, &q);
    let rel = ((got - want).exp() - 1.0).abs();
    assert!(rel < 0.2, "relative error {rel} (log {got} vs {want})");
}

#[test]
fn engine_with_pjrt_backend_serves() {
    let Some(scorer) = pjrt() else { return };
    let backend: Arc<dyn ScoreBackend> = scorer;
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 6_000;
    cfg.data.d = 64;
    cfg.index.kind = IndexKind::Ivf;
    cfg.index.n_clusters = 64;
    cfg.index.n_probe = 16;
    cfg.index.kmeans_iters = 4;
    cfg.index.train_sample = 3_000;
    let ds = Arc::new(data::generate(&cfg.data));
    let index = mips::build_index(&ds, &cfg.index, backend.clone()).unwrap();
    let engine = Arc::new(Engine::from_parts(cfg, ds.clone(), index, backend));
    // PJRT scorer serializes internally; 2 workers exercise contention
    let coord = Coordinator::start(engine.clone(), 2, 8, 11);
    let mut rng = Pcg64::new(12);
    let theta = data::random_theta(&ds, 0.05, &mut rng);
    match coord.call(Request::Sample { theta: theta.clone(), count: 4 }).unwrap() {
        Response::Samples { ids, scanned, .. } => {
            assert_eq!(ids.len(), 4);
            assert!(scanned < ds.n);
        }
        other => panic!("{other:?}"),
    }
    match coord.call(Request::LogPartition { theta }).unwrap() {
        Response::LogPartition { log_z, .. } => assert!(log_z.is_finite()),
        other => panic!("{other:?}"),
    }
    coord.shutdown();
}

#[test]
fn index_families_consistent_on_same_data() {
    // all index kinds must return plausibly-overlapping top sets
    let ds = testset(4_000, 64, 13);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut cfg = Config::default().index;
    cfg.n_clusters = 64;
    cfg.n_probe = 16;
    cfg.kmeans_iters = 5;
    cfg.train_sample = 2_000;
    cfg.tables = 10;
    cfg.bits = 7;
    cfg.rungs = 8;
    let brute = BruteForce::new(ds.clone(), backend.clone());
    let mut rng = Pcg64::new(14);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    let want = brute.top_k(&q, 50);
    for kind in [IndexKind::Ivf, IndexKind::Lsh] {
        cfg.kind = kind;
        let idx = mips::build_index(&ds, &cfg, backend.clone()).unwrap();
        let got = idx.top_k(&q, 50);
        let recall = mips::recall_at_k(&got, &want);
        assert!(recall > 0.5, "{:?} recall {recall}", kind);
    }
}
