//! Networked shard serving: cross-process conformance and fault drills.
//!
//! * with no faults injected, a coordinator fanning out to shard servers
//!   over TCP answers **bit-identically** to the in-process sharded
//!   engine built from the same config (N ∈ {1, 2, 4} shards, singles
//!   and batches);
//! * transient faults (severed connections, corrupted frames) are
//!   absorbed by the client's bounded retry and never reach the caller;
//! * a killed shard degrades service (`degraded: true`, `shards_ok`
//!   `s/N`, merge renormalized over survivors) instead of failing it,
//!   skips the dead shard without burning the deadline, and rejoins
//!   automatically once the heartbeat sees it again;
//! * queue saturation sheds with an explicit `overloaded` error instead
//!   of piling up connection threads.

use gmips::config::{Config, IndexKind};
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::data;
use gmips::dispatch::{ExpectationDispatch, PartitionDispatch, SamplerDispatch};
use gmips::mips::MipsIndex;
use gmips::remote::{FaultPlan, ShardEngine, ShardHandler, ShardHealth, ShardRequest, ShardResponse};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::server::{Client, Server};
use gmips::shard::ShardedIndex;
use gmips::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

fn remote_cfg(shards: usize) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 1800;
    cfg.data.d = 10;
    cfg.index.kind = IndexKind::Brute;
    cfg.index.shards = shards;
    cfg.remote.deadline_ms = 2000;
    cfg.remote.connect_timeout_ms = 250;
    cfg.remote.retries = 2;
    cfg.remote.backoff_ms = 5;
    cfg.remote.heartbeat_ms = 0; // tests opt in explicitly
    cfg.remote.down_after = 1;
    cfg
}

/// One in-process "fleet" of shard servers, each a full [`ShardEngine`]
/// behind the JSON-lines server with its own fault plan.
struct ShardFleet {
    addrs: Vec<String>,
    stops: Vec<Arc<AtomicBool>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    plans: Vec<Arc<FaultPlan>>,
}

impl ShardFleet {
    fn spawn(cfg: &Config) -> ShardFleet {
        let mut fleet = ShardFleet {
            addrs: Vec::new(),
            stops: Vec::new(),
            handles: Vec::new(),
            plans: Vec::new(),
        };
        for s in 0..cfg.index.shards.max(1) {
            let engine = Arc::new(ShardEngine::from_config(cfg, s, None).unwrap());
            let plan = Arc::new(FaultPlan::new());
            let server = Server::bind_handler(
                Arc::new(ShardHandler::new(engine)),
                "127.0.0.1:0",
                &cfg.serve,
            )
            .unwrap()
            .with_faults(plan.clone());
            fleet.addrs.push(server.local_addr().unwrap());
            fleet.stops.push(server.stop_flag());
            fleet.plans.push(plan);
            fleet.handles.push(std::thread::spawn(move || {
                let _ = server.serve();
            }));
        }
        fleet
    }

    fn addr_csv(&self) -> String {
        self.addrs.join(",")
    }

    fn shutdown(self) {
        for s in &self.stops {
            s.store(true, Ordering::SeqCst);
        }
        for h in self.handles {
            h.join().unwrap();
        }
    }
}

/// The in-process reference: the same sharded stack the shard servers
/// run, assembled locally (works for 1 shard too, where `from_config`
/// would build the monolithic stack instead).
fn local_reference(cfg: &Config) -> Engine {
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = Arc::new(ShardedIndex::build(&ds, &cfg.index, backend.clone()).unwrap());
    Engine::from_parts(cfg.clone(), ds, index, backend)
}

#[test]
fn remote_matches_in_process_bit_for_bit() {
    for shards in [1usize, 2, 4] {
        let mut cfg = remote_cfg(shards);
        let fleet = ShardFleet::spawn(&cfg);
        cfg.remote.addrs = fleet.addr_csv();
        cfg.validate().unwrap();
        let remote = Engine::from_remote(&cfg, None).unwrap();
        let local = local_reference(&cfg);
        let mut rng_r = Pcg64::new(7);
        let mut rng_l = Pcg64::new(7);
        let mut rng_q = Pcg64::new(11);

        // singles: every op, several θ — responses must be identical
        for qi in 0..3 {
            let theta = data::random_theta(&local.ds, 0.05, &mut rng_q);
            for req in [
                Request::Sample { theta: theta.clone(), count: 3 },
                Request::TopK { theta: theta.clone(), k: 9 },
                Request::LogPartition { theta: theta.clone() },
                Request::ExpectFeatures { theta: theta.clone() },
            ] {
                let a = remote.handle(&req, &mut rng_r);
                let b = local.handle(&req, &mut rng_l);
                assert_eq!(a, b, "shards={shards} q={qi} req={req:?}");
            }
        }

        // batches: grouped fan-outs must replay the same rounds
        let thetas: Vec<Vec<f32>> =
            (0..3).map(|_| data::random_theta(&local.ds, 0.05, &mut rng_q)).collect();
        let reqs = vec![
            Request::Sample { theta: thetas[0].clone(), count: 2 },
            Request::TopK { theta: thetas[1].clone(), k: 5 },
            Request::LogPartition { theta: thetas[2].clone() },
            Request::Sample { theta: thetas[1].clone(), count: 4 },
            Request::ExpectFeatures { theta: thetas[0].clone() },
            Request::TopK { theta: thetas[2].clone(), k: 5 },
        ];
        let ra = remote.handle_batch(&reqs, &mut rng_r);
        let rb = local.handle_batch(&reqs, &mut rng_l);
        assert_eq!(ra, rb, "shards={shards} batch");

        fleet.shutdown();
    }
}

#[test]
fn engine_routes_to_the_remote_stack() {
    let mut cfg = remote_cfg(2);
    let fleet = ShardFleet::spawn(&cfg);
    cfg.remote.addrs = fleet.addr_csv();
    let remote = Engine::from_remote(&cfg, None).unwrap();
    assert!(matches!(remote.sampler, SamplerDispatch::Remote(_)));
    assert!(matches!(remote.partition, PartitionDispatch::Remote(_)));
    assert!(matches!(remote.expectation, ExpectationDispatch::Remote(_)));
    assert_eq!(remote.index.name(), "remote");
    let mut rng = Pcg64::new(1);
    match remote.handle(&Request::Stats, &mut rng) {
        Response::Stats { text, .. } => {
            assert!(text.contains("remote[2 shards"), "{text}");
            assert!(text.contains("sampler=remote-gumbel"), "{text}");
            assert!(text.contains("partition=remote-alg3"), "{text}");
            assert!(text.contains("expectation=remote-alg4"), "{text}");
        }
        other => panic!("{other:?}"),
    }
    fleet.shutdown();
}

#[test]
fn transient_faults_are_retried_not_degraded() {
    let mut cfg = remote_cfg(2);
    let fleet = ShardFleet::spawn(&cfg);
    cfg.remote.addrs = fleet.addr_csv();
    let remote = Engine::from_remote(&cfg, None).unwrap();
    let mut rng = Pcg64::new(3);
    let theta = data::random_theta(&remote.ds, 0.05, &mut rng);

    // baseline answer with no faults
    let want = remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng);
    assert!(matches!(want, Response::LogPartition { .. }), "{want:?}");

    // one severed connection: the client reconnects and retries inside
    // its deadline, so the caller sees a normal (not degraded) answer
    fleet.plans[1].set_drop_conns(1);
    let got = remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng);
    assert!(matches!(got, Response::LogPartition { .. }), "{got:?}");

    // one corrupted frame: treated as an IO fault, retried the same way
    fleet.plans[0].set_corrupt_frames(1);
    let got = remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng);
    assert!(matches!(got, Response::LogPartition { .. }), "{got:?}");

    // both shards still healthy after the drill
    let stack = remote.remote.as_ref().unwrap();
    assert_eq!(stack.health().state(0), ShardHealth::Up);
    assert_eq!(stack.health().state(1), ShardHealth::Up);
    fleet.shutdown();
}

#[test]
fn killed_shard_degrades_then_recovers() {
    let mut cfg = remote_cfg(2);
    cfg.remote.heartbeat_ms = 30;
    cfg.remote.retries = 0;
    cfg.remote.deadline_ms = 500;
    let fleet = ShardFleet::spawn(&cfg);
    cfg.remote.addrs = fleet.addr_csv();
    let remote = Engine::from_remote(&cfg, None).unwrap();
    let stack = remote.remote.as_ref().unwrap().clone();
    let mut rng = Pcg64::new(5);
    let theta = data::random_theta(&remote.ds, 0.05, &mut rng);

    // healthy fleet: plain responses
    let r = remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng);
    assert!(matches!(r, Response::LogPartition { .. }), "{r:?}");

    // kill shard 1 in place: the acceptor refuses connections and open
    // connections sever mid-stream
    fleet.plans[1].set_down(true);
    match remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng) {
        Response::Degraded { inner, ok_shards, shards } => {
            assert_eq!((ok_shards, shards), (1, 2));
            match *inner {
                Response::LogPartition { log_z, .. } => {
                    // renormalized over the surviving shard: finite, and
                    // below the full-population estimate
                    assert!(log_z.is_finite());
                }
                other => panic!("{other:?}"),
            }
        }
        other => panic!("expected degraded response, got {other:?}"),
    }
    assert_eq!(stack.health().state(1), ShardHealth::Down);

    // while the shard is down it is skipped, not re-timed-out: degraded
    // answers come back well inside the per-request deadline
    let t0 = Instant::now();
    for req in [
        Request::Sample { theta: theta.clone(), count: 2 },
        Request::TopK { theta: theta.clone(), k: 6 },
        Request::ExpectFeatures { theta: theta.clone() },
    ] {
        match remote.handle(&req, &mut rng) {
            Response::Degraded { ok_shards, shards, .. } => {
                assert_eq!((ok_shards, shards), (1, 2));
            }
            other => panic!("expected degraded response, got {other:?}"),
        }
    }
    assert!(
        t0.elapsed() < Duration::from_millis(400),
        "down shard must be skipped without burning the deadline ({:?})",
        t0.elapsed()
    );

    // restart the shard in place: the heartbeat must revive it with no
    // operator action
    fleet.plans[1].set_down(false);
    let deadline = Instant::now() + Duration::from_secs(5);
    while stack.health().state(1) != ShardHealth::Up {
        assert!(Instant::now() < deadline, "heartbeat never revived the restarted shard");
        std::thread::sleep(Duration::from_millis(10));
    }
    let r = remote.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng);
    assert!(matches!(r, Response::LogPartition { .. }), "recovered: {r:?}");
    fleet.shutdown();
}

#[test]
fn metrics_aggregation_matches_per_shard_scrapes() {
    let mut cfg = remote_cfg(2);
    let fleet = ShardFleet::spawn(&cfg);
    cfg.remote.addrs = fleet.addr_csv();
    let remote = Engine::from_remote(&cfg, None).unwrap();
    let mut rng = Pcg64::new(21);
    let theta = data::random_theta(&remote.ds, 0.05, &mut rng);

    // TopK fans exactly one shard op per shard per request, and ping /
    // metrics traffic is not counted, so after q requests every shard's
    // local counter reads exactly q.
    let q = 5u64;
    for _ in 0..q {
        match remote.handle(&Request::TopK { theta: theta.clone(), k: 4 }, &mut rng) {
            Response::TopK { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    // direct per-shard scrape over the wire protocol
    let mut direct = Vec::new();
    for addr in &fleet.addrs {
        let mut c = Client::connect(addr).unwrap();
        let line = c.call_line(&ShardRequest::Metrics.to_json().to_string()).unwrap();
        let resp =
            ShardResponse::from_json(&gmips::util::json::Json::parse(&line).unwrap()).unwrap();
        match resp {
            ShardResponse::Metrics { exposition } => {
                let exp = gmips::obs::parse_exposition(&exposition).unwrap();
                let v = exp.value("gmips_shard_requests_total", None).unwrap();
                assert_eq!(v as u64, q, "{exposition}");
                direct.push(v);
            }
            other => panic!("{other:?}"),
        }
    }

    // coordinator aggregation: the same values resurface under
    // shard="<id>" labels in one merged exposition
    match remote.handle(&Request::Metrics, &mut rng) {
        Response::Metrics { exposition } => {
            let exp = gmips::obs::parse_exposition(&exposition).unwrap();
            for (s, want) in direct.iter().enumerate() {
                let label = s.to_string();
                let got = exp
                    .value("gmips_shard_requests_total", Some(("shard", &label)))
                    .unwrap_or_else(|| panic!("missing shard={s} sample:\n{exposition}"));
                assert_eq!(got, *want, "shard {s}");
            }
        }
        other => panic!("{other:?}"),
    }
    fleet.shutdown();
}

#[test]
fn saturation_sheds_with_explicit_overload() {
    let mut cfg = remote_cfg(1);
    cfg.serve.shed_ms = 1;
    cfg.serve.queue_depth = 1;
    let fleet = ShardFleet::spawn(&cfg);
    cfg.remote.addrs = fleet.addr_csv();
    let engine = Arc::new(Engine::from_remote(&cfg, None).unwrap());
    let mut rng = Pcg64::new(9);
    let theta = data::random_theta(&engine.ds, 0.05, &mut rng);

    // one worker, queue depth 1, and an 80 ms injected service delay:
    // concurrent clients must overflow the queue
    let coord = Arc::new(Coordinator::start(engine, 1, cfg.serve.queue_depth, 13));
    let server = Server::bind_with(coord, "127.0.0.1:0", &cfg.serve).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_flag();
    let serve_handle = std::thread::spawn(move || {
        let _ = server.serve();
    });
    fleet.plans[0].set_delay_ms(80);

    let n_clients = 8;
    let barrier = Arc::new(Barrier::new(n_clients));
    let mut workers = Vec::new();
    for _ in 0..n_clients {
        let addr = addr.clone();
        let theta = theta.clone();
        let barrier = barrier.clone();
        workers.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            barrier.wait();
            client.call(&Request::LogPartition { theta }).unwrap()
        }));
    }
    let responses: Vec<Response> = workers.into_iter().map(|w| w.join().unwrap()).collect();

    let shed = responses
        .iter()
        .filter(|r| matches!(r, Response::Error { message } if message.contains("overloaded")))
        .count();
    let served = responses
        .iter()
        .filter(|r| matches!(r, Response::LogPartition { .. } | Response::Degraded { .. }))
        .count();
    assert!(shed >= 1, "saturation must shed explicitly: {responses:?}");
    assert!(served >= 1, "some requests must still be served: {responses:?}");
    assert_eq!(shed + served, n_clients, "{responses:?}");

    // the front-end survives the storm and reports the sheds
    fleet.plans[0].set_delay_ms(0);
    let mut client = Client::connect(&addr).unwrap();
    match client.call(&Request::Stats).unwrap() {
        Response::Stats { text, numbers } => {
            assert!(text.contains("queue_depth="), "{text}");
            let counted: usize =
                text.rsplit("shed=").next().unwrap().trim().parse().expect("shed count");
            assert!(counted >= shed, "sheds must be counted: {text}");
            assert_eq!(numbers.shed as usize, counted, "structured shed must match the text");
        }
        other => panic!("{other:?}"),
    }
    stop.store(true, Ordering::SeqCst);
    serve_handle.join().unwrap();
    fleet.shutdown();
}
