//! Crash-safe snapshot store invariants: warm-reopening a saved index
//! must be indistinguishable from rebuilding it — top-k ids and score
//! bits, perturbed samples, and Algorithm-3/4 estimates at the same
//! seeds — for every index kind × quantization tier, in both read and
//! mmap modes, monolithic and sharded, including through the IVF
//! `update_row`/`compact()` lifecycle on a reopened index. Interrupted
//! saves must leave the previous snapshot intact, and corruption
//! anywhere in the file must produce a descriptive error (or, for the
//! quantized shadow sections only, a degraded open with bit-identical
//! f32 answers) — never a panic.

use gmips::config::{Config, IndexKind, QuantKind};
use gmips::coordinator::Engine;
use gmips::data;
use gmips::mips::{self, ivf::IvfIndex, BuiltIndex, MipsIndex, TopKResult};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::store::{self, tag, OpenMode, Snapshot};
use gmips::util::rng::Pcg64;
use std::sync::Arc;

fn tmp_path(name: &str) -> String {
    std::env::temp_dir()
        .join(format!("gmips_persist_{}_{name}.idx", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn base_cfg(kind: IndexKind, quant: QuantKind) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 1_200;
    cfg.data.d = 16;
    cfg.data.clusters = 12;
    cfg.index.kind = kind;
    cfg.index.n_clusters = 24;
    cfg.index.n_probe = 6;
    cfg.index.kmeans_iters = 3;
    cfg.index.train_sample = 600;
    cfg.index.tables = 4;
    cfg.index.bits = 6;
    cfg.index.quant = quant;
    cfg.index.quant_block = 48;
    cfg.index.overscan = 3;
    cfg
}

/// Bit-level fingerprints of every serving operation at fixed seeds.
#[derive(Debug, PartialEq)]
struct Probe {
    topk_ids: Vec<Vec<u32>>,
    topk_bits: Vec<Vec<u32>>,
    sample_ids: Vec<Vec<u32>>,
    logz_bits: Vec<u64>,
    mean_bits: Vec<Vec<u32>>,
}

fn probe(engine: &Engine, seed: u64) -> Probe {
    let mut rng = Pcg64::new(seed);
    let mut p = Probe {
        topk_ids: Vec::new(),
        topk_bits: Vec::new(),
        sample_ids: Vec::new(),
        logz_bits: Vec::new(),
        mean_bits: Vec::new(),
    };
    for _ in 0..3 {
        let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
        let top = engine.index.top_k(&theta, 12);
        p.topk_ids.push(top.items.iter().map(|s| s.id).collect());
        p.topk_bits.push(top.items.iter().map(|s| s.score.to_bits()).collect());
        let (outs, _) = engine.sampler.sample_many_status(&theta, 4, &mut rng).unwrap();
        p.sample_ids.push(outs.iter().map(|o| o.id).collect());
        let (est, _) = engine.partition.estimate_status(&theta, &mut rng).unwrap();
        p.logz_bits.push(est.log_z.to_bits());
        let (est, _) = engine.expectation.expect_features_status(&theta, &mut rng).unwrap();
        p.logz_bits.push(est.log_z.to_bits());
        p.mean_bits.push(est.mean.iter().map(|v| v.to_bits()).collect());
    }
    p
}

fn assert_topk_parity(got: &TopKResult, want: &TopKResult, label: &str) {
    assert_eq!(got.ids(), want.ids(), "{label}: ids diverge");
    for (g, w) in got.items.iter().zip(&want.items) {
        assert_eq!(g.score.to_bits(), w.score.to_bits(), "{label}: score bits diverge");
    }
}

#[test]
fn round_trip_bit_parity_all_kinds_and_tiers() {
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for kind in [IndexKind::Brute, IndexKind::Ivf, IndexKind::Lsh, IndexKind::Tiered] {
        for quant in [QuantKind::Off, QuantKind::Sq8, QuantKind::Sq4, QuantKind::Pq] {
            let cfg = base_cfg(kind, quant);
            let label = format!("{}/{}", kind.name(), quant.name());
            let path = tmp_path(&format!("rt_{}_{}", kind.name(), quant.name()));
            let _ = std::fs::remove_file(&path);
            let ds = Arc::new(data::load_or_generate(&cfg.data));
            let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
            store::save_index(&path, &cfg, &ds, &index).unwrap();
            let fresh = Engine::from_parts(cfg.clone(), ds, index, backend.clone());
            let want = probe(&fresh, 0xAB);
            for mmap in [true, false] {
                let mut c = cfg.clone();
                c.index.mmap = mmap;
                let opened = store::open_index(&path, &c, backend.clone()).unwrap();
                assert!(!opened.degraded, "{label}: clean snapshot must not degrade");
                let warm = Engine::from_parts(c, opened.ds, opened.index, backend.clone());
                assert_eq!(probe(&warm, 0xAB), want, "{label} mmap={mmap}");
            }
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn sharded_round_trip_bit_parity() {
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for kind in [IndexKind::Brute, IndexKind::Ivf] {
        for quant in [QuantKind::Off, QuantKind::Sq8] {
            let mut cfg = base_cfg(kind, quant);
            cfg.index.shards = 3;
            let label = format!("sharded {}/{}", kind.name(), quant.name());
            let path = tmp_path(&format!("shard_{}_{}", kind.name(), quant.name()));
            let _ = std::fs::remove_file(&path);
            let ds = Arc::new(data::load_or_generate(&cfg.data));
            let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
            assert!(matches!(index, BuiltIndex::Sharded(_)), "{label}: expected sharded build");
            store::save_index(&path, &cfg, &ds, &index).unwrap();
            let fresh = Engine::from_parts(cfg.clone(), ds, index, backend.clone());
            let want = probe(&fresh, 0xCD);
            for mmap in [true, false] {
                let mut c = cfg.clone();
                c.index.mmap = mmap;
                let opened = store::open_index(&path, &c, backend.clone()).unwrap();
                assert!(!opened.degraded, "{label}");
                assert!(matches!(opened.index, BuiltIndex::Sharded(_)), "{label}");
                let warm = Engine::from_parts(c, opened.ds, opened.index, backend.clone());
                assert_eq!(probe(&warm, 0xCD), want, "{label} mmap={mmap}");
            }
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn reopened_ivf_updates_compacts_and_resnapshots_like_fresh() {
    let cfg = base_cfg(IndexKind::Ivf, QuantKind::Sq8);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let path = tmp_path("ivf_lifecycle");
    let _ = std::fs::remove_file(&path);

    let mut fresh = IvfIndex::build(ds.clone(), &cfg.index, backend.clone()).unwrap();
    let saved = BuiltIndex::Mono(Arc::new(
        IvfIndex::build(ds.clone(), &cfg.index, backend.clone()).unwrap(),
    ) as Arc<dyn MipsIndex>);
    store::save_index(&path, &cfg, &ds, &saved).unwrap();

    let snap = Snapshot::open(&path, OpenMode::Mmap).unwrap();
    let mut degraded = false;
    let mut warm =
        IvfIndex::open_from(ds.clone(), &cfg.index, backend.clone(), &snap, &mut degraded)
            .unwrap();
    assert!(!degraded);

    let mut rng = Pcg64::new(0x11);
    let mut urng = Pcg64::new(0x12);
    for stage in ["fresh", "pending", "compacted"] {
        if stage == "pending" {
            for id in [5u32, 600, 1_100] {
                let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                fresh.update_row(id, &v);
                warm.update_row(id, &v);
            }
        }
        if stage == "compacted" {
            fresh.compact();
            warm.compact();
        }
        for k in [1usize, 20] {
            let q = data::random_theta(&ds, 0.05, &mut rng);
            assert_topk_parity(&warm.top_k(&q, k), &fresh.top_k(&q, k), &format!("{stage} k={k}"));
        }
    }

    // the mutated, compacted, reopened index must itself re-snapshot
    drop(snap);
    let path2 = tmp_path("ivf_resnap");
    let _ = std::fs::remove_file(&path2);
    let rewrapped = BuiltIndex::Mono(Arc::new(warm) as Arc<dyn MipsIndex>);
    store::save_index(&path2, &cfg, &ds, &rewrapped).unwrap();
    let reopened = store::open_index(&path2, &cfg, backend).unwrap();
    assert!(!reopened.degraded);
    for k in [1usize, 20] {
        let q = data::random_theta(&ds, 0.05, &mut rng);
        assert_topk_parity(
            &reopened.index.as_dyn().top_k(&q, k),
            &fresh.top_k(&q, k),
            &format!("re-snapshot k={k}"),
        );
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&path2);
}

#[test]
fn interrupted_save_preserves_previous_snapshot() {
    let cfg = base_cfg(IndexKind::Brute, QuantKind::Sq8);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let path = tmp_path("interrupted");
    let _ = std::fs::remove_file(&path);
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
    store::save_index(&path, &cfg, &ds, &index).unwrap();
    let good = std::fs::read(&path).unwrap();

    // crash leftovers: a half-written temp file must never shadow or
    // clobber the committed snapshot
    std::fs::write(format!("{path}.tmp"), b"partial garbage from a dead writer").unwrap();
    let opened = store::open_index(&path, &cfg, backend.clone()).unwrap();
    assert!(!opened.degraded);

    // a writer that dies before finish(): destination untouched
    {
        let mut w = store::SnapshotWriter::create(&path).unwrap();
        w.section(tag::CONFIG_STR, 0, b"half-written snapshot").unwrap();
        // dropped without finish() — simulated crash
    }
    assert_eq!(std::fs::read(&path).unwrap(), good, "previous snapshot must be intact");
    assert!(
        !std::path::Path::new(&format!("{path}.tmp")).exists(),
        "unfinished temp file must be cleaned up"
    );
    let fresh = Engine::from_parts(cfg.clone(), ds, index, backend.clone());
    let opened = store::open_index(&path, &cfg, backend.clone()).unwrap();
    let warm = Engine::from_parts(cfg.clone(), opened.ds, opened.index, backend);
    assert_eq!(probe(&warm, 0xEF), probe(&fresh, 0xEF));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corruption_drill_errors_or_degrades_but_never_panics() {
    let cfg = base_cfg(IndexKind::Brute, QuantKind::Sq8);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let path = tmp_path("drill_src");
    let _ = std::fs::remove_file(&path);
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
    store::save_index(&path, &cfg, &ds, &index).unwrap();

    let mut rng = Pcg64::new(0x77);
    let theta = data::random_theta(&ds, 0.05, &mut rng);
    let want = index.as_dyn().top_k(&theta, 10);

    let good = std::fs::read(&path).unwrap();
    let entries: Vec<store::SectionEntry> =
        Snapshot::open(&path, OpenMode::Read).unwrap().sections().to_vec();
    let table_off = u64::from_le_bytes(good[24..32].try_into().unwrap()) as usize;
    let _ = std::fs::remove_file(&path);

    let drill = tmp_path("drill_mut");
    // Ok(degraded) when the snapshot still opens, Err(..) otherwise; a
    // successful open must answer bit-identically to the fresh index
    // regardless of what was corrupted.
    let try_open = |bytes: &[u8], label: &str| -> Option<bool> {
        std::fs::write(&drill, bytes).unwrap();
        let mut outcome = None;
        for mmap in [false, true] {
            let mut c = cfg.clone();
            c.index.mmap = mmap;
            let one = match store::open_index(&drill, &c, backend.clone()) {
                Err(e) => {
                    assert!(!e.to_string().is_empty(), "{label}: error must be descriptive");
                    None
                }
                Ok(opened) => {
                    let got = opened.index.as_dyn().top_k(&theta, 10);
                    assert_topk_parity(&got, &want, &format!("{label} mmap={mmap}"));
                    Some(opened.degraded)
                }
            };
            if mmap {
                assert_eq!(outcome, Some(one), "{label}: read and mmap modes must agree");
            } else {
                outcome = Some(one);
            }
        }
        outcome.unwrap()
    };

    // every header byte: the header checksum must catch the flip
    for i in 0..store::format::HEADER_LEN {
        let mut b = good.clone();
        b[i] ^= 0xFF;
        assert!(try_open(&b, &format!("header byte {i}")).is_none(), "header byte {i}");
    }

    // truncations: empty, mid-header, header-only, mid-sections, one byte short
    for cut in [0usize, 7, store::format::HEADER_LEN - 1, store::format::HEADER_LEN] {
        assert!(try_open(&good[..cut], &format!("truncate {cut}")).is_none(), "truncate {cut}");
    }
    for cut in [good.len() / 2, good.len() - 1] {
        assert!(try_open(&good[..cut], &format!("truncate {cut}")).is_none(), "truncate {cut}");
    }

    // first/last byte of every section's payload
    let quant_tag = |t: u32| {
        t == tag::SQ8_META
            || t == tag::SQ8_CODES
            || t == tag::SQ4_META
            || t == tag::SQ4_CODES
            || t == tag::PQ_META
            || t == tag::PQ_CODES
    };
    for e in &entries {
        if e.len == 0 {
            continue;
        }
        for pos in [e.off as usize, (e.off + e.len - 1) as usize] {
            let mut b = good.clone();
            b[pos] ^= 0xFF;
            let label = format!("section tag={} byte {pos}", e.tag);
            let got = try_open(&b, &label);
            if e.tag == tag::PQ_TILES {
                // softer than the quant shadows: tiles re-block from the
                // validated plane codes — clean open, not even degraded
                assert_eq!(got, Some(false), "{label}: corrupt tiles must re-block cleanly");
            } else if quant_tag(e.tag) {
                assert_eq!(got, Some(true), "{label}: quantized shadow must degrade, not fail");
            } else {
                assert!(got.is_none(), "{label}: non-quant corruption must be an error");
            }
        }
    }

    // section-table entries: flip a byte of tag/off/len/checksum in each.
    // Depending on which field lands where this is either a descriptive
    // error or (for quantized entries) a degraded open — try_open already
    // enforces no-panic and bit-parity on any successful open.
    for i in 0..entries.len() {
        for field_off in [0usize, 8, 16, 24] {
            let mut b = good.clone();
            b[table_off + i * store::format::ENTRY_LEN + field_off] ^= 0xFF;
            try_open(&b, &format!("table entry {i} byte {field_off}"));
        }
    }

    let _ = std::fs::remove_file(&drill);
}

/// Snapshot version migration (PR 10): a PR-7-era snapshot carries only
/// plane-major `PQ_META`/`PQ_CODES` sections. Opening one must re-block
/// the fast-scan tiles in memory (clean open — no error, no degrade),
/// answer bit-identically on single and batched queries, and re-save in
/// the tiled format. Also drills the new `PQ_TILES` tag: corrupting its
/// payload re-blocks cleanly instead of degrading.
#[test]
fn pre_tiles_pq_snapshot_migrates_and_resaves_tiled() {
    let mut cfg = base_cfg(IndexKind::Brute, QuantKind::Pq);
    cfg.index.pq_bits = 4; // 4-bit codes are the fast-scan-eligible tier
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
    let path = tmp_path("pretiles_src");
    let _ = std::fs::remove_file(&path);
    store::save_index(&path, &cfg, &ds, &index).unwrap();

    let mut rng = Pcg64::new(0x99);
    let theta = data::random_theta(&ds, 0.05, &mut rng);
    let qs_owned: Vec<Vec<f32>> =
        (0..8).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
    let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
    let want = index.as_dyn().top_k(&theta, 12);
    let want_batch = index.as_dyn().top_k_batch(&qs, 12);

    // a fresh 4-bit PQ save must carry the tiled section
    let good = std::fs::read(&path).unwrap();
    let entries: Vec<store::SectionEntry> =
        Snapshot::open(&path, OpenMode::Read).unwrap().sections().to_vec();
    let tiles_at =
        entries.iter().position(|e| e.tag == tag::PQ_TILES).expect("fresh save must write tiles");
    assert!(entries[tiles_at].len > 0, "tiles section must be non-empty");
    let table_off = u64::from_le_bytes(good[24..32].try_into().unwrap()) as usize;
    let _ = std::fs::remove_file(&path);

    // opens bit-identically (single + 8-query batch), never degraded
    let open_and_check = |bytes: &[u8], label: &str| {
        let p = tmp_path("pretiles_mut");
        std::fs::write(&p, bytes).unwrap();
        for mmap in [false, true] {
            let mut c = cfg.clone();
            c.index.mmap = mmap;
            let opened = store::open_index(&p, &c, backend.clone())
                .unwrap_or_else(|e| panic!("{label} mmap={mmap}: must open: {e}"));
            assert!(!opened.degraded, "{label} mmap={mmap}: migration must not degrade");
            let got = opened.index.as_dyn().top_k(&theta, 12);
            assert_topk_parity(&got, &want, &format!("{label} mmap={mmap}"));
            let got_batch = opened.index.as_dyn().top_k_batch(&qs, 12);
            for (g, w) in got_batch.iter().zip(&want_batch) {
                assert_topk_parity(g, w, &format!("{label} mmap={mmap} batch"));
            }
            if !mmap {
                // the migrated view must re-save in the tiled format
                let resave = tmp_path("pretiles_resave");
                let _ = std::fs::remove_file(&resave);
                store::save_index(&resave, &c, &opened.ds, &opened.index).unwrap();
                let resaved = Snapshot::open(&resave, OpenMode::Read).unwrap();
                let te = resaved
                    .sections()
                    .iter()
                    .find(|e| e.tag == tag::PQ_TILES)
                    .unwrap_or_else(|| panic!("{label}: re-save must write tiles"));
                assert_eq!(te.len, entries[tiles_at].len, "{label}: re-saved tile bytes");
                let _ = std::fs::remove_file(&resave);
            }
        }
        let _ = std::fs::remove_file(&p);
    };

    // (a) PR-7-era file: no PQ_TILES section at all. Simulated by
    // retagging the entry as an unknown section — readers skip unknown
    // tags, which is byte-for-byte what an old writer's table looks like
    // to the PQ loader. Payload checksums are untouched.
    let mut pre_tiles = good.clone();
    let tag_pos = table_off + tiles_at * store::format::ENTRY_LEN;
    pre_tiles[tag_pos..tag_pos + 4].copy_from_slice(&0xFFFF_FFFEu32.to_le_bytes());
    open_and_check(&pre_tiles, "pre-tiles snapshot");

    // (b) corrupt tiles payload: first and last byte — re-block, not
    // degrade (the drill-style check for the new tag)
    let te = &entries[tiles_at];
    for pos in [te.off as usize, (te.off + te.len - 1) as usize] {
        let mut b = good.clone();
        b[pos] ^= 0xFF;
        open_and_check(&b, &format!("corrupt tiles byte {pos}"));
    }

    // (c) untouched file still opens with tiles served from the snapshot
    open_and_check(&good, "tiled snapshot");
}

#[test]
fn load_or_build_saves_then_warm_opens() {
    let mut cfg = base_cfg(IndexKind::Ivf, QuantKind::Sq8);
    let path = tmp_path("load_or_build");
    let _ = std::fs::remove_file(&path);
    cfg.index.path = path.clone();
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);

    let cold = store::load_or_build(&cfg, backend.clone(), true).unwrap();
    assert!(cold.built, "no snapshot yet: must build");
    assert!(std::path::Path::new(&path).exists(), "save_on_build must persist");

    let warm = store::load_or_build(&cfg, backend.clone(), true).unwrap();
    assert!(!warm.built, "snapshot exists: must warm-open");
    assert!(!warm.degraded);

    let e_cold = Engine::from_parts(cfg.clone(), cold.ds, cold.index, backend.clone());
    let e_warm = Engine::from_parts(cfg.clone(), warm.ds, warm.index, backend.clone());
    assert_eq!(probe(&e_warm, 0x33), probe(&e_cold, 0x33));

    // engines built from config take the same path
    let via_engine = Engine::from_config(&cfg, Some(backend)).unwrap();
    assert!(!via_engine.snapshot_degraded);
    assert_eq!(probe(&via_engine, 0x33), probe(&e_cold, 0x33));
    let _ = std::fs::remove_file(&path);
}
