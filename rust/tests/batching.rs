//! Batched-vs-single parity for the multi-query MIPS / sampler /
//! estimator / coordinator paths introduced with the SIMD scoring
//! subsystem: batching is a pure amortization — it must never change
//! *what* is computed, only how often the database is streamed.

use gmips::config::{Config, IndexKind};
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::data::{self, Dataset};
use gmips::estimator::partition::{exact_log_partition, PartitionEstimator};
use gmips::mips::{self, brute::BruteForce, MipsIndex};
use gmips::sampler::lazy_gumbel::LazyGumbelSampler;
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::check::Checker;
use gmips::util::rng::Pcg64;
use gmips::util::stats::gof_ok;
use std::sync::Arc;

fn testset(n: usize, d: usize, seed: u64) -> Arc<Dataset> {
    Arc::new(gmips::data::synth::imagenet_like(n, d, 20, 0.3, seed))
}

#[test]
fn property_brute_batch_identical_across_random_batches() {
    // satellite checklist: top_k_batch returns identical ids/scores to
    // per-query top_k on the brute index — checked as a property over
    // randomized batch compositions
    let ds = testset(1_500, 16, 1);
    let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
    Checker::new(31).cases(15).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0xBA7C4);
        let nq = 1 + (rng.next_below(7) as usize);
        let k = 1 + (rng.next_below(60) as usize);
        let qs_owned: Vec<Vec<f32>> =
            (0..nq).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let batch = idx.top_k_batch(&qs, k);
        batch.iter().enumerate().all(|(j, got)| {
            let want = idx.top_k(qs[j], k);
            got.ids() == want.ids()
                && got
                    .items
                    .iter()
                    .zip(&want.items)
                    .all(|(g, w)| g.score == w.score)
        })
    });
}

#[test]
fn default_batch_impl_matches_loop_for_lsh_families() {
    // lsh/tiered now batch via candidate-set union + one gathered
    // scores_batch pass per 64-query chunk: the batch path must remain
    // transparent (identical ids to per-query scans)
    let ds = testset(2_000, 16, 2);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut cfg = Config::default().index;
    cfg.tables = 6;
    cfg.bits = 7;
    cfg.rungs = 6;
    let mut rng = Pcg64::new(3);
    let qs_owned: Vec<Vec<f32>> =
        (0..4).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
    let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
    for kind in [IndexKind::Lsh, IndexKind::Tiered] {
        cfg.kind = kind;
        let idx = mips::build_index(&ds, &cfg, backend.clone()).unwrap();
        let batch = idx.top_k_batch(&qs, 20);
        assert_eq!(batch.len(), qs.len());
        for (j, got) in batch.iter().enumerate() {
            let want = idx.top_k(qs[j], 20);
            assert_eq!(got.ids(), want.ids(), "{kind:?} query {j}");
        }
    }
}

#[test]
fn batched_sampling_is_still_exact() {
    // Theorem 3.1 must survive the batched retrieval: GOF of batch-drawn
    // samples against the exact softmax
    let ds = testset(300, 8, 4);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
    let sampler = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), 30, 0.0);
    let exact = gmips::sampler::exact::ExactSampler::new(ds.clone(), backend);
    let mut rng = Pcg64::new(5);
    let q = data::random_theta(&ds, 0.2, &mut rng);
    let probs = exact.probabilities(&q);
    // batch of 4 copies of the same θ, many draws each
    let qs: Vec<&[f32]> = vec![q.as_slice(); 4];
    let per_q = 8_000usize;
    let mut counts = vec![0u64; ds.n];
    let outs = sampler.sample_batch(&qs, &[per_q; 4], &mut rng);
    assert_eq!(outs.len(), 4);
    for per_theta in &outs {
        assert_eq!(per_theta.len(), per_q);
        for o in per_theta {
            counts[o.id as usize] += 1;
        }
    }
    let total = (4 * per_q) as u64;
    assert!(gof_ok(&counts, &probs, total, 5.0), "batched Alg 1 GOF failed");
}

#[test]
fn batched_partition_estimates_are_accurate() {
    let ds = testset(2_000, 8, 6);
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
    let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 150, 150);
    let mut rng = Pcg64::new(7);
    let qs_owned: Vec<Vec<f32>> =
        (0..6).map(|_| data::random_theta(&ds, 0.1, &mut rng)).collect();
    let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
    let ests = est.estimate_batch(&qs, &mut rng);
    assert_eq!(ests.len(), qs.len());
    for (j, e) in ests.iter().enumerate() {
        let want = exact_log_partition(&ds, backend.as_ref(), qs[j]);
        let rel = ((e.log_z - want).exp() - 1.0).abs();
        assert!(rel < 0.25, "query {j}: rel err {rel} ({} vs {want})", e.log_z);
        assert!(e.work.k > 0 && e.work.l > 0);
    }
}

#[test]
fn coordinator_drains_batches_under_load() {
    // one worker + a deep queue: requests pile up while the worker is
    // busy, so whole batches flow through Engine::handle_batch; every
    // ticket must still get its own well-formed response
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 3_000;
    cfg.data.d = 16;
    cfg.index.kind = IndexKind::Ivf;
    cfg.index.n_clusters = 40;
    cfg.index.n_probe = 10;
    cfg.index.kmeans_iters = 3;
    cfg.index.train_sample = 1_500;
    let engine = Arc::new(Engine::from_config(&cfg, None).unwrap());
    let coord = Coordinator::start(engine.clone(), 1, 64, 11);
    let mut rng = Pcg64::new(12);
    let mut tickets = Vec::new();
    for i in 0..40 {
        let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
        let req = match i % 4 {
            0 => Request::Sample { theta, count: 2 },
            1 => Request::TopK { theta, k: 7 },
            2 => Request::LogPartition { theta },
            _ => Request::ExpectFeatures { theta },
        };
        tickets.push((i, coord.submit(req).unwrap()));
    }
    for (i, t) in tickets {
        match (i % 4, t.wait().unwrap()) {
            (0, Response::Samples { ids, .. }) => assert_eq!(ids.len(), 2),
            (1, Response::TopK { ids, scores }) => {
                assert_eq!(ids.len(), 7);
                assert!(scores.windows(2).all(|w| w[0] >= w[1]));
            }
            (2, Response::LogPartition { log_z, .. }) => assert!(log_z.is_finite()),
            (3, Response::Features { mean, .. }) => assert_eq!(mean.len(), engine.ds.d),
            (_, other) => panic!("request {i}: unexpected {other:?}"),
        }
    }
    coord.shutdown();
}
