//! End-to-end acceptance: the full service loop (config → data → index →
//! coordinator → TCP server → client) under a mixed workload, plus the
//! learning pipeline, at test scale.

use gmips::config::{Config, IndexKind};
use gmips::coordinator::{Coordinator, Engine, Request, Response};
use gmips::data;
use gmips::learner::{GradMethod, Learner};
use gmips::server::{Client, Server};
use gmips::util::rng::Pcg64;
use std::sync::Arc;

fn tiny_cfg() -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 4_000;
    cfg.data.d = 16;
    cfg.index.kind = IndexKind::Ivf;
    cfg.index.n_clusters = 50;
    cfg.index.n_probe = 12;
    cfg.index.kmeans_iters = 4;
    cfg.index.train_sample = 2_000;
    cfg
}

#[test]
fn full_service_loop_mixed_workload() {
    let cfg = tiny_cfg();
    let engine = Arc::new(Engine::from_config(&cfg, None).unwrap());
    let ds = engine.ds.clone();
    let coord = Arc::new(Coordinator::start(engine.clone(), 2, 32, 1));
    let server = Server::bind(coord, "127.0.0.1:0").unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.serve().unwrap());

    // two concurrent clients issuing interleaved ops
    let mut handles = Vec::new();
    for c in 0..2u64 {
        let addr = addr.clone();
        let ds = ds.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut rng = Pcg64::new_stream(7, c);
            for _ in 0..10 {
                let theta = data::random_theta(&ds, 0.05, &mut rng);
                match client.call(&Request::Sample { theta: theta.clone(), count: 2 }).unwrap() {
                    Response::Samples { ids, .. } => assert_eq!(ids.len(), 2),
                    other => panic!("{other:?}"),
                }
                match client.call(&Request::TopK { theta: theta.clone(), k: 5 }).unwrap() {
                    Response::TopK { ids, scores } => {
                        assert_eq!(ids.len(), 5);
                        assert!(scores.windows(2).all(|w| w[0] >= w[1]));
                    }
                    other => panic!("{other:?}"),
                }
                match client.call(&Request::ExpectFeatures { theta }).unwrap() {
                    Response::Features { mean, log_z } => {
                        assert_eq!(mean.len(), ds.d);
                        assert!(log_z.is_finite());
                    }
                    other => panic!("{other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // engine metrics observed all that traffic
    assert!(engine.metrics.sample.count() >= 20);
    assert!(engine.metrics.topk.count() >= 20);

    let mut client = Client::connect(&addr).unwrap();
    client.shutdown_server().unwrap();
    handle.join().unwrap();
}

#[test]
fn learning_pipeline_end_to_end() {
    let mut cfg = tiny_cfg();
    cfg.learn.iters = 120;
    cfg.learn.eval_every = 40;
    cfg.learn.lr = 6.0;
    cfg.learn.lr_halve_every = 50;
    cfg.learn.train_size = 10;
    cfg.learn.k_mult = 5.0;
    cfg.learn.l_ratio = 5.0;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn gmips::scorer::ScoreBackend> = Arc::new(gmips::scorer::NativeScorer);
    let index = gmips::mips::build_index(&ds, &cfg.index, backend.clone()).unwrap();
    let learner = Learner::new(ds, index, backend, cfg.learn.clone()).unwrap();
    let mut rng = Pcg64::new(2);
    let res = learner.train(GradMethod::Amortized, &mut rng);
    // learning must actually learn: the coherent subset becomes far more
    // likely than uniform
    let uniform_ll = -(cfg.data.n as f64).ln();
    assert!(
        res.final_ll > uniform_ll + 1.0,
        "LL {} should beat uniform {}",
        res.final_ll,
        uniform_ll
    );
    // curve is monotone-ish: final >= first point
    assert!(res.final_ll >= res.curve[0].log_likelihood);
}

#[test]
fn config_roundtrip_through_files() {
    // config file → engine → behaviour: k scales with k_mult
    let dir = std::env::temp_dir().join(format!("gmips_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("test.toml");
    std::fs::write(
        &path,
        "[data]\nn = 3000\nd = 8\n[sampler]\nk_mult = 2.0\n[index]\nkind = \"brute\"\n",
    )
    .unwrap();
    let mut cfg = Config::default();
    let doc = gmips::config::toml::TomlDoc::load(path.to_str().unwrap()).unwrap();
    cfg.apply_toml(&doc).unwrap();
    assert_eq!(cfg.data.n, 3000);
    assert_eq!(cfg.sampler_k(), (2.0 * (3000f64).sqrt()).round() as usize);
    let engine = Engine::from_config(&cfg, None).unwrap();
    assert_eq!(engine.sampler.k(), cfg.sampler_k());
    std::fs::remove_dir_all(&dir).ok();
}
