//! Engine-level sharded serving: an `Engine` built with
//! `index.shards > 1` must route sample / log-partition /
//! expect-features through the **sharded** sampler/estimator
//! implementations (no silent monolithic fallback), serve
//! shard-count-invariant samples and statistically matched estimates,
//! batch bit-identically to singles, and train a sharded `Learner`
//! (`GradMethod::Amortized`) with the paper's Table-2 ordering intact.

use gmips::config::{Config, IndexKind};
use gmips::coordinator::{Engine, Request, Response};
use gmips::data::{self, synth};
use gmips::dispatch::{ExpectationDispatch, PartitionDispatch, SamplerDispatch};
use gmips::estimator::expectation::exact_feature_expectation;
use gmips::estimator::partition::exact_log_partition;
use gmips::learner::{GradMethod, Learner};
use gmips::mips::MipsIndex;
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::shard::ShardedIndex;
use gmips::util::rng::Pcg64;
use std::sync::Arc;

fn engine_cfg(shards: usize) -> Config {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 2500;
    cfg.data.d = 12;
    cfg.index.kind = IndexKind::Brute;
    cfg.index.shards = shards;
    cfg.validate().unwrap();
    cfg
}

#[test]
fn engine_routes_to_the_sharded_stack() {
    let sharded = Engine::from_config(&engine_cfg(4), None).unwrap();
    assert!(matches!(sharded.sampler, SamplerDispatch::Sharded(_)));
    assert!(matches!(sharded.partition, PartitionDispatch::Sharded(_)));
    assert!(matches!(sharded.expectation, ExpectationDispatch::Sharded(_)));
    assert_eq!(sharded.index.name(), "sharded");
    let mut rng = Pcg64::new(1);
    match sharded.handle(&Request::Stats, &mut rng) {
        Response::Stats { text, .. } => {
            assert!(text.contains("sampler=sharded-gumbel"), "{text}");
            assert!(text.contains("partition=sharded-alg3"), "{text}");
            assert!(text.contains("expectation=sharded-alg4"), "{text}");
        }
        other => panic!("{other:?}"),
    }
    // shards = 1 keeps the monolithic stack (and says so)
    let mono = Engine::from_config(&engine_cfg(1), None).unwrap();
    assert!(matches!(mono.sampler, SamplerDispatch::Mono(_)));
    assert!(matches!(mono.partition, PartitionDispatch::Mono(_)));
    assert!(matches!(mono.expectation, ExpectationDispatch::Mono(_)));
    match mono.handle(&Request::Stats, &mut rng) {
        Response::Stats { text, .. } => {
            assert!(text.contains("sampler=lazy-gumbel"), "{text}");
            assert!(text.contains("partition=alg3"), "{text}");
            assert!(text.contains("expectation=alg4"), "{text}");
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn sharded_engines_serve_invariant_samples_and_matched_estimates() {
    // two fresh engines differing ONLY in shard count: the id-keyed
    // frozen streams make the served samples bit-identical, and every
    // estimate (including the monolithic shards=1 engine's) must match
    // the exact quantities within Algorithm 3/4 tolerance
    let e2 = Engine::from_config(&engine_cfg(2), None).unwrap();
    let e4 = Engine::from_config(&engine_cfg(4), None).unwrap();
    let e1 = Engine::from_config(&engine_cfg(1), None).unwrap();
    let mut trng = Pcg64::new(7);
    let theta = data::random_theta(&e2.ds, 0.05, &mut trng);

    let mut r2 = Pcg64::new(3);
    let mut r4 = Pcg64::new(3);
    let ids = |resp: Response| -> Vec<u32> {
        match resp {
            Response::Samples { ids, .. } => ids,
            other => panic!("{other:?}"),
        }
    };
    let a = ids(e2.handle(&Request::Sample { theta: theta.clone(), count: 40 }, &mut r2));
    let b = ids(e4.handle(&Request::Sample { theta: theta.clone(), count: 40 }, &mut r4));
    assert_eq!(a, b, "served samples must be shard-count invariant");

    let exact_lz = exact_log_partition(&e2.ds, e2.backend.as_ref(), &theta);
    let (exact_mean, _) = exact_feature_expectation(&e2.ds, e2.backend.as_ref(), &theta);
    for (label, e) in [("shards=1", &e1), ("shards=2", &e2), ("shards=4", &e4)] {
        let mut rng = Pcg64::new(9);
        match e.handle(&Request::LogPartition { theta: theta.clone() }, &mut rng) {
            Response::LogPartition { log_z, k, l } => {
                assert!((log_z - exact_lz).abs() < 0.5, "{label}: {log_z} vs {exact_lz}");
                assert!(k > 0 && l > 0, "{label}");
            }
            other => panic!("{other:?}"),
        }
        match e.handle(&Request::ExpectFeatures { theta: theta.clone() }, &mut rng) {
            Response::Features { mean, log_z } => {
                assert_eq!(mean.len(), e.ds.d);
                assert!((log_z - exact_lz).abs() < 0.5, "{label}");
                let err = mean
                    .iter()
                    .zip(&exact_mean)
                    .map(|(&a, &b)| (a - b).abs() as f64)
                    .fold(0.0, f64::max);
                assert!(err < 0.15, "{label}: max coord error {err}");
            }
            other => panic!("{other:?}"),
        }
    }
}

#[test]
fn sharded_batch_serving_matches_singles() {
    // the batched fan-out paths consume the same keyed rounds the
    // single-request paths would, so two fresh identical engines — one
    // draining a batch, one serving the same requests singly in grouped
    // order — must answer bit-identically
    let batch_engine = Engine::from_config(&engine_cfg(3), None).unwrap();
    let single_engine = Engine::from_config(&engine_cfg(3), None).unwrap();
    let mut trng = Pcg64::new(11);
    let t1 = data::random_theta(&batch_engine.ds, 0.05, &mut trng);
    let t2 = data::random_theta(&batch_engine.ds, 0.05, &mut trng);

    let reqs = vec![
        Request::Sample { theta: t1.clone(), count: 3 },
        Request::LogPartition { theta: t1.clone() },
        Request::ExpectFeatures { theta: t2.clone() },
        Request::Sample { theta: t2.clone(), count: 2 },
        Request::LogPartition { theta: t2.clone() },
        Request::ExpectFeatures { theta: t1.clone() },
    ];
    let mut rng = Pcg64::new(13);
    let batched = batch_engine.handle_batch(&reqs, &mut rng);

    // same ops in handle_batch's grouping order: samples, partitions,
    // expects — each dispatch family has its own round counter
    let mut rng = Pcg64::new(13);
    let singles: Vec<Response> = [0usize, 3, 1, 4, 2, 5]
        .iter()
        .map(|&i| single_engine.handle(&reqs[i], &mut rng))
        .collect();
    let pick = |i: usize| -> &Response {
        // invert the grouped order back to request order
        match i {
            0 => &singles[0],
            3 => &singles[1],
            1 => &singles[2],
            4 => &singles[3],
            2 => &singles[4],
            5 => &singles[5],
            _ => unreachable!(),
        }
    };
    for i in 0..reqs.len() {
        match (&batched[i], pick(i)) {
            (Response::Samples { ids: a, .. }, Response::Samples { ids: b, .. }) => {
                assert_eq!(a, b, "request {i}")
            }
            (
                Response::LogPartition { log_z: a, .. },
                Response::LogPartition { log_z: b, .. },
            ) => assert_eq!(a.to_bits(), b.to_bits(), "request {i}"),
            (
                Response::Features { mean: a, log_z: la },
                Response::Features { mean: b, log_z: lb },
            ) => {
                assert_eq!(a, b, "request {i}");
                assert_eq!(la.to_bits(), lb.to_bits(), "request {i}");
            }
            other => panic!("request {i}: mismatched kinds {other:?}"),
        }
    }
}

#[test]
fn sharded_learner_preserves_table2_ordering() {
    // GradMethod::Amortized over a sharded index runs the sharded
    // Algorithm 4; the paper's Table 2 ordering (exact ≈ ours > top-k)
    // must survive the decomposition
    let ds = Arc::new(synth::imagenet_like(1500, 8, 10, 0.25, 4));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut icfg = Config::default().index;
    icfg.kind = IndexKind::Brute;
    icfg.shards = 3;
    let index = Arc::new(ShardedIndex::build(&ds, &icfg, backend.clone()).unwrap());

    let mut lcfg = Config::default().learn;
    lcfg.iters = 60;
    lcfg.eval_every = 60;
    lcfg.lr = 4.0;
    lcfg.lr_halve_every = 31;
    lcfg.train_size = 8;
    lcfg.k_mult = 5.0;
    lcfg.l_ratio = 5.0;
    lcfg.topk_mult = 1.0;
    let learner = Learner::new(ds, index, backend, lcfg).unwrap();

    let mut rng = Pcg64::new(5);
    let exact = learner.train(GradMethod::Exact, &mut rng);
    let ours = learner.train(GradMethod::Amortized, &mut rng);
    let topk = learner.train(GradMethod::TopK, &mut rng);
    assert!(
        (ours.final_ll - exact.final_ll).abs() < 0.3,
        "ours {} vs exact {}",
        ours.final_ll,
        exact.final_ll
    );
    assert!(
        topk.final_ll < exact.final_ll - 0.1,
        "top-k {} should lag exact {}",
        topk.final_ll,
        exact.final_ll
    );
}
