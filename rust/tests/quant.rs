//! Quantized two-stage scan invariants (Checker-driven): the screening
//! pass — SQ8, SQ4, or PQ — is a pure bandwidth optimization. Pass 1
//! must always retain the exact top-k whenever its certificate fires
//! (coverage), the per-row error bounds must hold, and the end-to-end
//! `top_k` / `top_k_batch` results must be bit-identical to the
//! f32-only scan on brute, IVF, LSH, and the sharded index — including
//! through sparse updates, compaction, the tier-ladder fallback
//! (PQ/SQ4 → SQ8 → f32), and the multi-query kernels.

use gmips::config::{Config, IndexConfig, QuantKind};
use gmips::data::{self, synth};
use gmips::linalg::pq::PqView;
use gmips::linalg::{self, quant::*};
use gmips::mips::brute::BruteForce;
use gmips::mips::ivf::IvfIndex;
use gmips::mips::lsh::SrpLsh;
use gmips::mips::{MipsIndex, TopKResult};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::shard::ShardedIndex;
use gmips::util::check::Checker;
use gmips::util::rng::Pcg64;
use gmips::util::topk::{topk_reference, TopK};
use std::sync::Arc;

/// Every active tier configuration the suites sweep (PQ at both widths).
const TIERS: [(QuantKind, usize); 4] =
    [(QuantKind::Sq8, 8), (QuantKind::Sq4, 8), (QuantKind::Pq, 4), (QuantKind::Pq, 8)];

/// Bit-level result parity: same ids AND same f32 score bits.
fn assert_parity(got: &TopKResult, want: &TopKResult, label: &str) {
    assert_eq!(got.ids(), want.ids(), "{label}: ids diverge");
    for (g, w) in got.items.iter().zip(&want.items) {
        assert_eq!(g.score.to_bits(), w.score.to_bits(), "{label}: scores diverge");
    }
    assert_eq!(got.scanned, want.scanned, "{label}: scanned accounting diverges");
}

#[test]
fn property_exact_topk_always_inside_overscan_candidates() {
    // the coverage contract: for random datasets/dims/blocks, whenever
    // the coverage certificate fires, the exact top-k ids are a subset
    // of the pass-1 overscan candidate set (otherwise the pass honestly
    // reports failure and the caller rescans exactly)
    Checker::new(51).cases(50).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0x5EED);
        let n = 200 + rng.next_below(800) as usize;
        let d = 1 + rng.next_below(48) as usize;
        let block = 1 + rng.next_below(96) as usize;
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let qv = QuantView::encode(&rows, d, block);
        let qq = QuantQuery::encode(&q);
        let eps = qv.error_bound(&qq);
        let mut quant = vec![0f32; n];
        qv.scores(0, n, &qq, &mut quant);
        let mut exact = vec![0f32; n];
        linalg::matvec_block(&rows, d, &q, &mut exact);
        let k = 1 + rng.next_below(32) as usize;
        let overscan = 1 + rng.next_below(6) as usize;
        let cap = (k * overscan).clamp(k, n);
        let mut tk = TopK::new(cap);
        tk.push_block(0, &quant);
        let cands = tk.into_sorted();
        let full = cands.len() == cap;
        let q_floor = cands.last().map(|s| s.score).unwrap_or(f32::NEG_INFINITY);
        let mut rerank = TopK::new(k.min(n));
        for s in &cands {
            rerank.push(s.id, exact[s.id as usize]);
        }
        if !coverage_proved(full, q_floor, eps, rerank.threshold()) {
            return true; // honest refusal → caller rescans exactly
        }
        let cset: std::collections::HashSet<u32> = cands.iter().map(|s| s.id).collect();
        topk_reference(&exact, k.min(n)).iter().all(|s| cset.contains(&s.id))
    });
}

#[test]
fn property_brute_quant_bit_parity() {
    // end-to-end: two-stage brute == f32 brute, bit for bit, across
    // random datasets, dims, quantization blocks, and overscans
    Checker::new(52).cases(12).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0xB17);
        let n = 800 + rng.next_below(1200) as usize;
        let d = [4usize, 9, 16, 33][rng.next_below(4) as usize];
        let ds = Arc::new(synth::imagenet_like(n, d, 12, 0.3, seed));
        let qblock = 1 + rng.next_below(128) as usize;
        let overscan = 1 + rng.next_below(5) as usize;
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer))
            .with_quant(qblock, overscan);
        for _ in 0..3 {
            let k = 1 + rng.next_below(80) as usize;
            let q = data::random_theta(&ds, 0.05, &mut rng);
            let got = q_idx.top_k(&q, k);
            let want = f32_idx.top_k(&q, k);
            if got.ids() != want.ids()
                || got
                    .items
                    .iter()
                    .zip(&want.items)
                    .any(|(g, w)| g.score.to_bits() != w.score.to_bits())
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn brute_quant_batch_bit_parity() {
    let ds = Arc::new(synth::imagenet_like(2_500, 24, 20, 0.3, 3));
    let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
    let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(64, 4);
    let mut rng = Pcg64::new(4);
    for nq in [2usize, 4, 7] {
        let qs_owned: Vec<Vec<f32>> =
            (0..nq).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let got = q_idx.top_k_batch(&qs, 33);
        let want = f32_idx.top_k_batch(&qs, 33);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_parity(g, w, &format!("brute batch nq={nq} query {j}"));
        }
    }
}

fn ivf_cfg(quant: QuantKind) -> IndexConfig {
    let mut cfg = Config::default().index;
    cfg.n_clusters = 35;
    cfg.n_probe = 7;
    cfg.kmeans_iters = 5;
    cfg.train_sample = 1_500;
    cfg.quant = quant;
    cfg.quant_block = 48;
    cfg.overscan = 4;
    cfg
}

#[test]
fn ivf_quant_bit_parity_through_updates_and_compaction() {
    // same build seed → same clusters/grouped storage; the SQ8 pass must
    // be invisible in the results across the whole update lifecycle
    let ds = Arc::new(synth::imagenet_like(3_500, 16, 30, 0.25, 5));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut q_idx = IvfIndex::build(ds.clone(), &ivf_cfg(QuantKind::Sq8), backend.clone()).unwrap();
    let mut f_idx = IvfIndex::build(ds.clone(), &ivf_cfg(QuantKind::Off), backend).unwrap();
    let mut rng = Pcg64::new(6);
    let phases: [(&str, bool, bool); 3] =
        [("fresh", false, false), ("pending", true, false), ("compacted", false, true)];
    let mut urng = Pcg64::new(7);
    for (label, do_updates, do_compact) in phases {
        if do_updates {
            for id in [12u32, 901, 3_333] {
                let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                q_idx.update_row(id, &v);
                f_idx.update_row(id, &v);
            }
        }
        if do_compact {
            q_idx.compact();
            f_idx.compact();
        }
        for k in [1usize, 25, 90] {
            let q = data::random_theta(&ds, 0.05, &mut rng);
            assert_parity(&q_idx.top_k(&q, k), &f_idx.top_k(&q, k), &format!("{label} k={k}"));
        }
        // batch parity against BOTH the per-query quant path and the f32 batch
        let qs_owned: Vec<Vec<f32>> =
            (0..5).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let got = q_idx.top_k_batch(&qs, 40);
        let want = f_idx.top_k_batch(&qs, 40);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_parity(g, w, &format!("{label} batch query {j}"));
            assert_parity(g, &q_idx.top_k(qs[j], 40), &format!("{label} batch-vs-single {j}"));
        }
    }
}

#[test]
fn adversarial_flat_data_stays_bit_exact() {
    // (near-)identical rows collapse quantized scores into ties; the
    // coverage certificate must either still hold or trigger the f32
    // fallback — parity is required either way. Exactly-identical rows
    // guarantee the fallback branch runs (q_floor == kth exact).
    let mut rng = Pcg64::new(8);
    let (n, d) = (600usize, 8usize);
    let base: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    for jitter in [0.0f32, 1e-6] {
        let data_flat: Vec<f32> = (0..n)
            .flat_map(|_| {
                base.iter().map(|&x| x + jitter * rng.gaussian() as f32).collect::<Vec<f32>>()
            })
            .collect();
        let ds = Arc::new(gmips::data::Dataset::new(data_flat, n, d).unwrap());
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(32, 1);
        for _ in 0..5 {
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let got = q_idx.top_k(&q, 10);
            let want = f32_idx.top_k(&q, 10);
            assert_parity(&got, &want, &format!("flat-data jitter={jitter}"));
        }
    }
}

#[test]
fn build_index_honours_quant_config() {
    let ds = Arc::new(synth::imagenet_like(1_200, 8, 10, 0.3, 9));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for (quant, name) in
        [(QuantKind::Sq8, "sq8"), (QuantKind::Sq4, "sq4"), (QuantKind::Pq, "pq(")]
    {
        let mut cfg = ivf_cfg(quant);
        cfg.kind = gmips::config::IndexKind::Brute;
        let idx = gmips::mips::build_index(&ds, &cfg, backend.clone()).unwrap();
        assert!(idx.describe().contains(name), "{}", idx.describe());
        cfg.kind = gmips::config::IndexKind::Ivf;
        let idx = gmips::mips::build_index(&ds, &cfg, backend.clone()).unwrap();
        assert!(idx.describe().contains(name), "{}", idx.describe());
    }
}

// ---------------------------------------------------------------------------
// PQ / SQ4 screening tiers (PR 5)
// ---------------------------------------------------------------------------

#[test]
fn property_new_tier_error_bounds_hold_per_row() {
    // satellite (a): the per-row PQ and SQ4 error bounds hold on random
    // data across dims, blocks/subspaces, and code widths
    Checker::new(71).cases(25).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0xF00D);
        let n = 100 + rng.next_below(400) as usize;
        let dsub = 1 + rng.next_below(5) as usize;
        let m = 1 + rng.next_below(6) as usize;
        let d = m * dsub;
        let ds = synth::imagenet_like(n, d, 8, 0.4, seed);
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let mut exact = vec![0f32; n];
        linalg::matvec_block(&ds.data, d, &q, &mut exact);
        // SQ4
        let block = 1 + rng.next_below(70) as usize;
        let sq4 = Sq4View::encode(&ds.data, d, block);
        let qq = QuantQuery::encode(&q);
        let eps4 = sq4.error_bound(&qq) as f64;
        let mut out = vec![0f32; n];
        sq4.scores(0, n, &qq, &mut out);
        for r in 0..n {
            if (exact[r] as f64 - out[r] as f64).abs() > eps4 {
                return false;
            }
        }
        // PQ at both widths
        for bits in [4usize, 8] {
            let pv = PqView::train(&ds.data, d, m, bits, n, 5, seed ^ 7);
            let lut = pv.encode_query(&q);
            let eps = pv.error_bound(&lut) as f64;
            pv.scores(0, n, &lut, &mut out);
            for r in 0..n {
                if (exact[r] as f64 - out[r] as f64).abs() > eps {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn new_tiers_bit_parity_on_brute_ivf_lsh() {
    // satellite (b): certified results are bit-identical to the f32 scan
    // on brute/IVF/LSH for every tier config, incl. update_row + compact
    let ds = Arc::new(synth::imagenet_like(3_000, 16, 25, 0.25, 31));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rng = Pcg64::new(32);
    for (quant, pq_bits) in TIERS {
        let mut qcfg = ivf_cfg(quant);
        qcfg.pq_bits = pq_bits;
        let label = format!("{}/b{pq_bits}", quant.name());
        // brute
        let fb = BruteForce::new(ds.clone(), backend.clone());
        let qb = BruteForce::new(ds.clone(), backend.clone()).with_tier_cfg(&qcfg);
        for k in [1usize, 20, 75] {
            let q = data::random_theta(&ds, 0.05, &mut rng);
            assert_parity(&qb.top_k(&q, k), &fb.top_k(&q, k), &format!("brute {label} k={k}"));
        }
        // LSH
        let mut lcfg = qcfg.clone();
        lcfg.tables = 8;
        lcfg.bits = 7;
        let mut fcfg = lcfg.clone();
        fcfg.quant = QuantKind::Off;
        let ql = SrpLsh::build(ds.clone(), &lcfg, backend.clone()).unwrap();
        let fl = SrpLsh::build(ds.clone(), &fcfg, backend.clone()).unwrap();
        for k in [1usize, 12, 40] {
            let q = data::random_theta(&ds, 0.05, &mut rng);
            assert_parity(&ql.top_k(&q, k), &fl.top_k(&q, k), &format!("lsh {label} k={k}"));
        }
        // IVF through the update lifecycle
        let mut qi = IvfIndex::build(ds.clone(), &qcfg, backend.clone()).unwrap();
        let mut fi = IvfIndex::build(ds.clone(), &ivf_cfg(QuantKind::Off), backend.clone()).unwrap();
        let mut urng = Pcg64::new(33);
        for stage in ["fresh", "pending", "compacted"] {
            if stage == "pending" {
                for id in [7u32, 811, 2_222] {
                    let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                    qi.update_row(id, &v);
                    fi.update_row(id, &v);
                }
            }
            if stage == "compacted" {
                qi.compact();
                fi.compact();
            }
            for k in [1usize, 30] {
                let q = data::random_theta(&ds, 0.05, &mut rng);
                assert_parity(
                    &qi.top_k(&q, k),
                    &fi.top_k(&q, k),
                    &format!("ivf {label} {stage} k={k}"),
                );
            }
        }
    }
}

#[test]
fn new_tiers_sharded_parity() {
    // acceptance: certified PQ/SQ4 scans return bit-identical results on
    // the sharded index too (per-shard codebooks differ from the
    // monolithic ones — the certificate contract makes that invisible)
    let ds = Arc::new(synth::imagenet_like(2_500, 16, 20, 0.3, 41));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rng = Pcg64::new(42);
    for kind in [gmips::config::IndexKind::Brute, gmips::config::IndexKind::Ivf] {
        for (quant, pq_bits) in [(QuantKind::Sq4, 8), (QuantKind::Pq, 4)] {
            let mut cfg = ivf_cfg(quant);
            cfg.kind = kind;
            cfg.pq_bits = pq_bits;
            let mono = gmips::mips::build_index(&ds, &cfg, backend.clone()).unwrap();
            cfg.shards = 3;
            let idx = ShardedIndex::build(&ds, &cfg, backend.clone()).unwrap();
            let label = format!("{:?} {}/b{pq_bits}", kind, quant.name());
            for k in [1usize, 25, 70] {
                let q = data::random_theta(&ds, 0.05, &mut rng);
                assert_parity(&idx.top_k(&q, k), &mono.top_k(&q, k), &format!("{label} k={k}"));
            }
            let qs_owned: Vec<Vec<f32>> =
                (0..5).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
            let got = idx.top_k_batch(&qs, 21);
            let want = mono.top_k_batch(&qs, 21);
            for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_parity(g, w, &format!("{label} batch q{j}"));
            }
        }
    }
}

#[test]
fn adversarial_flat_data_walks_the_ladder() {
    // satellite (c): (near-)identical rows collapse quantized scores into
    // ties on EVERY tier — the ladder must keep falling (PQ/SQ4 → SQ8 →
    // f32) and end bit-exact regardless of which rung certifies
    let mut rng = Pcg64::new(51);
    let (n, d) = (600usize, 8usize);
    let base: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    for jitter in [0.0f32, 1e-6] {
        let data_flat: Vec<f32> = (0..n)
            .flat_map(|_| {
                base.iter().map(|&x| x + jitter * rng.gaussian() as f32).collect::<Vec<f32>>()
            })
            .collect();
        let ds = Arc::new(gmips::data::Dataset::new(data_flat, n, d).unwrap());
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        for (quant, pq_bits) in TIERS {
            let mut cfg = ivf_cfg(quant);
            cfg.pq_bits = pq_bits;
            cfg.quant_block = 32;
            cfg.overscan = 1;
            let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_tier_cfg(&cfg);
            for _ in 0..3 {
                let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                let got = q_idx.top_k(&q, 10);
                let want = f32_idx.top_k(&q, 10);
                assert_parity(
                    &got,
                    &want,
                    &format!("flat jitter={jitter} {}/b{pq_bits}", quant.name()),
                );
            }
        }
    }
}

#[test]
fn pq_fastscan_batches_bit_parity_through_update_and_compact() {
    // PR 10 acceptance: batches of ≥ 4 queries on the 4-bit PQ tier ride
    // the register-resident fast-scan tiles (`PqView::scores_batch`
    // dispatches internally), and results stay bit-identical to the
    // f32-only scan on brute, IVF — through update_row + compact, which
    // re-blocks the tiles — and the sharded index.
    let ds = Arc::new(synth::imagenet_like(3_000, 16, 25, 0.25, 71));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut cfg = ivf_cfg(QuantKind::Pq);
    cfg.pq_bits = 4;
    let mut rng = Pcg64::new(72);
    // the trained tier really carries tiles at this shape
    let pv = PqView::train(&ds.data, ds.d, 2, 4, 1_024, 5, 73);
    assert!(pv.serves_fastscan(8) && !pv.serves_fastscan(3));
    let batch8 = |rng: &mut Pcg64| -> Vec<Vec<f32>> {
        (0..8).map(|_| data::random_theta(&ds, 0.05, rng)).collect()
    };
    // brute
    let fb = BruteForce::new(ds.clone(), backend.clone());
    let qb = BruteForce::new(ds.clone(), backend.clone()).with_tier_cfg(&cfg);
    let qs_owned = batch8(&mut rng);
    let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
    let got = qb.top_k_batch(&qs, 25);
    let want = fb.top_k_batch(&qs, 25);
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_parity(g, w, &format!("brute fastscan q{j}"));
    }
    // IVF through the update lifecycle (compact() re-encodes → re-tiles)
    let mut qi = IvfIndex::build(ds.clone(), &cfg, backend.clone()).unwrap();
    let mut fi = IvfIndex::build(ds.clone(), &ivf_cfg(QuantKind::Off), backend.clone()).unwrap();
    let mut urng = Pcg64::new(74);
    for stage in ["fresh", "pending", "compacted"] {
        if stage == "pending" {
            for id in [5u32, 1_024, 2_900] {
                let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                qi.update_row(id, &v);
                fi.update_row(id, &v);
            }
        }
        if stage == "compacted" {
            qi.compact();
            fi.compact();
        }
        let qs_owned = batch8(&mut rng);
        let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
        let got = qi.top_k_batch(&qs, 30);
        let want = fi.top_k_batch(&qs, 30);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_parity(g, w, &format!("ivf fastscan {stage} q{j}"));
            assert_parity(g, &qi.top_k(qs[j], 30), &format!("ivf fastscan {stage} single q{j}"));
        }
    }
    // sharded (per-shard codebooks + per-shard tiles)
    let mono = gmips::mips::build_index(&ds, &cfg, backend.clone()).unwrap();
    let mut scfg = cfg.clone();
    scfg.shards = 3;
    let sharded = ShardedIndex::build(&ds, &scfg, backend.clone()).unwrap();
    let qs_owned = batch8(&mut rng);
    let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
    let got = sharded.top_k_batch(&qs, 21);
    let want = mono.top_k_batch(&qs, 21);
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_parity(g, w, &format!("sharded fastscan q{j}"));
    }
}

#[test]
fn multi_query_batches_bit_identical_to_singles_on_all_tiers() {
    // satellite (d): the batched (register-blocked / shared-LUT) kernels
    // drive top_k_batch to exactly the per-query results on every tier
    let ds = Arc::new(synth::imagenet_like(2_000, 24, 18, 0.3, 61));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rng = Pcg64::new(62);
    for (quant, pq_bits) in TIERS {
        let mut cfg = ivf_cfg(quant);
        cfg.pq_bits = pq_bits;
        let qb = BruteForce::new(ds.clone(), backend.clone()).with_tier_cfg(&cfg);
        let qi = IvfIndex::build(ds.clone(), &cfg, backend.clone()).unwrap();
        for nq in [2usize, 5, 9] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
            for (name, batch) in
                [("brute", qb.top_k_batch(&qs, 27)), ("ivf", qi.top_k_batch(&qs, 27))]
            {
                for (j, got) in batch.iter().enumerate() {
                    let want = if name == "brute" {
                        qb.top_k(qs[j], 27)
                    } else {
                        qi.top_k(qs[j], 27)
                    };
                    assert_parity(
                        got,
                        &want,
                        &format!("{name} {}/b{pq_bits} nq={nq} q{j}", quant.name()),
                    );
                }
            }
        }
    }
}
