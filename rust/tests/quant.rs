//! SQ8 two-stage scan invariants (Checker-driven): the quantized
//! screening pass is a pure bandwidth optimization — pass 1 must always
//! retain the exact top-k (coverage), and the end-to-end `top_k` /
//! `top_k_batch` results must be bit-identical to the f32-only scan on
//! brute and IVF, including through sparse updates and compaction.

use gmips::config::{Config, IndexConfig};
use gmips::data::{self, synth};
use gmips::linalg::{self, quant::*};
use gmips::mips::brute::BruteForce;
use gmips::mips::ivf::IvfIndex;
use gmips::mips::{MipsIndex, TopKResult};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::util::check::Checker;
use gmips::util::rng::Pcg64;
use gmips::util::topk::{topk_reference, TopK};
use std::sync::Arc;

/// Bit-level result parity: same ids AND same f32 score bits.
fn assert_parity(got: &TopKResult, want: &TopKResult, label: &str) {
    assert_eq!(got.ids(), want.ids(), "{label}: ids diverge");
    for (g, w) in got.items.iter().zip(&want.items) {
        assert_eq!(g.score.to_bits(), w.score.to_bits(), "{label}: scores diverge");
    }
    assert_eq!(got.scanned, want.scanned, "{label}: scanned accounting diverges");
}

#[test]
fn property_exact_topk_always_inside_overscan_candidates() {
    // the coverage contract: for random datasets/dims/blocks, whenever
    // the coverage certificate fires, the exact top-k ids are a subset
    // of the pass-1 overscan candidate set (otherwise the pass honestly
    // reports failure and the caller rescans exactly)
    Checker::new(51).cases(50).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0x5EED);
        let n = 200 + rng.next_below(800) as usize;
        let d = 1 + rng.next_below(48) as usize;
        let block = 1 + rng.next_below(96) as usize;
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        let qv = QuantView::encode(&rows, d, block);
        let qq = QuantQuery::encode(&q);
        let eps = qv.error_bound(&qq);
        let mut quant = vec![0f32; n];
        qv.scores(0, n, &qq, &mut quant);
        let mut exact = vec![0f32; n];
        linalg::matvec_block(&rows, d, &q, &mut exact);
        let k = 1 + rng.next_below(32) as usize;
        let overscan = 1 + rng.next_below(6) as usize;
        let cap = (k * overscan).clamp(k, n);
        let mut tk = TopK::new(cap);
        tk.push_block(0, &quant);
        let cands = tk.into_sorted();
        let full = cands.len() == cap;
        let q_floor = cands.last().map(|s| s.score).unwrap_or(f32::NEG_INFINITY);
        let mut rerank = TopK::new(k.min(n));
        for s in &cands {
            rerank.push(s.id, exact[s.id as usize]);
        }
        if !coverage_proved(full, q_floor, eps, rerank.threshold()) {
            return true; // honest refusal → caller rescans exactly
        }
        let cset: std::collections::HashSet<u32> = cands.iter().map(|s| s.id).collect();
        topk_reference(&exact, k.min(n)).iter().all(|s| cset.contains(&s.id))
    });
}

#[test]
fn property_brute_quant_bit_parity() {
    // end-to-end: two-stage brute == f32 brute, bit for bit, across
    // random datasets, dims, quantization blocks, and overscans
    Checker::new(52).cases(12).check_u64(1u64 << 32, |seed| {
        let mut rng = Pcg64::new(seed ^ 0xB17);
        let n = 800 + rng.next_below(1200) as usize;
        let d = [4usize, 9, 16, 33][rng.next_below(4) as usize];
        let ds = Arc::new(synth::imagenet_like(n, d, 12, 0.3, seed));
        let qblock = 1 + rng.next_below(128) as usize;
        let overscan = 1 + rng.next_below(5) as usize;
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer))
            .with_quant(qblock, overscan);
        for _ in 0..3 {
            let k = 1 + rng.next_below(80) as usize;
            let q = data::random_theta(&ds, 0.05, &mut rng);
            let got = q_idx.top_k(&q, k);
            let want = f32_idx.top_k(&q, k);
            if got.ids() != want.ids()
                || got
                    .items
                    .iter()
                    .zip(&want.items)
                    .any(|(g, w)| g.score.to_bits() != w.score.to_bits())
            {
                return false;
            }
        }
        true
    });
}

#[test]
fn brute_quant_batch_bit_parity() {
    let ds = Arc::new(synth::imagenet_like(2_500, 24, 20, 0.3, 3));
    let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
    let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(64, 4);
    let mut rng = Pcg64::new(4);
    for nq in [2usize, 4, 7] {
        let qs_owned: Vec<Vec<f32>> =
            (0..nq).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let got = q_idx.top_k_batch(&qs, 33);
        let want = f32_idx.top_k_batch(&qs, 33);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_parity(g, w, &format!("brute batch nq={nq} query {j}"));
        }
    }
}

fn ivf_cfg(quant: bool) -> IndexConfig {
    let mut cfg = Config::default().index;
    cfg.n_clusters = 35;
    cfg.n_probe = 7;
    cfg.kmeans_iters = 5;
    cfg.train_sample = 1_500;
    cfg.quant = quant;
    cfg.quant_block = 48;
    cfg.overscan = 4;
    cfg
}

#[test]
fn ivf_quant_bit_parity_through_updates_and_compaction() {
    // same build seed → same clusters/grouped storage; the SQ8 pass must
    // be invisible in the results across the whole update lifecycle
    let ds = Arc::new(synth::imagenet_like(3_500, 16, 30, 0.25, 5));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut q_idx = IvfIndex::build(ds.clone(), &ivf_cfg(true), backend.clone()).unwrap();
    let mut f_idx = IvfIndex::build(ds.clone(), &ivf_cfg(false), backend).unwrap();
    let mut rng = Pcg64::new(6);
    let phases: [(&str, bool, bool); 3] =
        [("fresh", false, false), ("pending", true, false), ("compacted", false, true)];
    let mut urng = Pcg64::new(7);
    for (label, do_updates, do_compact) in phases {
        if do_updates {
            for id in [12u32, 901, 3_333] {
                let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                q_idx.update_row(id, &v);
                f_idx.update_row(id, &v);
            }
        }
        if do_compact {
            q_idx.compact();
            f_idx.compact();
        }
        for k in [1usize, 25, 90] {
            let q = data::random_theta(&ds, 0.05, &mut rng);
            assert_parity(&q_idx.top_k(&q, k), &f_idx.top_k(&q, k), &format!("{label} k={k}"));
        }
        // batch parity against BOTH the per-query quant path and the f32 batch
        let qs_owned: Vec<Vec<f32>> =
            (0..5).map(|_| data::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let got = q_idx.top_k_batch(&qs, 40);
        let want = f_idx.top_k_batch(&qs, 40);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_parity(g, w, &format!("{label} batch query {j}"));
            assert_parity(g, &q_idx.top_k(qs[j], 40), &format!("{label} batch-vs-single {j}"));
        }
    }
}

#[test]
fn adversarial_flat_data_stays_bit_exact() {
    // (near-)identical rows collapse quantized scores into ties; the
    // coverage certificate must either still hold or trigger the f32
    // fallback — parity is required either way. Exactly-identical rows
    // guarantee the fallback branch runs (q_floor == kth exact).
    let mut rng = Pcg64::new(8);
    let (n, d) = (600usize, 8usize);
    let base: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    for jitter in [0.0f32, 1e-6] {
        let data_flat: Vec<f32> = (0..n)
            .flat_map(|_| {
                base.iter().map(|&x| x + jitter * rng.gaussian() as f32).collect::<Vec<f32>>()
            })
            .collect();
        let ds = Arc::new(gmips::data::Dataset::new(data_flat, n, d).unwrap());
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let q_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(32, 1);
        for _ in 0..5 {
            let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
            let got = q_idx.top_k(&q, 10);
            let want = f32_idx.top_k(&q, 10);
            assert_parity(&got, &want, &format!("flat-data jitter={jitter}"));
        }
    }
}

#[test]
fn build_index_honours_quant_config() {
    let ds = Arc::new(synth::imagenet_like(1_200, 8, 10, 0.3, 9));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut cfg = ivf_cfg(true);
    cfg.kind = gmips::config::IndexKind::Brute;
    let idx = gmips::mips::build_index(&ds, &cfg, backend.clone()).unwrap();
    assert!(idx.describe().contains("sq8"), "{}", idx.describe());
    cfg.kind = gmips::config::IndexKind::Ivf;
    let idx = gmips::mips::build_index(&ds, &cfg, backend).unwrap();
    assert!(idx.describe().contains("sq8"), "{}", idx.describe());
}
