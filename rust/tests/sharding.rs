//! Sharding invariants: a `ShardedIndex` with `N` shards must be
//! **bit-identical** (ids, f32 score bits, and `scanned` accounting) to
//! the monolithic index — and to itself at any other shard count — on
//! brute, IVF (shared coarse quantizer) and SRP-LSH (shared norm bound),
//! for single queries and batches, through sparse updates and
//! compaction, and with the SQ8 screen on. On top of the index parity,
//! the samplers/estimators driven through a sharded index must be
//! shard-count invariant too: the plain Algorithm 1/3 consume their RNG
//! identically because the merged top set is identical, and the sharded
//! sampler's id-keyed frozen Gumbel streams make the *sample* itself
//! invariant by construction.

use gmips::config::{Config, IndexConfig, IndexKind, QuantKind, ShardStrategy};
use gmips::data::{self, synth, Dataset};
use gmips::mips::brute::BruteForce;
use gmips::mips::ivf::IvfIndex;
use gmips::mips::lsh::SrpLsh;
use gmips::mips::{MipsIndex, TopKResult};
use gmips::prelude::{LazyGumbelSampler, PartitionEstimator, Sampler};
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::shard::{ShardedGumbelSampler, ShardedIndex};
use gmips::util::rng::Pcg64;
use std::sync::Arc;

/// Bit-level result parity: same ids AND same f32 score bits.
fn assert_parity(got: &TopKResult, want: &TopKResult, label: &str) {
    assert_eq!(got.ids(), want.ids(), "{label}: ids diverge");
    for (g, w) in got.items.iter().zip(&want.items) {
        assert_eq!(g.score.to_bits(), w.score.to_bits(), "{label}: scores diverge");
    }
    assert_eq!(got.scanned, want.scanned, "{label}: scanned accounting diverges");
}

fn base_cfg(kind: IndexKind) -> IndexConfig {
    let mut c = Config::default().index;
    c.kind = kind;
    c.n_clusters = 36;
    c.n_probe = 7;
    c.kmeans_iters = 5;
    c.train_sample = 2000;
    c.tables = 8;
    c.bits = 7;
    c
}

fn sharded(
    ds: &Arc<Dataset>,
    cfg: &IndexConfig,
    shards: usize,
    strategy: ShardStrategy,
    backend: &Arc<dyn ScoreBackend>,
) -> ShardedIndex {
    let mut c = cfg.clone();
    c.shards = shards;
    c.shard_strategy = strategy;
    ShardedIndex::build(ds, &c, backend.clone()).unwrap()
}

const STRATEGIES: [ShardStrategy; 2] = [ShardStrategy::RoundRobin, ShardStrategy::Contiguous];

#[test]
fn brute_shard_parity_single_and_batch() {
    let ds = Arc::new(synth::imagenet_like(3000, 16, 25, 0.3, 1));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for quant in [QuantKind::Off, QuantKind::Sq8, QuantKind::Sq4, QuantKind::Pq] {
        let mut cfg = base_cfg(IndexKind::Brute);
        cfg.quant = quant;
        cfg.pq_bits = 4;
        let mono = if quant.enabled() {
            BruteForce::new(ds.clone(), backend.clone()).with_tier_cfg(&cfg)
        } else {
            BruteForce::new(ds.clone(), backend.clone())
        };
        let mut rng = Pcg64::new(2);
        for strategy in STRATEGIES {
            for shards in [1usize, 2, 5] {
                let idx = sharded(&ds, &cfg, shards, strategy, &backend);
                for k in [1usize, 17, 80] {
                    let q = synth::random_theta(&ds, 0.05, &mut rng);
                    let label = format!("brute quant={} {strategy:?} N={shards} k={k}", quant.name());
                    assert_parity(&idx.top_k(&q, k), &mono.top_k(&q, k), &label);
                }
                // batch path vs monolithic batch
                let qs_owned: Vec<Vec<f32>> =
                    (0..5).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
                let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
                let got = idx.top_k_batch(&qs, 23);
                let want = mono.top_k_batch(&qs, 23);
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    let label = format!("brute batch quant={} {strategy:?} N={shards} q{j}", quant.name());
                    assert_parity(g, w, &label);
                }
            }
        }
    }
}

#[test]
fn ivf_shard_parity_through_updates_and_compaction() {
    let ds = Arc::new(synth::imagenet_like(4000, 16, 30, 0.25, 3));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for quant in [QuantKind::Off, QuantKind::Sq8, QuantKind::Pq] {
        let mut cfg = base_cfg(IndexKind::Ivf);
        cfg.quant = quant;
        cfg.pq_bits = 4;
        for strategy in STRATEGIES {
            // fresh pair per strategy: updates/compaction mutate state
            let mut mono = IvfIndex::build(ds.clone(), &cfg, backend.clone()).unwrap();
            let mut idx = sharded(&ds, &cfg, 4, strategy, &backend);
            let mut rng = Pcg64::new(4);
            let check = |idx: &ShardedIndex, mono: &IvfIndex, rng: &mut Pcg64, stage: &str| {
                for k in [1usize, 20, 60] {
                    let q = synth::random_theta(&ds, 0.05, rng);
                    let label = format!("ivf quant={} {strategy:?} {stage} k={k}", quant.name());
                    assert_parity(&idx.top_k(&q, k), &mono.top_k(&q, k), &label);
                }
                let qs_owned: Vec<Vec<f32>> =
                    (0..6).map(|_| synth::random_theta(&ds, 0.05, rng)).collect();
                let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
                let got = idx.top_k_batch(&qs, 25);
                let want = mono.top_k_batch(&qs, 25);
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    let label =
                        format!("ivf batch quant={} {strategy:?} {stage} q{j}", quant.name());
                    assert_parity(g, w, &label);
                }
            };
            check(&idx, &mono, &mut rng, "fresh");
            // identical sparse updates on both indexes (global ids route
            // through the shard map)
            let mut urng = Pcg64::new(5);
            for id in [9u32, 777, 2500, 3999] {
                let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.3).collect();
                idx.update_row(id, &v);
                mono.update_row(id, &v);
            }
            assert_eq!(idx.pending_len(), 4);
            check(&idx, &mono, &mut rng, "pending");
            idx.compact();
            mono.compact();
            assert_eq!(idx.pending_len(), 0);
            check(&idx, &mono, &mut rng, "compacted");
        }
    }
}

#[test]
fn lsh_shard_parity() {
    let ds = Arc::new(synth::imagenet_like(3000, 12, 25, 0.3, 7));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    for quant in [QuantKind::Off, QuantKind::Sq4, QuantKind::Pq] {
        let mut cfg = base_cfg(IndexKind::Lsh);
        cfg.quant = quant;
        cfg.pq_bits = 4;
        let mono = SrpLsh::build(ds.clone(), &cfg, backend.clone()).unwrap();
        let mut rng = Pcg64::new(8);
        for strategy in STRATEGIES {
            for shards in [2usize, 3] {
                let idx = sharded(&ds, &cfg, shards, strategy, &backend);
                for k in [1usize, 15, 50] {
                    let q = synth::random_theta(&ds, 0.05, &mut rng);
                    let label = format!("lsh quant={} {strategy:?} N={shards} k={k}", quant.name());
                    assert_parity(&idx.top_k(&q, k), &mono.top_k(&q, k), &label);
                }
                let qs_owned: Vec<Vec<f32>> =
                    (0..4).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
                let qs: Vec<&[f32]> = qs_owned.iter().map(|v| v.as_slice()).collect();
                let got = idx.top_k_batch(&qs, 18);
                let want = mono.top_k_batch(&qs, 18);
                for (j, (g, w)) in got.iter().zip(&want).enumerate() {
                    let label = format!("lsh batch quant={} {strategy:?} N={shards} q{j}", quant.name());
                    assert_parity(g, w, &label);
                }
            }
        }
    }
}

#[test]
fn tiered_shards_return_full_k_with_gap_bound() {
    // tiered LSH makes no parity claim under sharding (the ladder walk
    // stops on shard-local counts) — but it must stay a well-formed
    // approximate index: k results, merged gap bound
    let ds = Arc::new(synth::imagenet_like(2000, 12, 20, 0.3, 9));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut cfg = base_cfg(IndexKind::Tiered);
    cfg.rungs = 6;
    cfg.bits = 12;
    let idx = sharded(&ds, &cfg, 3, ShardStrategy::RoundRobin, &backend);
    let mut rng = Pcg64::new(10);
    let q = synth::random_theta(&ds, 0.05, &mut rng);
    for k in [1usize, 40, 200] {
        let got = idx.top_k(&q, k);
        assert_eq!(got.items.len(), k, "k={k}");
    }
    assert!(idx.gap_bound().unwrap() >= 0.0);
}

#[test]
fn lazy_sampler_and_estimator_are_shard_count_invariant() {
    // the plain Algorithm 1 sampler / Algorithm 3 estimator consume their
    // sequential RNG identically over a sharded index because the merged
    // top set is bit-identical — so shard=1 and shard=4 give the same
    // samples and the same log Ẑ bits under the same seed
    let ds = Arc::new(synth::imagenet_like(2500, 12, 20, 0.3, 11));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let cfg = base_cfg(IndexKind::Ivf);
    let one: Arc<dyn MipsIndex> =
        Arc::new(sharded(&ds, &cfg, 1, ShardStrategy::RoundRobin, &backend));
    let four: Arc<dyn MipsIndex> =
        Arc::new(sharded(&ds, &cfg, 4, ShardStrategy::Contiguous, &backend));
    let mut qrng = Pcg64::new(12);
    let q = synth::random_theta(&ds, 0.05, &mut qrng);

    let s1 = LazyGumbelSampler::new(ds.clone(), one.clone(), backend.clone(), 60, 0.0);
    let s4 = LazyGumbelSampler::new(ds.clone(), four.clone(), backend.clone(), 60, 0.0);
    let mut r1 = Pcg64::new(13);
    let mut r4 = Pcg64::new(13);
    let a: Vec<u32> = s1.sample_many(&q, 50, &mut r1).iter().map(|o| o.id).collect();
    let b: Vec<u32> = s4.sample_many(&q, 50, &mut r4).iter().map(|o| o.id).collect();
    assert_eq!(a, b, "Algorithm 1 over sharded index must be shard-count invariant");

    let e1 = PartitionEstimator::new(ds.clone(), one, backend.clone(), 50, 50);
    let e4 = PartitionEstimator::new(ds.clone(), four, backend.clone(), 50, 50);
    let mut r1 = Pcg64::new(14);
    let mut r4 = Pcg64::new(14);
    for i in 0..10 {
        let za = e1.estimate(&q, &mut r1).log_z;
        let zb = e4.estimate(&q, &mut r4).log_z;
        assert_eq!(za.to_bits(), zb.to_bits(), "estimate {i}");
    }
}

#[test]
fn sharded_gumbel_sampler_bit_identical_across_shard_counts() {
    // the tentpole guarantee: id-keyed frozen Gumbel streams make the
    // sharded sampler's draws identical for shard=1 and shard=N, across
    // strategies, round by round
    let ds = Arc::new(synth::imagenet_like(2000, 12, 20, 0.3, 15));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let cfg = base_cfg(IndexKind::Brute);
    let mut qrng = Pcg64::new(16);
    let q = synth::random_theta(&ds, 0.05, &mut qrng);
    let seed = 1234u64;
    let reference: Vec<u32> = {
        let idx = Arc::new(sharded(&ds, &cfg, 1, ShardStrategy::RoundRobin, &backend));
        let s = ShardedGumbelSampler::new(ds.clone(), idx, backend.clone(), 45, 0.0, seed);
        let sess = s.session(&q);
        (0..300).map(|r| s.sample_at(&sess, &q, r).id).collect()
    };
    for strategy in STRATEGIES {
        for shards in [2usize, 4, 7] {
            let idx = Arc::new(sharded(&ds, &cfg, shards, strategy, &backend));
            let s = ShardedGumbelSampler::new(ds.clone(), idx, backend.clone(), 45, 0.0, seed);
            let sess = s.session(&q);
            let got: Vec<u32> = (0..300).map(|r| s.sample_at(&sess, &q, r).id).collect();
            assert_eq!(got, reference, "{strategy:?} N={shards}");
        }
    }
}

#[test]
fn sharded_index_via_build_and_engine_paths() {
    // end-to-end construction wiring: build_index dispatches on
    // index.shards, and the engine serves every op over the sharded index
    use gmips::coordinator::{Engine, Request, Response};
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.data.n = 2500;
    cfg.data.d = 12;
    cfg.index.kind = IndexKind::Ivf;
    cfg.index.n_clusters = 30;
    cfg.index.n_probe = 8;
    cfg.index.kmeans_iters = 3;
    cfg.index.train_sample = 1200;
    cfg.index.shards = 4;
    cfg.validate().unwrap();
    let engine = Engine::from_config(&cfg, None).unwrap();
    assert_eq!(engine.index.name(), "sharded");
    assert!(engine.index.describe().contains("sharded[4×ivf"));
    let mut rng = Pcg64::new(17);
    let theta = data::random_theta(&engine.ds, 0.05, &mut rng);
    match engine.handle(&Request::Sample { theta: theta.clone(), count: 3 }, &mut rng) {
        Response::Samples { ids, .. } => assert_eq!(ids.len(), 3),
        other => panic!("{other:?}"),
    }
    match engine.handle(&Request::TopK { theta: theta.clone(), k: 9 }, &mut rng) {
        Response::TopK { ids, .. } => assert_eq!(ids.len(), 9),
        other => panic!("{other:?}"),
    }
    match engine.handle(&Request::LogPartition { theta }, &mut rng) {
        Response::LogPartition { log_z, .. } => assert!(log_z.is_finite()),
        other => panic!("{other:?}"),
    }
}
