//! Gumbel perturbation machinery — the paper's §2.2 and the lazy-tail
//! construction inside Algorithms 1 and 2.
//!
//! The Gumbel-max trick (Proposition 2.1): for i.i.d. standard Gumbels
//! `G_i`, `argmax_i (y_i + G_i)` is a categorical sample with
//! `Pr(i) ∝ exp(y_i)`. The paper's contribution is to instantiate only the
//! Gumbels that can matter: fresh Gumbels for the top-k set `S`, plus the
//! *lazily sampled* tail Gumbels exceeding a cutoff `B`.
//!
//! [`sample_tail`] implements the lazy tail: the number of tail Gumbels
//! above `B` is `m ~ Binomial(n_tail, 1 − F(B))` (exact, geometric-skip
//! sampler), their positions are a uniform draw from the tail, and their
//! values are i.i.d. truncated Gumbels `G | G > B` — together distributed
//! identically to "sample all `n_tail` Gumbels, keep those above `B`".

use crate::util::rng::Pcg64;
#[cfg(test)]
use crate::util::rng::gumbel_cdf;
use rustc_hash::FxHashSet;

/// Lazily-materialized tail Gumbels above a cutoff.
#[derive(Clone, Debug, Default)]
pub struct TailDraw {
    /// dataset ids of the tail points that received a large Gumbel
    pub ids: Vec<u32>,
    /// their Gumbel values (all `> b`)
    pub gumbels: Vec<f64>,
}

impl TailDraw {
    pub fn m(&self) -> usize {
        self.ids.len()
    }
}

/// Probability that a standard Gumbel exceeds `b`, computed stably:
/// `1 − exp(−exp(−b)) = −expm1(−exp(−b))`.
#[inline]
pub fn tail_prob(b: f64) -> f64 {
    -(-(-b).exp()).exp_m1()
}

/// The fixed cutoff of Algorithm 2: `B = −ln(−ln(1 − l/n))`, chosen so the
/// expected number of tail Gumbels above `B` is `l`.
#[inline]
pub fn fixed_cutoff(n: usize, l: usize) -> f64 {
    let frac = (l as f64 / n as f64).min(1.0 - 1e-12);
    // 1 - F(B) = frac  =>  B = -ln(-ln(1-frac))
    -(-(1.0 - frac).ln()).ln()
}

/// Sample the lazy tail for cutoff `b`: which of the `n − |exclude|`
/// non-top points receive a Gumbel above `b`, and those Gumbel values.
///
/// `n` is the total state count; `exclude` is the top set `S` (tail =
/// `[0,n) \ exclude`). Expected cost `O(E[m])`; Theorem 3.2 bounds
/// `E[m] ≤ n·e^c / k` for Algorithm 1's data-dependent cutoff.
pub fn sample_tail(n: usize, exclude: &FxHashSet<u32>, b: f64, rng: &mut Pcg64) -> TailDraw {
    let n_tail = n - exclude.len();
    let p = tail_prob(b);
    let m = rng.binomial(n_tail as u64, p) as usize;
    let m = m.min(n_tail);
    let ids = rng.distinct_excluding(n as u64, m, exclude);
    let gumbels = (0..m).map(|_| rng.gumbel_above(b)).collect();
    TailDraw { ids, gumbels }
}

/// Perturb the top set: `argmax_{i∈S} (y_i + G_i)` with fresh Gumbels,
/// returning `(argmax id, max value, per-element Gumbels)` — callers also
/// need `M = max` to form the cutoff `B = M − S_min` (Algorithm 1).
pub fn perturb_top(ids: &[u32], scores: &[f64], rng: &mut Pcg64) -> (u32, f64) {
    debug_assert_eq!(ids.len(), scores.len());
    debug_assert!(!ids.is_empty());
    let mut best_id = ids[0];
    let mut best = f64::NEG_INFINITY;
    for (&id, &y) in ids.iter().zip(scores) {
        let v = y + rng.gumbel();
        if v > best {
            best = v;
            best_id = id;
        }
    }
    (best_id, best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_prob_matches_cdf() {
        for &b in &[-2.0, 0.0, 1.0, 5.0, 20.0] {
            let direct = 1.0 - gumbel_cdf(b);
            let stable = tail_prob(b);
            assert!(
                (direct - stable).abs() <= 1e-12 + 1e-9 * direct,
                "b={b}: {direct} vs {stable}"
            );
        }
        // deep tail where the naive form underflows to 0
        let p = tail_prob(40.0);
        assert!(p > 0.0 && p < 1e-15);
    }

    #[test]
    fn fixed_cutoff_inverts_tail_prob() {
        let (n, l) = (100_000usize, 300usize);
        let b = fixed_cutoff(n, l);
        let p = tail_prob(b);
        assert!((p - l as f64 / n as f64).abs() < 1e-12);
    }

    #[test]
    fn sample_tail_count_distribution() {
        // E[m] = n_tail · p; check the empirical mean over repetitions
        let mut rng = Pcg64::new(1);
        let n = 50_000usize;
        let exclude: FxHashSet<u32> = (0..500u32).collect();
        let l = 200usize;
        let b = fixed_cutoff(n, l);
        let p = tail_prob(b);
        let want = (n - 500) as f64 * p;
        let reps = 300;
        let mut total = 0usize;
        for _ in 0..reps {
            let t = sample_tail(n, &exclude, b, &mut rng);
            assert_eq!(t.ids.len(), t.gumbels.len());
            assert!(t.gumbels.iter().all(|&g| g > b));
            assert!(t.ids.iter().all(|id| !exclude.contains(id)));
            // distinct ids
            let uniq: FxHashSet<u32> = t.ids.iter().copied().collect();
            assert_eq!(uniq.len(), t.ids.len());
            total += t.m();
        }
        let mean = total as f64 / reps as f64;
        let sd = (want / reps as f64).sqrt() * 4.0 + 1.0;
        assert!((mean - want).abs() < sd.max(want * 0.15), "mean={mean} want={want}");
    }

    #[test]
    fn lazy_tail_equals_dense_tail_in_distribution() {
        // The lazy construction must match "draw all tail Gumbels, keep
        // those > B" — compare the distribution of the *tail maximum*.
        let mut rng = Pcg64::new(2);
        let n = 2_000usize;
        let exclude: FxHashSet<u32> = FxHashSet::default();
        let b = fixed_cutoff(n, 50);
        let reps = 4_000;
        let mut lazy_max = Vec::with_capacity(reps);
        let mut dense_max = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = sample_tail(n, &exclude, b, &mut rng);
            lazy_max.push(
                t.gumbels.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            );
            let dm = (0..n)
                .map(|_| rng.gumbel())
                .filter(|&g| g > b)
                .fold(f64::NEG_INFINITY, f64::max);
            dense_max.push(dm);
        }
        // Both sequences should have the same distribution: compare means
        // over the finite (non-empty) draws and the empty-draw frequency.
        let finite = |xs: &[f64]| {
            let f: Vec<f64> = xs.iter().cloned().filter(|x| x.is_finite()).collect();
            (f.iter().sum::<f64>() / f.len() as f64, f.len())
        };
        let (ml, nl) = finite(&lazy_max);
        let (md, nd) = finite(&dense_max);
        assert!((ml - md).abs() < 0.05, "lazy mean {ml} dense mean {md}");
        let (el, ed) = (reps - nl, reps - nd);
        assert!(
            ((el as f64) - (ed as f64)).abs() < 4.0 * (el.max(ed).max(1) as f64).sqrt(),
            "empty-draw counts {el} vs {ed}"
        );
    }

    #[test]
    fn perturb_top_prefers_high_scores() {
        let mut rng = Pcg64::new(3);
        let ids = vec![10u32, 20, 30];
        let scores = vec![0.0, 10.0, 0.0]; // middle dominates
        let mut wins = 0;
        for _ in 0..1000 {
            let (id, m) = perturb_top(&ids, &scores, &mut rng);
            assert!(m.is_finite());
            if id == 20 {
                wins += 1;
            }
        }
        assert!(wins > 990, "wins={wins}");
    }

    #[test]
    fn gumbel_max_trick_samples_softmax() {
        // Proposition 2.1 smoke test on a 4-element distribution.
        let mut rng = Pcg64::new(4);
        let ids = vec![0u32, 1, 2, 3];
        let y = [1.0f64, 0.0, 2.0, -1.0];
        let z: f64 = y.iter().map(|v| v.exp()).sum();
        let want: Vec<f64> = y.iter().map(|v| v.exp() / z).collect();
        let mut counts = [0f64; 4];
        let reps = 200_000;
        for _ in 0..reps {
            let (id, _) = perturb_top(&ids, &y, &mut rng);
            counts[id as usize] += 1.0;
        }
        for i in 0..4 {
            let got = counts[i] / reps as f64;
            assert!((got - want[i]).abs() < 0.005, "i={i} got={got} want={}", want[i]);
        }
    }
}
