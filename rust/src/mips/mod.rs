//! Maximum Inner Product Search (paper §2.3, §3.4).
//!
//! The amortization engine: preprocess the fixed feature database once,
//! then answer `top_k(θ)` queries in sublinear time. Implementations:
//!
//! * [`brute::BruteForce`] — exact `O(n·d)` scan (the paper's baseline and
//!   the correctness oracle),
//! * [`ivf::IvfIndex`] — k-means clustering index with `n_probe` probing
//!   (Douze et al. 2016; what the paper's experiments use),
//! * [`lsh::SrpLsh`] — signed-random-projection LSH (Charikar 2002) with
//!   the Neyshabur–Srebro MIPS→cosine reduction,
//! * [`tiered::TieredLsh`] — the ladder of LSH instances of Theorem 3.6
//!   returning *approximate top-k* sets with a bounded gap `c`
//!   (Definition 3.1).

pub mod brute;
pub mod ivf;
pub mod kmeans;
pub mod lsh;
pub mod tiered;
pub mod two_stage;

use crate::config::{IndexConfig, IndexKind};
use crate::data::Dataset;
use crate::error::Result;
use crate::scorer::ScoreBackend;
use crate::util::topk::{Scored, TopK};
use std::sync::Arc;

/// Result of a top-k query.
#[derive(Clone, Debug, Default)]
pub struct TopKResult {
    /// retained elements, sorted by descending score
    pub items: Vec<Scored>,
    /// database rows actually scored (work metric; brute force = n)
    pub scanned: usize,
}

impl TopKResult {
    /// `min_{i∈S} y_i` — the cutoff anchor of Algorithm 1.
    pub fn s_min(&self) -> f64 {
        self.items.last().map(|s| s.score as f64).unwrap_or(f64::NEG_INFINITY)
    }
    /// `max_{i∈S} y_i`.
    pub fn s_max(&self) -> f64 {
        self.items.first().map(|s| s.score as f64).unwrap_or(f64::NEG_INFINITY)
    }
    pub fn ids(&self) -> Vec<u32> {
        self.items.iter().map(|s| s.id).collect()
    }
}

/// A preprocessed MIPS data structure over a fixed database.
pub trait MipsIndex: Send + Sync {
    /// Approximate (or exact) top-k by inner product with `q`.
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult;

    /// Batched top-k: one result per query, in order. Default: a
    /// per-query [`top_k`](Self::top_k) loop (what the LSH families use);
    /// batch-aware indexes (brute, IVF) override it to amortize the scan —
    /// every visited row block is streamed from memory once for the whole
    /// batch via [`ScoreBackend::scores_batch`]. Implementations must
    /// return exactly what per-query calls would (the native kernels make
    /// the two paths bit-identical).
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        qs.iter().map(|q| self.top_k(q, k)).collect()
    }

    /// Database size.
    fn n(&self) -> usize;
    /// Feature dimension.
    fn d(&self) -> usize;

    /// Approximation gap bound `c` (Definition 3.1) if this index provides
    /// one; `None` for heuristic indexes (IVF) and `Some(0)` for exact.
    fn gap_bound(&self) -> Option<f64> {
        None
    }

    /// Index family name for metrics/logs.
    fn name(&self) -> &'static str;

    /// One-line build/config summary.
    fn describe(&self) -> String {
        format!("{} over n={} d={}", self.name(), self.n(), self.d())
    }

    /// Serialize this index's sections into a snapshot under `shard`
    /// (see `crate::store`). The local kinds implement it; the default
    /// covers indexes with nothing meaningful to persist locally (e.g. a
    /// remote proxy).
    fn save_sections(
        &self,
        _w: &mut crate::store::SnapshotWriter,
        _shard: u32,
    ) -> Result<()> {
        Err(crate::error::Error::index(format!(
            "index kind {} does not support snapshot persistence",
            self.name()
        )))
    }
}

/// A freshly built index with the concrete sharded type preserved.
///
/// `Arc<dyn MipsIndex>` erases whether the index is a
/// [`crate::shard::ShardedIndex`], which is exactly the information the
/// engine and learner need to route sampling/estimation onto the sharded
/// sampler/estimator implementations (keyed replayable streams,
/// per-shard decomposed draws) instead of silently falling back to the
/// monolithic ones. Build through [`build_index_typed`] and erase with
/// [`as_dyn`](Self::as_dyn) only where a plain index is all that's
/// needed.
#[derive(Clone)]
pub enum BuiltIndex {
    Mono(Arc<dyn MipsIndex>),
    Sharded(Arc<crate::shard::ShardedIndex>),
}

impl BuiltIndex {
    /// The index as a plain trait object (for `top_k` and friends).
    pub fn as_dyn(&self) -> Arc<dyn MipsIndex> {
        match self {
            BuiltIndex::Mono(i) => i.clone(),
            // Arc<ShardedIndex> unsize-coerces against the return type
            BuiltIndex::Sharded(i) => i.clone(),
        }
    }

    /// The concrete sharded index, when this is one.
    pub fn sharded(&self) -> Option<&Arc<crate::shard::ShardedIndex>> {
        match self {
            BuiltIndex::Mono(_) => None,
            BuiltIndex::Sharded(i) => Some(i),
        }
    }
}

impl From<Arc<dyn MipsIndex>> for BuiltIndex {
    fn from(i: Arc<dyn MipsIndex>) -> Self {
        BuiltIndex::Mono(i)
    }
}

impl From<Arc<crate::shard::ShardedIndex>> for BuiltIndex {
    fn from(i: Arc<crate::shard::ShardedIndex>) -> Self {
        BuiltIndex::Sharded(i)
    }
}

/// Build the configured index over a dataset, preserving the concrete
/// sharded type. With `index.shards > 1` the configured kind becomes the
/// *per-shard* index behind a data-parallel
/// [`crate::shard::ShardedIndex`] (fan-out/merge, bit-identical to the
/// unsharded index on brute/IVF/LSH).
pub fn build_index_typed(
    ds: &Arc<Dataset>,
    cfg: &IndexConfig,
    backend: Arc<dyn ScoreBackend>,
) -> Result<BuiltIndex> {
    if cfg.shards > 1 {
        return Ok(BuiltIndex::Sharded(Arc::new(crate::shard::ShardedIndex::build(
            ds, cfg, backend,
        )?)));
    }
    Ok(BuiltIndex::Mono(match cfg.kind {
        IndexKind::Brute => {
            let mut idx = brute::BruteForce::new(ds.clone(), backend);
            if cfg.quant.enabled() {
                idx = idx.with_tier_cfg(cfg);
            }
            Arc::new(idx)
        }
        IndexKind::Ivf => Arc::new(ivf::IvfIndex::build(ds.clone(), cfg, backend)?),
        IndexKind::Lsh => Arc::new(lsh::SrpLsh::build(ds.clone(), cfg, backend)?),
        IndexKind::Tiered => Arc::new(tiered::TieredLsh::build(ds.clone(), cfg, backend)?),
    }))
}

/// [`build_index_typed`] with the sharded type erased — the convenience
/// form for callers that only ever call [`MipsIndex`] methods.
pub fn build_index(
    ds: &Arc<Dataset>,
    cfg: &IndexConfig,
    backend: Arc<dyn ScoreBackend>,
) -> Result<Arc<dyn MipsIndex>> {
    Ok(build_index_typed(ds, cfg, backend)?.as_dyn())
}

/// Exact top-k over an explicit candidate id list: gather candidate rows
/// into blocks and score with the f32 kernels — the scan both LSH
/// families share (`scanned` = number of candidates, matching their
/// work accounting).
pub(crate) fn scan_candidates_f32(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    q: &[f32],
    k: usize,
    cands: &[u32],
) -> TopKResult {
    let d = ds.d;
    let mut tk = TopK::new(k.min(ds.n).max(1));
    const BLOCK: usize = 1024;
    let mut rows = vec![0f32; BLOCK.min(cands.len().max(1)) * d];
    let mut out = vec![0f32; BLOCK];
    let mut start = 0;
    while start < cands.len() {
        let end = (start + BLOCK).min(cands.len());
        let ids = &cands[start..end];
        let rows_buf = &mut rows[..(end - start) * d];
        ds.gather(ids, rows_buf);
        let out_buf = &mut out[..end - start];
        backend.scores(rows_buf, d, q, out_buf);
        tk.push_ids(ids, out_buf);
        start = end;
    }
    TopKResult { items: tk.into_sorted(), scanned: cands.len() }
}

/// Batch-scan per-query candidate sets (the LSH families' batching
/// primitive): union each 64-query chunk's candidate ids, gather and
/// score every union block **once** per chunk via
/// [`ScoreBackend::scores_batch`], and push each scored row only to the
/// queries whose candidate set contained it — so results (ids, scores,
/// and per-query `scanned` counts) are exactly what per-query scans of
/// `cand_sets[j]` would produce, while each gathered row block streams
/// from memory once per chunk instead of once per query.
pub(crate) fn batch_scan_candidates(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    qs: &[&[f32]],
    k: usize,
    cand_sets: &[Vec<u32>],
) -> Vec<TopKResult> {
    debug_assert_eq!(qs.len(), cand_sets.len());
    let d = ds.d;
    let kk = k.min(ds.n).max(1);
    let mut results = Vec::with_capacity(qs.len());
    // per-id query-membership bitmask (one bit per query in the chunk)
    let mut mask = vec![0u64; ds.n];
    for (chunk_qs, chunk_cands) in qs.chunks(64).zip(cand_sets.chunks(64)) {
        let nq = chunk_qs.len();
        let mut union: Vec<u32> = Vec::new();
        for (j, cands) in chunk_cands.iter().enumerate() {
            let bit = 1u64 << j;
            for &id in cands {
                if mask[id as usize] == 0 {
                    union.push(id);
                }
                mask[id as usize] |= bit;
            }
        }
        let mut qflat = vec![0f32; nq * d];
        for (j, q) in chunk_qs.iter().enumerate() {
            debug_assert_eq!(q.len(), d);
            qflat[j * d..(j + 1) * d].copy_from_slice(q);
        }
        let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(kk)).collect();
        const BLOCK: usize = 1024;
        let mut rows = vec![0f32; BLOCK.min(union.len().max(1)) * d];
        let mut out = vec![0f32; BLOCK * nq];
        let mut start = 0;
        while start < union.len() {
            let end = (start + BLOCK).min(union.len());
            let ids = &union[start..end];
            let bn = end - start;
            let rows_buf = &mut rows[..bn * d];
            ds.gather(ids, rows_buf);
            let out_buf = &mut out[..bn * nq];
            backend.scores_batch(rows_buf, d, &qflat, nq, out_buf);
            for (j, tk) in tks.iter_mut().enumerate() {
                let bit = 1u64 << j;
                let sc = &out_buf[j * bn..(j + 1) * bn];
                for (t, &id) in ids.iter().enumerate() {
                    if mask[id as usize] & bit != 0 {
                        tk.push(id, sc[t]);
                    }
                }
            }
            start = end;
        }
        // reset the mask for the next chunk (touched entries only)
        for &id in &union {
            mask[id as usize] = 0;
        }
        for (tk, cands) in tks.into_iter().zip(chunk_cands) {
            results.push(TopKResult { items: tk.into_sorted(), scanned: cands.len() });
        }
    }
    results
}

/// Recall@k of `got` against the exact top-k `want` (id overlap / k) —
/// the standard index-quality metric used in tests and ablations.
pub fn recall_at_k(got: &TopKResult, want: &TopKResult) -> f64 {
    if want.items.is_empty() {
        return 1.0;
    }
    let want_ids: rustc_hash::FxHashSet<u32> = want.items.iter().map(|s| s.id).collect();
    let hit = got.items.iter().filter(|s| want_ids.contains(&s.id)).count();
    hit as f64 / want.items.len() as f64
}

/// Empirical gap of an approximate top-k set (Definition 3.1):
/// `max_{i∉S} y_i − min_{i∈S} y_i`, computed with an exact scan.
/// Negative values mean the set is exactly correct.
pub fn empirical_gap(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    q: &[f32],
    got: &TopKResult,
) -> f64 {
    let ids: rustc_hash::FxHashSet<u32> = got.items.iter().map(|s| s.id).collect();
    let mut out = vec![0f32; ds.n];
    backend.scores(&ds.data, ds.d, q, &mut out);
    let max_outside = out
        .iter()
        .enumerate()
        .filter(|(i, _)| !ids.contains(&(*i as u32)))
        .map(|(_, &s)| s as f64)
        .fold(f64::NEG_INFINITY, f64::max);
    max_outside - got.s_min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;

    #[test]
    fn build_index_dispatches_all_kinds() {
        let ds = Arc::new(synth::imagenet_like(2000, 16, 20, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut cfg = Config::default().index;
        cfg.train_sample = 1000;
        cfg.n_clusters = 32;
        cfg.tables = 8;
        cfg.bits = 6;
        cfg.rungs = 4;
        for kind in [IndexKind::Brute, IndexKind::Ivf, IndexKind::Lsh, IndexKind::Tiered] {
            cfg.kind = kind;
            let idx = build_index(&ds, &cfg, backend.clone()).unwrap();
            assert_eq!(idx.n(), 2000);
            assert_eq!(idx.d(), 16);
            assert_eq!(idx.name(), kind.name());
            assert!(!idx.describe().is_empty());
        }
    }

    #[test]
    fn recall_and_gap_against_self_are_perfect() {
        let ds = Arc::new(synth::imagenet_like(1000, 8, 10, 0.3, 2));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = brute::BruteForce::new(ds.clone(), backend.clone());
        let mut rng = Pcg64::new(3);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let got = idx.top_k(&q, 20);
        assert_eq!(recall_at_k(&got, &got), 1.0);
        let gap = empirical_gap(&ds, backend.as_ref(), &q, &got);
        assert!(gap <= 0.0, "exact top-k must have non-positive gap, got {gap}");
    }

    #[test]
    fn topk_result_accessors() {
        let r = TopKResult {
            items: vec![Scored { id: 4, score: 2.0 }, Scored { id: 9, score: 1.0 }],
            scanned: 10,
        };
        assert_eq!(r.s_max(), 2.0);
        assert_eq!(r.s_min(), 1.0);
        assert_eq!(r.ids(), vec![4, 9]);
        let empty = TopKResult::default();
        assert_eq!(empty.s_min(), f64::NEG_INFINITY);
    }
}
