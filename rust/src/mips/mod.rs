//! Maximum Inner Product Search (paper §2.3, §3.4).
//!
//! The amortization engine: preprocess the fixed feature database once,
//! then answer `top_k(θ)` queries in sublinear time. Implementations:
//!
//! * [`brute::BruteForce`] — exact `O(n·d)` scan (the paper's baseline and
//!   the correctness oracle),
//! * [`ivf::IvfIndex`] — k-means clustering index with `n_probe` probing
//!   (Douze et al. 2016; what the paper's experiments use),
//! * [`lsh::SrpLsh`] — signed-random-projection LSH (Charikar 2002) with
//!   the Neyshabur–Srebro MIPS→cosine reduction,
//! * [`tiered::TieredLsh`] — the ladder of LSH instances of Theorem 3.6
//!   returning *approximate top-k* sets with a bounded gap `c`
//!   (Definition 3.1).

pub mod brute;
pub mod ivf;
pub mod kmeans;
pub mod lsh;
pub mod tiered;

use crate::config::{IndexConfig, IndexKind};
use crate::data::Dataset;
use crate::error::Result;
use crate::scorer::ScoreBackend;
use crate::util::topk::Scored;
use std::sync::Arc;

/// Result of a top-k query.
#[derive(Clone, Debug, Default)]
pub struct TopKResult {
    /// retained elements, sorted by descending score
    pub items: Vec<Scored>,
    /// database rows actually scored (work metric; brute force = n)
    pub scanned: usize,
}

impl TopKResult {
    /// `min_{i∈S} y_i` — the cutoff anchor of Algorithm 1.
    pub fn s_min(&self) -> f64 {
        self.items.last().map(|s| s.score as f64).unwrap_or(f64::NEG_INFINITY)
    }
    /// `max_{i∈S} y_i`.
    pub fn s_max(&self) -> f64 {
        self.items.first().map(|s| s.score as f64).unwrap_or(f64::NEG_INFINITY)
    }
    pub fn ids(&self) -> Vec<u32> {
        self.items.iter().map(|s| s.id).collect()
    }
}

/// A preprocessed MIPS data structure over a fixed database.
pub trait MipsIndex: Send + Sync {
    /// Approximate (or exact) top-k by inner product with `q`.
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult;

    /// Batched top-k: one result per query, in order. Default: a
    /// per-query [`top_k`](Self::top_k) loop (what the LSH families use);
    /// batch-aware indexes (brute, IVF) override it to amortize the scan —
    /// every visited row block is streamed from memory once for the whole
    /// batch via [`ScoreBackend::scores_batch`]. Implementations must
    /// return exactly what per-query calls would (the native kernels make
    /// the two paths bit-identical).
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        qs.iter().map(|q| self.top_k(q, k)).collect()
    }

    /// Database size.
    fn n(&self) -> usize;
    /// Feature dimension.
    fn d(&self) -> usize;

    /// Approximation gap bound `c` (Definition 3.1) if this index provides
    /// one; `None` for heuristic indexes (IVF) and `Some(0)` for exact.
    fn gap_bound(&self) -> Option<f64> {
        None
    }

    /// Index family name for metrics/logs.
    fn name(&self) -> &'static str;

    /// One-line build/config summary.
    fn describe(&self) -> String {
        format!("{} over n={} d={}", self.name(), self.n(), self.d())
    }
}

/// Build the configured index over a dataset.
pub fn build_index(
    ds: &Arc<Dataset>,
    cfg: &IndexConfig,
    backend: Arc<dyn ScoreBackend>,
) -> Result<Arc<dyn MipsIndex>> {
    Ok(match cfg.kind {
        IndexKind::Brute => Arc::new(brute::BruteForce::new(ds.clone(), backend)),
        IndexKind::Ivf => Arc::new(ivf::IvfIndex::build(ds.clone(), cfg, backend)?),
        IndexKind::Lsh => Arc::new(lsh::SrpLsh::build(ds.clone(), cfg, backend)?),
        IndexKind::Tiered => Arc::new(tiered::TieredLsh::build(ds.clone(), cfg, backend)?),
    })
}

/// Recall@k of `got` against the exact top-k `want` (id overlap / k) —
/// the standard index-quality metric used in tests and ablations.
pub fn recall_at_k(got: &TopKResult, want: &TopKResult) -> f64 {
    if want.items.is_empty() {
        return 1.0;
    }
    let want_ids: rustc_hash::FxHashSet<u32> = want.items.iter().map(|s| s.id).collect();
    let hit = got.items.iter().filter(|s| want_ids.contains(&s.id)).count();
    hit as f64 / want.items.len() as f64
}

/// Empirical gap of an approximate top-k set (Definition 3.1):
/// `max_{i∉S} y_i − min_{i∈S} y_i`, computed with an exact scan.
/// Negative values mean the set is exactly correct.
pub fn empirical_gap(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    q: &[f32],
    got: &TopKResult,
) -> f64 {
    let ids: rustc_hash::FxHashSet<u32> = got.items.iter().map(|s| s.id).collect();
    let mut out = vec![0f32; ds.n];
    backend.scores(&ds.data, ds.d, q, &mut out);
    let max_outside = out
        .iter()
        .enumerate()
        .filter(|(i, _)| !ids.contains(&(*i as u32)))
        .map(|(_, &s)| s as f64)
        .fold(f64::NEG_INFINITY, f64::max);
    max_outside - got.s_min()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;

    #[test]
    fn build_index_dispatches_all_kinds() {
        let ds = Arc::new(synth::imagenet_like(2000, 16, 20, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut cfg = Config::default().index;
        cfg.train_sample = 1000;
        cfg.n_clusters = 32;
        cfg.tables = 8;
        cfg.bits = 6;
        cfg.rungs = 4;
        for kind in [IndexKind::Brute, IndexKind::Ivf, IndexKind::Lsh, IndexKind::Tiered] {
            cfg.kind = kind;
            let idx = build_index(&ds, &cfg, backend.clone()).unwrap();
            assert_eq!(idx.n(), 2000);
            assert_eq!(idx.d(), 16);
            assert_eq!(idx.name(), kind.name());
            assert!(!idx.describe().is_empty());
        }
    }

    #[test]
    fn recall_and_gap_against_self_are_perfect() {
        let ds = Arc::new(synth::imagenet_like(1000, 8, 10, 0.3, 2));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = brute::BruteForce::new(ds.clone(), backend.clone());
        let mut rng = Pcg64::new(3);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let got = idx.top_k(&q, 20);
        assert_eq!(recall_at_k(&got, &got), 1.0);
        let gap = empirical_gap(&ds, backend.as_ref(), &q, &got);
        assert!(gap <= 0.0, "exact top-k must have non-positive gap, got {gap}");
    }

    #[test]
    fn topk_result_accessors() {
        let r = TopKResult {
            items: vec![Scored { id: 4, score: 2.0 }, Scored { id: 9, score: 1.0 }],
            scanned: 10,
        };
        assert_eq!(r.s_max(), 2.0);
        assert_eq!(r.s_min(), 1.0);
        assert_eq!(r.ids(), vec![4, 9]);
        let empty = TopKResult::default();
        assert_eq!(empty.s_min(), f64::NEG_INFINITY);
    }
}
