//! IVF (inverted-file) clustering MIPS index — the method the paper's
//! experiments use (§4.1.1, after Douze et al. 2016, minus the compression
//! codes which the paper also disables).
//!
//! Build: k-means on a training subsample → assign every row to its
//! nearest centroid → store rows *contiguously per cluster* (cache- and
//! PJRT-block-friendly). Query: score all centroids against θ, visit the
//! `n_probe` best clusters, exact-score their member rows, keep the top-k.
//!
//! With `index.quant` the probe scan is two-stage: the probed clusters
//! are screened on a quantized shadow copy of the grouped storage (SQ8
//! ¼, SQ4 ⅛, PQ ~¹⁄₃₂ at its defaults), then only the surviving
//! candidates are re-ranked with the exact f32 kernels — bit-identical
//! results by the error-bound/certificate contract of
//! [`crate::linalg::quant`], with certificate misses riding the tier
//! ladder of [`crate::mips::two_stage`].
//!
//! No theoretical guarantee (the paper notes this too) — accuracy is
//! certified downstream by the TV-bound certificate (§4.2.1).

use super::kmeans::{self, Kmeans};
use super::two_stage::{self, QuantTier, TierLadder, TierQuery};
use super::{MipsIndex, TopKResult};
use crate::config::IndexConfig;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::scorer::ScoreBackend;
use crate::store::blob::Blob;
use crate::store::format::{self, sec_arg, tag, ByteWriter, Snapshot, SnapshotWriter};
use crate::util::rng::Pcg64;
use crate::util::topk::{Scored, TopK};
use std::sync::Arc;

/// Rows per survivor gather/re-rank block (quantized pass 2).
const GATHER_BLOCK: usize = 1024;

/// Resolve `(n_clusters, n_probe)` from config + database size:
/// `n_clusters = 0` → `4√n`, `n_probe = 0` → `max(8, n_clusters/16)`.
/// Standalone (not a method) so the shard layer can size from the
/// *global* n and hand every shard the same resolved values — per-shard
/// auto-sizing from shard-local n would break shard-count invariance.
pub fn resolve_sizes(cfg: &IndexConfig, n: usize) -> (usize, usize) {
    let n_clusters = if cfg.n_clusters == 0 {
        ((4.0 * (n as f64).sqrt()).round() as usize).clamp(1, n)
    } else {
        cfg.n_clusters.clamp(1, n)
    };
    let n_probe = if cfg.n_probe == 0 {
        (n_clusters / 16).max(8).min(n_clusters)
    } else {
        cfg.n_probe.min(n_clusters)
    };
    (n_clusters, n_probe)
}

/// Train the coarse quantizer (k-means on a subsample) for `ds` under
/// `cfg`. Standalone so the shard layer can train **once on the global
/// dataset** and share the centroids across every shard — the keystone of
/// sharded-IVF bit-parity: identical centroids ⇒ identical probe
/// rankings ⇒ the per-shard probed rows union to exactly the monolithic
/// probed rows.
pub fn train_coarse(ds: &Dataset, cfg: &IndexConfig, n_clusters: usize) -> Kmeans {
    let n = ds.n;
    let d = ds.d;
    let train_n = if cfg.train_sample == 0 { n } else { cfg.train_sample.min(n) };
    if train_n == n {
        kmeans::train(&ds.data, n, d, n_clusters, cfg.kmeans_iters, cfg.seed)
    } else {
        let mut rng = Pcg64::new(cfg.seed ^ 0x7A17);
        let mut sample = vec![0f32; train_n * d];
        let excl = rustc_hash::FxHashSet::default();
        let picks = rng.distinct_excluding(n as u64, train_n, &excl);
        for (j, &p) in picks.iter().enumerate() {
            sample[j * d..(j + 1) * d].copy_from_slice(ds.row(p as usize));
        }
        kmeans::train(&sample, train_n, d, n_clusters, cfg.kmeans_iters, cfg.seed)
    }
}

/// The `n_probe` best clusters for `q`, by centroid score — partial
/// selection of the probed prefix (§Perf iteration 3: a full sort of
/// all clusters cost ~C·log C per query; select_nth is O(C) and we only
/// order the probed prefix). Standalone so the shard layer can rank once
/// per query and fan the same probe list out to every shard.
pub(crate) fn rank_clusters(km: &Kmeans, q: &[f32], n_probe: usize) -> Vec<u32> {
    let mut cscores = vec![0f32; km.c];
    km.centroid_scores(q, &mut cscores);
    let mut order = select_probes(&cscores, km.c, n_probe);
    let cmp = |a: &u32, b: &u32| {
        cscores[*b as usize]
            .partial_cmp(&cscores[*a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    };
    order.sort_unstable_by(cmp);
    order
}

/// Batched probe ranking: centroids scored against the whole batch in one
/// multi-query pass. Per-query probe *sets* are identical to
/// [`rank_clusters`] (same scores — the native multi kernel is
/// bit-identical to per-query `centroid_scores` — and the same
/// `select_nth` partition), only unsorted: scan order does not affect
/// retained results ([`TopK`] is push-order independent) or accounting.
pub(crate) fn rank_clusters_batch(km: &Kmeans, qs: &[&[f32]], n_probe: usize) -> Vec<Vec<u32>> {
    let nq = qs.len();
    let d = km.d;
    let c = km.c;
    let mut qflat = vec![0f32; nq * d];
    for (j, q) in qs.iter().enumerate() {
        debug_assert_eq!(q.len(), d);
        qflat[j * d..(j + 1) * d].copy_from_slice(q);
    }
    // NOTE: deliberately the native multi-query kernel, not a backend:
    // single-query probing ranks centroids with the native
    // `km.centroid_scores` regardless of backend (the centroid block need
    // not match a PJRT executable's compiled shape), and batch/single
    // parity requires the same scores here.
    let mut cscores = vec![0f32; nq * c];
    crate::linalg::simd::matvec_block_multi(&km.centroids, d, &qflat, nq, &mut cscores);
    (0..nq).map(|j| select_probes(&cscores[j * c..(j + 1) * c], c, n_probe)).collect()
}

/// The (unsorted) `n_probe`-best cluster ids under `scores`.
fn select_probes(scores: &[f32], c: usize, n_probe: usize) -> Vec<u32> {
    let cmp = |a: &u32, b: &u32| {
        scores[*b as usize]
            .partial_cmp(&scores[*a as usize])
            .unwrap_or(std::cmp::Ordering::Equal)
    };
    let mut order: Vec<u32> = (0..c as u32).collect();
    if n_probe < c {
        order.select_nth_unstable_by(n_probe - 1, cmp);
        order.truncate(n_probe);
    }
    order
}

/// Clustering-based MIPS index with contiguous per-cluster storage.
pub struct IvfIndex {
    /// rows regrouped cluster-contiguously, row-major `[n × d]`
    /// (owned, or mapped straight out of a snapshot)
    grouped: Blob<f32>,
    /// original dataset id of each grouped row
    ids: Vec<u32>,
    /// cluster boundaries into `grouped`/`ids`: cluster c occupies
    /// `offsets[c]..offsets[c+1]`
    offsets: Vec<usize>,
    km: Kmeans,
    backend: Arc<dyn ScoreBackend>,
    pub n_probe: usize,
    n: usize,
    d: usize,
    /// screening-tier ladder over `grouped` for the two-stage probe scan
    quant: Option<TierLadder>,
    /// pass-1 retention factor (`k·overscan` candidates)
    overscan: usize,
    /// ids whose grouped copy is outdated (live version in pending)
    stale: rustc_hash::FxHashSet<u32>,
    /// LSM-style pending segment: updated rows awaiting compaction
    pending_ids: Vec<u32>,
    pending_rows: Vec<f32>,
}

impl IvfIndex {
    /// Build from config: `n_clusters = 0` → `4√n`, `n_probe = 0` →
    /// `max(8, n_clusters/16)`, `train_sample = 0` → all rows.
    pub fn build(ds: Arc<Dataset>, cfg: &IndexConfig, backend: Arc<dyn ScoreBackend>) -> Result<Self> {
        let (n_clusters, n_probe) = resolve_sizes(cfg, ds.n);
        let km = train_coarse(&ds, cfg, n_clusters);
        Ok(Self::build_with_kmeans(ds, cfg, backend, km, n_probe))
    }

    /// Assemble over an externally trained coarse quantizer. This is the
    /// shard layer's construction path: the `Kmeans` (and resolved
    /// `n_probe`) come from the global dataset, so every shard assigns
    /// its rows to the *same* centroids and ranks probes identically.
    pub fn build_with_kmeans(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        km: Kmeans,
        n_probe: usize,
    ) -> Self {
        let n = ds.n;
        let d = ds.d;
        let n_probe = n_probe.clamp(1, km.c);

        // ---- assign all rows, group contiguously ----------------------------
        let mut assign = vec![0u32; n];
        let mut counts = vec![0usize; km.c];
        for i in 0..n {
            let (a, _) = km.assign(ds.row(i));
            assign[i] = a as u32;
            counts[a] += 1;
        }
        let mut offsets = vec![0usize; km.c + 1];
        for c in 0..km.c {
            offsets[c + 1] = offsets[c] + counts[c];
        }
        let mut cursor = offsets.clone();
        let mut grouped = vec![0f32; n * d];
        let mut ids = vec![0u32; n];
        for i in 0..n {
            let a = assign[i] as usize;
            let pos = cursor[a];
            cursor[a] += 1;
            grouped[pos * d..(pos + 1) * d].copy_from_slice(ds.row(i));
            ids[pos] = i as u32;
        }

        let quant = TierLadder::from_cfg(&grouped, d, cfg);

        IvfIndex {
            grouped: grouped.into(),
            ids,
            offsets,
            km,
            backend,
            n_probe,
            n,
            d,
            quant,
            overscan: cfg.overscan.max(1),
            stale: rustc_hash::FxHashSet::default(),
            pending_ids: Vec::new(),
            pending_rows: Vec::new(),
        }
    }

    /// Number of clusters.
    pub fn n_clusters(&self) -> usize {
        self.km.c
    }

    /// Whether the quantized screening pass is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant.is_some()
    }

    /// The coarse quantizer (read-only; the shard layer ranks against it).
    pub fn kmeans(&self) -> &Kmeans {
        &self.km
    }

    /// Query with an explicit probe count (ablations sweep this).
    pub fn top_k_probes(&self, q: &[f32], k: usize, n_probe: usize) -> TopKResult {
        crate::obs::registry().ivf_queries.inc();
        let n_probe = n_probe.clamp(1, self.km.c);
        let order = rank_clusters(&self.km, q, n_probe);
        let mut r = self.top_k_clusters(q, k, &order);
        r.scanned += self.km.c; // centroid ranking work
        r
    }

    /// Top-k restricted to an explicitly given cluster list (plus the
    /// pending segment, which every query scans exactly). `scanned`
    /// counts **scored rows only** — the caller owns the centroid-ranking
    /// accounting, which lets the shard layer rank once and fan the same
    /// probe list out to every shard without multiply-counting the
    /// centroid work.
    pub fn top_k_clusters(&self, q: &[f32], k: usize, clusters: &[u32]) -> TopKResult {
        if let Some(ladder) = &self.quant {
            if let Some(r) = self.scan_clusters_quant(q, k, clusters, ladder.tiers()) {
                return r;
            }
        }
        self.scan_clusters_f32(q, k, clusters)
    }

    /// Plain one-stage f32 scan of the given clusters (also the fallback
    /// when a quantized pass cannot prove coverage).
    fn scan_clusters_f32(&self, q: &[f32], k: usize, clusters: &[u32]) -> TopKResult {
        let mut tk = TopK::new(k.min(self.n).max(1));
        let mut buf: Vec<f32> = Vec::new();
        let mut scanned = 0usize;
        let mut filtered = 0u64;
        for &c in clusters {
            let (s, e) = (self.offsets[c as usize], self.offsets[c as usize + 1]);
            if s == e {
                continue;
            }
            let rows = &self.grouped[s * self.d..e * self.d];
            buf.resize(e - s, 0.0);
            self.backend.scores(rows, self.d, q, &mut buf);
            if self.stale.is_empty() {
                tk.push_ids(&self.ids[s..e], &buf);
            } else {
                for (j, &id) in self.ids[s..e].iter().enumerate() {
                    if !self.stale.contains(&id) {
                        tk.push(id, buf[j]);
                    } else {
                        filtered += 1;
                    }
                }
            }
            scanned += e - s;
        }
        // pending segment (sparse updates, §6): always scanned exactly
        if !self.pending_ids.is_empty() {
            buf.resize(self.pending_ids.len(), 0.0);
            self.backend.scores(&self.pending_rows, self.d, q, &mut buf);
            tk.push_ids(&self.pending_ids, &buf);
            scanned += self.pending_ids.len();
        }
        let obs = crate::obs::registry();
        obs.ivf_probes_scanned.add(clusters.len() as u64);
        obs.ivf_rows_scanned.add(scanned as u64);
        obs.ivf_pending_rows.add(self.pending_ids.len() as u64);
        obs.ivf_tombstone_filtered.add(filtered);
        TopKResult { items: tk.into_sorted(), scanned }
    }

    /// Exact f32 re-rank of quantized-pass survivors (grouped-storage
    /// positions): gather the rows, score with the same kernels the
    /// one-stage scan uses, push under their dataset ids.
    fn rerank_grouped(&self, positions: &[u32], q: &[f32], tk: &mut TopK) {
        let d = self.d;
        let mut rows = vec![0f32; GATHER_BLOCK.min(positions.len().max(1)) * d];
        let mut out = vec![0f32; GATHER_BLOCK];
        let mut s = 0;
        while s < positions.len() {
            let e = (s + GATHER_BLOCK).min(positions.len());
            let m = e - s;
            for (i, &pos) in positions[s..e].iter().enumerate() {
                let p = pos as usize;
                rows[i * d..(i + 1) * d].copy_from_slice(&self.grouped[p * d..(p + 1) * d]);
            }
            self.backend.scores(&rows[..m * d], d, q, &mut out[..m]);
            for (i, &pos) in positions[s..e].iter().enumerate() {
                tk.push(self.ids[pos as usize], out[i]);
            }
            s = e;
        }
    }

    /// Two-stage scan of the given clusters over the given ladder rungs:
    /// per rung, a screening pass (collecting grouped positions), exact
    /// re-rank of the retained candidates + coverage certificate — a
    /// miss tries the next rung — then the pending segment exactly.
    /// `scanned` counts scored rows only, like [`scan_clusters_f32`].
    /// `None` when no rung certifies or the screen cannot prune anything
    /// (`k·overscan` covers the probed rows) — the caller falls back to
    /// the f32 scan.
    ///
    /// [`scan_clusters_f32`]: Self::scan_clusters_f32
    fn scan_clusters_quant(
        &self,
        q: &[f32],
        k: usize,
        clusters: &[u32],
        tiers: &[QuantTier],
    ) -> Option<TopKResult> {
        let kk = k.min(self.n).max(1);
        let cap = kk.saturating_mul(self.overscan).min(self.n).max(kk);
        let probed_rows: usize = clusters
            .iter()
            .map(|&c| self.offsets[c as usize + 1] - self.offsets[c as usize])
            .sum();
        if cap >= probed_rows {
            // pass 1 would retain everything: the one-stage scan is
            // strictly cheaper than screen + gather-re-rank-all
            return None;
        }
        let mut buf: Vec<f32> = Vec::new();
        for tier in tiers {
            let tq = tier.encode_query(q);
            let mut tk = TopK::new(cap);
            let mut scanned = 0usize;
            let mut pushed = 0usize;
            let mut filtered = 0u64;
            for &c in clusters {
                let (s, e) = (self.offsets[c as usize], self.offsets[c as usize + 1]);
                if s == e {
                    continue;
                }
                buf.resize(e - s, 0.0);
                tier.scores(s, e, &tq, &mut buf);
                if self.stale.is_empty() {
                    tk.push_block(s as u32, &buf);
                    pushed += e - s;
                } else {
                    for (j, &id) in self.ids[s..e].iter().enumerate() {
                        if !self.stale.contains(&id) {
                            tk.push((s + j) as u32, buf[j]);
                            pushed += 1;
                        } else {
                            filtered += 1;
                        }
                    }
                }
                scanned += e - s;
            }
            let obs = crate::obs::registry();
            obs.ivf_probes_scanned.add(clusters.len() as u64);
            obs.ivf_rows_scanned.add(scanned as u64);
            obs.ivf_tombstone_filtered.add(filtered);
            let finished = two_stage::finish_screen(
                tier,
                &tq,
                tk.into_sorted(),
                pushed,
                cap,
                kk,
                |positions, tk| self.rerank_grouped(positions, q, tk),
            );
            if let Some(mut tk2) = finished {
                if !self.pending_ids.is_empty() {
                    buf.resize(self.pending_ids.len(), 0.0);
                    self.backend.scores(&self.pending_rows, self.d, q, &mut buf);
                    tk2.push_ids(&self.pending_ids, &buf);
                    scanned += self.pending_ids.len();
                    obs.ivf_pending_rows.add(self.pending_ids.len() as u64);
                }
                return Some(TopKResult { items: tk2.into_sorted(), scanned });
            }
        }
        None
    }

    /// Batched query with an explicit probe count: centroids are scored
    /// against the *whole* batch in one multi-query pass, per-query probe
    /// lists are merged so each probed cluster's rows stream from memory
    /// exactly once per batch, and the cluster scans are parallelized
    /// with [`parallel_chunks`](crate::util::pool::parallel_chunks) when
    /// there is enough work to amortize the threads. With quantization
    /// enabled, the shared per-batch stream is the SQ8 code block and
    /// each query exact-re-ranks its own survivors.
    ///
    /// Returns exactly what per-query [`top_k_probes`](Self::top_k_probes)
    /// calls would: the native kernels make batched and single-query
    /// scores bit-identical, and [`TopK`] retention is push-order
    /// independent.
    pub fn top_k_batch_probes(&self, qs: &[&[f32]], k: usize, n_probe: usize) -> Vec<TopKResult> {
        if qs.is_empty() {
            return Vec::new();
        }
        crate::obs::registry().ivf_queries.add(qs.len() as u64);
        let n_probe = n_probe.clamp(1, self.km.c);
        let orders = rank_clusters_batch(&self.km, qs, n_probe);
        let mut results = self.scan_clusters_batch(qs, k, &orders);
        for r in &mut results {
            r.scanned += self.km.c; // centroid ranking work, as in top_k_probes
        }
        results
    }

    /// Batched scan of per-query cluster lists (the workhorse behind
    /// [`top_k_batch_probes`](Self::top_k_batch_probes), and the
    /// shard layer's batch entry point — it passes globally ranked
    /// `orders` to every shard). Per-query probe lists are merged so each
    /// scanned cluster's rows stream from memory exactly once per batch.
    /// `scanned` counts scored rows only, mirroring
    /// [`top_k_clusters`](Self::top_k_clusters).
    pub fn scan_clusters_batch(
        &self,
        qs: &[&[f32]],
        k: usize,
        orders: &[Vec<u32>],
    ) -> Vec<TopKResult> {
        let nq = qs.len();
        debug_assert_eq!(nq, orders.len());
        if nq == 0 {
            return Vec::new();
        }
        let d = self.d;
        let c = self.km.c;
        let kk = k.min(self.n).max(1);
        let mut qflat = vec![0f32; nq * d];
        for (j, q) in qs.iter().enumerate() {
            debug_assert_eq!(q.len(), d);
            qflat[j * d..(j + 1) * d].copy_from_slice(q);
        }

        // invert per-query probe sets into per-cluster query lists
        let mut cluster_queries: Vec<Vec<u32>> = vec![Vec::new(); c];
        for (j, order) in orders.iter().enumerate() {
            for &cl in order {
                cluster_queries[cl as usize].push(j as u32);
            }
        }
        let active: Vec<u32> = (0..c as u32)
            .filter(|&cl| {
                !cluster_queries[cl as usize].is_empty()
                    && self.offsets[cl as usize] < self.offsets[cl as usize + 1]
            })
            .collect();

        // ---- merged probe scan: each cluster streamed once per batch -------
        let scan_rows: usize = active
            .iter()
            .map(|&cl| self.offsets[cl as usize + 1] - self.offsets[cl as usize])
            .sum();
        // threads only pay off once the batch scans enough floats
        let nthreads = if scan_rows * d >= (1 << 18) {
            crate::util::pool::default_threads().min(active.len().max(1))
        } else {
            1
        };

        let cap = kk.saturating_mul(self.overscan).min(self.n).max(kk);
        if let (Some(ladder), true) = (&self.quant, cap < self.n) {
            // batched pass 1 on the primary tier: each probed cluster's
            // codes stream once for that cluster's whole query list via
            // the multi-query kernel; per-query certificate misses ride
            // the remaining rungs (then f32) exactly like single queries
            let primary = ladder.primary();
            let tqs: Vec<TierQuery> = qs.iter().map(|q| primary.encode_query(q)).collect();
            let parts = crate::util::pool::parallel_chunks(active.len(), nthreads, |_, s, e| {
                let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(cap)).collect();
                let mut scanned = vec![0usize; nq];
                let mut pushed = vec![0usize; nq];
                let mut out: Vec<f32> = Vec::new();
                // per-thread batch handle: query unwrap + selection
                // scratch reused across this chunk's clusters
                let mut tb = two_stage::TierBatch::new(primary, &tqs);
                for &cl in &active[s..e] {
                    let (cs, ce) = (self.offsets[cl as usize], self.offsets[cl as usize + 1]);
                    let nr = ce - cs;
                    let ids = &self.ids[cs..ce];
                    let qlist = &cluster_queries[cl as usize];
                    out.resize(qlist.len() * nr, 0.0);
                    tb.scores_sel(cs, ce, qlist, &mut out);
                    for (jj, &qj) in qlist.iter().enumerate() {
                        let sc = &out[jj * nr..(jj + 1) * nr];
                        let tk = &mut tks[qj as usize];
                        if self.stale.is_empty() {
                            tk.push_block(cs as u32, sc);
                            pushed[qj as usize] += nr;
                        } else {
                            for (t, &id) in ids.iter().enumerate() {
                                if !self.stale.contains(&id) {
                                    tk.push((cs + t) as u32, sc[t]);
                                    pushed[qj as usize] += 1;
                                }
                            }
                        }
                        scanned[qj as usize] += nr;
                    }
                }
                (tks, scanned, pushed)
            });
            let mut frags: Vec<Vec<Vec<Scored>>> = (0..nq).map(|_| Vec::new()).collect();
            let mut scanned = vec![0usize; nq];
            let mut pushed = vec![0usize; nq];
            for (part_tks, part_scanned, part_pushed) in parts {
                for (j, tk) in part_tks.into_iter().enumerate() {
                    frags[j].push(tk.into_sorted());
                }
                for (j, sc) in part_scanned.into_iter().enumerate() {
                    scanned[j] += sc;
                }
                for (j, p) in part_pushed.into_iter().enumerate() {
                    pushed[j] += p;
                }
            }
            let tks: Vec<TopK> =
                frags.into_iter().map(|f| crate::util::topk::merge_topk(f, cap)).collect();
            // per-query finish: survivors → exact re-rank, pending exact
            let np = self.pending_ids.len();
            let mut pend = vec![0f32; np * nq];
            if np > 0 {
                self.backend.scores_batch(&self.pending_rows, d, &qflat, nq, &mut pend);
            }
            return tks
                .into_iter()
                .enumerate()
                .map(|(j, tk)| {
                    let finished = two_stage::finish_screen(
                        primary,
                        &tqs[j],
                        tk.into_sorted(),
                        pushed[j],
                        cap,
                        kk,
                        |positions, tk| self.rerank_grouped(positions, qs[j], tk),
                    );
                    match finished {
                        // certificate miss: the remaining rungs (then the
                        // f32 scan) return the identical exact result and
                        // identical scan accounting
                        None => self
                            .scan_clusters_quant(qs[j], k, &orders[j], &ladder.tiers()[1..])
                            .unwrap_or_else(|| self.scan_clusters_f32(qs[j], k, &orders[j])),
                        Some(mut tk2) => {
                            let mut sc = scanned[j];
                            if np > 0 {
                                tk2.push_ids(&self.pending_ids, &pend[j * np..(j + 1) * np]);
                                sc += np;
                            }
                            let obs = crate::obs::registry();
                            obs.ivf_probes_scanned.add(orders[j].len() as u64);
                            obs.ivf_rows_scanned.add(sc as u64);
                            obs.ivf_pending_rows.add(np as u64);
                            TopKResult { items: tk2.into_sorted(), scanned: sc }
                        }
                    }
                })
                .collect();
        }

        let parts = crate::util::pool::parallel_chunks(active.len(), nthreads, |_, s, e| {
            let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(kk)).collect();
            let mut scanned = vec![0usize; nq];
            let mut qsel: Vec<f32> = Vec::new();
            let mut out: Vec<f32> = Vec::new();
            for &cl in &active[s..e] {
                let (cs, ce) = (self.offsets[cl as usize], self.offsets[cl as usize + 1]);
                let rows = &self.grouped[cs * d..ce * d];
                let ids = &self.ids[cs..ce];
                let nr = ce - cs;
                let qlist = &cluster_queries[cl as usize];
                qsel.clear();
                for &qj in qlist {
                    qsel.extend_from_slice(&qflat[qj as usize * d..(qj as usize + 1) * d]);
                }
                out.resize(qlist.len() * nr, 0.0);
                self.backend.scores_batch(rows, d, &qsel, qlist.len(), &mut out);
                for (jj, &qj) in qlist.iter().enumerate() {
                    let sc = &out[jj * nr..(jj + 1) * nr];
                    let tk = &mut tks[qj as usize];
                    if self.stale.is_empty() {
                        tk.push_ids(ids, sc);
                    } else {
                        for (t, &id) in ids.iter().enumerate() {
                            if !self.stale.contains(&id) {
                                tk.push(id, sc[t]);
                            }
                        }
                    }
                    scanned[qj as usize] += nr;
                }
            }
            (tks, scanned)
        });
        let mut frags: Vec<Vec<Vec<Scored>>> = (0..nq).map(|_| Vec::new()).collect();
        let mut scanned = vec![0usize; nq];
        for (part_tks, part_scanned) in parts {
            for (j, tk) in part_tks.into_iter().enumerate() {
                frags[j].push(tk.into_sorted());
            }
            for (j, sc) in part_scanned.into_iter().enumerate() {
                scanned[j] += sc;
            }
        }
        let mut tks: Vec<TopK> =
            frags.into_iter().map(|f| crate::util::topk::merge_topk(f, kk)).collect();

        // ---- pending segment: every query scans it exactly -----------------
        if !self.pending_ids.is_empty() {
            let np = self.pending_ids.len();
            let mut out = vec![0f32; np * nq];
            self.backend.scores_batch(&self.pending_rows, d, &qflat, nq, &mut out);
            for (j, tk) in tks.iter_mut().enumerate() {
                tk.push_ids(&self.pending_ids, &out[j * np..(j + 1) * np]);
                scanned[j] += np;
            }
        }

        let obs = crate::obs::registry();
        obs.ivf_probes_scanned.add(orders.iter().map(|o| o.len() as u64).sum());
        obs.ivf_rows_scanned.add(scanned.iter().map(|&s| s as u64).sum());
        obs.ivf_pending_rows.add((self.pending_ids.len() * nq) as u64);
        tks.into_iter()
            .zip(scanned)
            .map(|(tk, sc)| TopKResult { items: tk.into_sorted(), scanned: sc })
            .collect()
    }

    /// Fraction of the database scanned per query at the configured probe
    /// count (expected; exact value depends on cluster fill).
    pub fn expected_scan_fraction(&self) -> f64 {
        self.n_probe as f64 / self.km.c as f64
    }

    // ---- sparse updates (§6: "if a MIPS system allows for sparse
    // updates, our method will also allow for sparse updates") ----------
    //
    // LSM-style: an updated row is tombstoned in the grouped storage and
    // appended to a small pending segment that every query scans exactly;
    // `compact()` folds pending rows back into cluster-contiguous storage.
    // The SQ8 shadow copy stays coherent for free between compactions:
    // grouped rows are never rewritten in place (tombstoned copies are
    // filtered out of the quantized pass by id), the pending segment is
    // always scored exactly in f32, and `compact()` re-encodes the
    // rebuilt storage. Callers updating a *shared* index need external
    // synchronization and must keep the Dataset row in sync (tail
    // scoring reads the Dataset).

    /// Replace row `id`'s vector. O(d) plus an O(pending) scan per query
    /// until the next [`compact`](Self::compact).
    pub fn update_row(&mut self, id: u32, new_vec: &[f32]) {
        debug_assert_eq!(new_vec.len(), self.d);
        self.stale.insert(id);
        // drop any older pending version of the same id
        if let Some(pos) = self.pending_ids.iter().position(|&p| p == id) {
            self.pending_ids.swap_remove(pos);
            let last = self.pending_rows.len() - self.d;
            // swap_remove the row block
            let (dst, src) = (pos * self.d, last);
            if dst != src {
                let (a, b) = self.pending_rows.split_at_mut(src);
                a[dst..dst + self.d].copy_from_slice(&b[..self.d]);
            }
            self.pending_rows.truncate(last);
        }
        self.pending_ids.push(id);
        self.pending_rows.extend_from_slice(new_vec);
    }

    /// Number of rows awaiting compaction.
    pub fn pending_len(&self) -> usize {
        self.pending_ids.len()
    }

    /// Fold pending updates back into cluster-contiguous storage
    /// (reassigning each updated row to its nearest centroid) and
    /// re-encode the SQ8 shadow copy of the rebuilt storage.
    pub fn compact(&mut self) {
        if self.pending_ids.is_empty() {
            return;
        }
        let d = self.d;
        // rebuild per-cluster buckets from live grouped rows + pending
        let mut buckets: Vec<Vec<(u32, Vec<f32>)>> = vec![Vec::new(); self.km.c];
        for c in 0..self.km.c {
            for pos in self.offsets[c]..self.offsets[c + 1] {
                let id = self.ids[pos];
                if !self.stale.contains(&id) {
                    buckets[c].push((id, self.grouped[pos * d..(pos + 1) * d].to_vec()));
                }
            }
        }
        for (i, &id) in self.pending_ids.iter().enumerate() {
            let row = self.pending_rows[i * d..(i + 1) * d].to_vec();
            let (c, _) = self.km.assign(&row);
            buckets[c].push((id, row));
        }
        let mut offsets = vec![0usize; self.km.c + 1];
        let mut grouped = Vec::with_capacity(self.n * d);
        let mut ids = Vec::with_capacity(self.n);
        for (c, bucket) in buckets.into_iter().enumerate() {
            for (id, row) in bucket {
                ids.push(id);
                grouped.extend_from_slice(&row);
            }
            offsets[c + 1] = ids.len();
        }
        self.grouped = grouped.into();
        self.ids = ids;
        self.offsets = offsets;
        self.pending_ids.clear();
        self.pending_rows.clear();
        self.stale.clear();
        // every block of the rebuilt storage is touched, so the coherence
        // re-encode is a full pass over every ladder rung (PQ keeps its
        // codebooks and re-assigns codes)
        if let Some(ladder) = &mut self.quant {
            ladder.reencode(&self.grouped);
        }
    }

    // ---- snapshot persistence ------------------------------------------

    /// Write this index's own sections — everything except the coarse
    /// quantizer: layout + LSM update state under `IVF_META`, the
    /// cluster-grouped row storage under `IVF_GROUPED` (raw Pod bytes,
    /// 64-byte aligned, so a mapped open scans it zero-copy), and the
    /// quantized shadow tiers. Split from the trait method so the shard
    /// layer can save the *shared* coarse quantizer exactly once.
    pub(crate) fn save_body(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        let arg = sec_arg(shard, 0);
        let mut m = ByteWriter::default();
        m.u64(self.n as u64);
        m.u64(self.d as u64);
        let offsets: Vec<u64> = self.offsets.iter().map(|&o| o as u64).collect();
        m.slice(&offsets);
        m.slice(&self.ids);
        // FxHashSet iteration order is nondeterministic — sort so saving
        // the same index twice yields byte-identical snapshots
        let mut stale: Vec<u32> = self.stale.iter().copied().collect();
        stale.sort_unstable();
        m.slice(&stale);
        m.slice(&self.pending_ids);
        m.slice(&self.pending_rows);
        w.section(tag::IVF_META, arg, m.bytes())?;
        w.section(tag::IVF_GROUPED, arg, format::as_bytes(&self.grouped))?;
        if let Some(ladder) = &self.quant {
            ladder.save_sections(w, shard)?;
        }
        Ok(())
    }

    /// Rebuild from snapshot sections written by the
    /// [`MipsIndex::save_sections`] impl (monolithic layout: coarse
    /// quantizer and body at shard 0). `n_probe` is re-resolved from the
    /// config — it is a query-time knob, not part of the built structure.
    /// A missing/corrupt quantized shadow degrades to the f32 probe scan
    /// (sets `degraded`); answers stay bit-identical either way.
    pub fn open_from(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        snap: &Snapshot,
        degraded: &mut bool,
    ) -> Result<Self> {
        let km = crate::store::read_kmeans(snap, sec_arg(0, 0))?;
        let (_, n_probe) = resolve_sizes(cfg, ds.n);
        Self::open_shard(ds, cfg, backend, snap, km, n_probe, 0, degraded)
    }

    /// Rebuild one shard's IVF structure over an externally supplied
    /// coarse quantizer. The shard layer reads the shared `Kmeans` once
    /// and passes the same resolved `n_probe` to every shard, mirroring
    /// [`build_with_kmeans`](Self::build_with_kmeans). Every structural
    /// invariant the scan code indexes by is re-validated here so a
    /// corrupt-but-checksum-colliding file errors instead of panicking.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn open_shard(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        snap: &Snapshot,
        km: Kmeans,
        n_probe: usize,
        shard: u32,
        degraded: &mut bool,
    ) -> Result<Self> {
        let arg = sec_arg(shard, 0);
        let bad = |why: &str| {
            Error::data(format!(
                "snapshot {}: IVF section (shard {shard}) is inconsistent: {why}",
                snap.path()
            ))
        };
        let mut r = snap.reader(tag::IVF_META, arg)?;
        let n = r.usize()?;
        let d = r.usize()?;
        let offsets64: Vec<u64> = r.vec()?;
        let ids: Vec<u32> = r.vec()?;
        let stale_list: Vec<u32> = r.vec()?;
        let pending_ids: Vec<u32> = r.vec()?;
        let pending_rows: Vec<f32> = r.vec()?;
        let grouped: Blob<f32> = snap.blob(tag::IVF_GROUPED, arg)?;
        if n != ds.n || d != ds.d {
            return Err(bad("stored shape does not match the dataset"));
        }
        if offsets64.len() != km.c + 1 {
            return Err(bad("cluster offset table does not match the coarse quantizer"));
        }
        let offsets: Vec<usize> = offsets64.iter().map(|&o| o as usize).collect();
        if offsets[0] != 0
            || offsets.windows(2).any(|w| w[0] > w[1])
            || *offsets.last().unwrap() != ids.len()
        {
            return Err(bad("cluster offsets are not a monotone cover of the grouped rows"));
        }
        if grouped.len() != ids.len().checked_mul(d).unwrap_or(usize::MAX) {
            return Err(bad("grouped row storage does not match the id list"));
        }
        if ids.iter().any(|&i| i as usize >= n) {
            return Err(bad("grouped id out of range"));
        }
        if pending_rows.len() != pending_ids.len().checked_mul(d).unwrap_or(usize::MAX) {
            return Err(bad("pending segment rows do not match pending ids"));
        }
        let quant = TierLadder::open_from(snap, cfg, shard, degraded);
        let n_probe = n_probe.clamp(1, km.c);
        Ok(IvfIndex {
            grouped,
            ids,
            offsets,
            km,
            backend,
            n_probe,
            n,
            d,
            quant,
            overscan: cfg.overscan.max(1),
            stale: stale_list.into_iter().collect(),
            pending_ids,
            pending_rows,
        })
    }
}

impl MipsIndex for IvfIndex {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        self.top_k_probes(q, k, self.n_probe)
    }

    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        if qs.len() <= 1 {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        self.top_k_batch_probes(qs, k, self.n_probe)
    }

    fn n(&self) -> usize {
        self.n
    }
    fn d(&self) -> usize {
        self.d
    }
    fn name(&self) -> &'static str {
        "ivf"
    }
    fn save_sections(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        crate::store::write_kmeans(w, sec_arg(shard, 0), &self.km)?;
        self.save_body(w, shard)
    }
    fn describe(&self) -> String {
        format!(
            "ivf over n={} d={}: {} clusters, {} probes (~{:.1}% scan){}",
            self.n,
            self.d,
            self.km.c,
            self.n_probe,
            100.0 * self.expected_scan_fraction(),
            self.quant
                .as_ref()
                .map(|l| format!(", {} two-stage", l.describe()))
                .unwrap_or_default()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::mips::{brute::BruteForce, recall_at_k};
    use crate::scorer::NativeScorer;

    fn test_cfg() -> IndexConfig {
        let mut cfg = Config::default().index;
        cfg.n_clusters = 40;
        cfg.n_probe = 8;
        cfg.kmeans_iters = 6;
        cfg.train_sample = 2000;
        cfg
    }

    #[test]
    fn high_recall_on_clustered_data() {
        let ds = Arc::new(synth::imagenet_like(5000, 16, 40, 0.25, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = IvfIndex::build(ds.clone(), &test_cfg(), backend.clone()).unwrap();
        let brute = BruteForce::new(ds.clone(), backend);
        let mut rng = Pcg64::new(2);
        let mut recalls = 0.0;
        let trials = 20;
        for _ in 0..trials {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = idx.top_k(&q, 50);
            let want = brute.top_k(&q, 50);
            recalls += recall_at_k(&got, &want);
            assert!(got.scanned < ds.n, "IVF must scan a subset");
        }
        let mean_recall = recalls / trials as f64;
        assert!(mean_recall > 0.85, "recall@50 = {mean_recall}");
    }

    #[test]
    fn grouped_storage_covers_everything() {
        let ds = Arc::new(synth::imagenet_like(1000, 8, 10, 0.3, 3));
        let idx = IvfIndex::build(ds, &test_cfg(), Arc::new(NativeScorer)).unwrap();
        // every id appears exactly once
        let mut seen = vec![false; idx.n()];
        for &id in &idx.ids {
            assert!(!seen[id as usize], "duplicate id {id}");
            seen[id as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(*idx.offsets.last().unwrap(), idx.n());
    }

    #[test]
    fn more_probes_more_recall() {
        let ds = Arc::new(synth::imagenet_like(4000, 16, 40, 0.3, 4));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = IvfIndex::build(ds.clone(), &test_cfg(), backend.clone()).unwrap();
        let brute = BruteForce::new(ds.clone(), backend);
        let mut rng = Pcg64::new(5);
        let mut r_few = 0.0;
        let mut r_many = 0.0;
        for _ in 0..10 {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let want = brute.top_k(&q, 40);
            r_few += recall_at_k(&idx.top_k_probes(&q, 40, 2), &want);
            r_many += recall_at_k(&idx.top_k_probes(&q, 40, 40), &want);
        }
        assert!(r_many >= r_few, "recall must not decrease with probes");
        assert!((r_many / 10.0) > 0.99, "all-probe recall = {}", r_many / 10.0);
    }

    #[test]
    fn top_k_batch_matches_per_query() {
        // merged probe scan + batched centroid ranking must return exactly
        // the per-query results (ids, scores, and scanned-row accounting)
        let ds = Arc::new(synth::imagenet_like(4_000, 16, 30, 0.25, 7));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut idx = IvfIndex::build(ds.clone(), &test_cfg(), backend).unwrap();
        let mut rng = Pcg64::new(8);
        for nq in [2usize, 3, 8] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 40);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 40);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned, "nq={nq} query {j}");
            }
        }
        // with sparse updates in flight, the pending segment and stale
        // tombstones must behave identically on both paths
        let q = qs_for_update(&ds);
        let boosted: Vec<f32> = q.iter().map(|x| x * 2.0).collect();
        idx.update_row(77, &boosted);
        let qs: Vec<&[f32]> = vec![q.as_slice(), q.as_slice()];
        let batch = idx.top_k_batch(&qs, 5);
        let want = idx.top_k(&q, 5);
        for got in &batch {
            assert_eq!(got.items[0].id, 77);
            assert_eq!(got.ids(), want.ids());
        }
    }

    fn qs_for_update(ds: &Dataset) -> Vec<f32> {
        let mut v = ds.row(0).to_vec();
        crate::linalg::normalize(&mut v);
        v
    }

    #[test]
    fn auto_sizing() {
        let ds = Arc::new(synth::imagenet_like(2500, 8, 20, 0.3, 6));
        let mut cfg = test_cfg();
        cfg.n_clusters = 0;
        cfg.n_probe = 0;
        let idx = IvfIndex::build(ds, &cfg, Arc::new(NativeScorer)).unwrap();
        assert_eq!(idx.n_clusters(), 200); // 4·√2500
        assert_eq!(idx.n_probe, 12); // 200/16 = 12 (≥ 8)
        assert!(idx.describe().contains("clusters"));
    }

    #[test]
    fn sparse_updates_visible_immediately_and_after_compact() {
        let ds = Arc::new(synth::imagenet_like(2000, 8, 10, 0.3, 9));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut idx = IvfIndex::build(ds.clone(), &test_cfg(), backend).unwrap();
        // craft a query and force one row to be its perfect match
        let q: Vec<f32> = {
            let mut v = ds.row(0).to_vec();
            crate::linalg::normalize(&mut v);
            v
        };
        let target = 1234u32;
        let boosted: Vec<f32> = q.iter().map(|x| x * 2.0).collect(); // score 2.0 ≫ any unit dot
        idx.update_row(target, &boosted);
        assert_eq!(idx.pending_len(), 1);
        // visible pre-compaction
        let got = idx.top_k(&q, 5);
        assert_eq!(got.items[0].id, target);
        assert!((got.items[0].score - 2.0).abs() < 1e-5);
        // update the same row again: old pending version replaced
        let boosted3: Vec<f32> = q.iter().map(|x| x * 3.0).collect();
        idx.update_row(target, &boosted3);
        assert_eq!(idx.pending_len(), 1);
        // compact and re-query: still the top hit, now from grouped storage
        idx.compact();
        assert_eq!(idx.pending_len(), 0);
        let got = idx.top_k(&q, 5);
        assert_eq!(got.items[0].id, target);
        assert!((got.items[0].score - 3.0).abs() < 1e-5);
        // no duplicate of target anywhere
        let dup = got.items.iter().filter(|s| s.id == target).count();
        assert_eq!(dup, 1);
    }

    #[test]
    fn compact_preserves_coverage() {
        let ds = Arc::new(synth::imagenet_like(1000, 8, 10, 0.3, 11));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut idx = IvfIndex::build(ds.clone(), &test_cfg(), backend).unwrap();
        for id in [5u32, 99, 500] {
            let v = ds.row(id as usize).to_vec();
            idx.update_row(id, &v); // identity update
        }
        idx.compact();
        let mut seen = vec![false; idx.n()];
        for &id in &idx.ids {
            assert!(!seen[id as usize], "duplicate id {id}");
            seen[id as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "compact must preserve all ids");
        assert_eq!(*idx.offsets.last().unwrap(), idx.n());
    }

    #[test]
    fn quant_probe_scan_bit_identical_to_f32() {
        // same build (clusters, seed) with and without the SQ8 pass must
        // return identical ids/scores/scan accounting — including through
        // sparse updates and compaction
        let ds = Arc::new(synth::imagenet_like(4_000, 16, 30, 0.25, 13));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut qcfg = test_cfg();
        qcfg.quant = crate::config::QuantKind::Sq8;
        qcfg.quant_block = 48;
        qcfg.overscan = 4;
        let mut qidx = IvfIndex::build(ds.clone(), &qcfg, backend.clone()).unwrap();
        let mut fidx = IvfIndex::build(ds.clone(), &test_cfg(), backend).unwrap();
        assert!(qidx.quant_enabled() && !fidx.quant_enabled());
        let mut rng = Pcg64::new(14);
        let check = |qidx: &IvfIndex, fidx: &IvfIndex, rng: &mut Pcg64, label: &str| {
            for k in [1usize, 17, 60] {
                let q = synth::random_theta(&ds, 0.05, rng);
                let got = qidx.top_k(&q, k);
                let want = fidx.top_k(&q, k);
                assert_eq!(got.ids(), want.ids(), "{label} k={k}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "{label} k={k}");
                }
                assert_eq!(got.scanned, want.scanned, "{label} k={k}");
            }
        };
        check(&qidx, &fidx, &mut rng, "fresh");
        // identical sparse updates on both indexes
        let mut urng = Pcg64::new(15);
        for id in [3u32, 777, 2500] {
            let v: Vec<f32> = (0..ds.d).map(|_| urng.gaussian() as f32 * 0.2).collect();
            qidx.update_row(id, &v);
            fidx.update_row(id, &v);
        }
        check(&qidx, &fidx, &mut rng, "pending");
        qidx.compact();
        fidx.compact();
        check(&qidx, &fidx, &mut rng, "compacted");
    }

    #[test]
    fn quant_batch_matches_per_query() {
        let ds = Arc::new(synth::imagenet_like(3_000, 16, 25, 0.25, 21));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut cfg = test_cfg();
        cfg.quant = crate::config::QuantKind::Sq8;
        let idx = IvfIndex::build(ds.clone(), &cfg, backend).unwrap();
        let mut rng = Pcg64::new(22);
        for nq in [2usize, 5] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 30);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 30);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned, "nq={nq} query {j}");
            }
        }
    }

    use crate::util::rng::Pcg64;
}
