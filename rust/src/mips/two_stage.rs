//! The shared two-stage screen: quantized pass-1 keep → coverage
//! certificate → exact pass-2 re-rank → fallback ladder.
//!
//! Brute, IVF, and the LSH families all scan the same way when a
//! quantized tier is configured: pass 1 screens rows on compressed codes
//! and keeps the `k·overscan` best, pass 2 re-ranks the survivors with
//! the exact f32 kernels, and the coverage certificate
//! ([`crate::linalg::quant::coverage_proved`]) decides whether the
//! re-ranked result provably **is** the exact top-k. This module is the
//! single seam those indexes plug into:
//!
//! * [`QuantTier`] — one screening tier (SQ8 / SQ4 / PQ) behind a
//!   uniform encode/score/bound interface, so new code formats slot in
//!   here and every index picks them up.
//! * [`TierLadder`] — the configured tier stack, most-compressed first.
//!   A certificate miss falls **up** the ladder (PQ/SQ4 → SQ8) before
//!   surrendering to the plain f32 scan, so adversarial data costs at
//!   most the cheap screens; results are bit-identical to the f32-only
//!   scan on every rung by the certificate contract.
//! * [`finish_screen`] / [`rerank_gather`] — the shared pass-2 +
//!   certificate step the per-index screens feed.
//! * [`scan_candidates_quant`] — the complete two-stage candidate-list
//!   scan the LSH families use.

use super::TopKResult;
use crate::config::{IndexConfig, QuantKind};
use crate::data::Dataset;
use crate::error::Result;
use crate::linalg::pq::{PqLut, PqView};
use crate::linalg::quant::{coverage_proved, QuantQuery, QuantView, Sq4View};
use crate::scorer::ScoreBackend;
use crate::store::format::{sec_arg, Snapshot, SnapshotWriter};
use crate::util::topk::{Scored, TopK};

/// Rows per survivor gather/re-rank block (pass 2).
const GATHER_BLOCK: usize = 1024;

/// One quantized screening tier behind the uniform interface the
/// two-stage scan drives. All variants guarantee
/// `|exact − quantized| ≤ error_bound` per row, and their batched entry
/// points are bit-identical to single-query scoring.
pub enum QuantTier {
    /// 8-bit scalar codes ([`QuantView`]).
    Sq8(QuantView),
    /// Packed 4-bit scalar codes ([`Sq4View`]).
    Sq4(Sq4View),
    /// Product quantization ([`PqView`]).
    Pq(PqView),
}

/// A query encoded for one tier (integer codes for the scalar tiers, u8
/// lookup tables for PQ).
pub enum TierQuery {
    Int(QuantQuery),
    Lut(PqLut),
}

impl TierQuery {
    fn int(&self) -> &QuantQuery {
        match self {
            TierQuery::Int(q) => q,
            TierQuery::Lut(_) => unreachable!("integer tier scored with a PQ query"),
        }
    }
    fn lut(&self) -> &PqLut {
        match self {
            TierQuery::Lut(l) => l,
            TierQuery::Int(_) => unreachable!("PQ tier scored with an integer query"),
        }
    }
}

impl QuantTier {
    /// Encode a query for this tier's screening pass.
    pub fn encode_query(&self, q: &[f32]) -> TierQuery {
        match self {
            QuantTier::Sq8(_) | QuantTier::Sq4(_) => TierQuery::Int(QuantQuery::encode(q)),
            QuantTier::Pq(v) => TierQuery::Lut(v.encode_query(q)),
        }
    }

    /// Uniform per-row bound `|exact − quantized| ≤ ε` for `tq`.
    pub fn error_bound(&self, tq: &TierQuery) -> f32 {
        match self {
            QuantTier::Sq8(v) => v.error_bound(tq.int()),
            QuantTier::Sq4(v) => v.error_bound(tq.int()),
            QuantTier::Pq(v) => v.error_bound(tq.lut()),
        }
    }

    /// Quantized scores for rows `[row_start, row_end)`.
    pub fn scores(&self, row_start: usize, row_end: usize, tq: &TierQuery, out: &mut [f32]) {
        match self {
            QuantTier::Sq8(v) => v.scores(row_start, row_end, tq.int(), out),
            QuantTier::Sq4(v) => v.scores(row_start, row_end, tq.int(), out),
            QuantTier::Pq(v) => v.scores(row_start, row_end, tq.lut(), out),
        }
    }

    /// Quantized scores for an explicit (gathered) id list.
    pub fn scores_ids(&self, ids: &[u32], tq: &TierQuery, out: &mut [f32]) {
        match self {
            QuantTier::Sq8(v) => v.scores_ids(ids, tq.int(), out),
            QuantTier::Sq4(v) => v.scores_ids(ids, tq.int(), out),
            QuantTier::Pq(v) => v.scores_ids(ids, tq.lut(), out),
        }
    }

    /// Multi-query quantized scores, query-major `[nq × nrows]` — each
    /// code block streams once for the whole batch; output bit-identical
    /// to per-query [`scores`](Self::scores) calls. On a 4-bit PQ tier
    /// with built tiles and `nq ≥ `[`crate::linalg::pq::FS_MIN_BATCH`],
    /// the scan rides the register-resident fast-scan layout
    /// ([`PqView::scores_batch`] dispatches; [`Self::batch_layout`]
    /// names the path taken).
    pub fn scores_batch(
        &self,
        row_start: usize,
        row_end: usize,
        tqs: &[&TierQuery],
        out: &mut [f32],
    ) {
        match self {
            QuantTier::Sq8(v) => {
                let qs: Vec<&QuantQuery> = tqs.iter().map(|t| t.int()).collect();
                v.scores_batch(row_start, row_end, &qs, out);
            }
            QuantTier::Sq4(v) => {
                let qs: Vec<&QuantQuery> = tqs.iter().map(|t| t.int()).collect();
                v.scores_batch(row_start, row_end, &qs, out);
            }
            QuantTier::Pq(v) => {
                let qs: Vec<&PqLut> = tqs.iter().map(|t| t.lut()).collect();
                v.scores_batch(row_start, row_end, &qs, out);
            }
        }
    }

    /// Which batched-scan layout a `nq`-query pass-1 screen rides on
    /// this tier: `"fastscan"` for a 4-bit PQ tier whose register-
    /// resident tiles serve the batch (built tiles and
    /// `nq ≥ `[`crate::linalg::pq::FS_MIN_BATCH`]), `"plane"` otherwise.
    /// Dispatch itself lives in [`PqView::scores_batch`]; this predicate
    /// mirrors it for the `layout` label on
    /// `gmips_tier_rows_screened_total` and for describe strings.
    pub fn batch_layout(&self, nq: usize) -> &'static str {
        match self {
            QuantTier::Pq(v) if v.serves_fastscan(nq) => "fastscan",
            _ => "plane",
        }
    }

    /// Tier name for logs/describe strings.
    pub fn name(&self) -> &'static str {
        match self {
            QuantTier::Sq8(_) => "sq8",
            QuantTier::Sq4(_) => "sq4",
            QuantTier::Pq(_) => "pq",
        }
    }
}

/// Per-batch scoring handle for one tier: the whole query batch
/// unwrapped to its homogeneous form **once**, plus reusable selection
/// scratch — so the batched pass-1 screens (brute's block loop, IVF's
/// per-cluster merged-probe loop) stay allocation-free per scoring call.
pub struct TierBatch<'a> {
    tier: &'a QuantTier,
    int: Vec<&'a QuantQuery>,
    lut: Vec<&'a PqLut>,
    int_sel: Vec<&'a QuantQuery>,
    lut_sel: Vec<&'a PqLut>,
    /// `gmips_tier_rows_screened_total{layout=...}` handles, interned
    /// once per batch so the per-block/per-cluster scoring calls touch
    /// only the cached atomic.
    rows_plane: std::sync::Arc<crate::obs::Counter>,
    rows_fastscan: std::sync::Arc<crate::obs::Counter>,
}

impl<'a> TierBatch<'a> {
    /// Unwrap `tqs` (all encoded by `tier`) into the tier's homogeneous
    /// query form.
    pub fn new(tier: &'a QuantTier, tqs: &'a [TierQuery]) -> TierBatch<'a> {
        let mut int = Vec::new();
        let mut lut = Vec::new();
        match tier {
            QuantTier::Sq8(_) | QuantTier::Sq4(_) => int.extend(tqs.iter().map(|t| t.int())),
            QuantTier::Pq(_) => lut.extend(tqs.iter().map(|t| t.lut())),
        }
        let obs = crate::obs::registry();
        TierBatch {
            tier,
            int,
            lut,
            int_sel: Vec::new(),
            lut_sel: Vec::new(),
            rows_plane: obs.tier_rows_screened.handle("plane"),
            rows_fastscan: obs.tier_rows_screened.handle("fastscan"),
        }
    }

    /// Account `nq × nrows` row-scores to the layout that served them
    /// (coarse, per scoring call — never per row).
    fn note_rows(&self, nq: usize, nrows: usize) {
        let c = match self.tier.batch_layout(nq) {
            "fastscan" => &self.rows_fastscan,
            _ => &self.rows_plane,
        };
        c.add((nq * nrows) as u64);
    }

    /// Multi-query scores for the whole batch, query-major
    /// `[nq × nrows]` — [`QuantTier::scores_batch`] without the per-call
    /// unwrap.
    pub fn scores_all(&self, row_start: usize, row_end: usize, out: &mut [f32]) {
        match self.tier {
            QuantTier::Sq8(v) => v.scores_batch(row_start, row_end, &self.int, out),
            QuantTier::Sq4(v) => v.scores_batch(row_start, row_end, &self.int, out),
            QuantTier::Pq(v) => v.scores_batch(row_start, row_end, &self.lut, out),
        }
        self.note_rows(self.int.len().max(self.lut.len()), row_end - row_start);
    }

    /// Multi-query scores for the query subset `qsel` (indices into the
    /// batch), query-major `[qsel.len() × nrows]`, reusing the internal
    /// selection scratch — no allocation after warmup.
    pub fn scores_sel(&mut self, row_start: usize, row_end: usize, qsel: &[u32], out: &mut [f32]) {
        match self.tier {
            QuantTier::Sq8(v) => {
                self.int_sel.clear();
                self.int_sel.extend(qsel.iter().map(|&j| self.int[j as usize]));
                v.scores_batch(row_start, row_end, &self.int_sel, out);
            }
            QuantTier::Sq4(v) => {
                self.int_sel.clear();
                self.int_sel.extend(qsel.iter().map(|&j| self.int[j as usize]));
                v.scores_batch(row_start, row_end, &self.int_sel, out);
            }
            QuantTier::Pq(v) => {
                self.lut_sel.clear();
                self.lut_sel.extend(qsel.iter().map(|&j| self.lut[j as usize]));
                v.scores_batch(row_start, row_end, &self.lut_sel, out);
            }
        }
        self.note_rows(qsel.len(), row_end - row_start);
    }
}

/// The configured screening-tier stack, most-compressed first, with SQ8
/// as the safety rung under SQ4/PQ (tentpole ladder:
/// PQ/SQ4 → SQ8 → f32; the f32 rung is the caller's plain scan).
///
/// Memory: the SQ4/PQ ladders **eagerly** encode the SQ8 rung too, so
/// their quantized footprint is dominated by its `n·d` bytes (¼ of the
/// f32 rows) — the PQ/SQ4 codes only add `≤ n·d/8` on top. The rung is
/// built eagerly because scans take `&self`: materializing it lazily on
/// the first certificate miss would put locking on the hot path.
pub struct TierLadder {
    tiers: Vec<QuantTier>,
    desc: String,
}

/// `pq_m` resolution: 0 auto-picks the widest subspace of 8/4/2/1 dims
/// that divides `d`; an explicit `pq_m` must divide `d` — the same rule
/// `Config::validate` enforces on the config path, asserted here so
/// direct library builds fail loudly instead of silently training a
/// different subspace count.
fn resolve_pq_m(d: usize, pq_m: usize) -> usize {
    if pq_m != 0 {
        assert!(
            d % pq_m == 0,
            "index.pq_m = {pq_m} must evenly divide d = {d} (0 = auto)"
        );
        return pq_m;
    }
    for dsub in [8usize, 4, 2] {
        if d % dsub == 0 {
            return d / dsub;
        }
    }
    d
}

impl TierLadder {
    /// Build the configured ladder over a row-major `[n × d]` matrix
    /// (`None` when `index.quant` is off). PQ codebooks train on a
    /// deterministic subsample capped at `64 · 2^pq_bits` rows (and by
    /// `index.train_sample` when set).
    pub fn from_cfg(rows: &[f32], d: usize, cfg: &IndexConfig) -> Option<TierLadder> {
        let block = cfg.quant_block.max(1);
        let tiers = match cfg.quant {
            QuantKind::Off => return None,
            QuantKind::Sq8 => vec![QuantTier::Sq8(QuantView::encode(rows, d, block))],
            QuantKind::Sq4 => vec![
                QuantTier::Sq4(Sq4View::encode(rows, d, block)),
                QuantTier::Sq8(QuantView::encode(rows, d, block)),
            ],
            QuantKind::Pq => {
                let m = resolve_pq_m(d, cfg.pq_m);
                let bits = if cfg.pq_bits == 4 { 4 } else { 8 };
                let n = if d == 0 { 0 } else { rows.len() / d };
                let base = if cfg.train_sample == 0 { n } else { cfg.train_sample.min(n) };
                let train_n = base.min(64 << bits).max(1);
                vec![
                    QuantTier::Pq(PqView::train(
                        rows,
                        d,
                        m,
                        bits,
                        train_n,
                        cfg.kmeans_iters,
                        cfg.seed ^ 0x90C0DE,
                    )),
                    QuantTier::Sq8(QuantView::encode(rows, d, block)),
                ]
            }
        };
        let desc = match &tiers[0] {
            QuantTier::Pq(v) => format!("pq(m={},b={})→sq8", v.m(), v.bits()),
            QuantTier::Sq4(_) => "sq4→sq8".to_string(),
            QuantTier::Sq8(_) => "sq8".to_string(),
        };
        Some(TierLadder { tiers, desc })
    }

    /// The tiers, most-compressed first.
    pub fn tiers(&self) -> &[QuantTier] {
        &self.tiers
    }

    /// The first (most compressed) tier — what batched pass-1 screens
    /// run; per-query certificate misses continue with
    /// [`tiers`](Self::tiers)`[1..]`.
    pub fn primary(&self) -> &QuantTier {
        &self.tiers[0]
    }

    /// Ladder summary for describe strings (e.g. `pq(m=16,b=4)→sq8`).
    pub fn describe(&self) -> &str {
        &self.desc
    }

    /// Re-encode every tier against the current contents of `rows` —
    /// the compaction coherence hook. Scalar tiers re-encode their
    /// blocks; PQ re-assigns codes against its fixed codebooks.
    pub fn reencode(&mut self, rows: &[f32]) {
        for t in &mut self.tiers {
            match t {
                QuantTier::Sq8(v) => *v = QuantView::encode(rows, v.d(), v.block()),
                QuantTier::Sq4(v) => *v = Sq4View::encode(rows, v.d(), v.block()),
                QuantTier::Pq(v) => v.reencode(rows),
            }
        }
    }

    /// Write every tier's sections under `shard` (slot = ladder
    /// position, so the primary tier is slot 0 and the SQ8 safety rung
    /// slot 1).
    pub(crate) fn save_sections(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        for (slot, tier) in self.tiers.iter().enumerate() {
            let arg = sec_arg(shard, slot as u32);
            match tier {
                QuantTier::Sq8(v) => v.save_sections(w, arg)?,
                QuantTier::Sq4(v) => v.save_sections(w, arg)?,
                QuantTier::Pq(v) => v.save_sections(w, arg)?,
            }
        }
        Ok(())
    }

    /// Reopen the ladder `cfg` calls for from a snapshot. `None` with
    /// `degraded` untouched when `index.quant` is off; `None` with
    /// `degraded = true` when any tier section is missing, corrupt, or
    /// shape-inconsistent — the index then serves from the f32 tier
    /// (answers stay bit-identical by the certificate contract, only the
    /// screening bandwidth savings are lost).
    pub(crate) fn open_from(
        snap: &Snapshot,
        cfg: &IndexConfig,
        shard: u32,
        degraded: &mut bool,
    ) -> Option<TierLadder> {
        if matches!(cfg.quant, QuantKind::Off) {
            return None;
        }
        let opened = Self::open_tiers(snap, cfg, shard);
        if opened.is_none() {
            *degraded = true;
        }
        opened
    }

    fn open_tiers(snap: &Snapshot, cfg: &IndexConfig, shard: u32) -> Option<TierLadder> {
        let tiers = match cfg.quant {
            QuantKind::Off => return None,
            QuantKind::Sq8 => {
                vec![QuantTier::Sq8(QuantView::open_sections(snap, sec_arg(shard, 0))?)]
            }
            QuantKind::Sq4 => vec![
                QuantTier::Sq4(Sq4View::open_sections(snap, sec_arg(shard, 0))?),
                QuantTier::Sq8(QuantView::open_sections(snap, sec_arg(shard, 1))?),
            ],
            QuantKind::Pq => vec![
                QuantTier::Pq(PqView::open_sections(snap, sec_arg(shard, 0))?),
                QuantTier::Sq8(QuantView::open_sections(snap, sec_arg(shard, 1))?),
            ],
        };
        let desc = match &tiers[0] {
            QuantTier::Pq(v) => format!("pq(m={},b={})→sq8", v.m(), v.bits()),
            QuantTier::Sq4(_) => "sq4→sq8".to_string(),
            QuantTier::Sq8(_) => "sq8".to_string(),
        };
        Some(TierLadder { tiers, desc })
    }
}

/// Finish one tier's screen: exact pass-2 re-rank of the retained
/// candidates plus the coverage certificate. `cands` is pass 1's sorted
/// keep (capacity `cap`), `pushed` how many rows pass 1 offered —
/// `dropped` (rows were actually rejected/evicted) holds iff the
/// collector filled *and* more was offered than it holds. `rerank`
/// scores the retained ids with the exact f32 kernels into the returned
/// collector. `None` when the certificate fails — the caller tries the
/// next ladder rung (or the f32 scan).
pub(crate) fn finish_screen(
    tier: &QuantTier,
    tq: &TierQuery,
    cands: Vec<Scored>,
    pushed: usize,
    cap: usize,
    kk: usize,
    rerank: impl FnOnce(&[u32], &mut TopK),
) -> Option<TopK> {
    let dropped = cands.len() == cap && pushed > cap;
    let q_floor = cands.last().map(|s| s.score).unwrap_or(f32::NEG_INFINITY);
    let ids: Vec<u32> = cands.iter().map(|s| s.id).collect();
    let mut tk = TopK::new(kk);
    rerank(&ids, &mut tk);
    let obs = crate::obs::registry();
    obs.screen_rows_screened.add(pushed as u64);
    obs.screen_rows_reranked.add(ids.len() as u64);
    let rung = crate::obs::tier_index(tier.name());
    if !coverage_proved(dropped, q_floor, tier.error_bound(tq), tk.threshold()) {
        obs.screen_cert_misses[rung].inc();
        return None;
    }
    obs.screen_cert_hits[rung].inc();
    Some(tk)
}

/// Exact pass-2 re-rank for dataset-id candidates: gather the rows in
/// blocks, score with the same f32 kernels the one-stage scan uses, push
/// into `tk`. Shared by the brute screen and the candidate-list scan
/// (IVF reranks from its grouped storage instead).
pub(crate) fn rerank_gather(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    q: &[f32],
    ids: &[u32],
    tk: &mut TopK,
) {
    let d = ds.d;
    let mut rows = vec![0f32; GATHER_BLOCK.min(ids.len().max(1)) * d];
    let mut out = vec![0f32; GATHER_BLOCK];
    let mut start = 0;
    while start < ids.len() {
        let end = (start + GATHER_BLOCK).min(ids.len());
        let chunk = &ids[start..end];
        let rows_buf = &mut rows[..(end - start) * d];
        ds.gather(chunk, rows_buf);
        let out_buf = &mut out[..end - start];
        backend.scores(rows_buf, d, q, out_buf);
        tk.push_ids(chunk, out_buf);
        start = end;
    }
}

/// Two-stage candidate-list scan (the LSH families' quantized path):
/// screen the candidates on the ladder's codes
/// ([`QuantTier::scores_ids`]), keep the `k·overscan` best, exact-re-rank
/// the survivors, certify — walking the ladder on certificate misses.
/// When a rung certifies, ids *and* scores are bit-identical to the
/// f32-only candidate scan, with the same `scanned` accounting (pass 1
/// visits every candidate). `None` when the screen cannot prune
/// (`k·overscan ≥ |cands|`) or no rung certifies; the caller falls back
/// to [`super::scan_candidates_f32`].
pub(crate) fn scan_candidates_quant(
    ds: &Dataset,
    ladder: &TierLadder,
    backend: &dyn ScoreBackend,
    q: &[f32],
    k: usize,
    cands: &[u32],
    overscan: usize,
) -> Option<TopKResult> {
    let kk = k.min(ds.n).max(1);
    let cap = kk.saturating_mul(overscan).max(kk);
    if cap >= cands.len() {
        // pass 1 would retain everything: the one-stage scan is strictly
        // cheaper than screen + gather-re-rank-all
        return None;
    }
    const BLOCK: usize = 4096;
    let mut out = vec![0f32; BLOCK.min(cands.len())];
    for tier in ladder.tiers() {
        let tq = tier.encode_query(q);
        let mut tk = TopK::new(cap);
        let mut start = 0;
        while start < cands.len() {
            let end = (start + BLOCK).min(cands.len());
            let ids = &cands[start..end];
            let out_buf = &mut out[..end - start];
            tier.scores_ids(ids, &tq, out_buf);
            tk.push_ids(ids, out_buf);
            start = end;
        }
        let rerank = |ids: &[u32], tk: &mut TopK| rerank_gather(ds, backend, q, ids, tk);
        let finished = finish_screen(tier, &tq, tk.into_sorted(), cands.len(), cap, kk, rerank);
        if let Some(tk2) = finished {
            return Some(TopKResult { items: tk2.into_sorted(), scanned: cands.len() });
        }
    }
    crate::obs::registry().screen_f32_fallbacks.inc();
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::rng::Pcg64;

    #[test]
    fn resolve_pq_m_prefers_wide_divisors() {
        assert_eq!(resolve_pq_m(64, 16), 16); // explicit divisor wins
        assert_eq!(resolve_pq_m(64, 0), 8); // auto: dsub = 8
        assert_eq!(resolve_pq_m(12, 0), 3); // dsub = 4
        assert_eq!(resolve_pq_m(7, 0), 7); // prime d → per-dim tables
    }

    #[test]
    #[should_panic(expected = "must evenly divide")]
    fn resolve_pq_m_rejects_non_divisors() {
        // direct library builds get the same rule Config::validate
        // enforces, loudly
        resolve_pq_m(64, 7);
    }

    #[test]
    fn ladder_shapes_per_kind() {
        let mut rng = Pcg64::new(1);
        let d = 16usize;
        let rows: Vec<f32> = (0..200 * d).map(|_| rng.gaussian() as f32).collect();
        let mut cfg = Config::default().index;
        cfg.quant = crate::config::QuantKind::Off;
        assert!(TierLadder::from_cfg(&rows, d, &cfg).is_none());
        cfg.quant = crate::config::QuantKind::Sq8;
        let l = TierLadder::from_cfg(&rows, d, &cfg).unwrap();
        assert_eq!(l.tiers().len(), 1);
        assert_eq!(l.describe(), "sq8");
        cfg.quant = crate::config::QuantKind::Sq4;
        let l = TierLadder::from_cfg(&rows, d, &cfg).unwrap();
        assert_eq!(l.tiers().len(), 2);
        assert_eq!(l.primary().name(), "sq4");
        assert_eq!(l.tiers()[1].name(), "sq8");
        cfg.quant = crate::config::QuantKind::Pq;
        cfg.pq_bits = 4;
        let l = TierLadder::from_cfg(&rows, d, &cfg).unwrap();
        assert_eq!(l.primary().name(), "pq");
        assert!(l.describe().contains("pq(m=2,b=4)"), "{}", l.describe());
    }

    #[test]
    fn tier_queries_score_consistently_across_forms() {
        // every tier: scores / scores_ids / scores_batch agree bitwise
        let mut rng = Pcg64::new(2);
        let (n, d) = (120usize, 24usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let mut cfg = Config::default().index;
        cfg.pq_bits = 4;
        for kind in
            [crate::config::QuantKind::Sq8, crate::config::QuantKind::Sq4, crate::config::QuantKind::Pq]
        {
            cfg.quant = kind;
            let ladder = TierLadder::from_cfg(&rows, d, &cfg).unwrap();
            for tier in ladder.tiers() {
                let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
                let tq = tier.encode_query(&q);
                let mut full = vec![0f32; n];
                tier.scores(0, n, &tq, &mut full);
                let ids: Vec<u32> = (0..n as u32).step_by(3).collect();
                let mut scattered = vec![0f32; ids.len()];
                tier.scores_ids(&ids, &tq, &mut scattered);
                for (i, &id) in ids.iter().enumerate() {
                    assert_eq!(
                        scattered[i].to_bits(),
                        full[id as usize].to_bits(),
                        "{} id {id}",
                        tier.name()
                    );
                }
                let tq2 = tier.encode_query(&q);
                let refs = [&tq, &tq2];
                let mut batch = vec![0f32; 2 * n];
                tier.scores_batch(0, n, &refs, &mut batch);
                for j in 0..2 {
                    for (a, b) in batch[j * n..(j + 1) * n].iter().zip(&full) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{} batch q{j}", tier.name());
                    }
                }
                assert!(tier.error_bound(&tq) >= 0.0);
            }
        }
    }

    #[test]
    fn batch_layout_tracks_fastscan_dispatch() {
        let mut rng = Pcg64::new(7);
        let (n, d) = (200usize, 16usize);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let mut cfg = Config::default().index;
        cfg.quant = crate::config::QuantKind::Pq;
        cfg.pq_bits = 4;
        let ladder = TierLadder::from_cfg(&rows, d, &cfg).unwrap();
        let pq = ladder.primary();
        // the label predicate mirrors PqView's dispatch thresholds
        assert_eq!(pq.batch_layout(crate::linalg::pq::FS_MIN_BATCH), "fastscan");
        assert_eq!(pq.batch_layout(crate::linalg::pq::FS_MIN_BATCH - 1), "plane");
        assert_eq!(ladder.tiers()[1].batch_layout(64), "plane"); // sq8 never tiles
        // a fast-scan batch through TierBatch stays bit-identical to
        // per-query scoring and moves the labeled family monotonically
        let obs = crate::obs::registry();
        let before = obs.tier_rows_screened.handle("fastscan").get();
        let qs: Vec<Vec<f32>> =
            (0..4).map(|_| (0..d).map(|_| rng.gaussian() as f32).collect()).collect();
        let tqs: Vec<TierQuery> = qs.iter().map(|q| pq.encode_query(q)).collect();
        let tb = TierBatch::new(pq, &tqs);
        let mut out = vec![0f32; 4 * n];
        tb.scores_all(0, n, &mut out);
        for (j, tq) in tqs.iter().enumerate() {
            let mut one = vec![0f32; n];
            pq.scores(0, n, tq, &mut one);
            for (a, b) in out[j * n..(j + 1) * n].iter().zip(&one) {
                assert_eq!(a.to_bits(), b.to_bits(), "fastscan batch q{j}");
            }
        }
        assert!(obs.tier_rows_screened.handle("fastscan").get() >= before);
    }
}
