//! Tiered LSH — the construction behind Theorem 3.6 / Definition 3.1.
//!
//! The paper builds a *ladder* of LSH instances tuned to similarity
//! thresholds spaced `c/2` apart; at query time it walks the ladder from
//! the most selective instance down, gathering candidates until `k` are
//! found. The returned set is an **approximate top-k with gap `c`**:
//! `max_{i∉S} y_i − min_{i∈S} y_i < c` with high probability.
//!
//! With SRP hashes, selectivity is tuned by the number of bits: a rung
//! with `b` bits collides with probability `(1 − angle/π)^b`, so higher
//! rungs only retain near-duplicates of the query direction. We build
//! `rungs` instances with decreasing bit counts and walk them top-down.
//!
//! Because SRP rungs are probabilistic rather than threshold-sharp, the
//! implementation *measures* its gap at build time on held-out probe
//! queries (exact scan) and reports that as `gap_bound` — an honest,
//! data-dependent `c` that the samplers then feed into the
//! `B ← B − c` adjustment (§3.4).

use super::two_stage::{self, TierLadder};
use super::{MipsIndex, TopKResult};
use crate::config::IndexConfig;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg;
use crate::scorer::ScoreBackend;
use crate::store::format::{sec_arg, tag, ByteWriter, Snapshot, SnapshotWriter};
use crate::util::rng::Pcg64;
use std::sync::Arc;

struct Rung {
    bits: usize,
    /// row-major `[bits × d]` projection planes
    planes: Vec<f32>,
    /// CSR buckets
    bucket_off: Vec<u32>,
    members: Vec<u32>,
}

/// Ladder of LSH instances (most selective first).
pub struct TieredLsh {
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    rungs: Vec<Rung>,
    /// measured approximate-top-k gap (Definition 3.1), in *score units of
    /// a unit-norm query*; scale by ‖θ‖ for a given query
    gap_per_unit_query: f64,
    /// screening-tier ladder for the two-stage candidate scan (None =
    /// plain f32 gather scan)
    quant: Option<TierLadder>,
    /// pass-1 retention factor (`k·overscan` candidates)
    overscan: usize,
}

impl TieredLsh {
    pub fn build(ds: Arc<Dataset>, cfg: &IndexConfig, backend: Arc<dyn ScoreBackend>) -> Result<Self> {
        let n = ds.n;
        let d = ds.d;
        let n_rungs = cfg.rungs.clamp(2, 24);
        // bit counts from fine to coarse, e.g. 16,14,12,…
        let max_bits = cfg.bits.clamp(4, 20).max(n_rungs + 3);
        let mut rng = Pcg64::new(cfg.seed ^ 0x71E7);
        let mut rungs = Vec::with_capacity(n_rungs);
        for r in 0..n_rungs {
            let bits = (max_bits - r).max(3);
            let planes: Vec<f32> = (0..bits * d).map(|_| rng.gaussian() as f32).collect();
            let nbuckets = 1usize << bits;
            let mut codes = vec![0u32; n];
            for i in 0..n {
                codes[i] = srp_hash(&planes, bits, ds.row(i));
            }
            let mut counts = vec![0u32; nbuckets + 1];
            for &c in &codes {
                counts[c as usize + 1] += 1;
            }
            for b in 0..nbuckets {
                counts[b + 1] += counts[b];
            }
            let bucket_off = counts.clone();
            let mut cursor = counts;
            let mut members = vec![0u32; n];
            for (i, &c) in codes.iter().enumerate() {
                members[cursor[c as usize] as usize] = i as u32;
                cursor[c as usize] += 1;
            }
            rungs.push(Rung { bits, planes, bucket_off, members });
        }

        let quant = TierLadder::from_cfg(&ds.data, d, cfg);
        let mut idx = TieredLsh {
            ds,
            backend,
            rungs,
            gap_per_unit_query: 0.0,
            quant,
            overscan: cfg.overscan.max(1),
        };
        idx.gap_per_unit_query = idx.measure_gap(8, cfg.seed ^ 0xC0FF);
        Ok(idx)
    }

    /// Whether the quantized screening pass is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant.is_some()
    }

    // ---- snapshot persistence ------------------------------------------

    /// Rebuild from the `TIERED_META` section written by
    /// [`MipsIndex::save_sections`]. The build-time *measured* gap
    /// (Definition 3.1) is persisted and restored verbatim — re-measuring
    /// on open would both cost probe scans and report a different bound
    /// than the index the snapshot was taken from.
    pub fn open_from(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        snap: &Snapshot,
        shard: u32,
        degraded: &mut bool,
    ) -> Result<Self> {
        let mut r = snap.reader(tag::TIERED_META, sec_arg(shard, 0))?;
        let bad = |why: &str| {
            Error::data(format!(
                "snapshot {}: tiered-LSH section (shard {shard}) is inconsistent: {why}",
                snap.path()
            ))
        };
        let gap_per_unit_query = r.f64()?;
        if !gap_per_unit_query.is_finite() || gap_per_unit_query < 0.0 {
            return Err(bad("measured gap is not a finite non-negative value"));
        }
        let n_rungs = r.usize()?;
        if n_rungs == 0 || n_rungs > 24 {
            return Err(bad("implausible rung count"));
        }
        let d = ds.d;
        let n = ds.n;
        let mut rungs = Vec::with_capacity(n_rungs);
        for _ in 0..n_rungs {
            let bits = r.usize()?;
            let planes: Vec<f32> = r.vec()?;
            let bucket_off: Vec<u32> = r.vec()?;
            let members: Vec<u32> = r.vec()?;
            if !(1..=27).contains(&bits) {
                // build caps at max(20, rungs+3) ≤ 27 bits
                return Err(bad("rung bits out of range"));
            }
            if planes.len() != bits * d {
                return Err(bad("rung planes do not match bits × d"));
            }
            if bucket_off.len() != (1usize << bits) + 1 {
                return Err(bad("rung bucket table does not match bits"));
            }
            if bucket_off[0] != 0
                || bucket_off.windows(2).any(|w| w[0] > w[1])
                || *bucket_off.last().unwrap() as usize != members.len()
            {
                return Err(bad("rung bucket offsets are not a monotone cover of the members"));
            }
            if members.iter().any(|&id| id as usize >= n) {
                return Err(bad("rung bucket member out of range"));
            }
            rungs.push(Rung { bits, planes, bucket_off, members });
        }
        let quant = TierLadder::open_from(snap, cfg, shard, degraded);
        Ok(TieredLsh {
            ds,
            backend,
            rungs,
            gap_per_unit_query,
            quant,
            overscan: cfg.overscan.max(1),
        })
    }

    /// Measure the empirical Definition-3.1 gap on `probes` random
    /// database-drawn queries with an exact scan; returns the max observed
    /// gap per unit query norm (≥ 0).
    fn measure_gap(&self, probes: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::new(seed);
        let k = (self.ds.n as f64).sqrt().round() as usize;
        let k = k.clamp(1, self.ds.n);
        let mut all = vec![0f32; self.ds.n];
        let mut worst = 0f64;
        for _ in 0..probes {
            let q = self.ds.row(rng.next_below(self.ds.n as u64) as usize).to_vec();
            let got = self.top_k(&q, k);
            self.backend.scores(&self.ds.data, self.ds.d, &q, &mut all);
            let ids: rustc_hash::FxHashSet<u32> = got.items.iter().map(|s| s.id).collect();
            let max_out = all
                .iter()
                .enumerate()
                .filter(|(i, _)| !ids.contains(&(*i as u32)))
                .map(|(_, &s)| s as f64)
                .fold(f64::NEG_INFINITY, f64::max);
            let qn = linalg::norm(&q) as f64;
            if qn > 0.0 {
                worst = worst.max((max_out - got.s_min()) / qn);
            }
        }
        worst.max(0.0)
    }

    /// The measured per-unit-norm gap (scale by ‖θ‖ to get score-space c).
    pub fn gap_per_unit_query(&self) -> f64 {
        self.gap_per_unit_query
    }

    /// Candidate ids for `q`: walk the ladder fine → coarse until `k`
    /// candidates are gathered, topping up sequentially if the ladder is
    /// exhausted (Definition 3.1 needs a fixed-size set).
    fn candidates(&self, q: &[f32], k: usize) -> Vec<u32> {
        let mut seen = vec![false; self.ds.n];
        let mut cands: Vec<u32> = Vec::with_capacity(2 * k);
        for rung in &self.rungs {
            let code = srp_hash(&rung.planes, rung.bits, q);
            // probe the query bucket and its 1-bit neighbors (sharper
            // rungs otherwise miss borderline points)
            let mut visit = |c: u32| {
                let (s, e) = (rung.bucket_off[c as usize], rung.bucket_off[c as usize + 1]);
                for &id in &rung.members[s as usize..e as usize] {
                    if !seen[id as usize] {
                        seen[id as usize] = true;
                        cands.push(id);
                    }
                }
            };
            visit(code);
            for b in 0..rung.bits {
                visit(code ^ (1u32 << b));
            }
            if cands.len() >= k {
                break;
            }
        }
        // fallback: ladder exhausted without k candidates → top up with a
        // sequential fill so |S| = k always holds
        if cands.len() < k {
            for id in 0..self.ds.n as u32 {
                if !seen[id as usize] {
                    seen[id as usize] = true;
                    cands.push(id);
                    if cands.len() >= k {
                        break;
                    }
                }
            }
        }
        cands
    }
}

fn srp_hash(planes: &[f32], bits: usize, v: &[f32]) -> u32 {
    let d = v.len();
    let mut code = 0u32;
    for b in 0..bits {
        if linalg::dot(&planes[b * d..(b + 1) * d], v) >= 0.0 {
            code |= 1 << b;
        }
    }
    code
}

impl MipsIndex for TieredLsh {
    /// With `index.quant`, the candidate scan is two-stage
    /// ([`two_stage::scan_candidates_quant`]): screen on the ladder's
    /// compressed codes, exact re-rank of survivors, bit-identical by
    /// the coverage certificate — else the plain f32 gather scan.
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        let k = k.min(self.ds.n).max(1);
        let cands = self.candidates(q, k);
        if let Some(ladder) = &self.quant {
            if let Some(r) = two_stage::scan_candidates_quant(
                &self.ds,
                ladder,
                self.backend.as_ref(),
                q,
                k,
                &cands,
                self.overscan,
            ) {
                return r;
            }
        }
        super::scan_candidates_f32(&self.ds, self.backend.as_ref(), q, k, &cands)
    }

    /// Batch-aware probing: each query's ladder walk produces its
    /// candidate set exactly as [`top_k`](MipsIndex::top_k) would, then
    /// the union is gathered and scored once per batch via
    /// [`ScoreBackend::scores_batch`] — identical results, one stream of
    /// the gathered rows instead of one per query. With quantization
    /// enabled the batch degrades to per-query two-stage scans.
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        if qs.len() <= 1 || self.quant.is_some() {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let kk = k.min(self.ds.n).max(1);
        let cand_sets: Vec<Vec<u32>> = qs.iter().map(|q| self.candidates(q, kk)).collect();
        super::batch_scan_candidates(&self.ds, self.backend.as_ref(), qs, kk, &cand_sets)
    }

    fn n(&self) -> usize {
        self.ds.n
    }
    fn d(&self) -> usize {
        self.ds.d
    }
    fn gap_bound(&self) -> Option<f64> {
        Some(self.gap_per_unit_query)
    }
    fn name(&self) -> &'static str {
        "tiered"
    }
    fn save_sections(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.f64(self.gap_per_unit_query);
        m.u64(self.rungs.len() as u64);
        for rung in &self.rungs {
            m.u64(rung.bits as u64);
            m.slice(&rung.planes);
            m.slice(&rung.bucket_off);
            m.slice(&rung.members);
        }
        w.section(tag::TIERED_META, sec_arg(shard, 0), m.bytes())?;
        if let Some(ladder) = &self.quant {
            ladder.save_sections(w, shard)?;
        }
        Ok(())
    }
    fn describe(&self) -> String {
        format!(
            "tiered-lsh over n={} d={}: {} rungs (bits {}..{}), measured gap/unit-q = {:.4}",
            self.ds.n,
            self.ds.d,
            self.rungs.len(),
            self.rungs.first().map(|r| r.bits).unwrap_or(0),
            self.rungs.last().map(|r| r.bits).unwrap_or(0),
            self.gap_per_unit_query
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::mips::{brute::BruteForce, empirical_gap, recall_at_k};
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;

    fn cfg() -> IndexConfig {
        let mut c = Config::default().index;
        c.rungs = 8;
        c.bits = 14;
        c
    }

    #[test]
    fn always_returns_k_elements() {
        let ds = Arc::new(synth::imagenet_like(3000, 16, 30, 0.3, 1));
        let idx = TieredLsh::build(ds.clone(), &cfg(), Arc::new(NativeScorer)).unwrap();
        let mut rng = Pcg64::new(2);
        for _ in 0..5 {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            for k in [1, 10, 55, 200] {
                let got = idx.top_k(&q, k);
                assert_eq!(got.items.len(), k, "k={k}");
            }
        }
    }

    #[test]
    fn gap_definition_holds_with_measured_c() {
        // Definition 3.1: max_{i∉S} y_i − min_{i∈S} y_i < c.
        let ds = Arc::new(synth::imagenet_like(3000, 16, 30, 0.25, 3));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = TieredLsh::build(ds.clone(), &cfg(), backend.clone()).unwrap();
        let c_unit = idx.gap_bound().unwrap();
        let mut rng = Pcg64::new(4);
        let k = (ds.n as f64).sqrt() as usize;
        let mut violations = 0;
        let trials = 12;
        for _ in 0..trials {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = idx.top_k(&q, k);
            let gap = empirical_gap(&ds, backend.as_ref(), &q, &got);
            let c = c_unit * linalg::norm(&q) as f64;
            // allow slack: measured c came from different probes
            if gap > c * 1.5 + 1e-9 {
                violations += 1;
            }
        }
        assert!(violations <= trials / 4, "{violations}/{trials} gap violations");
    }

    #[test]
    fn better_recall_than_random_subset() {
        let ds = Arc::new(synth::imagenet_like(3000, 16, 30, 0.3, 5));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = TieredLsh::build(ds.clone(), &cfg(), backend.clone()).unwrap();
        let brute = BruteForce::new(ds.clone(), backend);
        let mut rng = Pcg64::new(6);
        let mut recall = 0.0;
        let trials = 10;
        for _ in 0..trials {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = idx.top_k(&q, 30);
            let want = brute.top_k(&q, 30);
            recall += recall_at_k(&got, &want);
        }
        recall /= trials as f64;
        // tiered LSH is the *theoretically certified* index, not the
        // fastest/most accurate — a random 30-of-3000 subset would score
        // ≈ 0.01, so anything ≫ that shows the ladder concentrates on
        // high-score states (the gap certificate is tested separately)
        assert!(recall > 0.12, "recall = {recall}");
    }

    #[test]
    fn top_k_batch_matches_per_query() {
        let ds = Arc::new(synth::imagenet_like(2500, 12, 25, 0.3, 11));
        let idx = TieredLsh::build(ds.clone(), &cfg(), Arc::new(NativeScorer)).unwrap();
        let mut rng = Pcg64::new(12);
        for nq in [2usize, 5] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 25);
            assert_eq!(batch.len(), nq);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 25);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned, "nq={nq} query {j}");
            }
        }
    }

    #[test]
    fn quant_candidate_scan_bit_identical_to_f32() {
        let ds = Arc::new(synth::imagenet_like(2500, 12, 25, 0.25, 21));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut qcfg = cfg();
        qcfg.quant = crate::config::QuantKind::Sq8;
        qcfg.overscan = 3;
        let qidx = TieredLsh::build(ds.clone(), &qcfg, backend.clone()).unwrap();
        let fidx = TieredLsh::build(ds.clone(), &cfg(), backend).unwrap();
        assert!(qidx.quant_enabled() && !fidx.quant_enabled());
        // identical ladders (planes are seed-derived, data-independent)
        assert_eq!(qidx.gap_bound().unwrap(), fidx.gap_bound().unwrap());
        let mut rng = Pcg64::new(22);
        for k in [1usize, 25, 120] {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = qidx.top_k(&q, k);
            let want = fidx.top_k(&q, k);
            assert_eq!(got.ids(), want.ids(), "k={k}");
            for (g, w) in got.items.iter().zip(&want.items) {
                assert_eq!(g.score, w.score, "k={k}");
            }
            assert_eq!(got.scanned, want.scanned, "k={k}");
        }
    }

    #[test]
    fn ladder_walks_fine_to_coarse() {
        let ds = Arc::new(synth::imagenet_like(1500, 8, 15, 0.3, 7));
        let idx = TieredLsh::build(ds.clone(), &cfg(), Arc::new(NativeScorer)).unwrap();
        // rung bit counts strictly decrease (until the floor)
        for w in idx.rungs.windows(2) {
            assert!(w[0].bits >= w[1].bits);
        }
        // small k should scan fewer candidates than large k on average
        let mut rng = Pcg64::new(8);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let small = idx.top_k(&q, 5).scanned;
        let large = idx.top_k(&q, 500).scanned;
        assert!(large >= small);
    }
}
