//! Exact brute-force MIPS — the `O(n·d)` baseline every experiment
//! compares against, and the correctness oracle for the approximate
//! indexes.
//!
//! With [`with_quant`](BruteForce::with_quant) the scan becomes
//! two-stage: pass 1 screens every row on SQ8 quantized scores (¼ of the
//! memory traffic), pass 2 re-ranks the few survivors with the exact f32
//! kernels. The error-bound/overscan contract of
//! [`crate::linalg::quant`] guarantees the returned ids *and* f32 scores
//! are bit-identical to the f32-only scan.

use super::{MipsIndex, TopKResult};
use crate::data::Dataset;
use crate::linalg::quant::{coverage_proved, QuantQuery, QuantView};
use crate::scorer::ScoreBackend;
use crate::util::topk::{Scored, TopK};
use std::sync::Arc;

/// Rows per survivor gather/re-rank block (pass 2).
const GATHER_BLOCK: usize = 1024;

/// Exact scan over the whole database in scorer-sized blocks.
pub struct BruteForce {
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    /// rows per scoring call (PJRT backends want their AOT block size)
    pub block: usize,
    /// SQ8 shadow copy for the two-stage scan (None = plain f32 scan)
    quant: Option<QuantView>,
    /// pass-1 retention factor (`k·overscan` candidates)
    overscan: usize,
}

impl BruteForce {
    pub fn new(ds: Arc<Dataset>, backend: Arc<dyn ScoreBackend>) -> Self {
        BruteForce { ds, backend, block: 4096, quant: None, overscan: 4 }
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = block.max(1);
        self
    }

    /// Enable the SQ8 two-stage scan (`qblock` rows per quantization
    /// block, `k·overscan` pass-1 candidates). Results stay bit-identical
    /// to the f32-only scan.
    pub fn with_quant(mut self, qblock: usize, overscan: usize) -> Self {
        self.quant = Some(QuantView::encode(&self.ds.data, self.ds.d, qblock.max(1)));
        self.overscan = overscan.max(1);
        self
    }

    /// Whether the quantized screening pass is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant.is_some()
    }

    /// Exact scores for ALL rows (used by evaluation: exact partition,
    /// TV-bound certificates). `out.len() == n`.
    pub fn all_scores(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.ds.n);
        let d = self.ds.d;
        let mut start = 0;
        while start < self.ds.n {
            let end = (start + self.block).min(self.ds.n);
            self.backend.scores(
                &self.ds.data[start * d..end * d],
                d,
                q,
                &mut out[start..end],
            );
            start = end;
        }
    }

    /// Plain one-stage f32 scan (also the fallback when a quantized pass
    /// cannot prove coverage).
    fn top_k_f32(&self, q: &[f32], k: usize) -> TopKResult {
        let d = self.ds.d;
        let n = self.ds.n;
        let mut tk = TopK::new(k.min(n).max(1));
        let mut buf = vec![0f32; self.block];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let out = &mut buf[..end - start];
            self.backend.scores(&self.ds.data[start * d..end * d], d, q, out);
            tk.push_block(start as u32, out);
            start = end;
        }
        TopKResult { items: tk.into_sorted(), scanned: n }
    }

    /// Exact f32 re-rank of pass-1 candidates (gather + score into `tk`).
    fn rerank_exact(&self, cands: &[u32], q: &[f32], tk: &mut TopK) {
        let d = self.ds.d;
        let mut rows = vec![0f32; GATHER_BLOCK.min(cands.len().max(1)) * d];
        let mut out = vec![0f32; GATHER_BLOCK];
        let mut start = 0;
        while start < cands.len() {
            let end = (start + GATHER_BLOCK).min(cands.len());
            let ids = &cands[start..end];
            let rows_buf = &mut rows[..(end - start) * d];
            self.ds.gather(ids, rows_buf);
            let out_buf = &mut out[..end - start];
            self.backend.scores(rows_buf, d, q, out_buf);
            tk.push_ids(ids, out_buf);
            start = end;
        }
    }

    /// Finish a quantized pass: exact re-rank of the retained candidates
    /// plus the coverage certificate. `dropped` says pass 1 actually
    /// rejected/evicted rows (more were pushed than its capacity held —
    /// when false, the candidates are the whole scanned set and coverage
    /// is trivially proved). `None` when the certificate fails (caller
    /// falls back to the f32 scan).
    fn finish_quant(
        &self,
        qv: &QuantView,
        qq: &QuantQuery,
        cands: Vec<Scored>,
        q: &[f32],
        kk: usize,
        dropped: bool,
    ) -> Option<TopKResult> {
        let q_floor = cands.last().map(|s| s.score).unwrap_or(f32::NEG_INFINITY);
        let ids: Vec<u32> = cands.iter().map(|s| s.id).collect();
        let mut tk = TopK::new(kk);
        self.rerank_exact(&ids, q, &mut tk);
        if !coverage_proved(dropped, q_floor, qv.error_bound(qq), tk.threshold()) {
            return None;
        }
        // pass 1 visited every row; account the scan like the f32 path
        Some(TopKResult { items: tk.into_sorted(), scanned: self.ds.n })
    }

    /// Two-stage scan: SQ8 screening pass over all rows, exact re-rank of
    /// the retained candidates, coverage certificate. `None` when the
    /// certificate fails or the screen cannot prune anything
    /// (`k·overscan ≥ n`) — the caller falls back to
    /// [`top_k_f32`](Self::top_k_f32).
    fn top_k_quant(&self, qv: &QuantView, q: &[f32], k: usize) -> Option<TopKResult> {
        let n = self.ds.n;
        let kk = k.min(n).max(1);
        let cap = kk.saturating_mul(self.overscan).min(n).max(kk);
        if cap >= n {
            // pass 1 would retain everything: the one-stage scan is
            // strictly cheaper than screen + gather-re-rank-all
            return None;
        }
        let qq = QuantQuery::encode(q);
        let mut tk = TopK::new(cap);
        let mut buf = vec![0f32; self.block];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let out = &mut buf[..end - start];
            qv.scores(start, end, &qq, out);
            tk.push_block(start as u32, out);
            start = end;
        }
        // cap < n, so a full collector really did drop rows
        let cands = tk.into_sorted();
        let dropped = cands.len() == cap;
        self.finish_quant(qv, &qq, cands, q, kk, dropped)
    }
}

impl MipsIndex for BruteForce {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        if let Some(qv) = &self.quant {
            if let Some(r) = self.top_k_quant(qv, q, k) {
                return r;
            }
        }
        self.top_k_f32(q, k)
    }

    /// Batched exact scan: every database block is read from memory once
    /// for the whole query batch (multi-query scoring), instead of once
    /// per query. With quantization enabled, the shared stream is the SQ8
    /// code block and each query re-ranks its own survivors exactly.
    /// Scores are bit-identical to per-query [`top_k`] calls either way.
    ///
    /// [`top_k`]: MipsIndex::top_k
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        let nq = qs.len();
        if nq <= 1 {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let d = self.ds.d;
        let n = self.ds.n;
        let kk = k.min(n).max(1);
        let cap = kk.saturating_mul(self.overscan).min(n).max(kk);
        if let (Some(qv), true) = (&self.quant, cap < n) {
            let qqs: Vec<QuantQuery> = qs.iter().map(|q| QuantQuery::encode(q)).collect();
            let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(cap)).collect();
            let mut buf = vec![0f32; self.block];
            let mut start = 0;
            while start < n {
                let end = (start + self.block).min(n);
                for (j, qq) in qqs.iter().enumerate() {
                    let out = &mut buf[..end - start];
                    qv.scores(start, end, qq, out);
                    tks[j].push_block(start as u32, out);
                }
                start = end;
            }
            return tks
                .into_iter()
                .enumerate()
                .map(|(j, tk)| {
                    let cands = tk.into_sorted();
                    let dropped = cands.len() == cap; // cap < n ⇒ rows were dropped
                    self.finish_quant(qv, &qqs[j], cands, qs[j], kk, dropped)
                        .unwrap_or_else(|| self.top_k_f32(qs[j], k))
                })
                .collect();
        }
        let mut qflat = vec![0f32; nq * d];
        for (j, q) in qs.iter().enumerate() {
            qflat[j * d..(j + 1) * d].copy_from_slice(q);
        }
        let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k.min(n).max(1))).collect();
        let mut buf = vec![0f32; self.block * nq];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let bn = end - start;
            let out = &mut buf[..bn * nq];
            self.backend.scores_batch(&self.ds.data[start * d..end * d], d, &qflat, nq, out);
            for (j, tk) in tks.iter_mut().enumerate() {
                tk.push_block(start as u32, &out[j * bn..(j + 1) * bn]);
            }
            start = end;
        }
        tks.into_iter()
            .map(|tk| TopKResult { items: tk.into_sorted(), scanned: n })
            .collect()
    }

    fn n(&self) -> usize {
        self.ds.n
    }
    fn d(&self) -> usize {
        self.ds.d
    }
    fn gap_bound(&self) -> Option<f64> {
        Some(0.0) // exact
    }
    fn name(&self) -> &'static str {
        "brute"
    }
    fn describe(&self) -> String {
        if let Some(qv) = &self.quant {
            format!(
                "brute over n={} d={} (sq8 two-stage, block={}, overscan={})",
                self.ds.n,
                self.ds.d,
                qv.block(),
                self.overscan
            )
        } else {
            format!("brute over n={} d={}", self.ds.n, self.ds.d)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;
    use crate::util::topk::topk_reference;

    #[test]
    fn matches_reference_topk() {
        let ds = Arc::new(synth::imagenet_like(1500, 12, 15, 0.3, 1));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(100);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let got = idx.top_k(&q, 25);
        assert_eq!(got.scanned, 1500);
        let mut all = vec![0f32; ds.n];
        idx.all_scores(&q, &mut all);
        let want = topk_reference(&all, 25);
        assert_eq!(got.items.len(), 25);
        for (g, w) in got.items.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.score, w.score);
        }
        // sorted descending
        for w in got.items.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let ds = Arc::new(synth::uniform_sphere(10, 4, 3));
        let idx = BruteForce::new(ds, Arc::new(NativeScorer));
        let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 100);
        assert_eq!(got.items.len(), 10);
    }

    #[test]
    fn top_k_batch_identical_to_per_query() {
        // the batch path must be bit-compatible with per-query scans:
        // same ids AND same scores (acceptance criterion of the batched
        // MIPS work; the SIMD kernels guarantee identical accumulation
        // order for both paths)
        let ds = Arc::new(synth::imagenet_like(2_000, 24, 16, 0.3, 8));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(333);
        let mut rng = Pcg64::new(9);
        for nq in [1usize, 2, 5, 8] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 31);
            assert_eq!(batch.len(), nq);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 31);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned);
            }
        }
    }

    #[test]
    fn block_boundary_cases() {
        let ds = Arc::new(synth::uniform_sphere(257, 4, 4));
        for block in [1, 7, 256, 257, 1000] {
            let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(block);
            let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.items.len(), 5, "block={block}");
            let idx_ref = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
            let want = idx_ref.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.ids(), want.ids(), "block={block}");
        }
    }

    #[test]
    fn quant_two_stage_bit_identical_to_f32() {
        let ds = Arc::new(synth::imagenet_like(3_000, 24, 20, 0.3, 5));
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let mut rng = Pcg64::new(6);
        for (qblock, overscan) in [(64usize, 4usize), (7, 2), (1000, 1)] {
            let q_idx =
                BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(qblock, overscan);
            assert!(q_idx.quant_enabled());
            for k in [1usize, 10, 77] {
                let q = synth::random_theta(&ds, 0.05, &mut rng);
                let got = q_idx.top_k(&q, k);
                let want = f32_idx.top_k(&q, k);
                assert_eq!(got.ids(), want.ids(), "qblock={qblock} overscan={overscan} k={k}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "qblock={qblock} k={k}");
                }
                assert_eq!(got.scanned, want.scanned);
            }
        }
    }

    #[test]
    fn quant_batch_identical_to_per_query() {
        let ds = Arc::new(synth::imagenet_like(2_000, 16, 15, 0.3, 11));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(64, 3);
        let mut rng = Pcg64::new(12);
        for nq in [2usize, 5] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 23);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 23);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
            }
        }
    }
}
