//! Exact brute-force MIPS — the `O(n·d)` baseline every experiment
//! compares against, and the correctness oracle for the approximate
//! indexes.
//!
//! With a quantized tier configured ([`with_tier_cfg`] /
//! [`with_quant`]) the scan becomes two-stage: pass 1 screens every row
//! on compressed codes (SQ8 ¼, SQ4 ⅛, PQ ~¹⁄₃₂ at its defaults),
//! pass 2 re-ranks the few survivors with the exact f32 kernels. The
//! error-bound/certificate contract of [`crate::linalg::quant`]
//! guarantees the returned ids *and* f32 scores are bit-identical to the
//! f32-only scan — a certificate miss rides the tier ladder
//! (PQ/SQ4 → SQ8 → f32, see [`crate::mips::two_stage`]).
//!
//! [`with_tier_cfg`]: BruteForce::with_tier_cfg
//! [`with_quant`]: BruteForce::with_quant

use super::two_stage::{self, QuantTier, TierLadder, TierQuery};
use super::{MipsIndex, TopKResult};
use crate::config::{IndexConfig, QuantKind};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::scorer::ScoreBackend;
use crate::store::format::{sec_arg, tag, ByteWriter, Snapshot, SnapshotWriter};
use crate::util::topk::TopK;
use std::sync::Arc;

/// Exact scan over the whole database in scorer-sized blocks.
pub struct BruteForce {
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    /// rows per scoring call (PJRT backends want their AOT block size)
    pub block: usize,
    /// screening-tier ladder for the two-stage scan (None = plain f32)
    quant: Option<TierLadder>,
    /// pass-1 retention factor (`k·overscan` candidates)
    overscan: usize,
}

impl BruteForce {
    pub fn new(ds: Arc<Dataset>, backend: Arc<dyn ScoreBackend>) -> Self {
        BruteForce { ds, backend, block: 4096, quant: None, overscan: 4 }
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = block.max(1);
        self
    }

    /// Enable the SQ8 two-stage scan (`qblock` rows per quantization
    /// block, `k·overscan` pass-1 candidates) — the historical
    /// single-rung form. Results stay bit-identical to the f32-only scan.
    pub fn with_quant(self, qblock: usize, overscan: usize) -> Self {
        let mut cfg = crate::config::Config::default().index;
        cfg.quant = QuantKind::Sq8;
        cfg.quant_block = qblock.max(1);
        cfg.overscan = overscan.max(1);
        self.with_tier_cfg(&cfg)
    }

    /// Enable the configured screening-tier ladder
    /// (`index.quant = sq8|sq4|pq` plus the `quant_block`/`overscan`/
    /// `pq_m`/`pq_bits` knobs). Results stay bit-identical to the
    /// f32-only scan on every rung.
    pub fn with_tier_cfg(mut self, cfg: &IndexConfig) -> Self {
        self.quant = TierLadder::from_cfg(&self.ds.data, self.ds.d, cfg);
        self.overscan = cfg.overscan.max(1);
        self
    }

    /// Whether the quantized screening pass is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant.is_some()
    }

    /// Exact scores for ALL rows (used by evaluation: exact partition,
    /// TV-bound certificates). `out.len() == n`.
    pub fn all_scores(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.ds.n);
        let d = self.ds.d;
        let mut start = 0;
        while start < self.ds.n {
            let end = (start + self.block).min(self.ds.n);
            self.backend.scores(
                &self.ds.data[start * d..end * d],
                d,
                q,
                &mut out[start..end],
            );
            start = end;
        }
    }

    /// Plain one-stage f32 scan (also the fallback when a quantized pass
    /// cannot prove coverage).
    fn top_k_f32(&self, q: &[f32], k: usize) -> TopKResult {
        let d = self.ds.d;
        let n = self.ds.n;
        let mut tk = TopK::new(k.min(n).max(1));
        let mut buf = vec![0f32; self.block];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let out = &mut buf[..end - start];
            self.backend.scores(&self.ds.data[start * d..end * d], d, q, out);
            tk.push_block(start as u32, out);
            start = end;
        }
        TopKResult { items: tk.into_sorted(), scanned: n }
    }

    /// Rebuild a brute-force index from snapshot sections written by
    /// [`MipsIndex::save_sections`]. The dataset rows themselves come
    /// from the caller (they live in the shared `DATASET_ROWS` section);
    /// only the scan shape (`block`) and the quantized shadow tiers are
    /// read here. A missing/corrupt shadow section degrades to the plain
    /// f32 scan (sets `degraded`) — answers stay bit-identical by the
    /// coverage-certificate contract, only `scanned` accounting can
    /// differ from a freshly built two-stage index.
    pub fn open_from(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        snap: &Snapshot,
        shard: u32,
        degraded: &mut bool,
    ) -> Result<BruteForce> {
        let mut r = snap.reader(tag::BRUTE_META, sec_arg(shard, 0))?;
        let block = r.usize()?;
        if block == 0 {
            return Err(Error::data(format!(
                "snapshot {}: brute meta has block=0",
                snap.path()
            )));
        }
        let quant = TierLadder::open_from(snap, cfg, shard, degraded);
        Ok(BruteForce { ds, backend, block, quant, overscan: cfg.overscan.max(1) })
    }

    /// Two-stage scan over the given ladder rungs: per rung, a screening
    /// pass over all rows, exact re-rank of the retained candidates, and
    /// the coverage certificate — a miss tries the next rung. `None`
    /// when no rung certifies or the screen cannot prune anything
    /// (`k·overscan ≥ n`) — the caller falls back to
    /// [`top_k_f32`](Self::top_k_f32).
    fn top_k_quant(&self, q: &[f32], k: usize, tiers: &[QuantTier]) -> Option<TopKResult> {
        let n = self.ds.n;
        let kk = k.min(n).max(1);
        let cap = kk.saturating_mul(self.overscan).min(n).max(kk);
        if cap >= n {
            // pass 1 would retain everything: the one-stage scan is
            // strictly cheaper than screen + gather-re-rank-all
            return None;
        }
        let mut buf = vec![0f32; self.block];
        for tier in tiers {
            let tq = tier.encode_query(q);
            let mut tk = TopK::new(cap);
            let mut start = 0;
            while start < n {
                let end = (start + self.block).min(n);
                let out = &mut buf[..end - start];
                tier.scores(start, end, &tq, out);
                tk.push_block(start as u32, out);
                start = end;
            }
            let rerank = |ids: &[u32], tk: &mut TopK| {
                two_stage::rerank_gather(&self.ds, self.backend.as_ref(), q, ids, tk)
            };
            let finished =
                two_stage::finish_screen(tier, &tq, tk.into_sorted(), n, cap, kk, rerank);
            if let Some(tk2) = finished {
                // pass 1 visited every row; account like the f32 path
                return Some(TopKResult { items: tk2.into_sorted(), scanned: n });
            }
        }
        None
    }
}

impl MipsIndex for BruteForce {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        if let Some(ladder) = &self.quant {
            if let Some(r) = self.top_k_quant(q, k, ladder.tiers()) {
                return r;
            }
        }
        self.top_k_f32(q, k)
    }

    /// Batched exact scan: every database block is read from memory once
    /// for the whole query batch (multi-query scoring), instead of once
    /// per query. With quantization enabled, the shared stream is the SQ8
    /// code block and each query re-ranks its own survivors exactly.
    /// Scores are bit-identical to per-query [`top_k`] calls either way.
    ///
    /// [`top_k`]: MipsIndex::top_k
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        let nq = qs.len();
        if nq <= 1 {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let d = self.ds.d;
        let n = self.ds.n;
        let kk = k.min(n).max(1);
        let cap = kk.saturating_mul(self.overscan).min(n).max(kk);
        if let (Some(ladder), true) = (&self.quant, cap < n) {
            // batched pass 1 on the primary (most compressed) tier: each
            // code block streams once for the whole batch; a per-query
            // certificate miss rides the remaining rungs, then f32 —
            // exactly the single-query ladder walk, so batch ≡ singles
            let primary = ladder.primary();
            let tqs: Vec<TierQuery> = qs.iter().map(|q| primary.encode_query(q)).collect();
            let batch = two_stage::TierBatch::new(primary, &tqs);
            let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(cap)).collect();
            let mut buf = vec![0f32; self.block * nq];
            let mut start = 0;
            while start < n {
                let end = (start + self.block).min(n);
                let bn = end - start;
                let out = &mut buf[..bn * nq];
                batch.scores_all(start, end, out);
                for (j, tk) in tks.iter_mut().enumerate() {
                    tk.push_block(start as u32, &out[j * bn..(j + 1) * bn]);
                }
                start = end;
            }
            return tks
                .into_iter()
                .enumerate()
                .map(|(j, tk)| {
                    two_stage::finish_screen(
                        primary,
                        &tqs[j],
                        tk.into_sorted(),
                        n,
                        cap,
                        kk,
                        |ids, tk| {
                            two_stage::rerank_gather(
                                &self.ds,
                                self.backend.as_ref(),
                                qs[j],
                                ids,
                                tk,
                            )
                        },
                    )
                    .map(|tk2| TopKResult { items: tk2.into_sorted(), scanned: n })
                    .or_else(|| self.top_k_quant(qs[j], k, &ladder.tiers()[1..]))
                    .unwrap_or_else(|| self.top_k_f32(qs[j], k))
                })
                .collect();
        }
        let mut qflat = vec![0f32; nq * d];
        for (j, q) in qs.iter().enumerate() {
            qflat[j * d..(j + 1) * d].copy_from_slice(q);
        }
        let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k.min(n).max(1))).collect();
        let mut buf = vec![0f32; self.block * nq];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let bn = end - start;
            let out = &mut buf[..bn * nq];
            self.backend.scores_batch(&self.ds.data[start * d..end * d], d, &qflat, nq, out);
            for (j, tk) in tks.iter_mut().enumerate() {
                tk.push_block(start as u32, &out[j * bn..(j + 1) * bn]);
            }
            start = end;
        }
        tks.into_iter()
            .map(|tk| TopKResult { items: tk.into_sorted(), scanned: n })
            .collect()
    }

    fn n(&self) -> usize {
        self.ds.n
    }
    fn d(&self) -> usize {
        self.ds.d
    }
    fn gap_bound(&self) -> Option<f64> {
        Some(0.0) // exact
    }
    fn name(&self) -> &'static str {
        "brute"
    }
    fn save_sections(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.block as u64);
        w.section(tag::BRUTE_META, sec_arg(shard, 0), m.bytes())?;
        if let Some(ladder) = &self.quant {
            ladder.save_sections(w, shard)?;
        }
        Ok(())
    }
    fn describe(&self) -> String {
        if let Some(ladder) = &self.quant {
            format!(
                "brute over n={} d={} ({} two-stage, overscan={})",
                self.ds.n,
                self.ds.d,
                ladder.describe(),
                self.overscan
            )
        } else {
            format!("brute over n={} d={}", self.ds.n, self.ds.d)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;
    use crate::util::topk::topk_reference;

    #[test]
    fn matches_reference_topk() {
        let ds = Arc::new(synth::imagenet_like(1500, 12, 15, 0.3, 1));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(100);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let got = idx.top_k(&q, 25);
        assert_eq!(got.scanned, 1500);
        let mut all = vec![0f32; ds.n];
        idx.all_scores(&q, &mut all);
        let want = topk_reference(&all, 25);
        assert_eq!(got.items.len(), 25);
        for (g, w) in got.items.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.score, w.score);
        }
        // sorted descending
        for w in got.items.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let ds = Arc::new(synth::uniform_sphere(10, 4, 3));
        let idx = BruteForce::new(ds, Arc::new(NativeScorer));
        let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 100);
        assert_eq!(got.items.len(), 10);
    }

    #[test]
    fn top_k_batch_identical_to_per_query() {
        // the batch path must be bit-compatible with per-query scans:
        // same ids AND same scores (acceptance criterion of the batched
        // MIPS work; the SIMD kernels guarantee identical accumulation
        // order for both paths)
        let ds = Arc::new(synth::imagenet_like(2_000, 24, 16, 0.3, 8));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(333);
        let mut rng = Pcg64::new(9);
        for nq in [1usize, 2, 5, 8] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 31);
            assert_eq!(batch.len(), nq);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 31);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned);
            }
        }
    }

    #[test]
    fn block_boundary_cases() {
        let ds = Arc::new(synth::uniform_sphere(257, 4, 4));
        for block in [1, 7, 256, 257, 1000] {
            let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(block);
            let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.items.len(), 5, "block={block}");
            let idx_ref = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
            let want = idx_ref.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.ids(), want.ids(), "block={block}");
        }
    }

    #[test]
    fn quant_two_stage_bit_identical_to_f32() {
        let ds = Arc::new(synth::imagenet_like(3_000, 24, 20, 0.3, 5));
        let f32_idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let mut rng = Pcg64::new(6);
        for (qblock, overscan) in [(64usize, 4usize), (7, 2), (1000, 1)] {
            let q_idx =
                BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(qblock, overscan);
            assert!(q_idx.quant_enabled());
            for k in [1usize, 10, 77] {
                let q = synth::random_theta(&ds, 0.05, &mut rng);
                let got = q_idx.top_k(&q, k);
                let want = f32_idx.top_k(&q, k);
                assert_eq!(got.ids(), want.ids(), "qblock={qblock} overscan={overscan} k={k}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "qblock={qblock} k={k}");
                }
                assert_eq!(got.scanned, want.scanned);
            }
        }
    }

    #[test]
    fn quant_batch_identical_to_per_query() {
        let ds = Arc::new(synth::imagenet_like(2_000, 16, 15, 0.3, 11));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_quant(64, 3);
        let mut rng = Pcg64::new(12);
        for nq in [2usize, 5] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 23);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 23);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
            }
        }
    }
}
