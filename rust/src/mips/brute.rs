//! Exact brute-force MIPS — the `O(n·d)` baseline every experiment
//! compares against, and the correctness oracle for the approximate
//! indexes.

use super::{MipsIndex, TopKResult};
use crate::data::Dataset;
use crate::scorer::ScoreBackend;
use crate::util::topk::TopK;
use std::sync::Arc;

/// Exact scan over the whole database in scorer-sized blocks.
pub struct BruteForce {
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    /// rows per scoring call (PJRT backends want their AOT block size)
    pub block: usize,
}

impl BruteForce {
    pub fn new(ds: Arc<Dataset>, backend: Arc<dyn ScoreBackend>) -> Self {
        BruteForce { ds, backend, block: 4096 }
    }

    pub fn with_block(mut self, block: usize) -> Self {
        self.block = block.max(1);
        self
    }

    /// Exact scores for ALL rows (used by evaluation: exact partition,
    /// TV-bound certificates). `out.len() == n`.
    pub fn all_scores(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.ds.n);
        let d = self.ds.d;
        let mut start = 0;
        while start < self.ds.n {
            let end = (start + self.block).min(self.ds.n);
            self.backend.scores(
                &self.ds.data[start * d..end * d],
                d,
                q,
                &mut out[start..end],
            );
            start = end;
        }
    }
}

impl MipsIndex for BruteForce {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        let d = self.ds.d;
        let n = self.ds.n;
        let mut tk = TopK::new(k.min(n).max(1));
        let mut buf = vec![0f32; self.block];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let out = &mut buf[..end - start];
            self.backend.scores(&self.ds.data[start * d..end * d], d, q, out);
            tk.push_block(start as u32, out);
            start = end;
        }
        TopKResult { items: tk.into_sorted(), scanned: n }
    }

    /// Batched exact scan: every database block is read from memory once
    /// for the whole query batch (multi-query scoring), instead of once
    /// per query. Scores are bit-identical to per-query [`top_k`] calls.
    ///
    /// [`top_k`]: MipsIndex::top_k
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        let nq = qs.len();
        if nq <= 1 {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let d = self.ds.d;
        let n = self.ds.n;
        let mut qflat = vec![0f32; nq * d];
        for (j, q) in qs.iter().enumerate() {
            qflat[j * d..(j + 1) * d].copy_from_slice(q);
        }
        let mut tks: Vec<TopK> = (0..nq).map(|_| TopK::new(k.min(n).max(1))).collect();
        let mut buf = vec![0f32; self.block * nq];
        let mut start = 0;
        while start < n {
            let end = (start + self.block).min(n);
            let bn = end - start;
            let out = &mut buf[..bn * nq];
            self.backend.scores_batch(&self.ds.data[start * d..end * d], d, &qflat, nq, out);
            for (j, tk) in tks.iter_mut().enumerate() {
                tk.push_block(start as u32, &out[j * bn..(j + 1) * bn]);
            }
            start = end;
        }
        tks.into_iter()
            .map(|tk| TopKResult { items: tk.into_sorted(), scanned: n })
            .collect()
    }

    fn n(&self) -> usize {
        self.ds.n
    }
    fn d(&self) -> usize {
        self.ds.d
    }
    fn gap_bound(&self) -> Option<f64> {
        Some(0.0) // exact
    }
    fn name(&self) -> &'static str {
        "brute"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;
    use crate::util::topk::topk_reference;

    #[test]
    fn matches_reference_topk() {
        let ds = Arc::new(synth::imagenet_like(1500, 12, 15, 0.3, 1));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(100);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let got = idx.top_k(&q, 25);
        assert_eq!(got.scanned, 1500);
        let mut all = vec![0f32; ds.n];
        idx.all_scores(&q, &mut all);
        let want = topk_reference(&all, 25);
        assert_eq!(got.items.len(), 25);
        for (g, w) in got.items.iter().zip(&want) {
            assert_eq!(g.id, w.id);
            assert_eq!(g.score, w.score);
        }
        // sorted descending
        for w in got.items.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let ds = Arc::new(synth::uniform_sphere(10, 4, 3));
        let idx = BruteForce::new(ds, Arc::new(NativeScorer));
        let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 100);
        assert_eq!(got.items.len(), 10);
    }

    #[test]
    fn top_k_batch_identical_to_per_query() {
        // the batch path must be bit-compatible with per-query scans:
        // same ids AND same scores (acceptance criterion of the batched
        // MIPS work; the SIMD kernels guarantee identical accumulation
        // order for both paths)
        let ds = Arc::new(synth::imagenet_like(2_000, 24, 16, 0.3, 8));
        let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(333);
        let mut rng = Pcg64::new(9);
        for nq in [1usize, 2, 5, 8] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 31);
            assert_eq!(batch.len(), nq);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 31);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned);
            }
        }
    }

    #[test]
    fn block_boundary_cases() {
        let ds = Arc::new(synth::uniform_sphere(257, 4, 4));
        for block in [1, 7, 256, 257, 1000] {
            let idx = BruteForce::new(ds.clone(), Arc::new(NativeScorer)).with_block(block);
            let got = idx.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.items.len(), 5, "block={block}");
            let idx_ref = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
            let want = idx_ref.top_k(&[1.0, 0.0, 0.0, 0.0], 5);
            assert_eq!(got.ids(), want.ids(), "block={block}");
        }
    }
}
