//! k-means clustering (k-means++ seeding + Lloyd iterations) — the
//! training stage of the IVF index, mirroring the clustering MIPS method
//! of Douze et al. (2016) / Auvolat et al. (2015) the paper uses.
//!
//! Trains on a subsample (FAISS-style) to keep index build time sublinear
//! in practice; assignment of the full database happens in the IVF build.

use crate::linalg;
use crate::util::rng::Pcg64;

/// Trained centroids, row-major `[c × d]`.
#[derive(Clone, Debug)]
pub struct Kmeans {
    pub centroids: Vec<f32>,
    pub c: usize,
    pub d: usize,
    /// mean squared distance at the last Lloyd iteration (convergence
    /// diagnostics)
    pub inertia: f64,
}

impl Kmeans {
    /// Assign one vector to its nearest centroid (L2 == max dot for
    /// unit-norm data, but we use true L2 so non-normalized data also
    /// clusters correctly). Returns (cluster, squared distance).
    pub fn assign(&self, v: &[f32]) -> (usize, f64) {
        let mut best = 0usize;
        let mut best_d2 = f64::INFINITY;
        for c in 0..self.c {
            let cent = &self.centroids[c * self.d..(c + 1) * self.d];
            let d2 = sq_dist(v, cent);
            if d2 < best_d2 {
                best_d2 = d2;
                best = c;
            }
        }
        (best, best_d2)
    }

    /// Scores of a query against every centroid (inner products), for IVF
    /// probe ordering.
    pub fn centroid_scores(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.c);
        linalg::matvec_block(&self.centroids, self.d, q, out);
    }
}

#[inline]
fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    // ||a-b||² = ||a||² + ||b||² − 2a·b ; direct loop is fine here (train
    // path only)
    let mut s = 0f64;
    for (x, y) in a.iter().zip(b) {
        let diff = (x - y) as f64;
        s += diff * diff;
    }
    s
}

/// Train k-means with k-means++ seeding and `iters` Lloyd steps on
/// row-major `data [n × d]`.
pub fn train(data: &[f32], n: usize, d: usize, c: usize, iters: usize, seed: u64) -> Kmeans {
    assert!(c >= 1 && n >= 1);
    let c = c.min(n);
    let mut rng = Pcg64::new(seed);

    // ---- k-means++ seeding -------------------------------------------------
    let mut centroids = vec![0f32; c * d];
    let first = rng.next_below(n as u64) as usize;
    centroids[..d].copy_from_slice(&data[first * d..(first + 1) * d]);
    // squared distance to nearest chosen centroid
    let mut d2 = vec![0f64; n];
    for i in 0..n {
        d2[i] = sq_dist(&data[i * d..(i + 1) * d], &centroids[..d]);
    }
    for j in 1..c {
        // sample proportional to d2 (k-means++)
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            rng.next_below(n as u64) as usize
        } else {
            let mut u = rng.next_f64() * total;
            let mut pick = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                u -= w;
                if u <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        let (dst, src) = (j * d, next * d);
        centroids.copy_within_wrapping(src, dst, d, data);
        // update d2
        for i in 0..n {
            let nd = sq_dist(&data[i * d..(i + 1) * d], &centroids[dst..dst + d]);
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }

    // ---- Lloyd iterations ---------------------------------------------------
    let mut assign = vec![0u32; n];
    let mut inertia = f64::INFINITY;
    let km_view = |cent: &Vec<f32>| Kmeans { centroids: cent.clone(), c, d, inertia: 0.0 };
    for _it in 0..iters {
        // assignment step
        let km = km_view(&centroids);
        let mut total = 0f64;
        for i in 0..n {
            let (a, dist) = km.assign(&data[i * d..(i + 1) * d]);
            assign[i] = a as u32;
            total += dist;
        }
        inertia = total / n as f64;
        // update step
        let mut counts = vec![0u64; c];
        let mut sums = vec![0f64; c * d];
        for i in 0..n {
            let a = assign[i] as usize;
            counts[a] += 1;
            let row = &data[i * d..(i + 1) * d];
            for j in 0..d {
                sums[a * d + j] += row[j] as f64;
            }
        }
        for a in 0..c {
            if counts[a] == 0 {
                // re-seed empty cluster at a random point (standard fix)
                let p = rng.next_below(n as u64) as usize;
                centroids[a * d..(a + 1) * d].copy_from_slice(&data[p * d..(p + 1) * d]);
            } else {
                for j in 0..d {
                    centroids[a * d + j] = (sums[a * d + j] / counts[a] as f64) as f32;
                }
            }
        }
    }
    Kmeans { centroids, c, d, inertia }
}

/// Helper: copy a row from `data` into `self[dst..dst+d]` (split-borrow
/// safe).
trait CopyRow {
    fn copy_within_wrapping(&mut self, src: usize, dst: usize, d: usize, data: &[f32]);
}
impl CopyRow for Vec<f32> {
    fn copy_within_wrapping(&mut self, src: usize, dst: usize, d: usize, data: &[f32]) {
        self[dst..dst + d].copy_from_slice(&data[src..src + d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn recovers_separated_clusters() {
        // 4 well-separated clusters in 2D
        let mut data = Vec::new();
        let centers = [(10.0, 0.0), (-10.0, 0.0), (0.0, 10.0), (0.0, -10.0)];
        let mut rng = Pcg64::new(1);
        for i in 0..400 {
            let (cx, cy) = centers[i % 4];
            data.push(cx + rng.gaussian() as f32 * 0.2);
            data.push(cy + rng.gaussian() as f32 * 0.2);
        }
        let km = train(&data, 400, 2, 4, 10, 2);
        // every centroid should be within 1.0 of a true center
        for c in 0..4 {
            let cent = &km.centroids[c * 2..c * 2 + 2];
            let ok = centers
                .iter()
                .any(|&(x, y)| ((cent[0] - x).powi(2) + (cent[1] - y).powi(2)) < 1.0);
            assert!(ok, "centroid {c} = {cent:?}");
        }
        assert!(km.inertia < 0.2, "inertia={}", km.inertia);
    }

    #[test]
    fn assign_returns_nearest() {
        let km = Kmeans { centroids: vec![0.0, 0.0, 10.0, 10.0], c: 2, d: 2, inertia: 0.0 };
        assert_eq!(km.assign(&[1.0, 1.0]).0, 0);
        assert_eq!(km.assign(&[9.0, 9.0]).0, 1);
    }

    #[test]
    fn centroid_scores_are_dots() {
        let km = Kmeans { centroids: vec![1.0, 0.0, 0.0, 2.0], c: 2, d: 2, inertia: 0.0 };
        let mut out = vec![0f32; 2];
        km.centroid_scores(&[3.0, 4.0], &mut out);
        assert_eq!(out, vec![3.0, 8.0]);
    }

    #[test]
    fn handles_c_greater_than_distinct_points() {
        let data = vec![1.0f32, 1.0, 1.0, 1.0, 1.0, 1.0]; // 3 identical 2-d points
        let km = train(&data, 3, 2, 5, 3, 3);
        assert_eq!(km.c, 3, "c is clamped to n");
    }

    #[test]
    fn clusters_spherical_data_reasonably() {
        let ds = synth::imagenet_like(3000, 16, 30, 0.25, 5);
        let km = train(&ds.data, ds.n, ds.d, 30, 8, 6);
        // inertia should be far below 2.0 (the expected sq-dist of random
        // unit vectors to an uninformative centroid)
        assert!(km.inertia < 0.7, "inertia={}", km.inertia);
    }

    #[test]
    fn deterministic() {
        let ds = synth::imagenet_like(500, 8, 10, 0.3, 7);
        let a = train(&ds.data, ds.n, ds.d, 10, 5, 9);
        let b = train(&ds.data, ds.n, ds.d, 10, 5, 9);
        assert_eq!(a.centroids, b.centroids);
    }

    use crate::util::rng::Pcg64;
}
