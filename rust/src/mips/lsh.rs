//! Signed-random-projection LSH for MIPS (paper §2.3).
//!
//! Charikar (2002) SRP hashing solves *cosine* similarity search; the
//! Neyshabur–Srebro (2014) reduction turns MIPS into cosine search by
//! augmenting every database vector with one extra coordinate
//! `sqrt(M² − ‖v‖²)` (M = max norm) so all database vectors share norm M,
//! while queries get a 0 in that coordinate: then
//! `cos(q', v') ∝ q·v` and SRP applies.
//!
//! Structure: `tables` independent hash tables, each hashing to `bits`
//! signed projections → a bucket id. Queries gather the union of their
//! buckets across tables (plus optional 1-bit multiprobe to boost recall),
//! exact-score the candidates, and keep the top-k.

use super::two_stage::{self, TierLadder};
use super::{MipsIndex, TopKResult};
use crate::config::IndexConfig;
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::linalg;
use crate::scorer::ScoreBackend;
use crate::store::format::{sec_arg, tag, ByteReader, ByteWriter, Snapshot, SnapshotWriter};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// One SRP hash table.
struct Table {
    /// projection matrix, row-major `[bits × d_aug]`
    planes: Vec<f32>,
    /// bucket → member ids (CSR layout: `bucket_off[b]..bucket_off[b+1]`
    /// into `members`)
    bucket_off: Vec<u32>,
    members: Vec<u32>,
}

/// Multi-table SRP-LSH index with MIPS→cosine augmentation.
pub struct SrpLsh {
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    tables: Vec<Table>,
    bits: usize,
    /// augmented dimension = d + 1
    d_aug: usize,
    /// per-row augmentation coordinate `sqrt(M² − ‖v‖²)`
    aug: Vec<f32>,
    /// whether to probe all 1-bit-flip neighbors of the query bucket
    pub multiprobe: bool,
    /// screening-tier ladder for the two-stage candidate scan (None =
    /// plain f32 gather scan)
    quant: Option<TierLadder>,
    /// pass-1 retention factor (`k·overscan` candidates)
    overscan: usize,
}

/// `max_i ‖row_i‖²` — the Neyshabur–Srebro norm bound. Standalone so the
/// shard layer can compute it once over the *global* dataset and hand
/// every shard the same `M`: identical augmentation ⇒ identical hash
/// codes ⇒ the per-shard candidate sets union to exactly the monolithic
/// candidate set (shard-count invariance).
pub(crate) fn max_sq_norm(ds: &Dataset) -> f64 {
    let mut max_norm2 = 0f64;
    for i in 0..ds.n {
        let r = ds.row(i);
        max_norm2 = max_norm2.max(linalg::dot(r, r) as f64);
    }
    max_norm2
}

impl SrpLsh {
    pub fn build(ds: Arc<Dataset>, cfg: &IndexConfig, backend: Arc<dyn ScoreBackend>) -> Result<Self> {
        Self::build_scaled(ds, cfg, backend, None)
    }

    /// [`build`](Self::build) with an externally supplied norm bound
    /// `M² = max‖v‖²` (the shard layer passes the global bound; `None`
    /// computes it from `ds`). `M²` may exceed the local max (never be
    /// below it) — augmentation coordinates stay well-defined.
    pub(crate) fn build_scaled(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        global_max_norm2: Option<f64>,
    ) -> Result<Self> {
        let n = ds.n;
        let d = ds.d;
        let bits = cfg.bits.clamp(1, 24);
        let ntables = cfg.tables.max(1);
        let d_aug = d + 1;
        let mut rng = Pcg64::new(cfg.seed ^ 0x15B4);

        // ---- Neyshabur–Srebro augmentation ---------------------------------
        let max_norm2 = global_max_norm2.unwrap_or_else(|| max_sq_norm(&ds));
        let aug: Vec<f32> = (0..n)
            .map(|i| {
                let r = ds.row(i);
                ((max_norm2 - linalg::dot(r, r) as f64).max(0.0)).sqrt() as f32
            })
            .collect();

        // ---- build tables ----------------------------------------------------
        let nbuckets = 1usize << bits;
        let mut tables = Vec::with_capacity(ntables);
        for _t in 0..ntables {
            let planes: Vec<f32> =
                (0..bits * d_aug).map(|_| rng.gaussian() as f32).collect();
            // hash every row
            let mut codes = vec![0u32; n];
            for i in 0..n {
                codes[i] = hash_row(&planes, bits, d_aug, ds.row(i), aug[i]);
            }
            // CSR buckets
            let mut counts = vec![0u32; nbuckets + 1];
            for &c in &codes {
                counts[c as usize + 1] += 1;
            }
            for b in 0..nbuckets {
                counts[b + 1] += counts[b];
            }
            let bucket_off = counts.clone();
            let mut cursor = counts;
            let mut members = vec![0u32; n];
            for (i, &c) in codes.iter().enumerate() {
                members[cursor[c as usize] as usize] = i as u32;
                cursor[c as usize] += 1;
            }
            tables.push(Table { planes, bucket_off, members });
        }

        let quant = TierLadder::from_cfg(&ds.data, d, cfg);
        let overscan = cfg.overscan.max(1);
        Ok(SrpLsh { ds, backend, tables, bits, d_aug, aug, multiprobe: true, quant, overscan })
    }

    /// Whether the quantized screening pass is enabled.
    pub fn quant_enabled(&self) -> bool {
        self.quant.is_some()
    }

    // ---- snapshot persistence ------------------------------------------

    /// Rebuild from the `LSH_META` section written by
    /// [`MipsIndex::save_sections`]. The persisted augmentation
    /// coordinates already encode the build-time norm bound (global bound
    /// under sharding), so nothing is recomputed and hash codes — hence
    /// candidate sets — are bit-identical to the saved index. Bucket
    /// tables are re-validated before use so a corrupt file errors
    /// instead of panicking on an out-of-range bucket or member.
    pub fn open_from(
        ds: Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        snap: &Snapshot,
        shard: u32,
        degraded: &mut bool,
    ) -> Result<Self> {
        let mut r = snap.reader(tag::LSH_META, sec_arg(shard, 0))?;
        let bad = |why: &str| {
            Error::data(format!(
                "snapshot {}: LSH section (shard {shard}) is inconsistent: {why}",
                snap.path()
            ))
        };
        let bits = r.usize()?;
        let d_aug = r.usize()?;
        let multiprobe = r.u8()? != 0;
        let aug: Vec<f32> = r.vec()?;
        let ntables = r.usize()?;
        if !(1..=24).contains(&bits) {
            return Err(bad("bits out of range"));
        }
        if d_aug != ds.d + 1 || aug.len() != ds.n {
            return Err(bad("augmentation does not match the dataset shape"));
        }
        if ntables == 0 || ntables > 4096 {
            return Err(bad("implausible table count"));
        }
        let mut tables = Vec::with_capacity(ntables);
        for _ in 0..ntables {
            tables.push(read_table(&mut r, bits, d_aug, ds.n, &bad)?);
        }
        let quant = TierLadder::open_from(snap, cfg, shard, degraded);
        Ok(SrpLsh {
            ds,
            backend,
            tables,
            bits,
            d_aug,
            aug,
            multiprobe,
            quant,
            overscan: cfg.overscan.max(1),
        })
    }

    /// Collect candidate ids for a query (deduplicated via a stamp array).
    fn candidates(&self, q: &[f32]) -> Vec<u32> {
        let mut seen = vec![false; self.ds.n];
        let mut cands = Vec::new();
        for t in &self.tables {
            let code = hash_row(&t.planes, self.bits, self.d_aug, q, 0.0);
            let mut visit = |c: u32| {
                let (s, e) = (t.bucket_off[c as usize], t.bucket_off[c as usize + 1]);
                for &id in &t.members[s as usize..e as usize] {
                    if !seen[id as usize] {
                        seen[id as usize] = true;
                        cands.push(id);
                    }
                }
            };
            visit(code);
            if self.multiprobe {
                for b in 0..self.bits {
                    visit(code ^ (1u32 << b));
                }
            }
        }
        cands
    }
}

/// Append one hash table to the meta byte stream (planes + CSR buckets).
fn write_table(m: &mut ByteWriter, t: &Table) {
    m.slice(&t.planes);
    m.slice(&t.bucket_off);
    m.slice(&t.members);
}

/// Read back one hash table, validating every invariant the probe path
/// indexes by: plane shape, CSR monotonicity/cover, and member range.
fn read_table(
    r: &mut ByteReader,
    bits: usize,
    d_aug: usize,
    n: usize,
    bad: &dyn Fn(&str) -> Error,
) -> Result<Table> {
    let planes: Vec<f32> = r.vec()?;
    let bucket_off: Vec<u32> = r.vec()?;
    let members: Vec<u32> = r.vec()?;
    if planes.len() != bits * d_aug {
        return Err(bad("projection planes do not match bits × d_aug"));
    }
    if bucket_off.len() != (1usize << bits) + 1 {
        return Err(bad("bucket table does not match bits"));
    }
    if bucket_off[0] != 0
        || bucket_off.windows(2).any(|w| w[0] > w[1])
        || *bucket_off.last().unwrap() as usize != members.len()
    {
        return Err(bad("bucket offsets are not a monotone cover of the members"));
    }
    if members.iter().any(|&id| id as usize >= n) {
        return Err(bad("bucket member out of range"));
    }
    Ok(Table { planes, bucket_off, members })
}

/// SRP hash of an (augmented) vector: bit b = sign(planes_b · [v; aug]).
fn hash_row(planes: &[f32], bits: usize, d_aug: usize, v: &[f32], aug: f32) -> u32 {
    let d = d_aug - 1;
    let mut code = 0u32;
    for b in 0..bits {
        let p = &planes[b * d_aug..(b + 1) * d_aug];
        let s = linalg::dot(&p[..d], v) + p[d] * aug;
        if s >= 0.0 {
            code |= 1 << b;
        }
    }
    code
}

impl MipsIndex for SrpLsh {
    /// With `index.quant`, the candidate scan is two-stage: candidates
    /// are screened on the ladder's compressed codes
    /// ([`two_stage::scan_candidates_quant`]) and only the survivors are
    /// gathered and re-ranked in f32 — bit-identical
    /// ids/scores/`scanned` by the coverage-certificate contract, else
    /// the plain f32 gather scan.
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        let cands = self.candidates(q);
        if let Some(ladder) = &self.quant {
            if let Some(r) = two_stage::scan_candidates_quant(
                &self.ds,
                ladder,
                self.backend.as_ref(),
                q,
                k,
                &cands,
                self.overscan,
            ) {
                return r;
            }
        }
        super::scan_candidates_f32(&self.ds, self.backend.as_ref(), q, k, &cands)
    }

    /// Batch-aware probing: per-query candidate sets are unioned and every
    /// gathered row block is scored once for the whole batch
    /// ([`ScoreBackend::scores_batch`]), with each row pushed only to the
    /// queries whose buckets produced it — results and per-query `scanned`
    /// counts are identical to per-query [`top_k`](MipsIndex::top_k) calls.
    /// With quantization enabled the batch degrades to per-query
    /// two-stage scans (the screen already cuts the gather traffic the
    /// union pass would have shared).
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        if qs.len() <= 1 || self.quant.is_some() {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let cand_sets: Vec<Vec<u32>> = qs.iter().map(|q| self.candidates(q)).collect();
        super::batch_scan_candidates(&self.ds, self.backend.as_ref(), qs, k, &cand_sets)
    }

    fn n(&self) -> usize {
        self.ds.n
    }
    fn d(&self) -> usize {
        self.ds.d
    }
    fn name(&self) -> &'static str {
        "lsh"
    }
    fn save_sections(&self, w: &mut SnapshotWriter, shard: u32) -> Result<()> {
        let mut m = ByteWriter::default();
        m.u64(self.bits as u64);
        m.u64(self.d_aug as u64);
        m.u8(self.multiprobe as u8);
        m.slice(&self.aug);
        m.u64(self.tables.len() as u64);
        for t in &self.tables {
            write_table(&mut m, t);
        }
        w.section(tag::LSH_META, sec_arg(shard, 0), m.bytes())?;
        if let Some(ladder) = &self.quant {
            ladder.save_sections(w, shard)?;
        }
        Ok(())
    }
    fn describe(&self) -> String {
        format!(
            "srp-lsh over n={} d={}: {} tables × {} bits, multiprobe={}{}",
            self.ds.n,
            self.ds.d,
            self.tables.len(),
            self.bits,
            self.multiprobe,
            self.quant
                .as_ref()
                .map(|l| format!(", {} screen", l.describe()))
                .unwrap_or_default()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::mips::{brute::BruteForce, recall_at_k};
    use crate::scorer::NativeScorer;

    fn cfg(bits: usize, tables: usize) -> IndexConfig {
        let mut c = Config::default().index;
        c.bits = bits;
        c.tables = tables;
        c
    }

    #[test]
    fn srp_collision_probability_monotone_in_angle() {
        // SRP theory: Pr[h(x)=h(y)] = 1 − angle/π per bit.
        let mut rng = Pcg64::new(1);
        let d_aug = 9;
        let trials = 3000;
        let mut close_coll = 0;
        let mut far_coll = 0;
        for _ in 0..trials {
            let planes: Vec<f32> = (0..d_aug).map(|_| rng.gaussian() as f32).collect();
            let mut a = vec![0f32; 8];
            for x in a.iter_mut() {
                *x = rng.gaussian() as f32;
            }
            // close: small perturbation; far: independent
            let mut b_close = a.clone();
            for x in b_close.iter_mut() {
                *x += 0.1 * rng.gaussian() as f32;
            }
            let b_far: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
            let h = |v: &[f32]| hash_row(&planes, 1, d_aug, v, 0.0);
            if h(&a) == h(&b_close) {
                close_coll += 1;
            }
            if h(&a) == h(&b_far) {
                far_coll += 1;
            }
        }
        assert!(
            close_coll > far_coll + trials / 10,
            "close={close_coll} far={far_coll}"
        );
    }

    #[test]
    fn decent_recall_on_clustered_data() {
        let ds = Arc::new(synth::imagenet_like(4000, 16, 40, 0.25, 2));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let idx = SrpLsh::build(ds.clone(), &cfg(7, 12), backend.clone()).unwrap();
        let brute = BruteForce::new(ds.clone(), backend);
        let mut rng = Pcg64::new(3);
        let mut recall = 0.0;
        let mut scan_frac = 0.0;
        let trials = 15;
        for _ in 0..trials {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = idx.top_k(&q, 20);
            let want = brute.top_k(&q, 20);
            recall += recall_at_k(&got, &want);
            scan_frac += got.scanned as f64 / ds.n as f64;
        }
        recall /= trials as f64;
        scan_frac /= trials as f64;
        assert!(recall > 0.6, "recall@20 = {recall}");
        assert!(scan_frac < 0.9, "must prune something, scanned {scan_frac}");
    }

    #[test]
    fn augmentation_norms_equalized() {
        let ds = Arc::new(synth::wordemb_like(500, 8, 10, 0.4, 1.1, 4));
        let idx = SrpLsh::build(ds.clone(), &cfg(6, 4), Arc::new(NativeScorer)).unwrap();
        // augmented norms ‖[v; aug]‖ should all equal max norm
        let mut norms: Vec<f64> = (0..ds.n)
            .map(|i| {
                let r = ds.row(i);
                (linalg::dot(r, r) as f64 + (idx.aug[i] as f64).powi(2)).sqrt()
            })
            .collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((norms[0] - norms[norms.len() - 1]).abs() < 1e-3);
    }

    #[test]
    fn top_k_batch_matches_per_query() {
        // the merged-candidate batch scan must return exactly the
        // per-query results: ids, scores, and scanned accounting
        let ds = Arc::new(synth::imagenet_like(2500, 12, 25, 0.3, 9));
        let idx = SrpLsh::build(ds.clone(), &cfg(7, 8), Arc::new(NativeScorer)).unwrap();
        let mut rng = Pcg64::new(10);
        for nq in [2usize, 3, 7] {
            let qs_owned: Vec<Vec<f32>> =
                (0..nq).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
            let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
            let batch = idx.top_k_batch(&qs, 15);
            assert_eq!(batch.len(), nq);
            for (j, got) in batch.iter().enumerate() {
                let want = idx.top_k(qs[j], 15);
                assert_eq!(got.ids(), want.ids(), "nq={nq} query {j}");
                for (g, w) in got.items.iter().zip(&want.items) {
                    assert_eq!(g.score, w.score, "nq={nq} query {j}");
                }
                assert_eq!(got.scanned, want.scanned, "nq={nq} query {j}");
            }
        }
    }

    #[test]
    fn quant_candidate_scan_bit_identical_to_f32() {
        // the SQ8 screen must not change anything observable: same build
        // with and without index.quant returns identical ids, scores, and
        // scanned accounting (single queries and batches)
        let ds = Arc::new(synth::imagenet_like(3000, 16, 30, 0.25, 15));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut qcfg = cfg(7, 10);
        qcfg.quant = crate::config::QuantKind::Sq8;
        qcfg.quant_block = 48;
        qcfg.overscan = 3;
        let qidx = SrpLsh::build(ds.clone(), &qcfg, backend.clone()).unwrap();
        let fidx = SrpLsh::build(ds.clone(), &cfg(7, 10), backend).unwrap();
        assert!(qidx.quant_enabled() && !fidx.quant_enabled());
        let mut rng = Pcg64::new(16);
        for k in [1usize, 10, 40] {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = qidx.top_k(&q, k);
            let want = fidx.top_k(&q, k);
            assert_eq!(got.ids(), want.ids(), "k={k}");
            for (g, w) in got.items.iter().zip(&want.items) {
                assert_eq!(g.score, w.score, "k={k}");
            }
            assert_eq!(got.scanned, want.scanned, "k={k}");
        }
        // batch path (per-query two-stage under quant) vs f32 batch
        let qs_owned: Vec<Vec<f32>> =
            (0..5).map(|_| synth::random_theta(&ds, 0.05, &mut rng)).collect();
        let qs: Vec<&[f32]> = qs_owned.iter().map(|q| q.as_slice()).collect();
        let got = qidx.top_k_batch(&qs, 12);
        let want = fidx.top_k_batch(&qs, 12);
        for (j, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.ids(), w.ids(), "query {j}");
            for (a, b) in g.items.iter().zip(&w.items) {
                assert_eq!(a.score, b.score, "query {j}");
            }
            assert_eq!(g.scanned, w.scanned, "query {j}");
        }
    }

    #[test]
    fn multiprobe_increases_candidates() {
        let ds = Arc::new(synth::imagenet_like(2000, 8, 20, 0.3, 5));
        let mut idx = SrpLsh::build(ds.clone(), &cfg(8, 4), Arc::new(NativeScorer)).unwrap();
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        idx.multiprobe = false;
        let without = idx.top_k(&q, 10).scanned;
        idx.multiprobe = true;
        let with = idx.top_k(&q, 10).scanned;
        assert!(with >= without);
    }

    use crate::util::rng::Pcg64;
}
