//! Monolithic ↔ sharded **enum dispatch** for the sampler/estimator
//! stack.
//!
//! The engine (and the learner's Algorithm 4 gradient) must route each
//! operation onto the implementation that matches the built index:
//!
//! | op                | monolithic index            | [`ShardedIndex`](crate::shard::ShardedIndex) |
//! |-------------------|-----------------------------|-----------------------------------|
//! | sample            | [`LazyGumbelSampler`]       | [`ShardedGumbelSampler`]          |
//! | log-partition     | [`PartitionEstimator`]      | [`ShardedPartitionEstimator`]     |
//! | expect-features   | [`ExpectationEstimator`]    | [`ShardedExpectationEstimator`]   |
//!
//! Historically the engine always built the left column, so a server
//! configured with `index.shards > 1` still got its *scans* sharded but
//! silently lost the sharded semantics — replayable id/shard-keyed
//! streams, per-shard decomposed tail draws, log-sum-exp merges. These
//! enums make the routing explicit and cheap (one match per request; no
//! trait-object indirection on the estimator hot paths), and
//! [`build_stack`] is the single constructor both the engine and the
//! learner share.
//!
//! The sharded variants draw all randomness from frozen streams keyed by
//! `(seed, round, salt, idx)` ([`crate::util::rng::Pcg64::keyed`]) — the
//! `rng` argument threaded through the dispatch methods is consumed only
//! by the monolithic variants.
//!
//! A third column, `Remote(...)`, routes the same three operations onto
//! the [`crate::remote`] fan-out over out-of-process shard servers. The
//! remote variants can *partially* fail (some shards down), so each
//! operation also has a `*_status` twin returning the `(ok, total)`
//! shard count alongside the result — `None` for the in-process
//! variants, which cannot degrade. The plain methods degrade silently
//! (empty/`-inf` results on total fan-out failure) and exist for callers
//! that cannot carry a status, e.g. the learner; the engine always uses
//! the `*_status` twins.

use crate::config::Config;
use crate::data::Dataset;
use crate::error::Result;
use crate::estimator::expectation::{ExpectationEstimator, FeatureExpectation};
use crate::estimator::partition::{PartitionEstimate, PartitionEstimator};
use crate::estimator::EstimateWork;
use crate::mips::BuiltIndex;
use crate::remote::{RemoteExpectation, RemotePartition, RemoteSampler};
use crate::sampler::lazy_gumbel::LazyGumbelSampler;
use crate::sampler::{SampleOutcome, Sampler};
use crate::scorer::ScoreBackend;
use crate::shard::{ShardedExpectationEstimator, ShardedGumbelSampler, ShardedPartitionEstimator};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Algorithm 1 behind either implementation.
pub enum SamplerDispatch {
    Mono(LazyGumbelSampler),
    Sharded(ShardedGumbelSampler),
    Remote(RemoteSampler),
}

impl SamplerDispatch {
    /// Top-set size k.
    pub fn k(&self) -> usize {
        match self {
            SamplerDispatch::Mono(s) => s.k,
            SamplerDispatch::Sharded(s) => s.k,
            SamplerDispatch::Remote(s) => s.k,
        }
    }

    /// Implementation name for stats/metrics (`lazy-gumbel` /
    /// `sharded-gumbel` / `remote-gumbel`).
    pub fn name(&self) -> &'static str {
        match self {
            SamplerDispatch::Mono(s) => s.name(),
            SamplerDispatch::Sharded(s) => s.name(),
            SamplerDispatch::Remote(s) => s.name(),
        }
    }

    /// Draw `count` samples for one θ (one MIPS retrieval per θ).
    pub fn sample_many(&self, q: &[f32], count: usize, rng: &mut Pcg64) -> Vec<SampleOutcome> {
        match self {
            SamplerDispatch::Mono(s) => s.sample_many(q, count, rng),
            SamplerDispatch::Sharded(s) => s.sample_many(q, count, rng),
            SamplerDispatch::Remote(s) => {
                s.sample_many(q, count).map(|(v, _)| v).unwrap_or_default()
            }
        }
    }

    /// Batched draws: `counts[i]` samples for `qs[i]`, one batched
    /// retrieval for the whole batch.
    pub fn sample_batch(
        &self,
        qs: &[&[f32]],
        counts: &[usize],
        rng: &mut Pcg64,
    ) -> Vec<Vec<SampleOutcome>> {
        match self {
            SamplerDispatch::Mono(s) => s.sample_batch(qs, counts, rng),
            SamplerDispatch::Sharded(s) => s.sample_batch(qs, counts),
            SamplerDispatch::Remote(s) => s
                .sample_batch(qs, counts)
                .map(|(v, _)| v)
                .unwrap_or_else(|_| vec![Vec::new(); qs.len()]),
        }
    }

    /// [`sample_many`](Self::sample_many) with remote fan-out health:
    /// `Some((ok, total))` from the remote variant (`Err` only when *no*
    /// shard answered), `None` from the in-process variants.
    pub fn sample_many_status(
        &self,
        q: &[f32],
        count: usize,
        rng: &mut Pcg64,
    ) -> Result<(Vec<SampleOutcome>, Option<(usize, usize)>)> {
        match self {
            SamplerDispatch::Remote(s) => s.sample_many(q, count).map(|(v, st)| (v, Some(st))),
            other => Ok((other.sample_many(q, count, rng), None)),
        }
    }

    /// [`sample_batch`](Self::sample_batch) with remote fan-out health.
    pub fn sample_batch_status(
        &self,
        qs: &[&[f32]],
        counts: &[usize],
        rng: &mut Pcg64,
    ) -> Result<(Vec<Vec<SampleOutcome>>, Option<(usize, usize)>)> {
        match self {
            SamplerDispatch::Remote(s) => s.sample_batch(qs, counts).map(|(v, st)| (v, Some(st))),
            other => Ok((other.sample_batch(qs, counts, rng), None)),
        }
    }
}

/// Algorithm 3 behind either implementation.
pub enum PartitionDispatch {
    Mono(PartitionEstimator),
    Sharded(ShardedPartitionEstimator),
    Remote(RemotePartition),
}

/// Degenerate estimate used when every remote shard is unreachable and
/// the caller has no error channel (the status methods return `Err`
/// instead).
fn failed_partition() -> PartitionEstimate {
    PartitionEstimate { log_z: f64::NEG_INFINITY, work: EstimateWork::default() }
}

fn failed_expectation() -> FeatureExpectation {
    FeatureExpectation { mean: Vec::new(), log_z: f64::NEG_INFINITY, work: EstimateWork::default() }
}

impl PartitionDispatch {
    /// Implementation name for stats/metrics.
    pub fn name(&self) -> &'static str {
        match self {
            PartitionDispatch::Mono(_) => "alg3",
            PartitionDispatch::Sharded(_) => "sharded-alg3",
            PartitionDispatch::Remote(e) => e.name(),
        }
    }

    /// One `log Ẑ` estimate.
    pub fn estimate(&self, q: &[f32], rng: &mut Pcg64) -> PartitionEstimate {
        match self {
            PartitionDispatch::Mono(e) => e.estimate(q, rng),
            PartitionDispatch::Sharded(e) => e.estimate(q),
            PartitionDispatch::Remote(e) => {
                e.estimate(q).map(|(v, _)| v).unwrap_or_else(|_| failed_partition())
            }
        }
    }

    /// Batched estimates sharing one retrieval/fan-out.
    pub fn estimate_batch(&self, qs: &[&[f32]], rng: &mut Pcg64) -> Vec<PartitionEstimate> {
        match self {
            PartitionDispatch::Mono(e) => e.estimate_batch(qs, rng),
            PartitionDispatch::Sharded(e) => e.estimate_batch(qs),
            PartitionDispatch::Remote(e) => e
                .estimate_batch(qs)
                .map(|(v, _)| v)
                .unwrap_or_else(|_| vec![failed_partition(); qs.len()]),
        }
    }

    /// [`estimate`](Self::estimate) with remote fan-out health.
    pub fn estimate_status(
        &self,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> Result<(PartitionEstimate, Option<(usize, usize)>)> {
        match self {
            PartitionDispatch::Remote(e) => e.estimate(q).map(|(v, st)| (v, Some(st))),
            other => Ok((other.estimate(q, rng), None)),
        }
    }

    /// [`estimate_batch`](Self::estimate_batch) with remote fan-out
    /// health.
    pub fn estimate_batch_status(
        &self,
        qs: &[&[f32]],
        rng: &mut Pcg64,
    ) -> Result<(Vec<PartitionEstimate>, Option<(usize, usize)>)> {
        match self {
            PartitionDispatch::Remote(e) => e.estimate_batch(qs).map(|(v, st)| (v, Some(st))),
            other => Ok((other.estimate_batch(qs, rng), None)),
        }
    }
}

/// Algorithm 4 behind either implementation.
pub enum ExpectationDispatch {
    Mono(ExpectationEstimator),
    Sharded(ShardedExpectationEstimator),
    Remote(RemoteExpectation),
}

impl ExpectationDispatch {
    /// Implementation name for stats/metrics.
    pub fn name(&self) -> &'static str {
        match self {
            ExpectationDispatch::Mono(_) => "alg4",
            ExpectationDispatch::Sharded(_) => "sharded-alg4",
            ExpectationDispatch::Remote(e) => e.name(),
        }
    }

    /// One `E_θ[φ]` estimate (the MLE gradient's model term).
    pub fn expect_features(&self, q: &[f32], rng: &mut Pcg64) -> FeatureExpectation {
        match self {
            ExpectationDispatch::Mono(e) => e.expect_features(q, rng),
            ExpectationDispatch::Sharded(e) => e.expect_features(q),
            ExpectationDispatch::Remote(e) => {
                e.expect_features(q).map(|(v, _)| v).unwrap_or_else(|_| failed_expectation())
            }
        }
    }

    /// Batched estimates sharing one retrieval/fan-out.
    pub fn expect_features_batch(
        &self,
        qs: &[&[f32]],
        rng: &mut Pcg64,
    ) -> Vec<FeatureExpectation> {
        match self {
            ExpectationDispatch::Mono(e) => e.expect_features_batch(qs, rng),
            ExpectationDispatch::Sharded(e) => e.expect_features_batch(qs),
            ExpectationDispatch::Remote(e) => e
                .expect_features_batch(qs)
                .map(|(v, _)| v)
                .unwrap_or_else(|_| vec![failed_expectation(); qs.len()]),
        }
    }

    /// [`expect_features`](Self::expect_features) with remote fan-out
    /// health.
    pub fn expect_features_status(
        &self,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> Result<(FeatureExpectation, Option<(usize, usize)>)> {
        match self {
            ExpectationDispatch::Remote(e) => e.expect_features(q).map(|(v, st)| (v, Some(st))),
            other => Ok((other.expect_features(q, rng), None)),
        }
    }

    /// [`expect_features_batch`](Self::expect_features_batch) with remote
    /// fan-out health.
    pub fn expect_features_batch_status(
        &self,
        qs: &[&[f32]],
        rng: &mut Pcg64,
    ) -> Result<(Vec<FeatureExpectation>, Option<(usize, usize)>)> {
        match self {
            ExpectationDispatch::Remote(e) => {
                e.expect_features_batch(qs).map(|(v, st)| (v, Some(st)))
            }
            other => Ok((other.expect_features_batch(qs, rng), None)),
        }
    }
}

/// Build the sampler/partition/expectation stack matching the built
/// index: monolithic implementations over a [`BuiltIndex::Mono`],
/// sharded ones over a [`BuiltIndex::Sharded`] (seeded from
/// `config.index.seed`; the three subsystems use distinct stream salts,
/// so one seed is safe to share).
pub fn build_stack(
    config: &Config,
    ds: &Arc<Dataset>,
    index: &BuiltIndex,
    backend: &Arc<dyn ScoreBackend>,
) -> (SamplerDispatch, PartitionDispatch, ExpectationDispatch) {
    // honour the index's measured gap if larger than the configured one
    let gap_c = config.sampler.gap_c.max(index.as_dyn().gap_bound().unwrap_or(0.0));
    let (k, l) = (config.estimator_k(), config.estimator_l());
    match index {
        BuiltIndex::Mono(idx) => (
            SamplerDispatch::Mono(LazyGumbelSampler::new(
                ds.clone(),
                idx.clone(),
                backend.clone(),
                config.sampler_k(),
                gap_c,
            )),
            PartitionDispatch::Mono(PartitionEstimator::new(
                ds.clone(),
                idx.clone(),
                backend.clone(),
                k,
                l,
            )),
            ExpectationDispatch::Mono(ExpectationEstimator::new(
                ds.clone(),
                idx.clone(),
                backend.clone(),
                k,
                l,
            )),
        ),
        BuiltIndex::Sharded(idx) => {
            let seed = config.index.seed;
            (
                SamplerDispatch::Sharded(ShardedGumbelSampler::new(
                    ds.clone(),
                    idx.clone(),
                    backend.clone(),
                    config.sampler_k(),
                    gap_c,
                    seed,
                )),
                PartitionDispatch::Sharded(ShardedPartitionEstimator::new(
                    ds.clone(),
                    idx.clone(),
                    backend.clone(),
                    k,
                    l,
                    seed,
                )),
                ExpectationDispatch::Sharded(ShardedExpectationEstimator::new(
                    ds.clone(),
                    idx.clone(),
                    backend.clone(),
                    k,
                    l,
                    seed,
                )),
            )
        }
    }
}
