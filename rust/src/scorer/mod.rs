//! Score computation backends.
//!
//! Everything the paper does reduces to *scoring*: inner products
//! `y_i = θ·φ(x_i)` over blocks of database rows. [`ScoreBackend`]
//! abstracts where that compute runs:
//!
//! * [`NativeScorer`] — pure-Rust blocked matvec (this module),
//! * `PjrtScorer` (in [`crate::runtime`]) — the AOT-compiled XLA
//!   executables produced by the JAX/Pallas layer, run via PJRT.
//!
//! Besides raw scores, backends expose the two *fused* reductions the
//! estimator path needs, so both backends can run them without
//! materializing a full score buffer in host memory:
//!
//! * [`ScoreBackend::max_sumexp`] → streaming `(max, Σ exp(s − max))`
//!   partition fragments (Algorithm 3),
//! * [`ScoreBackend::expect_fragment`] → additionally `Σ exp(s − max)·φ`
//!   (the unnormalized feature expectation, Algorithm 4 / learning).
//!
//! The native backend routes all of these onto the runtime-dispatched
//! SIMD kernels in [`crate::linalg::simd`]: single-pass fused reductions
//! (no score buffer, no second pass) and a register-blocked multi-query
//! [`ScoreBackend::scores_batch`] that streams each database row from
//! memory once per query batch — the per-query and batched paths produce
//! bit-identical scores by construction.

use crate::data::Dataset;
use crate::linalg::{self, simd, MaxSumExp};

/// Score a scattered id list against `q` — the one shared tail-scoring
/// fast path for every sampler/estimator: gather-free per-row dots on
/// backends that score rows in place (native), one gather + block scan
/// on backends that prefer staged rows (PJRT).
pub fn score_ids(ds: &Dataset, backend: &dyn ScoreBackend, ids: &[u32], q: &[f32]) -> Vec<f32> {
    if ids.is_empty() {
        return Vec::new();
    }
    let d = ds.d;
    if backend.prefers_gather() {
        let mut rows = vec![0f32; ids.len() * d];
        ds.gather(ids, &mut rows);
        let mut out = vec![0f32; ids.len()];
        backend.scores(&rows, d, q, &mut out);
        out
    } else {
        ids.iter().map(|&id| linalg::dot(ds.row(id as usize), q)).collect()
    }
}

/// A backend that can score row blocks against one query or a batch.
pub trait ScoreBackend: Send + Sync {
    /// `out[r] = rows[r·d .. (r+1)·d] · q`.
    fn scores(&self, rows: &[f32], d: usize, q: &[f32], out: &mut [f32]);

    /// Multi-query block scoring: `qs` is `nq` queries flattened
    /// row-major `[nq × d]`, and `out[j·nrows + r] = rows[r]·qs[j]`
    /// (query-major, `nrows = rows.len()/d`). Default: one
    /// [`scores`](Self::scores) pass per query; batch-aware backends
    /// override to amortize the row-block memory traffic across the
    /// whole batch.
    fn scores_batch(&self, rows: &[f32], d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
        let nrows = if d == 0 { 0 } else { rows.len() / d };
        debug_assert_eq!(qs.len(), nq * d);
        debug_assert_eq!(out.len(), nq * nrows);
        for j in 0..nq {
            self.scores(rows, d, &qs[j * d..(j + 1) * d], &mut out[j * nrows..(j + 1) * nrows]);
        }
    }

    /// Streaming partition fragment over a row block.
    fn max_sumexp(&self, rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
        let n = rows.len() / d;
        let mut out = vec![0f32; n];
        self.scores(rows, d, q, &mut out);
        let mut acc = MaxSumExp::default();
        acc.push_all(&out);
        acc
    }

    /// Expectation fragment over a row block: partition fragment plus the
    /// weighted feature sum `wsum = Σ_r exp(s_r − max)·rows[r]`.
    fn expect_fragment(&self, rows: &[f32], d: usize, q: &[f32]) -> (MaxSumExp, Vec<f32>) {
        let n = rows.len() / d;
        let mut out = vec![0f32; n];
        self.scores(rows, d, q, &mut out);
        let mut acc = MaxSumExp::default();
        acc.push_all(&out);
        let mut wsum = vec![0f32; d];
        for r in 0..n {
            let w = ((out[r] as f64) - acc.max).exp() as f32;
            linalg::axpy(w, &rows[r * d..(r + 1) * d], &mut wsum);
        }
        (acc, wsum)
    }

    /// Human-readable backend name (metrics / logs).
    fn name(&self) -> &'static str;

    /// Whether callers should stage scattered rows into a contiguous
    /// buffer before calling [`scores`](Self::scores). Block-shaped
    /// backends (PJRT) need it; the native backend scores rows in place,
    /// skipping the copy (§Perf iteration 1).
    fn prefers_gather(&self) -> bool {
        true
    }
}

/// Pure-Rust scoring backend over the runtime-dispatched SIMD kernels.
#[derive(Default, Clone, Debug)]
pub struct NativeScorer;

impl ScoreBackend for NativeScorer {
    fn scores(&self, rows: &[f32], d: usize, q: &[f32], out: &mut [f32]) {
        linalg::matvec_block(rows, d, q, out);
    }

    fn scores_batch(&self, rows: &[f32], d: usize, qs: &[f32], nq: usize, out: &mut [f32]) {
        simd::matvec_block_multi(rows, d, qs, nq, out);
    }

    fn max_sumexp(&self, rows: &[f32], d: usize, q: &[f32]) -> MaxSumExp {
        simd::block_max_sumexp(rows, d, q)
    }

    fn expect_fragment(&self, rows: &[f32], d: usize, q: &[f32]) -> (MaxSumExp, Vec<f32>) {
        simd::block_expect_fragment(rows, d, q)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn prefers_gather(&self) -> bool {
        false // scores rows wherever they are; no staging copy needed
    }
}

/// Merge expectation fragments `(acc_f, wsum_f)` into a global
/// `(MaxSumExp, wsum)` pair, rescaling each fragment's weighted sum by
/// `exp(max_f − max_global)`.
pub fn merge_expect_fragments(fragments: &[(MaxSumExp, Vec<f32>)], d: usize) -> (MaxSumExp, Vec<f32>) {
    let mut global = MaxSumExp::default();
    for (acc, _) in fragments {
        global.merge(acc);
    }
    let mut wsum = vec![0f32; d];
    for (acc, ws) in fragments {
        if acc.count == 0 {
            continue;
        }
        let scale = (acc.max - global.max).exp() as f32;
        linalg::axpy(scale, ws, &mut wsum);
    }
    (global, wsum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn randmat(rng: &mut Pcg64, n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
        (rows, q)
    }

    #[test]
    fn native_scores_match_dot() {
        let mut rng = Pcg64::new(1);
        let (rows, q) = randmat(&mut rng, 50, 17);
        let mut out = vec![0f32; 50];
        NativeScorer.scores(&rows, 17, &q, &mut out);
        for r in 0..50 {
            assert_eq!(out[r], linalg::dot(&rows[r * 17..(r + 1) * 17], &q));
        }
    }

    #[test]
    fn max_sumexp_equals_logsumexp_of_scores() {
        let mut rng = Pcg64::new(2);
        let (rows, q) = randmat(&mut rng, 64, 9);
        let mut out = vec![0f32; 64];
        NativeScorer.scores(&rows, 9, &q, &mut out);
        let direct: Vec<f64> = out.iter().map(|&x| x as f64).collect();
        let frag = NativeScorer.max_sumexp(&rows, 9, &q);
        // the fused SIMD path uses a polynomial expf (|rel err| ≲ 2e-7),
        // so the comparison tolerance is 1e-5 rather than f64-exact
        assert!((frag.logsumexp() - linalg::logsumexp(&direct)).abs() < 1e-5);
        assert_eq!(frag.count, 64);
    }

    #[test]
    fn expect_fragment_matches_direct_softmax_mean() {
        let mut rng = Pcg64::new(3);
        let (n, d) = (40, 6);
        let (rows, q) = randmat(&mut rng, n, d);
        let (acc, wsum) = NativeScorer.expect_fragment(&rows, d, &q);
        // direct: E[φ] = Σ softmax(s)_r · rows_r ; our fragment encodes
        // wsum = Σ exp(s - max) rows, so E[φ] = wsum / sumexp
        let mut out = vec![0f32; n];
        NativeScorer.scores(&rows, d, &q, &mut out);
        let m = out.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let z: f64 = out.iter().map(|&s| ((s as f64) - m).exp()).sum();
        for j in 0..d {
            let direct: f64 = (0..n)
                .map(|r| ((out[r] as f64) - m).exp() * rows[r * d + j] as f64)
                .sum::<f64>()
                / z;
            let got = wsum[j] as f64 / acc.sumexp;
            assert!((got - direct).abs() < 1e-4, "j={j}: {got} vs {direct}");
        }
    }

    #[test]
    fn merge_expect_fragments_equals_whole() {
        let mut rng = Pcg64::new(4);
        let (n, d) = (90, 5);
        let (rows, q) = randmat(&mut rng, n, d);
        let whole = NativeScorer.expect_fragment(&rows, d, &q);
        let f1 = NativeScorer.expect_fragment(&rows[..30 * d], d, &q);
        let f2 = NativeScorer.expect_fragment(&rows[30 * d..70 * d], d, &q);
        let f3 = NativeScorer.expect_fragment(&rows[70 * d..], d, &q);
        let (acc, wsum) = merge_expect_fragments(&[f1, f2, f3], d);
        // polynomial-expf tolerance (see max_sumexp_equals_logsumexp_of_scores)
        assert!((acc.logsumexp() - whole.0.logsumexp()).abs() < 1e-5);
        for j in 0..d {
            let a = wsum[j] as f64 / acc.sumexp;
            let b = whole.1[j] as f64 / whole.0.sumexp;
            assert!((a - b).abs() < 1e-4, "j={j}");
        }
    }

    #[test]
    fn empty_fragment_merge() {
        let (acc, wsum) = merge_expect_fragments(&[], 3);
        assert_eq!(acc.count, 0);
        assert_eq!(wsum, vec![0.0; 3]);
    }

    #[test]
    fn scores_batch_matches_per_query() {
        let mut rng = Pcg64::new(5);
        let (n, d, nq) = (61, 23, 5);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let qs: Vec<f32> = (0..nq * d).map(|_| rng.gaussian() as f32).collect();
        let mut got = vec![0f32; nq * n];
        NativeScorer.scores_batch(&rows, d, &qs, nq, &mut got);
        for j in 0..nq {
            let mut want = vec![0f32; n];
            NativeScorer.scores(&rows, d, &qs[j * d..(j + 1) * d], &mut want);
            // bit-identical by kernel construction — the batched MIPS
            // paths rely on this for id-level parity with per-query scans
            assert_eq!(&got[j * n..(j + 1) * n], &want[..], "query {j}");
        }
    }
}
