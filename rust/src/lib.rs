//! # gmips — fast amortized inference and learning in log-linear models
//!
//! A production-grade reproduction of *"Fast Amortized Inference and
//! Learning in Log-linear Models with Randomly Perturbed Nearest Neighbor
//! Search"* (Mussmann*, Levy*, Ermon — UAI 2017).
//!
//! Given a large-but-enumerable state space with fixed features `φ(x)` and
//! a stream of queries with changing parameters `θ`, gmips answers
//! sampling / partition-function / expectation / gradient queries against
//! `Pr(x; θ) ∝ exp(θ·φ(x))` in **sublinear amortized time**, by combining
//!
//! * a preprocessed **MIPS index** ([`mips`]) for the top-`O(√n)` scores,
//! * **lazily instantiated Gumbel perturbations** ([`gumbel`],
//!   [`sampler`]) for exact sampling (Algorithms 1–2),
//! * **top-k + uniform-tail estimators** ([`estimator`]) for the
//!   partition function and bounded expectations (Algorithms 3–4), and
//! * a gradient-ascent **learner** ([`learner`]) driven by Algorithm 4.
//!
//! ## Architecture
//!
//! Three layers; Python never runs on the request path:
//!
//! 1. **L1 (Pallas)** and **L2 (JAX)** live in `python/compile/` and are
//!    AOT-lowered once (`make artifacts`) to HLO text.
//! 2. **L3 (this crate)** loads those artifacts through the PJRT C API
//!    ([`runtime`], behind the `pjrt` cargo feature) and serves queries
//!    from a worker-pool [`coordinator`], optionally over TCP
//!    ([`server`]).
//!
//! The native scoring floor is [`linalg::simd`]: runtime-dispatched
//! explicit-SIMD kernels (AVX2+FMA / NEON / scalar, chosen once at
//! startup) with single-pass fused `(max, Σexp, Σexp·φ)` reductions and
//! register-blocked multi-query scoring. On top of it sits the SQ8
//! two-stage scan ([`linalg::quant`]): brute/IVF scans screen candidates
//! on an int8 shadow copy (¼ of the memory traffic) and exact-re-rank
//! the few survivors, bit-identical to the f32-only scan by an
//! error-bound certificate. Batching threads all the way up the stack —
//! [`mips::MipsIndex::top_k_batch`] merges probe scans so a query batch
//! streams each row block once (brute, IVF, and the LSH families), the
//! samplers/estimators expose `*_batch` entry points, and the
//! [`coordinator`] drains its queue in batches (with an optional bounded
//! micro-wait to deepen them) so concurrent users share index scans.
//! Above the single index sits the [`shard`] layer (`index.shards > 1`):
//! `N` sub-indexes over disjoint row partitions answer each query in a
//! parallel fan-out and k-way merge — bit-identical to the monolithic
//! index on brute/IVF/LSH (shared IVF coarse quantizer, shared LSH norm
//! bound) — with sharded sampling (per-shard Gumbel maxima merged by
//! argmax under id-keyed frozen streams), sharded partition estimation
//! (per-shard partials merged by log-sum-exp), and sharded Algorithm-4
//! expectation estimation (per-shard `(log Ẑ_s, μ̂_s)` fragments merged
//! by weighted log-sum-exp). The [`dispatch`] enums route the engine and
//! the learner onto whichever implementation matches the built index, so
//! `index.shards > 1` serves every operation through the sharded stack.
//! The [`remote`] layer distributes that same fan-out across processes:
//! shard servers answer per-shard fragments over the JSON-lines wire
//! protocol and a coordinator-side [`remote::RemoteStack`] merges them
//! with the identical merge code — bit-parity with the in-process
//! sharded stack — under per-request deadlines, bounded retries with
//! backoff, background health probing, and graceful degradation when
//! shards die (responses renormalize over survivors and carry a
//! `degraded` flag).
//!
//! ## Quickstart
//!
//! ```no_run
//! use gmips::prelude::*;
//! use std::sync::Arc;
//!
//! let cfg = Config::preset("tiny").unwrap();
//! let ds = Arc::new(gmips::data::generate(&cfg.data));
//! let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
//! let index = gmips::mips::build_index(&ds, &cfg.index, backend.clone()).unwrap();
//! let sampler = LazyGumbelSampler::new(ds.clone(), index, backend, cfg.sampler_k(), 0.0);
//! let mut rng = Pcg64::new(0);
//! let theta = gmips::data::random_theta(&ds, cfg.data.temperature, &mut rng);
//! let sample = sampler.sample(&theta, &mut rng);
//! println!("sampled state {}", sample.id);
//! ```

// Unsafe-code policy (see rust/UNSAFE_POLICY.md): every unsafe operation
// inside an `unsafe fn` must sit in its own explicitly justified block —
// the function-level `unsafe` stops implying body-wide license. Together
// with the `// SAFETY:` comment convention and `# Safety` doc sections
// this is enforced by `cargo xtask lint`.
#![deny(unsafe_op_in_unsafe_fn)]
// Curated pedantic subset (warn-level so local builds stay usable; the
// clippy CI lane promotes warnings to errors with `-D warnings`):
// `ptr_as_ptr` keeps raw-pointer reinterpretation explicit via
// `.cast::<T>()` instead of `as` chains — the store/linalg unsafe code is
// exactly where a silently retyped pointer becomes UB. The wire/store
// truncation-cast policy (`cast_possible_truncation` on the codecs) is
// scoped to `remote/protocol.rs` and `store/format.rs` via module-level
// attributes there, and re-checked textually by `cargo xtask lint`.
#![warn(clippy::ptr_as_ptr)]
// Style lint tolerated crate-wide (deliberately broad): the blocked
// numeric kernels and the row-major index arithmetic around them
// (linalg, mips, data::pca/synth) use explicit index loops on purpose —
// they mirror the unsafe SIMD variants they are the scalar reference
// for, and iterator rewrites obscure the offset math. Revisit scoping
// this down to the kernel modules once clippy runs regularly in CI.
#![allow(clippy::needless_range_loop)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod dispatch;
pub mod error;
pub mod estimator;
pub mod eval;
pub mod gumbel;
pub mod learner;
pub mod linalg;
pub mod mips;
pub mod obs;
pub mod remote;
pub mod runtime;
pub mod sampler;
pub mod scorer;
pub mod server;
pub mod shard;
pub mod store;
pub mod util;
pub mod walk;

/// Convenient re-exports for downstream users and examples.
pub mod prelude {
    pub use crate::config::{Backend, Config, DataKind, IndexKind};
    pub use crate::data::Dataset;
    pub use crate::error::{Error, Result};
    pub use crate::estimator::expectation::ExpectationEstimator;
    pub use crate::estimator::partition::PartitionEstimator;
    pub use crate::learner::{GradMethod, Learner};
    pub use crate::mips::{build_index, build_index_typed, BuiltIndex, MipsIndex};
    pub use crate::sampler::exact::ExactSampler;
    pub use crate::sampler::fixed_b::FixedBSampler;
    pub use crate::sampler::lazy_gumbel::LazyGumbelSampler;
    pub use crate::sampler::Sampler;
    pub use crate::scorer::{NativeScorer, ScoreBackend};
    pub use crate::shard::{
        ShardedExpectationEstimator, ShardedGumbelSampler, ShardedIndex,
        ShardedPartitionEstimator,
    };
    pub use crate::util::rng::Pcg64;
    pub use crate::walk::RandomWalk;
}
