//! TOML-subset parser for gmips config files (no serde/toml crate offline).
//!
//! Supported grammar — the subset real config files use:
//!
//! * `[section]` and `[section.sub]` headers,
//! * `key = value` with value ∈ {string `"…"`, integer, float, bool,
//!   array of scalars `[1, 2, 3]`},
//! * `#` comments, blank lines,
//! * keys are bare (`[A-Za-z0-9_-]+`).
//!
//! Values are stored flat as `"section.sub.key" → TomlValue`, which is all
//! the typed [`super::Config`] loader needs.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// A scalar or array TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => Err(Error::config(format!("expected string, got {self:?}"))),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => Err(Error::config(format!("expected non-negative integer, got {self:?}"))),
        }
    }
    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => Err(Error::config(format!("expected number, got {self:?}"))),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => Err(Error::config(format!("expected bool, got {self:?}"))),
        }
    }
}

/// Flat `section.key → value` document.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| Error::config(format!("line {}: unterminated section", lineno + 1)))?
                    .trim();
                if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-') {
                    return Err(Error::config(format!("line {}: bad section name '{name}'", lineno + 1)));
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::config(format!("line {}: expected key = value", lineno + 1)))?;
            let key = k.trim();
            // dotted keys (`a.b = 1`) are accepted and treated as an
            // inline section path — the CLI's `--set sampler.k_mult=3`
            // form depends on this
            if key.is_empty()
                || !key.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
            {
                return Err(Error::config(format!("line {}: bad key '{key}'", lineno + 1)));
            }
            let value = parse_value(v.trim())
                .map_err(|e| Error::config(format!("line {}: {e}", lineno + 1)))?;
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            doc.values.insert(full, value);
        }
        Ok(doc)
    }

    /// Load and parse a file.
    pub fn load(path: &str) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::config(format!("cannot read config '{path}': {e}")))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v.as_str()?.to_string()),
        }
    }
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize(),
        }
    }
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_u64(),
        }
    }
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64(),
        }
    }
    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool(),
        }
    }

    /// Overlay another document's values on top of this one (CLI overrides).
    pub fn overlay(&mut self, other: &TomlDoc) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a string literal is respected
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        // minimal escapes
        let un = body.replace("\\\"", "\"").replace("\\\\", "\\").replace("\\n", "\n");
        return Ok(TomlValue::Str(un));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: std::result::Result<Vec<TomlValue>, String> =
            split_top_level(body).into_iter().map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    let cleaned = s.replace('_', "");
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value '{s}'"))
}

/// Split an array body on commas (no nested arrays supported / needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# gmips config
[data]
kind = "imagenet-like"   # synthetic mixture
n = 200_000
d = 64
temperature = 0.05
unit_norm = true

[index]
kind = "ivf"
n_clusters = 1024
n_probe = 32

[sampler]
k_mult = 10.0
ls = [1, 2, 3]
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("data.kind", "").unwrap(), "imagenet-like");
        assert_eq!(doc.get_usize("data.n", 0).unwrap(), 200_000);
        assert_eq!(doc.get_f64("data.temperature", 0.0).unwrap(), 0.05);
        assert!(doc.get_bool("data.unit_norm", false).unwrap());
        assert_eq!(doc.get_str("index.kind", "").unwrap(), "ivf");
        assert_eq!(doc.get_f64("sampler.k_mult", 0.0).unwrap(), 10.0);
        match doc.get("sampler.ls").unwrap() {
            TomlValue::Arr(xs) => assert_eq!(xs.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_for_missing_keys() {
        let doc = TomlDoc::parse("[a]\nx = 1").unwrap();
        assert_eq!(doc.get_usize("a.y", 9).unwrap(), 9);
        assert_eq!(doc.get_str("b.z", "d").unwrap(), "d");
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let doc = TomlDoc::parse("s = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.get_str("s", "").unwrap(), "a#b");
    }

    #[test]
    fn overlay_overrides() {
        let mut base = TomlDoc::parse("[a]\nx = 1\ny = 2").unwrap();
        let over = TomlDoc::parse("[a]\nx = 5").unwrap();
        base.overlay(&over);
        assert_eq!(base.get_usize("a.x", 0).unwrap(), 5);
        assert_eq!(base.get_usize("a.y", 0).unwrap(), 2);
    }

    #[test]
    fn error_cases() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("keyonly").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("bad key = 1").is_err());
    }

    #[test]
    fn type_errors() {
        let doc = TomlDoc::parse("x = \"s\"\ny = -3").unwrap();
        assert!(doc.get_usize("x", 0).is_err());
        assert!(doc.get_usize("y", 0).is_err());
        assert!(doc.get_bool("x", false).is_err());
        // int promotes to float
        let doc = TomlDoc::parse("z = 4").unwrap();
        assert_eq!(doc.get_f64("z", 0.0).unwrap(), 4.0);
    }

    #[test]
    fn scientific_notation() {
        let doc = TomlDoc::parse("eps = 1e-4\nbig = 2.5E3").unwrap();
        assert_eq!(doc.get_f64("eps", 0.0).unwrap(), 1e-4);
        assert_eq!(doc.get_f64("big", 0.0).unwrap(), 2500.0);
    }
}
