//! Typed configuration for the whole system.
//!
//! Config is layered: built-in defaults ← preset (`--preset imagenet` /
//! `wordemb`) ← TOML file (`--config path.toml`) ← CLI `--set sec.key=val`
//! overrides. Every subsystem (data, index, sampler, estimator, learner,
//! runtime, server) reads its parameters from here, so experiments are
//! fully reproducible from a config file.

pub mod toml;

use crate::error::{Error, Result};
use crate::util::cli::Args;
use toml::{TomlDoc, TomlValue};

/// Which synthetic dataset family to generate (see `data::synth`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    /// ImageNet-like: ~1000 balanced Gaussian clusters on the unit sphere
    /// (ResNet-feature geometry after PCA + unit-norm).
    ImagenetLike,
    /// Word-embedding-like: Zipf-sized anisotropic clusters (fastText
    /// geometry).
    WordembLike,
    /// Uniform on the sphere (adversarially unstructured; MIPS-hostile).
    UniformSphere,
}

impl DataKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "imagenet-like" | "imagenet" => Ok(DataKind::ImagenetLike),
            "wordemb-like" | "wordemb" | "embeddings" => Ok(DataKind::WordembLike),
            "uniform" | "uniform-sphere" => Ok(DataKind::UniformSphere),
            other => Err(Error::config(format!("unknown data.kind '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            DataKind::ImagenetLike => "imagenet-like",
            DataKind::WordembLike => "wordemb-like",
            DataKind::UniformSphere => "uniform-sphere",
        }
    }
}

/// MIPS index family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    /// Exact scan (baseline).
    Brute,
    /// k-means / IVF clustering index (Douze et al. 2016 — the paper's
    /// experimental choice).
    Ivf,
    /// Signed-random-projection LSH (Charikar 2002) with the
    /// Neyshabur–Srebro MIPS→cosine reduction.
    Lsh,
    /// Tiered LSH ladder (paper Theorem 3.6): approximate top-k with a
    /// provable gap c.
    Tiered,
}

impl IndexKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "brute" | "exact" => Ok(IndexKind::Brute),
            "ivf" | "kmeans" => Ok(IndexKind::Ivf),
            "lsh" => Ok(IndexKind::Lsh),
            "tiered" | "tiered-lsh" => Ok(IndexKind::Tiered),
            other => Err(Error::config(format!("unknown index.kind '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            IndexKind::Brute => "brute",
            IndexKind::Ivf => "ivf",
            IndexKind::Lsh => "lsh",
            IndexKind::Tiered => "tiered",
        }
    }
}

/// How a sharded index partitions database rows across sub-indexes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Row `i` lives on shard `i mod N` (interleaved; load-balances
    /// clustered id ranges).
    RoundRobin,
    /// Balanced contiguous id ranges (`⌊s·n/N⌋ .. ⌊(s+1)·n/N⌋`; keeps
    /// neighboring rows on one shard, cheap id arithmetic).
    Contiguous,
}

impl ShardStrategy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "round-robin" | "rr" | "interleaved" => Ok(ShardStrategy::RoundRobin),
            "contiguous" | "range" => Ok(ShardStrategy::Contiguous),
            other => Err(Error::config(format!("unknown index.shard_strategy '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            ShardStrategy::RoundRobin => "round-robin",
            ShardStrategy::Contiguous => "contiguous",
        }
    }
}

/// Quantized screening tier for the two-stage MIPS scans (all results
/// stay bit-identical to the f32-only scan via the coverage-certificate
/// contract of `linalg::quant`; a tier that cannot certify falls back up
/// the ladder PQ/SQ4 → SQ8 → f32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantKind {
    /// No quantized screening: plain f32 scans.
    Off,
    /// 8-bit scalar codes (¼ the scan bandwidth; tightest error bound).
    Sq8,
    /// Packed 4-bit scalar codes (⅛ the bandwidth; falls back to SQ8
    /// when its looser bound cannot certify).
    Sq4,
    /// Product quantization: per-subspace codebooks + per-query lookup
    /// tables (`pq_m`/`pq_bits` knobs; smallest codes, loosest bound,
    /// same SQ8 safety net).
    Pq,
}

impl QuantKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" | "none" | "f32" => Ok(QuantKind::Off),
            "sq8" | "int8" => Ok(QuantKind::Sq8),
            "sq4" | "int4" => Ok(QuantKind::Sq4),
            "pq" => Ok(QuantKind::Pq),
            other => Err(Error::config(format!(
                "unknown index.quant '{other}' (expected off|sq8|sq4|pq, or a bool)"
            ))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            QuantKind::Off => "off",
            QuantKind::Sq8 => "sq8",
            QuantKind::Sq4 => "sq4",
            QuantKind::Pq => "pq",
        }
    }
    /// Whether any quantized screening tier is active.
    pub fn enabled(&self) -> bool {
        !matches!(self, QuantKind::Off)
    }
}

/// Score computation backend for block scans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust blocked matvec.
    Native,
    /// AOT-compiled XLA executables via PJRT (`artifacts/`).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" | "rust" => Ok(Backend::Native),
            "pjrt" | "xla" => Ok(Backend::Pjrt),
            other => Err(Error::config(format!("unknown runtime.backend '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Dataset generation parameters.
#[derive(Clone, Debug)]
pub struct DataConfig {
    pub kind: DataKind,
    /// number of database vectors (paper: 1.28M / 2.0M; default scaled)
    pub n: usize,
    /// feature dimension (paper: 256 / 300)
    pub d: usize,
    /// number of latent clusters in the generator
    pub clusters: usize,
    /// within-cluster noise scale (before re-normalization)
    pub noise: f64,
    /// Zipf exponent for wordemb-like cluster sizes
    pub zipf_s: f64,
    /// softmax temperature τ: queries are scaled by 1/τ (paper: τ=0.05)
    pub temperature: f64,
    pub seed: u64,
    /// optional on-disk cache path ("" = regenerate in memory)
    pub path: String,
}

/// MIPS index parameters.
#[derive(Clone, Debug)]
pub struct IndexConfig {
    pub kind: IndexKind,
    /// IVF: number of clusters (0 = auto ≈ 4√n)
    pub n_clusters: usize,
    /// IVF: clusters probed per query (0 = auto)
    pub n_probe: usize,
    /// IVF: k-means iterations
    pub kmeans_iters: usize,
    /// IVF: sample size for k-means training (0 = all)
    pub train_sample: usize,
    /// LSH: number of hash tables
    pub tables: usize,
    /// LSH: bits per hash
    pub bits: usize,
    /// Tiered LSH: number of ladder rungs
    pub rungs: usize,
    /// quantized screening tier for the two-stage scans (all four index
    /// kinds): screen candidates on compressed codes, then re-rank
    /// survivors with the exact f32 kernels. Results are bit-identical
    /// to the f32-only scan (certificate miss → tier ladder
    /// PQ/SQ4 → SQ8 → f32).
    pub quant: QuantKind,
    /// quantized pass-1 retains `k·overscan` candidates before the exact
    /// re-rank (larger = fewer exact-scan fallbacks, more pass-2 work)
    pub overscan: usize,
    /// rows per SQ8/SQ4 `(scale, offset)` quantization block
    pub quant_block: usize,
    /// PQ: number of subspaces (must divide `data.d`; 0 = auto — the
    /// largest of 8/4/2/1 dividing d picks the subspace width)
    pub pq_m: usize,
    /// PQ: bits per subspace code (4 → 16 centroids + SIMD LUT gather,
    /// 8 → 256 centroids)
    pub pq_bits: usize,
    /// number of data-parallel sub-indexes (1 = monolithic). Each shard
    /// holds a disjoint row partition behind its own index; queries fan
    /// out and k-way-merge, bit-identical to the unsharded index on
    /// brute/IVF/LSH (see `crate::shard`).
    pub shards: usize,
    /// how rows are partitioned across shards
    pub shard_strategy: ShardStrategy,
    /// fan shard scans out over `util::pool` threads (false = sequential
    /// fan-out, useful for deterministic profiling)
    pub shard_parallel: bool,
    /// snapshot file for crash-safe persistence: `gmips build --save`
    /// writes it; serve/shard-serve/learn warm-open it when it exists
    /// (and persist a fresh build to it otherwise). "" = no persistence.
    pub path: String,
    /// serve large snapshot sections zero-copy from an mmap (default);
    /// false reads the whole file into RAM instead
    pub mmap: bool,
    pub seed: u64,
}

/// Sampler (Algorithms 1–2) parameters.
#[derive(Clone, Debug)]
pub struct SamplerConfig {
    /// top set size k = k_mult · √n (paper uses 10√n in learning)
    pub k_mult: f64,
    /// fixed-B variant: expected tail count l = l_mult · √n
    pub l_mult: f64,
    /// approximate-MIPS gap allowance c (Algorithm 1 adapts B ← B − c)
    pub gap_c: f64,
}

/// Estimator (Algorithms 3–4) parameters.
#[derive(Clone, Debug)]
pub struct EstimatorConfig {
    pub k_mult: f64,
    pub l_mult: f64,
}

/// Learner (§4.4) parameters.
#[derive(Clone, Debug)]
pub struct LearnConfig {
    /// gradient ascent iterations (paper: 5000)
    pub iters: usize,
    /// learning rate α (paper: 10)
    pub lr: f64,
    /// halve LR every this many iters (paper: 1000)
    pub lr_halve_every: usize,
    /// |D|: training subset size (paper: 16)
    pub train_size: usize,
    /// ours: k = k_mult·√n, l = l_ratio·k (paper: k=10√n, l=10k)
    pub k_mult: f64,
    pub l_ratio: f64,
    /// top-k baseline: k = topk_mult·√n (paper: 100√n)
    pub topk_mult: f64,
    /// evaluate exact log-likelihood every this many iters
    pub eval_every: usize,
    pub seed: u64,
}

/// Runtime (PJRT) parameters.
#[derive(Clone, Debug)]
pub struct RuntimeConfig {
    pub backend: Backend,
    pub artifacts_dir: String,
    /// block rows per scoring executable call (must match an AOT shape)
    pub block: usize,
}

/// Coordinator/server parameters.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub addr: String,
    pub workers: usize,
    pub queue_depth: usize,
    /// bounded micro-wait (µs) a worker spends deepening a drained batch
    /// before serving it — trades a little p50 latency for deeper batches
    /// under moderate load. 0 (default) = serve whatever is queued.
    pub micro_wait_us: u64,
    /// max concurrent client connections; excess connections get an
    /// immediate `overloaded` error and are closed instead of queueing
    pub max_conns: usize,
    /// max bytes in one request line; longer lines are rejected with an
    /// error and the connection resynchronizes at the next newline
    pub max_line_bytes: usize,
    /// how long (ms) a connection thread keeps trying to enqueue a
    /// request on a full coordinator queue before shedding it with an
    /// `overloaded` error (bounds latency under saturation)
    pub shed_ms: u64,
}

/// Remote shard-serving parameters (coordinator side of the networked
/// fan-out; see `crate::remote`).
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// comma-separated shard-server addresses, in shard order
    /// (`"host:port,host:port"`); shard s of N lives at the s-th entry
    pub addrs: String,
    /// per-request deadline (ms) covering all retries to one shard
    pub deadline_ms: u64,
    /// TCP connect timeout (ms) per attempt
    pub connect_timeout_ms: u64,
    /// retry attempts per shard call after the first try
    pub retries: u32,
    /// base backoff (ms) between retries; attempt a sleeps
    /// `backoff_ms · 2^a` plus deterministic jitter
    pub backoff_ms: u64,
    /// background heartbeat period (ms); 0 disables the prober
    pub heartbeat_ms: u64,
    /// consecutive failures before a shard is declared down and the
    /// fan-out stops paying its retry budget
    pub down_after: u32,
}

/// Observability parameters (`crate::obs`): the metrics registry gate
/// and the sampled request-tracing knobs.
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// master switch for registry writes (false = counters/gauges no-op;
    /// the `metrics` op still answers, with frozen values)
    pub enabled: bool,
    /// trace 1 request in every `trace_sample` (deterministic,
    /// counter-based); 0 disables tracing, 1 traces every request
    pub trace_sample: u64,
    /// JSON-lines sink path for sampled traces, appended; "" = discard
    pub trace_sink: String,
}

impl RemoteConfig {
    /// Shard addresses in shard order (split on commas, trimmed,
    /// empties dropped).
    pub fn addr_list(&self) -> Vec<String> {
        self.addrs
            .split(',')
            .map(|a| a.trim().to_string())
            .filter(|a| !a.is_empty())
            .collect()
    }
}

/// Full system config.
#[derive(Clone, Debug)]
pub struct Config {
    pub data: DataConfig,
    pub index: IndexConfig,
    pub sampler: SamplerConfig,
    pub estimator: EstimatorConfig,
    pub learn: LearnConfig,
    pub runtime: RuntimeConfig,
    pub serve: ServeConfig,
    pub remote: RemoteConfig,
    pub obs: ObsConfig,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            data: DataConfig {
                kind: DataKind::ImagenetLike,
                n: 200_000,
                d: 64,
                clusters: 1000,
                // total perturbation norm (per-coord σ = noise/√d):
                // within-cluster cosine ≈ 1/√(1+noise²) ≈ 0.71
                noise: 1.0,
                zipf_s: 1.07,
                temperature: 0.05,
                seed: 42,
                path: String::new(),
            },
            index: IndexConfig {
                kind: IndexKind::Ivf,
                n_clusters: 0,
                n_probe: 0,
                kmeans_iters: 12,
                train_sample: 50_000,
                tables: 16,
                bits: 14,
                rungs: 12,
                quant: QuantKind::Off,
                overscan: 4,
                quant_block: 64,
                pq_m: 0,
                pq_bits: 8,
                shards: 1,
                shard_strategy: ShardStrategy::RoundRobin,
                shard_parallel: true,
                path: String::new(),
                mmap: true,
                seed: 7,
            },
            sampler: SamplerConfig { k_mult: 5.0, l_mult: 5.0, gap_c: 0.0 },
            estimator: EstimatorConfig { k_mult: 5.0, l_mult: 5.0 },
            learn: LearnConfig {
                iters: 5000,
                lr: 10.0,
                lr_halve_every: 1000,
                train_size: 16,
                k_mult: 10.0,
                l_ratio: 10.0,
                topk_mult: 100.0,
                eval_every: 100,
                seed: 1234,
            },
            runtime: RuntimeConfig {
                backend: Backend::Native,
                artifacts_dir: "artifacts".to_string(),
                block: 4096,
            },
            serve: ServeConfig {
                addr: "127.0.0.1:7431".to_string(),
                workers: 0,
                queue_depth: 256,
                micro_wait_us: 0,
                max_conns: 64,
                max_line_bytes: 1 << 20,
                shed_ms: 100,
            },
            remote: RemoteConfig {
                addrs: String::new(),
                deadline_ms: 2000,
                connect_timeout_ms: 500,
                retries: 3,
                backoff_ms: 20,
                heartbeat_ms: 200,
                down_after: 2,
            },
            obs: ObsConfig {
                enabled: true,
                trace_sample: 0,
                trace_sink: String::new(),
            },
        }
    }
}

impl Config {
    /// Paper-described presets for the two evaluation datasets.
    pub fn preset(name: &str) -> Result<Config> {
        let mut c = Config::default();
        match name {
            // ImageNet: N=1,281,167 d=256 τ=0.05 (§4.1.2); scaled default n
            "imagenet" => {
                c.data.kind = DataKind::ImagenetLike;
                c.data.d = 256;
                c.data.clusters = 1000;
                c.data.temperature = 0.05;
            }
            "imagenet-paper-scale" => {
                c.data.kind = DataKind::ImagenetLike;
                c.data.n = 1_281_167;
                c.data.d = 256;
                c.data.clusters = 1000;
                c.data.temperature = 0.05;
            }
            // Word embeddings: N=2,000,126 d=300 unit-norm (§4.1.2)
            "wordemb" => {
                c.data.kind = DataKind::WordembLike;
                c.data.d = 300;
                c.data.clusters = 4000;
                c.data.temperature = 0.05;
            }
            "wordemb-paper-scale" => {
                c.data.kind = DataKind::WordembLike;
                c.data.n = 2_000_126;
                c.data.d = 300;
                c.data.clusters = 4000;
                c.data.temperature = 0.05;
            }
            // small config for tests / CI
            "tiny" => {
                c.data.n = 20_000;
                c.data.d = 32;
                c.data.clusters = 100;
                c.index.train_sample = 10_000;
                c.learn.iters = 200;
                c.learn.eval_every = 20;
            }
            other => return Err(Error::config(format!("unknown preset '{other}'"))),
        }
        Ok(c)
    }

    /// Load from a parsed TOML doc on top of `self`.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let c = self;
        if let Some(v) = doc.get("data.kind") {
            c.data.kind = DataKind::parse(v.as_str()?)?;
        }
        c.data.n = doc.get_usize("data.n", c.data.n)?;
        c.data.d = doc.get_usize("data.d", c.data.d)?;
        c.data.clusters = doc.get_usize("data.clusters", c.data.clusters)?;
        c.data.noise = doc.get_f64("data.noise", c.data.noise)?;
        c.data.zipf_s = doc.get_f64("data.zipf_s", c.data.zipf_s)?;
        c.data.temperature = doc.get_f64("data.temperature", c.data.temperature)?;
        c.data.seed = doc.get_u64("data.seed", c.data.seed)?;
        c.data.path = doc.get_str("data.path", &c.data.path)?;

        if let Some(v) = doc.get("index.kind") {
            c.index.kind = IndexKind::parse(v.as_str()?)?;
        }
        c.index.n_clusters = doc.get_usize("index.n_clusters", c.index.n_clusters)?;
        c.index.n_probe = doc.get_usize("index.n_probe", c.index.n_probe)?;
        c.index.kmeans_iters = doc.get_usize("index.kmeans_iters", c.index.kmeans_iters)?;
        c.index.train_sample = doc.get_usize("index.train_sample", c.index.train_sample)?;
        c.index.tables = doc.get_usize("index.tables", c.index.tables)?;
        c.index.bits = doc.get_usize("index.bits", c.index.bits)?;
        c.index.rungs = doc.get_usize("index.rungs", c.index.rungs)?;
        if let Some(v) = doc.get("index.quant") {
            // historical bool form (`quant = true`) still means SQ8
            c.index.quant = match v {
                TomlValue::Bool(true) => QuantKind::Sq8,
                TomlValue::Bool(false) => QuantKind::Off,
                other => QuantKind::parse(other.as_str()?)?,
            };
        }
        c.index.overscan = doc.get_usize("index.overscan", c.index.overscan)?;
        c.index.quant_block = doc.get_usize("index.quant_block", c.index.quant_block)?;
        c.index.pq_m = doc.get_usize("index.pq_m", c.index.pq_m)?;
        c.index.pq_bits = doc.get_usize("index.pq_bits", c.index.pq_bits)?;
        c.index.shards = doc.get_usize("index.shards", c.index.shards)?;
        if let Some(v) = doc.get("index.shard_strategy") {
            c.index.shard_strategy = ShardStrategy::parse(v.as_str()?)?;
        }
        c.index.shard_parallel = doc.get_bool("index.shard_parallel", c.index.shard_parallel)?;
        c.index.path = doc.get_str("index.path", &c.index.path)?;
        c.index.mmap = doc.get_bool("index.mmap", c.index.mmap)?;
        c.index.seed = doc.get_u64("index.seed", c.index.seed)?;

        c.sampler.k_mult = doc.get_f64("sampler.k_mult", c.sampler.k_mult)?;
        c.sampler.l_mult = doc.get_f64("sampler.l_mult", c.sampler.l_mult)?;
        c.sampler.gap_c = doc.get_f64("sampler.gap_c", c.sampler.gap_c)?;

        c.estimator.k_mult = doc.get_f64("estimator.k_mult", c.estimator.k_mult)?;
        c.estimator.l_mult = doc.get_f64("estimator.l_mult", c.estimator.l_mult)?;

        c.learn.iters = doc.get_usize("learn.iters", c.learn.iters)?;
        c.learn.lr = doc.get_f64("learn.lr", c.learn.lr)?;
        c.learn.lr_halve_every = doc.get_usize("learn.lr_halve_every", c.learn.lr_halve_every)?;
        c.learn.train_size = doc.get_usize("learn.train_size", c.learn.train_size)?;
        c.learn.k_mult = doc.get_f64("learn.k_mult", c.learn.k_mult)?;
        c.learn.l_ratio = doc.get_f64("learn.l_ratio", c.learn.l_ratio)?;
        c.learn.topk_mult = doc.get_f64("learn.topk_mult", c.learn.topk_mult)?;
        c.learn.eval_every = doc.get_usize("learn.eval_every", c.learn.eval_every)?;
        c.learn.seed = doc.get_u64("learn.seed", c.learn.seed)?;

        if let Some(v) = doc.get("runtime.backend") {
            c.runtime.backend = Backend::parse(v.as_str()?)?;
        }
        c.runtime.artifacts_dir = doc.get_str("runtime.artifacts_dir", &c.runtime.artifacts_dir)?;
        c.runtime.block = doc.get_usize("runtime.block", c.runtime.block)?;

        c.serve.addr = doc.get_str("serve.addr", &c.serve.addr)?;
        c.serve.workers = doc.get_usize("serve.workers", c.serve.workers)?;
        c.serve.queue_depth = doc.get_usize("serve.queue_depth", c.serve.queue_depth)?;
        c.serve.micro_wait_us = doc.get_u64("serve.micro_wait_us", c.serve.micro_wait_us)?;
        c.serve.max_conns = doc.get_usize("serve.max_conns", c.serve.max_conns)?;
        c.serve.max_line_bytes = doc.get_usize("serve.max_line_bytes", c.serve.max_line_bytes)?;
        c.serve.shed_ms = doc.get_u64("serve.shed_ms", c.serve.shed_ms)?;

        c.remote.addrs = doc.get_str("remote.addrs", &c.remote.addrs)?;
        c.remote.deadline_ms = doc.get_u64("remote.deadline_ms", c.remote.deadline_ms)?;
        c.remote.connect_timeout_ms =
            doc.get_u64("remote.connect_timeout_ms", c.remote.connect_timeout_ms)?;
        c.remote.retries = doc.get_u64("remote.retries", c.remote.retries as u64)? as u32;
        c.remote.backoff_ms = doc.get_u64("remote.backoff_ms", c.remote.backoff_ms)?;
        c.remote.heartbeat_ms = doc.get_u64("remote.heartbeat_ms", c.remote.heartbeat_ms)?;
        c.remote.down_after = doc.get_u64("remote.down_after", c.remote.down_after as u64)? as u32;

        c.obs.enabled = doc.get_bool("obs.enabled", c.obs.enabled)?;
        c.obs.trace_sample = doc.get_u64("obs.trace_sample", c.obs.trace_sample)?;
        c.obs.trace_sink = doc.get_str("obs.trace_sink", &c.obs.trace_sink)?;
        Ok(())
    }

    /// Full layered load from parsed CLI args:
    /// defaults ← `--preset` ← `--config file` ← repeated `--set k=v`
    /// (`--set` uses the flat `section.key=value` form) ← common shorthand
    /// options (`--n`, `--d`, `--backend`, `--index`).
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut c = match args.get("preset") {
            Some(p) => Config::preset(p)?,
            None => Config::default(),
        };
        if let Some(path) = args.get("config") {
            let doc = TomlDoc::load(path)?;
            c.apply_toml(&doc)?;
        }
        if let Some(sets) = args.get("set") {
            // --set a.b=1,c.d=2
            let mut text = String::new();
            for pair in sets.split(',') {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| Error::config(format!("--set expects key=value, got '{pair}'")))?;
                text.push_str(&format!("{k} = {v}\n"));
            }
            let doc = TomlDoc::parse(&text)?;
            c.apply_toml(&doc)?;
        }
        // common shorthands
        c.data.n = args.get_usize("n", c.data.n)?;
        c.data.d = args.get_usize("d", c.data.d)?;
        c.data.seed = args.get_u64("seed", c.data.seed)?;
        if let Some(b) = args.get("backend") {
            c.runtime.backend = Backend::parse(b)?;
        }
        if let Some(i) = args.get("index") {
            c.index.kind = IndexKind::parse(i)?;
        }
        // `--index` already means the index *kind*, so the snapshot file
        // gets its own flag
        if let Some(p) = args.get("index-path") {
            c.index.path = p.to_string();
        }
        c.validate()?;
        Ok(c)
    }

    /// Sanity-check invariants between sections.
    pub fn validate(&self) -> Result<()> {
        if self.data.n == 0 || self.data.d == 0 {
            return Err(Error::config("data.n and data.d must be positive"));
        }
        if self.data.temperature <= 0.0 {
            return Err(Error::config("data.temperature must be positive"));
        }
        if self.sampler.k_mult <= 0.0 || self.sampler.l_mult <= 0.0 {
            return Err(Error::config("sampler multipliers must be positive"));
        }
        if self.runtime.block == 0 {
            return Err(Error::config("runtime.block must be positive"));
        }
        if self.index.overscan == 0 {
            return Err(Error::config(
                "index.overscan must be ≥ 1 (pass 1 keeps k·overscan candidates)",
            ));
        }
        if self.index.quant_block == 0 {
            return Err(Error::config(
                "index.quant_block must be ≥ 1 (rows per SQ8/SQ4 quantization block)",
            ));
        }
        if self.index.pq_bits != 4 && self.index.pq_bits != 8 {
            return Err(Error::config(format!(
                "index.pq_bits = {} is unsupported: PQ codes are 4-bit (16 centroids \
                 per subspace, SIMD LUT gather) or 8-bit (256 centroids)",
                self.index.pq_bits
            )));
        }
        if self.index.quant == QuantKind::Pq
            && self.index.pq_m != 0
            && self.data.d % self.index.pq_m != 0
        {
            return Err(Error::config(format!(
                "index.pq_m = {} must evenly divide data.d = {} so every subspace has \
                 the same width (set pq_m = 0 to auto-pick a divisor)",
                self.index.pq_m, self.data.d
            )));
        }
        if self.index.shards == 0 {
            return Err(Error::config("index.shards must be ≥ 1 (1 = unsharded)"));
        }
        if self.index.shards > self.data.n {
            return Err(Error::config("index.shards must not exceed data.n"));
        }
        if self.learn.train_size == 0 || self.learn.train_size > self.data.n {
            return Err(Error::config("learn.train_size must be in [1, n]"));
        }
        if self.serve.max_conns == 0 {
            return Err(Error::config("serve.max_conns must be ≥ 1"));
        }
        if self.serve.max_line_bytes < 256 {
            return Err(Error::config(
                "serve.max_line_bytes must be ≥ 256 (requests must fit on one line)",
            ));
        }
        if self.remote.deadline_ms == 0 {
            return Err(Error::config("remote.deadline_ms must be positive"));
        }
        if self.remote.connect_timeout_ms == 0 {
            return Err(Error::config("remote.connect_timeout_ms must be positive"));
        }
        Ok(())
    }

    /// Effective k for samplers: `k_mult · √n`, clamped to `[1, n]`.
    pub fn sampler_k(&self) -> usize {
        eff(self.sampler.k_mult, self.data.n)
    }
    /// Effective l for the fixed-B sampler.
    pub fn sampler_l(&self) -> usize {
        eff(self.sampler.l_mult, self.data.n)
    }
    /// Effective k for estimators.
    pub fn estimator_k(&self) -> usize {
        eff(self.estimator.k_mult, self.data.n)
    }
    /// Effective l for estimators.
    pub fn estimator_l(&self) -> usize {
        eff(self.estimator.l_mult, self.data.n)
    }
    /// Worker count for serving (0 = all cores).
    pub fn serve_workers(&self) -> usize {
        if self.serve.workers == 0 {
            crate::util::pool::default_threads()
        } else {
            self.serve.workers
        }
    }
}

/// `mult · √n` clamped to `[1, n]`.
pub fn eff(mult: f64, n: usize) -> usize {
    ((mult * (n as f64).sqrt()).round() as usize).clamp(1, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Spec;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn default_is_valid() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn presets_match_paper() {
        let c = Config::preset("imagenet-paper-scale").unwrap();
        assert_eq!(c.data.n, 1_281_167);
        assert_eq!(c.data.d, 256);
        assert_eq!(c.data.temperature, 0.05);
        let c = Config::preset("wordemb-paper-scale").unwrap();
        assert_eq!(c.data.n, 2_000_126);
        assert_eq!(c.data.d, 300);
        assert!(Config::preset("nope").is_err());
    }

    #[test]
    fn toml_overrides() {
        let mut c = Config::default();
        let doc = TomlDoc::parse("[data]\nn = 999\nkind = \"wordemb\"\n[index]\nkind = \"lsh\"").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.data.n, 999);
        assert_eq!(c.data.kind, DataKind::WordembLike);
        assert_eq!(c.index.kind, IndexKind::Lsh);
    }

    #[test]
    fn cli_layering() {
        let spec = Spec::new(&["preset", "set", "n", "d", "seed", "backend", "index", "config"]);
        let a = spec
            .parse(argv("gmips run --preset tiny --set sampler.k_mult=3.5,data.d=16 --n 5000"))
            .unwrap();
        let c = Config::from_args(&a).unwrap();
        assert_eq!(c.data.n, 5000); // CLI shorthand wins
        assert_eq!(c.data.d, 16); // --set applied
        assert_eq!(c.sampler.k_mult, 3.5);
    }

    #[test]
    fn effective_sizes() {
        let mut c = Config::default();
        c.data.n = 10_000;
        c.sampler.k_mult = 5.0;
        assert_eq!(c.sampler_k(), 500);
        c.sampler.k_mult = 1e9; // clamped to n
        assert_eq!(c.sampler_k(), 10_000);
    }

    #[test]
    fn validate_rejects_bad() {
        let mut c = Config::default();
        c.data.temperature = 0.0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.learn.train_size = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.index.overscan = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.index.quant_block = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_pq_combos() {
        // pq_bits outside {4, 8} always rejected, with an actionable message
        let mut c = Config::default();
        c.index.pq_bits = 6;
        let err = format!("{}", c.validate().unwrap_err());
        assert!(err.contains("pq_bits"), "{err}");
        // pq_m not dividing d rejected only when the pq tier is selected
        let mut c = Config::default();
        c.data.d = 64;
        c.index.pq_m = 7;
        c.validate().unwrap(); // quant = off: pq knobs inert
        c.index.quant = QuantKind::Pq;
        let err = format!("{}", c.validate().unwrap_err());
        assert!(err.contains("pq_m") && err.contains("divide"), "{err}");
        c.index.pq_m = 16;
        c.validate().unwrap();
        c.index.pq_m = 0; // auto always valid
        c.validate().unwrap();
    }

    #[test]
    fn quant_kind_from_toml_string_and_bool() {
        let mut c = Config::default();
        assert_eq!(c.index.quant, QuantKind::Off);
        // string form selects the tier
        let doc =
            TomlDoc::parse("[index]\nquant = \"pq\"\npq_m = 8\npq_bits = 4").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.index.quant, QuantKind::Pq);
        assert_eq!(c.index.pq_m, 8);
        assert_eq!(c.index.pq_bits, 4);
        // historical bool form still means SQ8 / off
        let doc = TomlDoc::parse("[index]\nquant = true").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.index.quant, QuantKind::Sq8);
        let doc = TomlDoc::parse("[index]\nquant = false").unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.index.quant, QuantKind::Off);
        for k in ["off", "sq8", "sq4", "pq"] {
            assert_eq!(QuantKind::parse(k).unwrap().name(), k);
        }
        assert!(QuantKind::parse("int3").is_err());
    }

    #[test]
    fn quant_and_micro_wait_knobs_from_toml() {
        let mut c = Config::default();
        assert!(!c.index.quant.enabled());
        assert_eq!(c.serve.micro_wait_us, 0);
        let doc = TomlDoc::parse(
            "[index]\nquant = true\noverscan = 8\nquant_block = 32\n[serve]\nmicro_wait_us = 150",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.index.quant, QuantKind::Sq8);
        assert_eq!(c.index.overscan, 8);
        assert_eq!(c.index.quant_block, 32);
        assert_eq!(c.serve.micro_wait_us, 150);
        c.validate().unwrap();
    }

    #[test]
    fn remote_and_serve_knobs_from_toml() {
        let mut c = Config::default();
        assert_eq!(c.serve.max_conns, 64);
        assert_eq!(c.serve.max_line_bytes, 1 << 20);
        assert_eq!(c.serve.shed_ms, 100);
        assert!(c.remote.addr_list().is_empty());
        let doc = TomlDoc::parse(
            "[serve]\nmax_conns = 8\nmax_line_bytes = 4096\nshed_ms = 50\n\
             [remote]\naddrs = \"127.0.0.1:9001, 127.0.0.1:9002\"\ndeadline_ms = 500\n\
             retries = 2\nbackoff_ms = 5\nheartbeat_ms = 0\ndown_after = 3",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.serve.max_conns, 8);
        assert_eq!(c.serve.max_line_bytes, 4096);
        assert_eq!(c.serve.shed_ms, 50);
        assert_eq!(c.remote.addr_list(), vec!["127.0.0.1:9001", "127.0.0.1:9002"]);
        assert_eq!(c.remote.deadline_ms, 500);
        assert_eq!(c.remote.retries, 2);
        assert_eq!(c.remote.backoff_ms, 5);
        assert_eq!(c.remote.heartbeat_ms, 0);
        assert_eq!(c.remote.down_after, 3);
        c.validate().unwrap();
        // degenerate limits must be rejected
        c.serve.max_conns = 0;
        assert!(c.validate().is_err());
        c.serve.max_conns = 8;
        c.serve.max_line_bytes = 16;
        assert!(c.validate().is_err());
        c.serve.max_line_bytes = 4096;
        c.remote.deadline_ms = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn obs_knobs_from_toml() {
        let mut c = Config::default();
        assert!(c.obs.enabled);
        assert_eq!(c.obs.trace_sample, 0);
        assert_eq!(c.obs.trace_sink, "");
        let doc = TomlDoc::parse(
            "[obs]\nenabled = false\ntrace_sample = 128\ntrace_sink = \"/tmp/traces.jsonl\"",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.trace_sample, 128);
        assert_eq!(c.obs.trace_sink, "/tmp/traces.jsonl");
        c.validate().unwrap();
    }

    #[test]
    fn kind_roundtrip() {
        for k in ["imagenet-like", "wordemb-like", "uniform-sphere"] {
            assert_eq!(DataKind::parse(k).unwrap().name(), k);
        }
        for k in ["brute", "ivf", "lsh", "tiered"] {
            assert_eq!(IndexKind::parse(k).unwrap().name(), k);
        }
        for b in ["native", "pjrt"] {
            assert_eq!(Backend::parse(b).unwrap().name(), b);
        }
        for s in ["round-robin", "contiguous"] {
            assert_eq!(ShardStrategy::parse(s).unwrap().name(), s);
        }
        assert!(ShardStrategy::parse("hash").is_err());
    }

    #[test]
    fn shard_knobs_from_toml_and_validation() {
        let mut c = Config::default();
        assert_eq!(c.index.shards, 1);
        assert_eq!(c.index.shard_strategy, ShardStrategy::RoundRobin);
        assert!(c.index.shard_parallel);
        let doc = TomlDoc::parse(
            "[index]\nshards = 8\nshard_strategy = \"contiguous\"\nshard_parallel = false",
        )
        .unwrap();
        c.apply_toml(&doc).unwrap();
        assert_eq!(c.index.shards, 8);
        assert_eq!(c.index.shard_strategy, ShardStrategy::Contiguous);
        assert!(!c.index.shard_parallel);
        c.validate().unwrap();
        // shards = 0 and shards > n must both be rejected
        c.index.shards = 0;
        assert!(c.validate().is_err());
        c.index.shards = c.data.n + 1;
        assert!(c.validate().is_err());
    }
}
