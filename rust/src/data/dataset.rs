//! Dataset container and on-disk binary format.
//!
//! A [`Dataset`] is a dense row-major `[n × d]` f32 matrix of feature
//! vectors `φ(x)` (the paper's fixed sufficient statistics), plus optional
//! per-row latent cluster labels from the synthetic generators (used by
//! evaluation: e.g. cluster purity of the learned model's top samples).
//!
//! Binary format ("GMD1"): little-endian header
//! `magic[4] | n:u64 | d:u32 | has_labels:u32`, then `n*d` f32 rows, then
//! (optionally) `n` u32 labels. Written/read with buffered IO; a 2M×300
//! dataset round-trips in a few seconds.

use crate::error::{Error, Result};
use crate::linalg;
use crate::store::blob::Blob;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"GMD1";

/// Dense feature database.
///
/// Row storage is a [`Blob`]: owned when generated/loaded, zero-copy
/// mapped when opened from an index snapshot (`crate::store`). Either
/// way it derefs to `&[f32]`, so scan kernels and callers are agnostic.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// row-major `[n × d]`
    pub data: Blob<f32>,
    pub n: usize,
    pub d: usize,
    /// latent generator cluster per row (empty if unknown)
    pub labels: Vec<u32>,
}

impl Dataset {
    /// Build from a raw matrix.
    pub fn new(data: Vec<f32>, n: usize, d: usize) -> Result<Self> {
        if data.len() != n * d {
            return Err(Error::data(format!(
                "matrix size {} != n*d = {}*{}",
                data.len(),
                n,
                d
            )));
        }
        Ok(Dataset { data: data.into(), n, d, labels: Vec::new() })
    }

    /// Build from already-validated blob storage (snapshot open path;
    /// the blob may serve directly from a memory map).
    pub fn from_blob(data: Blob<f32>, n: usize, d: usize, labels: Vec<u32>) -> Result<Self> {
        if data.len() != n * d {
            return Err(Error::data(format!(
                "matrix size {} != n*d = {}*{}",
                data.len(),
                n,
                d
            )));
        }
        if !labels.is_empty() && labels.len() != n {
            return Err(Error::data(format!("labels len {} != n = {}", labels.len(), n)));
        }
        Ok(Dataset { data, n, d, labels })
    }

    /// Row accessor.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Normalize every row to unit L2 norm (paper §4.1.2 scales both
    /// datasets to unit norm).
    pub fn normalize_rows(&mut self) {
        let d = self.d;
        let data = self.data.to_mut();
        for r in 0..self.n {
            linalg::normalize(&mut data[r * d..(r + 1) * d]);
        }
    }

    /// Take the first `m` rows (the paper's Figure 2 subsets datasets by
    /// size; generator rows are in random order so prefixes are uniform
    /// subsamples).
    pub fn prefix(&self, m: usize) -> Dataset {
        let m = m.min(self.n);
        Dataset {
            data: self.data[..m * self.d].to_vec().into(),
            n: m,
            d: self.d,
            labels: if self.labels.is_empty() { vec![] } else { self.labels[..m].to_vec() },
        }
    }

    /// Gather rows by id into a caller buffer (`out.len() == ids.len()*d`).
    /// Used to stage scattered S/T rows into contiguous blocks for the
    /// PJRT executables and for the LSH / quantized-survivor re-rank
    /// paths, so the copy loop is hot: bounds are validated once up
    /// front (O(ids) cheap passes) instead of per row inside the loop.
    pub fn gather(&self, ids: &[u32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), ids.len() * self.d);
        let d = self.d;
        if ids.is_empty() || d == 0 {
            return;
        }
        // one-time validation that makes the unchecked copies below sound
        assert!(out.len() >= ids.len() * d, "gather: output buffer too small");
        let max_id = ids.iter().copied().max().unwrap() as usize;
        assert!(max_id < self.n, "gather: id {max_id} out of range (n={})", self.n);
        for (j, &id) in ids.iter().enumerate() {
            // SAFETY: id ≤ max_id < n so the source row [id·d, (id+1)·d)
            // lies inside `data` (len n·d), and j < ids.len() so the
            // destination [j·d, (j+1)·d) lies inside `out` (len ≥
            // ids.len()·d, asserted above). Source and destination are
            // distinct allocations, so the ranges cannot overlap.
            unsafe {
                std::ptr::copy_nonoverlapping(
                    self.data.as_ptr().add(id as usize * d),
                    out.as_mut_ptr().add(j * d),
                    d,
                );
            }
        }
    }

    /// Write to the GMD1 binary format.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&(self.n as u64).to_le_bytes())?;
        w.write_all(&(self.d as u32).to_le_bytes())?;
        w.write_all(&(u32::from(!self.labels.is_empty())).to_le_bytes())?;
        // bulk-write the matrix as bytes
        let bytes = bytemuck_cast_f32(&self.data);
        w.write_all(bytes)?;
        if !self.labels.is_empty() {
            let lbytes = bytemuck_cast_u32(&self.labels);
            w.write_all(lbytes)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Read from the GMD1 binary format.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Dataset> {
        let f = std::fs::File::open(&path)?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::data(format!(
                "bad magic in {:?}: {:?}",
                path.as_ref(),
                magic
            )));
        }
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8) as usize;
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let d = u32::from_le_bytes(b4) as usize;
        r.read_exact(&mut b4)?;
        let has_labels = u32::from_le_bytes(b4) != 0;
        if n.checked_mul(d).is_none() || n * d > (1 << 33) {
            return Err(Error::data(format!("implausible dims n={n} d={d}")));
        }
        let mut data = vec![0f32; n * d];
        r.read_exact(bytemuck_cast_f32_mut(&mut data))?;
        let labels = if has_labels {
            let mut l = vec![0u32; n];
            r.read_exact(bytemuck_cast_u32_mut(&mut l))?;
            l
        } else {
            Vec::new()
        };
        Ok(Dataset { data: data.into(), n, d, labels })
    }
}

// ---- byte casts (little-endian hosts; asserted) ---------------------------

fn bytemuck_cast_f32(x: &[f32]) -> &[u8] {
    assert!(cfg!(target_endian = "little"), "GMD1 format requires little-endian");
    // SAFETY: the byte view covers exactly the slice's own allocation
    // (len·4 bytes at its base); u8 has no alignment requirement and any
    // initialized f32 bytes are valid u8s; the borrow pins the source.
    unsafe { std::slice::from_raw_parts(x.as_ptr().cast::<u8>(), x.len() * 4) }
}
fn bytemuck_cast_f32_mut(x: &mut [f32]) -> &mut [u8] {
    assert!(cfg!(target_endian = "little"));
    // SAFETY: same extent argument as `bytemuck_cast_f32`; the &mut
    // borrow makes this the unique view, and every u8 pattern written
    // back is a valid f32 bit pattern (no invalid values for f32).
    unsafe { std::slice::from_raw_parts_mut(x.as_mut_ptr().cast::<u8>(), x.len() * 4) }
}
fn bytemuck_cast_u32(x: &[u32]) -> &[u8] {
    assert!(cfg!(target_endian = "little"));
    // SAFETY: as `bytemuck_cast_f32` — exact-extent read-only byte view.
    unsafe { std::slice::from_raw_parts(x.as_ptr().cast::<u8>(), x.len() * 4) }
}
fn bytemuck_cast_u32_mut(x: &mut [u32]) -> &mut [u8] {
    assert!(cfg!(target_endian = "little"));
    // SAFETY: as `bytemuck_cast_f32_mut` — unique exact-extent byte view;
    // every bit pattern is a valid u32.
    unsafe { std::slice::from_raw_parts_mut(x.as_mut_ptr().cast::<u8>(), x.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("gmips_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn roundtrip_with_labels() {
        let mut rng = Pcg64::new(1);
        let (n, d) = (123, 7);
        let data: Vec<f32> = (0..n * d).map(|_| rng.gaussian() as f32).collect();
        let mut ds = Dataset::new(data, n, d).unwrap();
        ds.labels = (0..n as u32).map(|i| i % 5).collect();
        let path = tmpfile("roundtrip.bin");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert_eq!(back.n, n);
        assert_eq!(back.d, d);
        assert_eq!(back.data, ds.data);
        assert_eq!(back.labels, ds.labels);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn roundtrip_without_labels() {
        let ds = Dataset::new(vec![1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        let path = tmpfile("nolabels.bin");
        ds.save(&path).unwrap();
        let back = Dataset::load(&path).unwrap();
        assert!(back.labels.is_empty());
        assert_eq!(back.data, ds.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmpfile("badmagic.bin");
        std::fs::write(&path, b"XXXXjunkjunkjunk").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Dataset::new(vec![0.0; 5], 2, 3).is_err());
    }

    #[test]
    fn normalize_and_prefix() {
        let mut ds = Dataset::new(vec![3.0, 4.0, 0.0, 5.0, 6.0, 8.0], 3, 2).unwrap();
        ds.labels = vec![0, 1, 2];
        ds.normalize_rows();
        for r in 0..3 {
            assert!((linalg::norm(ds.row(r)) - 1.0).abs() < 1e-6);
        }
        let p = ds.prefix(2);
        assert_eq!(p.n, 2);
        assert_eq!(p.labels, vec![0, 1]);
        assert_eq!(p.row(1), ds.row(1));
    }

    #[test]
    fn gather_stages_rows() {
        let ds = Dataset::new((0..12).map(|x| x as f32).collect(), 4, 3).unwrap();
        let mut out = vec![0f32; 6];
        ds.gather(&[3, 1], &mut out);
        assert_eq!(out, vec![9.0, 10.0, 11.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn miri_gather_and_byte_casts() {
        // Miri-lane subset: the unchecked-copy gather loop and the
        // slice byte reinterpretations, on tiny inputs
        let ds = Dataset::new((0..20).map(|x| x as f32 * 0.5).collect(), 5, 4).unwrap();
        let mut out = vec![0f32; 12];
        ds.gather(&[4, 0, 2], &mut out);
        assert_eq!(&out[..4], ds.row(4));
        assert_eq!(&out[4..8], ds.row(0));
        assert_eq!(&out[8..], ds.row(2));
        let f = [1.0f32, -2.5];
        assert_eq!(bytemuck_cast_f32(&f).len(), 8);
        assert_eq!(&bytemuck_cast_f32(&f)[..4], &1.0f32.to_le_bytes());
        let mut u = [0u32; 2];
        bytemuck_cast_u32_mut(&mut u)[4] = 7;
        assert_eq!(u, [0, 7]);
        assert_eq!(&bytemuck_cast_u32(&u)[4..], &7u32.to_le_bytes());
        let mut back = [0f32; 1];
        bytemuck_cast_f32_mut(&mut back).copy_from_slice(&3.25f32.to_le_bytes());
        assert_eq!(back[0], 3.25);
    }
}
