//! Feature databases: container + binary IO ([`dataset`]), synthetic
//! generators standing in for ImageNet/fastText ([`synth`]), and the PCA
//! preprocessing stage ([`pca`]).

pub mod dataset;
pub mod pca;
pub mod synth;

pub use dataset::Dataset;
pub use synth::{generate, load_or_generate, random_theta};
