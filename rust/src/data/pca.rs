//! PCA by power iteration with deflation.
//!
//! The paper's ImageNet pipeline (§4.1.2) extracts 2048-d ResNet features,
//! averages over the spatial map, **reduces dimensionality with a PCA**,
//! and unit-normalizes. We reproduce that preprocessing stage so the
//! end-to-end data pipeline matches the paper's: high-d raw features →
//! PCA → d-dim → unit-norm.
//!
//! Power iteration on the covariance is exact enough for the leading
//! components of well-separated spectra and needs only matvec passes —
//! no eigendecomposition dependency.

use crate::linalg;
use crate::util::rng::Pcg64;

/// PCA model: mean + principal axes (row-major `[k × d]`).
#[derive(Clone, Debug)]
pub struct Pca {
    pub mean: Vec<f32>,
    /// orthonormal components, row-major `[k × d_in]`
    pub components: Vec<f32>,
    pub d_in: usize,
    pub k: usize,
    /// eigenvalue estimates (variance captured per component)
    pub eigenvalues: Vec<f64>,
}

impl Pca {
    /// Fit `k` components on row-major `data [n × d]`.
    ///
    /// `iters` power iterations per component (20 is plenty for separated
    /// spectra); deterministic given `seed`.
    pub fn fit(data: &[f32], n: usize, d: usize, k: usize, iters: usize, seed: u64) -> Pca {
        assert!(k <= d, "cannot extract more components than dims");
        assert_eq!(data.len(), n * d);
        let mut rng = Pcg64::new(seed);
        // mean
        let mut mean = vec![0f32; d];
        for r in 0..n {
            linalg::axpy(1.0, &data[r * d..(r + 1) * d], &mut mean);
        }
        linalg::scale(&mut mean, 1.0 / n as f32);

        let mut components = Vec::with_capacity(k * d);
        let mut eigenvalues = Vec::with_capacity(k);
        let mut v: Vec<f32> = vec![0.0; d];
        let mut av: Vec<f32> = vec![0.0; d];
        for _comp in 0..k {
            // random start, orthogonal to found components
            for x in v.iter_mut() {
                *x = rng.gaussian() as f32;
            }
            orthogonalize(&mut v, &components, d);
            linalg::normalize(&mut v);
            let mut lambda = 0.0f64;
            for _ in 0..iters {
                // av = Cov · v computed as (1/n) Σ (x-μ) ((x-μ)·v)
                av.iter_mut().for_each(|x| *x = 0.0);
                for r in 0..n {
                    let row = &data[r * d..(r + 1) * d];
                    // centered dot: (x-μ)·v = x·v − μ·v
                    let c = linalg::dot(row, &v) - linalg::dot(&mean, &v);
                    // av += c * (x - μ)
                    for j in 0..d {
                        av[j] += c * (row[j] - mean[j]);
                    }
                }
                linalg::scale(&mut av, 1.0 / n as f32);
                orthogonalize(&mut av, &components, d);
                lambda = linalg::norm(&av) as f64;
                if lambda < 1e-12 {
                    break;
                }
                v.copy_from_slice(&av);
                linalg::scale(&mut v, (1.0 / lambda) as f32);
            }
            components.extend_from_slice(&v);
            eigenvalues.push(lambda);
        }
        Pca { mean, components, d_in: d, k, eigenvalues }
    }

    /// Project one row into the component space.
    pub fn transform_row(&self, row: &[f32], out: &mut [f32]) {
        debug_assert_eq!(row.len(), self.d_in);
        debug_assert_eq!(out.len(), self.k);
        for c in 0..self.k {
            let comp = &self.components[c * self.d_in..(c + 1) * self.d_in];
            out[c] = linalg::dot(row, comp) - linalg::dot(&self.mean, comp);
        }
    }

    /// Project a whole matrix `[n × d_in] → [n × k]`.
    pub fn transform(&self, data: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0f32; n * self.k];
        for r in 0..n {
            let (i, o) = (r * self.d_in, r * self.k);
            let row = &data[i..i + self.d_in];
            // split borrow
            let out_row = &mut out[o..o + self.k];
            self.transform_row(row, out_row);
        }
        out
    }
}

/// Gram-Schmidt `v ⟂ components`.
fn orthogonalize(v: &mut [f32], components: &[f32], d: usize) {
    let k = components.len() / d.max(1);
    for c in 0..k {
        let comp = &components[c * d..(c + 1) * d];
        let proj = linalg::dot(v, comp);
        for j in 0..d {
            v[j] -= proj * comp[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Build data with a known low-rank structure plus noise.
    fn planted(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let axis1: Vec<f32> = {
            let mut a = vec![0f32; d];
            a[0] = 1.0;
            a
        };
        let axis2: Vec<f32> = {
            let mut a = vec![0f32; d];
            a[1] = 1.0;
            a
        };
        let mut data = vec![0f32; n * d];
        for r in 0..n {
            let c1 = 5.0 * rng.gaussian() as f32;
            let c2 = 2.0 * rng.gaussian() as f32;
            for j in 0..d {
                data[r * d + j] =
                    c1 * axis1[j] + c2 * axis2[j] + 0.05 * rng.gaussian() as f32 + 3.0;
                // +3.0 offset: PCA must remove the mean
            }
        }
        data
    }

    #[test]
    fn recovers_planted_axes() {
        let (n, d) = (2000, 10);
        let data = planted(n, d, 1);
        let pca = Pca::fit(&data, n, d, 2, 30, 2);
        // first component should align with e0 (variance 25), second with e1 (4)
        let c0 = &pca.components[0..d];
        let c1 = &pca.components[d..2 * d];
        assert!(c0[0].abs() > 0.95, "c0 = {c0:?}");
        assert!(c1[1].abs() > 0.9, "c1 = {c1:?}");
        assert!(pca.eigenvalues[0] > pca.eigenvalues[1]);
        assert!((pca.eigenvalues[0] - 25.0).abs() < 4.0, "λ0={}", pca.eigenvalues[0]);
    }

    #[test]
    fn components_orthonormal() {
        let (n, d) = (500, 12);
        let data = planted(n, d, 3);
        let pca = Pca::fit(&data, n, d, 4, 25, 4);
        for a in 0..4 {
            for b in 0..4 {
                let ca = &pca.components[a * d..(a + 1) * d];
                let cb = &pca.components[b * d..(b + 1) * d];
                let dot = linalg::dot(ca, cb);
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-3, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn transform_centers_data() {
        let (n, d) = (400, 8);
        let data = planted(n, d, 5);
        let pca = Pca::fit(&data, n, d, 3, 25, 6);
        let proj = pca.transform(&data, n);
        // projected data should have ~zero mean per component
        for c in 0..3 {
            let mean: f64 = (0..n).map(|r| proj[r * 3 + c] as f64).sum::<f64>() / n as f64;
            assert!(mean.abs() < 0.2, "component {c} mean={mean}");
        }
        // variance along component 0 should be the largest
        let var = |c: usize| -> f64 {
            (0..n).map(|r| (proj[r * 3 + c] as f64).powi(2)).sum::<f64>() / n as f64
        };
        assert!(var(0) > var(1) && var(1) > var(2) - 0.05);
    }

    #[test]
    fn pipeline_high_d_to_low_d() {
        // mimic the paper: raw 64-d features → PCA to 8 → unit norm
        let (n, d_raw, d) = (300, 64, 8);
        let data = planted(n, d_raw, 7);
        let pca = Pca::fit(&data, n, d_raw, d, 20, 8);
        let mut proj = pca.transform(&data, n);
        for r in 0..n {
            linalg::normalize(&mut proj[r * d..(r + 1) * d]);
        }
        let ds = crate::data::dataset::Dataset::new(proj, n, d).unwrap();
        assert!((linalg::norm(ds.row(0)) - 1.0).abs() < 1e-5);
    }
}
