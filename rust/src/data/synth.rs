//! Synthetic dataset generators standing in for the paper's two feature
//! databases (see DESIGN.md §Substitutions).
//!
//! * [`imagenet_like`] — ResNet-152 features after depth-average + PCA +
//!   unit-norm (§4.1.2) form ~1000 class-shaped clusters on the sphere.
//!   We generate `clusters` centers uniform on S^{d-1} and perturb with
//!   isotropic Gaussian noise before re-normalizing (a von-Mises-Fisher
//!   like concentration).
//! * [`wordemb_like`] — fastText embeddings have heavy-tailed cluster
//!   structure; we draw cluster sizes from a Zipf law and use anisotropic
//!   within-cluster noise (random per-cluster scale).
//! * [`uniform_sphere`] — no structure at all; the adversarial case where
//!   clustering-based MIPS degrades (used in ablations).
//!
//! Rows are emitted in globally shuffled order so dataset *prefixes* are
//! uniform subsamples (Figure 2 sweeps subset sizes).

use super::dataset::Dataset;
use crate::config::{DataConfig, DataKind};
use crate::linalg;
use crate::util::rng::Pcg64;

/// Generate a dataset according to config.
pub fn generate(cfg: &DataConfig) -> Dataset {
    match cfg.kind {
        DataKind::ImagenetLike => imagenet_like(cfg.n, cfg.d, cfg.clusters, cfg.noise, cfg.seed),
        DataKind::WordembLike => {
            wordemb_like(cfg.n, cfg.d, cfg.clusters, cfg.noise, cfg.zipf_s, cfg.seed)
        }
        DataKind::UniformSphere => uniform_sphere(cfg.n, cfg.d, cfg.seed),
    }
}

/// Load from `cfg.path` if set and present, else generate (and cache when a
/// path is configured).
pub fn load_or_generate(cfg: &DataConfig) -> Dataset {
    if !cfg.path.is_empty() {
        if let Ok(ds) = Dataset::load(&cfg.path) {
            if ds.n == cfg.n && ds.d == cfg.d {
                return ds;
            }
            eprintln!("warning: cached dataset at {} has wrong shape; regenerating", cfg.path);
        }
        let ds = generate(cfg);
        if let Err(e) = ds.save(&cfg.path) {
            eprintln!("warning: failed to cache dataset at {}: {e}", cfg.path);
        }
        return ds;
    }
    generate(cfg)
}

fn unit_gaussian_vec(rng: &mut Pcg64, d: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..d).map(|_| rng.gaussian() as f32).collect();
    linalg::normalize(&mut v);
    v
}

/// Balanced spherical clusters (ImageNet-feature stand-in).
///
/// `noise` is the *total* perturbation norm relative to the unit-norm
/// center (per-coordinate σ = noise/√d), so cluster tightness is
/// dimension-independent: expected within-cluster cosine ≈
/// `1/√(1+noise²)` — e.g. noise 0.35 → ~0.94, noise 1.0 → ~0.71, the
/// range real ResNet features exhibit within a class.
pub fn imagenet_like(n: usize, d: usize, clusters: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed);
    let clusters = clusters.clamp(1, n.max(1));
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| unit_gaussian_vec(&mut rng, d)).collect();
    let sigma = noise / (d as f64).sqrt();
    let mut data = vec![0f32; n * d];
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let c = (i % clusters) as u32; // balanced assignment
        labels[i] = c;
        let row = &mut data[i * d..(i + 1) * d];
        for (j, x) in row.iter_mut().enumerate() {
            *x = centers[c as usize][j] + (sigma * rng.gaussian()) as f32;
        }
        linalg::normalize(row);
    }
    shuffle_rows(&mut data, &mut labels, d, &mut rng);
    let mut ds = Dataset::new(data, n, d).unwrap();
    ds.labels = labels;
    ds
}

/// Zipf-sized anisotropic clusters (word-embedding stand-in).
pub fn wordemb_like(n: usize, d: usize, clusters: usize, noise: f64, zipf_s: f64, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ 0x5EED_0002);
    let clusters = clusters.clamp(1, n.max(1));
    let centers: Vec<Vec<f32>> = (0..clusters).map(|_| unit_gaussian_vec(&mut rng, d)).collect();
    // per-cluster anisotropy: noise scale multiplier in [0.4, 1.8]
    let aniso: Vec<f64> = (0..clusters).map(|_| 0.4 + 1.4 * rng.next_f64()).collect();
    // Zipf cluster weights w_c ∝ 1/(c+1)^s
    let weights: Vec<f64> = (0..clusters).map(|c| 1.0 / ((c + 1) as f64).powf(zipf_s)).collect();
    let total: f64 = weights.iter().sum();
    // build a cumulative table once, then draw labels by inverse CDF
    let mut cum = Vec::with_capacity(clusters);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cum.push(acc);
    }
    let mut data = vec![0f32; n * d];
    let mut labels = vec![0u32; n];
    for i in 0..n {
        let u = rng.next_f64();
        let c = cum.partition_point(|&x| x < u).min(clusters - 1);
        labels[i] = c as u32;
        let s = noise * aniso[c] / (d as f64).sqrt();
        let row = &mut data[i * d..(i + 1) * d];
        for (j, x) in row.iter_mut().enumerate() {
            *x = centers[c][j] + (s * rng.gaussian()) as f32;
        }
        linalg::normalize(row);
    }
    shuffle_rows(&mut data, &mut labels, d, &mut rng);
    let mut ds = Dataset::new(data, n, d).unwrap();
    ds.labels = labels;
    ds
}

/// Unstructured: uniform on the sphere.
pub fn uniform_sphere(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed ^ 0x5EED_0003);
    let mut data = vec![0f32; n * d];
    for i in 0..n {
        let row = &mut data[i * d..(i + 1) * d];
        for x in row.iter_mut() {
            *x = rng.gaussian() as f32;
        }
        linalg::normalize(row);
    }
    Dataset::new(data, n, d).unwrap()
}

/// Draw a query parameter vector θ the way the paper does for evaluation:
/// "θ drawn uniformly from the dataset" scaled by 1/τ (the temperature is
/// folded into the query so scoring stays a plain inner product).
pub fn random_theta(ds: &Dataset, temperature: f64, rng: &mut Pcg64) -> Vec<f32> {
    let i = rng.next_below(ds.n as u64) as usize;
    let mut q = ds.row(i).to_vec();
    let inv_t = (1.0 / temperature) as f32;
    linalg::scale(&mut q, inv_t);
    q
}

/// Fisher–Yates over rows of a row-major matrix (+ parallel label array).
fn shuffle_rows(data: &mut [f32], labels: &mut [u32], d: usize, rng: &mut Pcg64) {
    let n = labels.len();
    let mut swap_buf = vec![0f32; d];
    for i in (1..n).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        if i != j {
            labels.swap(i, j);
            // swap rows i and j
            let (lo, hi) = if i < j { (i, j) } else { (j, i) };
            let (a, b) = data.split_at_mut(hi * d);
            swap_buf.copy_from_slice(&a[lo * d..(lo + 1) * d]);
            a[lo * d..(lo + 1) * d].copy_from_slice(&b[..d]);
            b[..d].copy_from_slice(&swap_buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imagenet_like_unit_norm_and_clustered() {
        let ds = imagenet_like(2000, 16, 20, 0.3, 1);
        assert_eq!(ds.n, 2000);
        for r in (0..ds.n).step_by(97) {
            assert!((linalg::norm(ds.row(r)) - 1.0).abs() < 1e-5);
        }
        // same-cluster pairs should be much closer than random pairs
        let mut same = 0.0;
        let mut diff = 0.0;
        let mut ns = 0;
        let mut nd = 0;
        for i in 0..200 {
            for j in (i + 1)..200 {
                let dot = linalg::dot(ds.row(i), ds.row(j));
                if ds.labels[i] == ds.labels[j] {
                    same += dot as f64;
                    ns += 1;
                } else {
                    diff += dot as f64;
                    nd += 1;
                }
            }
        }
        assert!(ns > 0 && nd > 0);
        assert!(same / ns as f64 > diff / nd as f64 + 0.3, "clusters not separated");
    }

    #[test]
    fn wordemb_like_zipf_sizes() {
        let ds = wordemb_like(30_000, 16, 50, 0.3, 1.2, 2);
        let mut counts = vec![0usize; 50];
        for &l in &ds.labels {
            counts[l as usize] += 1;
        }
        // the largest cluster should dominate the smallest by a wide margin
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > 8 * (min + 1), "zipf skew missing: max={max} min={min}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = imagenet_like(500, 8, 10, 0.3, 7);
        let b = imagenet_like(500, 8, 10, 0.3, 7);
        let c = imagenet_like(500, 8, 10, 0.3, 8);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn prefixes_mix_clusters() {
        // after shuffling, a prefix must contain many distinct clusters
        let ds = imagenet_like(5000, 8, 50, 0.3, 3);
        let prefix = ds.prefix(500);
        let distinct: std::collections::HashSet<u32> = prefix.labels.iter().copied().collect();
        assert!(distinct.len() > 35, "prefix saw {} clusters", distinct.len());
    }

    #[test]
    fn random_theta_scaled_by_temperature() {
        let ds = uniform_sphere(100, 8, 4);
        let mut rng = Pcg64::new(9);
        let q = random_theta(&ds, 0.05, &mut rng);
        let norm = linalg::norm(&q);
        assert!((norm - 20.0).abs() < 1e-3, "1/τ scaling, got {norm}");
    }

    #[test]
    fn generate_dispatches() {
        let mut cfg = crate::config::Config::default().data;
        cfg.n = 300;
        cfg.d = 8;
        cfg.clusters = 5;
        for kind in [DataKind::ImagenetLike, DataKind::WordembLike, DataKind::UniformSphere] {
            cfg.kind = kind;
            let ds = generate(&cfg);
            assert_eq!(ds.n, 300);
            assert_eq!(ds.d, 8);
        }
    }
}
