//! **Algorithm 4 — Expectation Estimation.**
//!
//! For bounded `|f_i| ≤ C`, estimate `F = Σ_i (e^{y_i}/Z)·f_i` by
//! combining the top-k head with an upweighted uniform tail sample:
//!
//! `Ĵ = Σ_S e^{y} f + (n−k)/l · Σ_T e^{y} f`, `F̂ = Ĵ / Ẑ`.
//!
//! Theorem 3.5 gives `|F̂ − F| ≤ εC` w.p. 1−δ when
//! `k²l ≥ 8n²ε⁻²·ln(4/δ)` and `kl ≥ (8/3)ε⁻²·n·ln(2/δ)`.
//!
//! The vector-valued form ([`ExpectationEstimator::expect_features`])
//! computes `E_θ[φ(x)]` — the model term of the MLE gradient (§4.4) —
//! sharing one `(S, T)` draw across all d coordinates.

use super::EstimateWork;
use crate::data::Dataset;
use crate::linalg::{self, MaxSumExp};
use crate::mips::{MipsIndex, TopKResult};
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Vector expectation estimate (`E_θ[φ]` and the matching `log Ẑ`).
#[derive(Clone, Debug)]
pub struct FeatureExpectation {
    /// Ê[φ] ∈ R^d
    pub mean: Vec<f32>,
    /// log Ẑ from the same (S,T) draw — reused for likelihood tracking
    pub log_z: f64,
    pub work: EstimateWork,
}

/// Algorithm 4 estimator bound to a database + index.
pub struct ExpectationEstimator {
    ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    backend: Arc<dyn ScoreBackend>,
    pub k: usize,
    pub l: usize,
}

impl ExpectationEstimator {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<dyn MipsIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        l: usize,
    ) -> Self {
        let k = k.clamp(1, ds.n);
        let l = l.max(1);
        ExpectationEstimator { ds, index, backend, k, l }
    }

    fn draw_tail(&self, exclude: &FxHashSet<u32>, rng: &mut Pcg64) -> Vec<u32> {
        let n = self.ds.n;
        let l = super::effective_tail_len(self.l, n, exclude.len());
        if l == 0 {
            return Vec::new();
        }
        rng.with_replacement_excluding(n as u64, l, exclude)
    }

    /// Scalar Algorithm 4 for an arbitrary bounded function `f(id)`.
    pub fn expect_scalar(
        &self,
        q: &[f32],
        f: &dyn Fn(u32) -> f64,
        rng: &mut Pcg64,
    ) -> (f64, EstimateWork) {
        let top = self.index.top_k(q, self.k);
        let exclude: FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
        let t_ids = self.draw_tail(&exclude, rng);
        let t_scores = self.score_ids(&t_ids, q);

        let n = self.ds.n;
        let k = top.items.len();
        let weight = if t_ids.is_empty() { 0.0 } else { (n - k) as f64 / t_ids.len() as f64 };
        // stable reference: head max dominates w.h.p.
        let m = top.s_max().max(t_scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64);
        let mut z_hat = 0f64;
        let mut j_hat = 0f64;
        for it in &top.items {
            let w = ((it.score as f64) - m).exp();
            z_hat += w;
            j_hat += w * f(it.id);
        }
        for (&id, &y) in t_ids.iter().zip(&t_scores) {
            let w = ((y as f64) - m).exp() * weight;
            z_hat += w;
            j_hat += w * f(id);
        }
        (
            j_hat / z_hat,
            EstimateWork { scanned: top.scanned, k, l: t_ids.len() },
        )
    }

    /// Vector Algorithm 4 over `f = φ`: the MLE gradient's model term.
    pub fn expect_features(&self, q: &[f32], rng: &mut Pcg64) -> FeatureExpectation {
        let top = self.index.top_k(q, self.k);
        self.expect_features_given_top(&top, q, rng)
    }

    /// Batched Algorithm 4: one [`MipsIndex::top_k_batch`] retrieval for
    /// the whole batch of θs (index scans shared across users), then the
    /// per-query tail draw and head+tail combine.
    pub fn expect_features_batch(&self, qs: &[&[f32]], rng: &mut Pcg64) -> Vec<FeatureExpectation> {
        let tops = self.index.top_k_batch(qs, self.k);
        qs.iter()
            .zip(&tops)
            .map(|(q, top)| self.expect_features_given_top(top, q, rng))
            .collect()
    }

    /// Same, reusing an already retrieved top set.
    pub fn expect_features_given_top(
        &self,
        top: &TopKResult,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> FeatureExpectation {
        let d = self.ds.d;
        let n = self.ds.n;
        let k = top.items.len();
        let exclude: FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
        let t_ids = self.draw_tail(&exclude, rng);
        let t_scores = self.score_ids(&t_ids, q);
        let weight = if t_ids.is_empty() { 0.0 } else { (n - k) as f64 / t_ids.len() as f64 };

        let m = top.s_max().max(t_scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64);
        let mut z_hat = 0f64;
        let mut wsum = vec![0f32; d];
        for it in &top.items {
            let w = ((it.score as f64) - m).exp();
            z_hat += w;
            linalg::axpy(w as f32, self.ds.row(it.id as usize), &mut wsum);
        }
        for (&id, &y) in t_ids.iter().zip(&t_scores) {
            let w = ((y as f64) - m).exp() * weight;
            z_hat += w;
            linalg::axpy(w as f32, self.ds.row(id as usize), &mut wsum);
        }
        let mut mean = wsum;
        linalg::scale(&mut mean, (1.0 / z_hat) as f32);
        let obs = crate::obs::registry();
        obs.estimator_rounds.inc();
        obs.estimator_tail_draws.add(t_ids.len() as u64);
        FeatureExpectation {
            mean,
            log_z: m + z_hat.ln(),
            work: EstimateWork { scanned: top.scanned, k, l: t_ids.len() },
        }
    }

    /// Head-only baseline: softmax expectation truncated to S (the
    /// "top-k gradient" of Table 2; biased).
    pub fn expect_features_topk_only(&self, q: &[f32]) -> FeatureExpectation {
        let top = self.index.top_k(q, self.k);
        let d = self.ds.d;
        let m = top.s_max();
        let mut z = 0f64;
        let mut wsum = vec![0f32; d];
        for it in &top.items {
            let w = ((it.score as f64) - m).exp();
            z += w;
            linalg::axpy(w as f32, self.ds.row(it.id as usize), &mut wsum);
        }
        let mut mean = wsum;
        linalg::scale(&mut mean, (1.0 / z) as f32);
        FeatureExpectation {
            mean,
            log_z: m + z.ln(),
            work: EstimateWork { scanned: top.scanned, k: top.items.len(), l: 0 },
        }
    }

    fn score_ids(&self, ids: &[u32], q: &[f32]) -> Vec<f32> {
        crate::scorer::score_ids(&self.ds, self.backend.as_ref(), ids, q)
    }
}

/// Exact `E_θ[φ]` and log Z by full scan (baseline / evaluation).
pub fn exact_feature_expectation(
    ds: &Dataset,
    backend: &dyn ScoreBackend,
    q: &[f32],
) -> (Vec<f32>, f64) {
    let d = ds.d;
    const BLOCK: usize = 8192;
    let mut acc = MaxSumExp::default();
    let mut out = vec![0f32; BLOCK];
    // pass 1: max + sumexp via the backend's fused reduction
    let mut start = 0;
    while start < ds.n {
        let end = (start + BLOCK).min(ds.n);
        let frag = backend.max_sumexp(&ds.data[start * d..end * d], d, q);
        acc.merge(&frag);
        start = end;
    }
    let m = acc.max;
    // pass 2: weighted feature sum
    let mut wsum = vec![0f64; d];
    let mut start = 0;
    while start < ds.n {
        let end = (start + BLOCK).min(ds.n);
        let buf = &mut out[..end - start];
        backend.scores(&ds.data[start * d..end * d], d, q, buf);
        for (r, &y) in buf.iter().enumerate() {
            let w = ((y as f64) - m).exp();
            let row = &ds.data[(start + r) * d..(start + r + 1) * d];
            for j in 0..d {
                wsum[j] += w * row[j] as f64;
            }
        }
        start = end;
    }
    let mean: Vec<f32> = wsum.iter().map(|&x| (x / acc.sumexp) as f32).collect();
    (mean, acc.logsumexp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::scorer::NativeScorer;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn MipsIndex>, Arc<dyn ScoreBackend>) {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.3, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        (ds, index, backend)
    }

    #[test]
    fn theorem_3_5_scalar_additive_error() {
        let (ds, index, backend) = setup(1_000, 1);
        let est = ExpectationEstimator::new(ds.clone(), index, backend.clone(), 120, 150);
        let mut rng = Pcg64::new(2);
        // bounded f with C = 1
        let f = |id: u32| ((id as f64 * 0.37).sin());
        let mut worst = 0f64;
        for _ in 0..15 {
            let q = synth::random_theta(&ds, 0.2, &mut rng);
            // exact F
            let (_, _log_z) = exact_feature_expectation(&ds, backend.as_ref(), &q);
            let mut all = vec![0f32; ds.n];
            let brute = BruteForce::new(ds.clone(), backend.clone());
            brute.all_scores(&q, &mut all);
            let m = all.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            let z: f64 = all.iter().map(|&y| ((y as f64) - m).exp()).sum();
            let f_true: f64 = all
                .iter()
                .enumerate()
                .map(|(i, &y)| ((y as f64) - m).exp() * f(i as u32))
                .sum::<f64>()
                / z;
            let (f_hat, work) = est.expect_scalar(&q, &f, &mut rng);
            assert_eq!(work.k, 120);
            worst = worst.max((f_hat - f_true).abs());
        }
        // C = 1; with k=120,l=150 on n=1000 the additive error should be
        // comfortably below 0.15
        assert!(worst < 0.15, "worst additive error {worst}");
    }

    #[test]
    fn feature_expectation_matches_exact() {
        let (ds, index, backend) = setup(1_500, 3);
        let est = ExpectationEstimator::new(ds.clone(), index, backend.clone(), 150, 300);
        let mut rng = Pcg64::new(4);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let (want, want_log_z) = exact_feature_expectation(&ds, backend.as_ref(), &q);
        // average a few estimates to suppress sampling noise
        let reps = 10;
        let mut mean = vec![0f64; ds.d];
        let mut lz = 0f64;
        for _ in 0..reps {
            let e = est.expect_features(&q, &mut rng);
            for j in 0..ds.d {
                mean[j] += e.mean[j] as f64 / reps as f64;
            }
            lz += e.log_z / reps as f64;
        }
        let err: f64 = mean
            .iter()
            .zip(&want)
            .map(|(a, &b)| (a - b as f64).abs())
            .fold(0.0, f64::max);
        assert!(err < 0.05, "max coord error {err}");
        assert!((lz - want_log_z).abs() < 0.2, "logZ {lz} vs {want_log_z}");
    }

    #[test]
    fn topk_only_biased_toward_head() {
        // on a spread-out distribution the truncated expectation must
        // deviate from the exact one more than Alg 4 does
        let (ds, index, backend) = setup(2_000, 5);
        let est = ExpectationEstimator::new(ds.clone(), index, backend.clone(), 40, 80);
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 1.0, &mut rng); // high τ ⇒ flat
        let (want, _) = exact_feature_expectation(&ds, backend.as_ref(), &q);
        let head = est.expect_features_topk_only(&q);
        let ours = est.expect_features(&q, &mut rng);
        let err = |m: &[f32]| -> f64 {
            m.iter().zip(&want).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>().sqrt()
        };
        assert!(
            err(&ours.mean) < err(&head.mean),
            "ours {} vs head {}",
            err(&ours.mean),
            err(&head.mean)
        );
    }

    #[test]
    fn shared_st_draw_is_consistent() {
        // log_z from expect_features should be a valid Alg-3 style
        // estimate of the same partition function
        let (ds, index, backend) = setup(800, 7);
        let est = ExpectationEstimator::new(ds.clone(), index, backend.clone(), 100, 150);
        let mut rng = Pcg64::new(8);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let e = est.expect_features(&q, &mut rng);
        let want = crate::estimator::partition::exact_log_partition(&ds, backend.as_ref(), &q);
        assert!((e.log_z - want).abs() < 0.3, "{} vs {}", e.log_z, want);
        assert!(e.work.l > 0);
    }

    use crate::util::rng::Pcg64;
}
