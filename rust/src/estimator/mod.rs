//! Partition-function and expectation estimation (paper §3.2–§3.3).
//!
//! Both estimators combine the exactly-summed **top-k head** `S` with an
//! upweighted **uniform tail sample** `T` (with replacement):
//!
//! * [`partition::PartitionEstimator`] — **Algorithm 3**, unbiased, with
//!   `(ε, δ)` guarantee for `kl ≥ (2/3)(1/ε²)·n·ln(1/δ)` (Theorem 3.4),
//! * [`expectation::ExpectationEstimator`] — **Algorithm 4**, additive
//!   `εC` error for bounded `|f| ≤ C` (Theorem 3.5); the vector-valued
//!   form over `f = φ` is the gradient engine for learning (§4.4).

pub mod expectation;
pub mod partition;

/// Work accounting for one estimation query.
#[derive(Clone, Copy, Debug, Default)]
pub struct EstimateWork {
    /// rows scored during MIPS retrieval
    pub scanned: usize,
    pub k: usize,
    pub l: usize,
}
