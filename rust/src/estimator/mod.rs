//! Partition-function and expectation estimation (paper §3.2–§3.3).
//!
//! Both estimators combine the exactly-summed **top-k head** `S` with an
//! upweighted **uniform tail sample** `T` (with replacement):
//!
//! * [`partition::PartitionEstimator`] — **Algorithm 3**, unbiased, with
//!   `(ε, δ)` guarantee for `kl ≥ (2/3)(1/ε²)·n·ln(1/δ)` (Theorem 3.4),
//! * [`expectation::ExpectationEstimator`] — **Algorithm 4**, additive
//!   `εC` error for bounded `|f| ≤ C` (Theorem 3.5); the vector-valued
//!   form over `f = φ` is the gradient engine for learning (§4.4).

pub mod expectation;
pub mod partition;

/// Effective tail-sample size for Algorithms 3 **and** 4: the configured
/// `l` capped at the tail population `n − k`, floored at 1 whenever any
/// tail row exists, and 0 when the head already covers everything.
///
/// This is the one documented capping rule. The tail is drawn *with
/// replacement*, so `l > n − k` is well-defined — but the two estimators
/// share a single `(S, T)` draw contract (the `log Ẑ` returned by
/// Algorithm 4 must be a valid Algorithm 3 estimate of the same `Z`), so
/// they must agree on the realized `|T|` for any configured `l`.
/// Historically Algorithm 3 capped at `n − k` while Algorithm 4 capped at
/// `8(n − k)`, silently breaking that contract for large `l`; the tighter
/// cap wins because past `n − k` extra with-replacement draws add tail
/// *scoring* cost linearly while the variance of the tail mean is already
/// dominated by the population size.
pub fn effective_tail_len(l: usize, n: usize, k: usize) -> usize {
    if k >= n {
        return 0;
    }
    l.min(n - k).max(1)
}

/// Work accounting for one estimation query.
#[derive(Clone, Copy, Debug, Default)]
pub struct EstimateWork {
    /// rows scored during MIPS retrieval
    pub scanned: usize,
    pub k: usize,
    pub l: usize,
}

#[cfg(test)]
mod tests {
    use super::effective_tail_len;

    #[test]
    fn tail_cap_rule() {
        // capped at the tail population, floored at 1, zero when k ≥ n
        assert_eq!(effective_tail_len(50, 100, 20), 50);
        assert_eq!(effective_tail_len(500, 100, 20), 80);
        assert_eq!(effective_tail_len(0, 100, 20), 1);
        assert_eq!(effective_tail_len(10, 100, 100), 0);
        assert_eq!(effective_tail_len(10, 100, 150), 0);
        assert_eq!(effective_tail_len(1, 2, 1), 1);
    }
}
