//! **Algorithm 3 — Partition Function Estimation.**
//!
//! `Ẑ = Σ_{i∈S} e^{y_i} + (n−k)/l · Σ_{i∈T} e^{y_i}` with `S` the top-k
//! set and `T` a uniform with-replacement sample of the tail. Unbiased
//! (Theorem 3.4); relative error ≤ ε with probability 1−δ when
//! `kl ≥ (2/3)(1/ε²)·n·e^c·ln(1/δ)`.
//!
//! All arithmetic is carried in log space relative to the top score, so
//! τ = 0.05 score ranges (±20) cannot overflow.

use super::EstimateWork;
use crate::data::Dataset;
use crate::linalg::MaxSumExp;
use crate::mips::{MipsIndex, TopKResult};
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Result of a partition estimate.
#[derive(Clone, Copy, Debug)]
pub struct PartitionEstimate {
    /// log Ẑ
    pub log_z: f64,
    pub work: EstimateWork,
}

/// Algorithm 3 estimator bound to a database + index.
pub struct PartitionEstimator {
    ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    backend: Arc<dyn ScoreBackend>,
    pub k: usize,
    pub l: usize,
}

impl PartitionEstimator {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<dyn MipsIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        l: usize,
    ) -> Self {
        let k = k.clamp(1, ds.n);
        let l = l.max(1);
        PartitionEstimator { ds, index, backend, k, l }
    }

    /// Minimum `kl` product for an `(ε, δ)` guarantee (Theorem 3.4, c=0).
    pub fn required_kl(n: usize, eps: f64, delta: f64) -> f64 {
        (2.0 / 3.0) * (1.0 / (eps * eps)) * n as f64 * (1.0 / delta).ln()
    }

    /// Estimate given an already-retrieved top set (amortized setting).
    pub fn estimate_given_top(
        &self,
        top: &TopKResult,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> PartitionEstimate {
        let n = self.ds.n;
        let k = top.items.len();
        debug_assert!(k > 0);

        // tail sample T (uniform, with replacement, excluding S) — sized
        // by the rule shared with Algorithm 4
        let exclude: FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
        let l = super::effective_tail_len(self.l, n, k);
        let t_ids = if l > 0 {
            rng.with_replacement_excluding(n as u64, l, &exclude)
        } else {
            Vec::new()
        };

        // score T via the shared fast path (gather-free on backends
        // that score rows in place)
        let t_scores = crate::scorer::score_ids(&self.ds, self.backend.as_ref(), &t_ids, q);

        // log-space combination relative to the global head max
        let mut head = MaxSumExp::default();
        for it in &top.items {
            head.push(it.score as f64);
        }
        let mut tail = MaxSumExp::default();
        tail.push_all(&t_scores);

        let log_z = combine_head_tail(&head, &tail, n, k, t_ids.len());
        let obs = crate::obs::registry();
        obs.estimator_rounds.inc();
        obs.estimator_tail_draws.add(t_ids.len() as u64);
        PartitionEstimate {
            log_z,
            work: EstimateWork { scanned: top.scanned, k, l: t_ids.len() },
        }
    }

    /// Full Algorithm 3: retrieve S, sample T, combine.
    pub fn estimate(&self, q: &[f32], rng: &mut Pcg64) -> PartitionEstimate {
        let top = self.index.top_k(q, self.k);
        self.estimate_given_top(&top, q, rng)
    }

    /// Batched Algorithm 3: one [`MipsIndex::top_k_batch`] retrieval for
    /// the whole batch of θs, then the per-query tail sample + log-space
    /// combine. The coordinator drains concurrent `log_partition`
    /// requests through this so index scans amortize across users.
    pub fn estimate_batch(&self, qs: &[&[f32]], rng: &mut Pcg64) -> Vec<PartitionEstimate> {
        let tops = self.index.top_k_batch(qs, self.k);
        qs.iter()
            .zip(&tops)
            .map(|(q, top)| self.estimate_given_top(top, q, rng))
            .collect()
    }

    /// Head-only baseline (`Ẑ = Σ_S e^{y}` — what Vijayanarasimhan et al.
    /// 2014 style truncation gives; biased low).
    pub fn estimate_topk_only(&self, q: &[f32]) -> PartitionEstimate {
        let top = self.index.top_k(q, self.k);
        let mut head = MaxSumExp::default();
        for it in &top.items {
            head.push(it.score as f64);
        }
        PartitionEstimate {
            log_z: head.logsumexp(),
            work: EstimateWork { scanned: top.scanned, k: top.items.len(), l: 0 },
        }
    }
}

/// `log( Σ_head e^y + (n−k)/l · Σ_tail e^y )` from streaming fragments.
pub fn combine_head_tail(
    head: &MaxSumExp,
    tail: &MaxSumExp,
    n: usize,
    k: usize,
    l: usize,
) -> f64 {
    if tail.count == 0 || l == 0 || n == k {
        return head.logsumexp();
    }
    let weight = (n - k) as f64 / l as f64;
    // reference point: max of both fragment maxima
    let m = head.max.max(tail.max);
    let head_mass = if head.count > 0 { head.sumexp * (head.max - m).exp() } else { 0.0 };
    let tail_mass = tail.sumexp * (tail.max - m).exp() * weight;
    m + (head_mass + tail_mass).ln()
}

/// Exact log partition via a full scan (baseline / evaluation). Runs on
/// the backend's fused `(max, Σexp)` reduction block by block — no score
/// buffer, single memory pass per block on the native backend.
pub fn exact_log_partition(ds: &Dataset, backend: &dyn ScoreBackend, q: &[f32]) -> f64 {
    crate::obs::registry().estimator_exact_evals.inc();
    let mut acc = MaxSumExp::default();
    const BLOCK: usize = 8192;
    let d = ds.d;
    let mut start = 0;
    while start < ds.n {
        let end = (start + BLOCK).min(ds.n);
        let frag = backend.max_sumexp(&ds.data[start * d..end * d], d, q);
        acc.merge(&frag);
        start = end;
    }
    acc.logsumexp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::scorer::NativeScorer;
    use crate::util::stats;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn MipsIndex>, Arc<dyn ScoreBackend>) {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.3, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        (ds, index, backend)
    }

    #[test]
    fn theorem_3_4_unbiased() {
        // E[Ẑ] = Z: average many estimates in the *linear* domain.
        let (ds, index, backend) = setup(800, 1);
        let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 40, 40);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let true_log_z = exact_log_partition(&ds, backend.as_ref(), &q);
        let top = est.index.top_k(&q, est.k);
        let reps = 600;
        // average Ẑ/Z to avoid overflow
        let mean_ratio: f64 = (0..reps)
            .map(|_| (est.estimate_given_top(&top, &q, &mut rng).log_z - true_log_z).exp())
            .sum::<f64>()
            / reps as f64;
        assert!((mean_ratio - 1.0).abs() < 0.05, "E[Ẑ]/Z = {mean_ratio}");
    }

    #[test]
    fn theorem_3_4_epsilon_delta_coverage() {
        // with kl ≥ (2/3)(1/ε²) n ln(1/δ), |Ẑ−Z|/Z ≤ ε w.p. 1−δ
        let (ds, index, backend) = setup(1_000, 3);
        let (eps, delta) = (0.35, 0.1);
        let need = PartitionEstimator::required_kl(ds.n, eps, delta);
        let k = (need.sqrt().ceil() as usize).min(ds.n / 2);
        let l = (need / k as f64).ceil() as usize;
        assert!((k * l) as f64 >= need);
        let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), k, l);
        let mut rng = Pcg64::new(4);
        let mut violations = 0;
        let trials = 60;
        for _ in 0..trials {
            let q = synth::random_theta(&ds, 0.2, &mut rng);
            let true_log_z = exact_log_partition(&ds, backend.as_ref(), &q);
            let got = est.estimate(&q, &mut rng).log_z;
            let rel = ((got - true_log_z).exp() - 1.0).abs();
            if rel > eps {
                violations += 1;
            }
        }
        // δ = 0.1 → expect ≤ ~6 violations of 60; allow 4σ slack
        assert!(violations <= 16, "{violations}/{trials} exceeded ε");
    }

    #[test]
    fn topk_only_is_biased_low() {
        let (ds, index, backend) = setup(2_000, 5);
        let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 50, 50);
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 0.3, &mut rng);
        let true_log_z = exact_log_partition(&ds, backend.as_ref(), &q);
        let head_only = est.estimate_topk_only(&q).log_z;
        assert!(head_only < true_log_z, "head-only must underestimate");
        // while Alg 3 is accurate
        let full = est.estimate(&q, &mut rng).log_z;
        assert!(
            stats::rel_err(full.exp(), true_log_z.exp()) < stats::rel_err(head_only.exp(), true_log_z.exp()),
            "Alg 3 must beat head-only"
        );
    }

    #[test]
    fn numerically_stable_at_low_temperature() {
        // τ = 0.01 ⇒ scores up to 100: naive Σe^y overflows f64? (e^100 ≈
        // 2.7e43 fine, but e^800 would not be) — use extreme θ norm to
        // force the log-space path
        let (ds, index, backend) = setup(500, 7);
        let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 30, 30);
        let mut rng = Pcg64::new(8);
        let mut q = synth::random_theta(&ds, 0.05, &mut rng);
        crate::linalg::scale(&mut q, 50.0); // scores ~ ±1000
        let got = est.estimate(&q, &mut rng).log_z;
        assert!(got.is_finite());
        let want = exact_log_partition(&ds, backend.as_ref(), &q);
        assert!((got - want).abs() < 1.0, "got {got} want {want}");
    }

    #[test]
    fn k_equals_n_degenerates_to_exact() {
        let (ds, index, backend) = setup(200, 9);
        let est = PartitionEstimator::new(ds.clone(), index, backend.clone(), 200, 10);
        let mut rng = Pcg64::new(10);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let got = est.estimate(&q, &mut rng).log_z;
        let want = exact_log_partition(&ds, backend.as_ref(), &q);
        // exact path uses the fused polynomial-expf reduction; the head
        // path uses exact f64 exps — they agree to ≲1e-6, not exactly
        assert!((got - want).abs() < 1e-5);
    }

    use crate::util::rng::Pcg64;
}
