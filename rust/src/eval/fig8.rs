//! **Figure 8 (appendix)** — empirical sampling accuracy.
//!
//! Left/center panels: histogram of samples over probability-ranked bins
//! (top-10, top-100, top-1k, rest) for random θ — ours must match the
//! true distribution bin-for-bin. Right panel: relative error between
//! empirical and true bin masses over many θ, for exact sampling vs ours
//! (the two error profiles should be statistically indistinguishable).

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::mips::brute::BruteForce;
use crate::sampler::{exact::ExactSampler, lazy_gumbel::LazyGumbelSampler, Sampler};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timing::{ascii_table, write_csv};
use std::sync::Arc;

/// Probability-ranked bin edges (by rank): top-10, 10–100, 100–1k, rest.
const BIN_EDGES: [usize; 3] = [10, 100, 1000];

#[derive(Clone, Debug)]
pub struct Fig8Row {
    pub theta_id: usize,
    pub bin: String,
    pub true_mass: f64,
    pub exact_freq: f64,
    pub ours_freq: f64,
}

/// Aggregate over θ: mean |empirical − true| relative error per sampler.
#[derive(Clone, Debug)]
pub struct Fig8Summary {
    pub exact_err_mean: f64,
    pub exact_err_std: f64,
    pub ours_err_mean: f64,
    pub ours_err_std: f64,
}

fn bin_of(rank: usize) -> usize {
    for (b, &e) in BIN_EDGES.iter().enumerate() {
        if rank < e {
            return b;
        }
    }
    BIN_EDGES.len()
}

fn bin_name(b: usize) -> String {
    match b {
        0 => "top-10".into(),
        1 => "10-100".into(),
        2 => "100-1k".into(),
        _ => "rest".into(),
    }
}

pub fn run(opts: &EvalOpts) -> (Vec<Fig8Row>, Fig8Summary) {
    let mut cfg = Config::preset("imagenet").unwrap();
    cfg.data.n = opts.n.min(20_000); // exact probabilities need full scans
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(&cfg, &ds, backend.clone());
    let k = cfg.sampler_k();
    let ours = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), k, 0.0);
    let exact = ExactSampler::new(ds.clone(), backend.clone());
    let _brute = BruteForce::new(ds.clone(), backend.clone());

    let mut rng = Pcg64::new(opts.seed ^ 0xF168);
    let n_theta = opts.queries.clamp(3, 30);
    let samples_per_theta = 8_000usize;
    let nbins = BIN_EDGES.len() + 1;

    let mut rows = Vec::new();
    let mut exact_errs = Vec::new();
    let mut ours_errs = Vec::new();
    for t in 0..n_theta {
        let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);
        // true bin masses from exact probabilities, ranked
        let probs = exact.probabilities(&q);
        let mut order: Vec<usize> = (0..ds.n).collect();
        order.sort_unstable_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
        let mut rank_of = vec![0usize; ds.n];
        for (rank, &id) in order.iter().enumerate() {
            rank_of[id] = rank;
        }
        let mut true_mass = vec![0f64; nbins];
        for id in 0..ds.n {
            true_mass[bin_of(rank_of[id])] += probs[id];
        }
        // empirical bin frequencies
        let mut count_bins = |sampler: &dyn Sampler, rng: &mut Pcg64| -> Vec<f64> {
            let mut c = vec![0f64; nbins];
            for o in sampler.sample_many(&q, samples_per_theta, rng) {
                c[bin_of(rank_of[o.id as usize])] += 1.0;
            }
            c.iter().map(|x| x / samples_per_theta as f64).collect()
        };
        let ef = count_bins(&exact, &mut rng);
        let of = count_bins(&ours, &mut rng);
        for b in 0..nbins {
            if true_mass[b] > 1e-4 {
                exact_errs.push((ef[b] - true_mass[b]).abs() / true_mass[b]);
                ours_errs.push((of[b] - true_mass[b]).abs() / true_mass[b]);
            }
            if t < 2 {
                rows.push(Fig8Row {
                    theta_id: t,
                    bin: bin_name(b),
                    true_mass: true_mass[b],
                    exact_freq: ef[b],
                    ours_freq: of[b],
                });
            }
        }
    }
    let (em, es) = stats::mean_std(&exact_errs);
    let (om, os) = stats::mean_std(&ours_errs);
    let summary = Fig8Summary { exact_err_mean: em, exact_err_std: es, ours_err_mean: om, ours_err_std: os };
    report(&rows, &summary, opts);
    (rows, summary)
}

fn report(rows: &[Fig8Row], s: &Fig8Summary, opts: &EvalOpts) {
    let headers = ["theta", "bin", "true_mass", "exact_freq", "ours_freq"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.theta_id.to_string(),
                r.bin.clone(),
                format!("{:.4}", r.true_mass),
                format!("{:.4}", r.exact_freq),
                format!("{:.4}", r.ours_freq),
            ]
        })
        .collect();
    println!("\n=== Figure 8: sampling histogram match (2 example θ) ===");
    println!("{}", ascii_table(&headers, &table));
    println!(
        "bin relative error over all θ: exact {:.3}±{:.3} | ours {:.3}±{:.3}",
        s.exact_err_mean, s.exact_err_std, s.ours_err_mean, s.ours_err_std
    );
    if opts.write_csv {
        if let Ok(p) = write_csv("fig8_sampling_accuracy", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ours_error_statistically_close_to_exact() {
        let opts = EvalOpts { n: 3_000, queries: 3, seed: 7, write_csv: false };
        let (rows, s) = run(&opts);
        assert!(!rows.is_empty());
        // the paper's claim: error rates not statistically different —
        // accept ours within exact ± a few std
        assert!(
            s.ours_err_mean < s.exact_err_mean + 3.0 * (s.exact_err_std + s.ours_err_std + 0.01),
            "{s:?}"
        );
    }

    #[test]
    fn bins_partition_ranks() {
        assert_eq!(bin_of(0), 0);
        assert_eq!(bin_of(9), 0);
        assert_eq!(bin_of(10), 1);
        assert_eq!(bin_of(999), 2);
        assert_eq!(bin_of(10_000), 3);
    }
}
