//! **Figure 4** — partition-function estimation: runtime vs relative
//! error frontier.
//!
//! Three families, as in the paper:
//! * **ours** (Algorithm 3) sweeping (k, l),
//! * **top-k only** (truncated mass; error floors at the tail mass),
//! * **frozen Gumbel** (Mussmann & Ermon 2016) sweeping noise length t —
//!   cannot get below ~15% error even at t = 64, and slows as t grows.
//! Plus the exact full-scan time as the reference line.

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::estimator::partition::{exact_log_partition, PartitionEstimator};
use crate::sampler::frozen::FrozenGumbel;
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timing::{ascii_table, write_csv, Stopwatch};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Fig4Row {
    pub method: String,
    pub param: String,
    pub runtime_us: f64,
    pub rel_err: f64,
}

pub fn run(opts: &EvalOpts) -> Vec<Fig4Row> {
    let mut cfg = Config::preset("imagenet").unwrap();
    // frozen-Gumbel baselines rebuild augmented indexes; keep n moderate
    cfg.data.n = opts.n.min(60_000);
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(&cfg, &ds, backend.clone());

    let mut rng = Pcg64::new(opts.seed ^ 0xF164);
    let thetas: Vec<Vec<f32>> = (0..opts.queries.clamp(3, 12))
        .map(|_| data::random_theta(&ds, cfg.data.temperature, &mut rng))
        .collect();
    let exact_lz: Vec<f64> = thetas
        .iter()
        .map(|q| exact_log_partition(&ds, backend.as_ref(), q))
        .collect();
    // exact runtime reference
    let sw = Stopwatch::start();
    for q in &thetas {
        std::hint::black_box(exact_log_partition(&ds, backend.as_ref(), q));
    }
    let exact_us = sw.micros() / thetas.len() as f64;

    let mut rows = vec![Fig4Row {
        method: "exact".into(),
        param: "-".into(),
        runtime_us: exact_us,
        rel_err: 0.0,
    }];

    // ---- ours: (k,l) sweep ------------------------------------------------
    for mult in [1.0, 2.0, 5.0, 10.0, 20.0] {
        let k = crate::config::eff(mult, ds.n);
        let est = PartitionEstimator::new(ds.clone(), index.clone(), backend.clone(), k, k);
        let sw = Stopwatch::start();
        let mut errs = Vec::new();
        for (q, &lz) in thetas.iter().zip(&exact_lz) {
            let got = est.estimate(q, &mut rng).log_z;
            errs.push(((got - lz).exp() - 1.0).abs());
        }
        rows.push(Fig4Row {
            method: "ours".into(),
            param: format!("k=l={mult}√n"),
            runtime_us: sw.micros() / thetas.len() as f64,
            rel_err: stats::mean_std(&errs).0,
        });
    }

    // ---- top-k only ---------------------------------------------------------
    for mult in [1.0, 5.0, 20.0, 50.0] {
        let k = crate::config::eff(mult, ds.n);
        let est = PartitionEstimator::new(ds.clone(), index.clone(), backend.clone(), k, 1);
        let sw = Stopwatch::start();
        let mut errs = Vec::new();
        for (q, &lz) in thetas.iter().zip(&exact_lz) {
            let got = est.estimate_topk_only(q).log_z;
            errs.push(((got - lz).exp() - 1.0).abs());
        }
        rows.push(Fig4Row {
            method: "top-k".into(),
            param: format!("k={mult}√n"),
            runtime_us: sw.micros() / thetas.len() as f64,
            rel_err: stats::mean_std(&errs).0,
        });
    }

    // ---- frozen Gumbel (M&E 2016) -------------------------------------------
    let mut icfg = cfg.index.clone();
    icfg.n_clusters = 0;
    icfg.n_probe = 0;
    icfg.kmeans_iters = 4;
    icfg.train_sample = 10_000.min(ds.n);
    for t in [4usize, 16, 64] {
        let fg = FrozenGumbel::build(&ds, t, &icfg, backend.clone(), opts.seed ^ t as u64)
            .expect("frozen build");
        let sw = Stopwatch::start();
        let mut errs = Vec::new();
        for (q, &lz) in thetas.iter().zip(&exact_lz) {
            let (got, _) = fg.log_partition_estimate(q);
            errs.push(((got - lz).exp() - 1.0).abs());
        }
        rows.push(Fig4Row {
            method: "frozen".into(),
            param: format!("t={t}"),
            runtime_us: sw.micros() / thetas.len() as f64,
            rel_err: stats::mean_std(&errs).0,
        });
    }

    report(&rows, opts);
    rows
}

fn report(rows: &[Fig4Row], opts: &EvalOpts) {
    let headers = ["method", "param", "runtime_us", "rel_err"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                r.param.clone(),
                format!("{:.1}", r.runtime_us),
                format!("{:.4}", r.rel_err),
            ]
        })
        .collect();
    println!("\n=== Figure 4: partition estimate — runtime vs relative error ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("fig4_partition", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_shape_holds() {
        let opts = EvalOpts { n: 6_000, queries: 4, seed: 3, write_csv: false };
        let rows = run(&opts);
        // ours at k=l=20√n must be much more accurate than top-k at 20√n
        let ours_best = rows
            .iter()
            .filter(|r| r.method == "ours")
            .map(|r| r.rel_err)
            .fold(f64::INFINITY, f64::min);
        let topk_best = rows
            .iter()
            .filter(|r| r.method == "top-k")
            .map(|r| r.rel_err)
            .fold(f64::INFINITY, f64::min);
        let frozen_best = rows
            .iter()
            .filter(|r| r.method == "frozen")
            .map(|r| r.rel_err)
            .fold(f64::INFINITY, f64::min);
        assert!(ours_best < 0.1, "ours best err {ours_best}");
        assert!(frozen_best > ours_best, "frozen must not beat ours");
        // top-k only floors at tail mass
        assert!(topk_best > ours_best);
    }
}
