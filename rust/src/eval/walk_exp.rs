//! **§4.2.2** — random walk over the dataset: exact chain vs
//! lazy-Gumbel chain.
//!
//! Paper (1M steps over ImageNet): 73.6% top-1000 overlap between chains
//! vs 69.3% / 72.9% within-chain window overlaps — i.e. between-chain
//! differences match finite-sample noise, so the approximate chain has
//! the same stationary behaviour.

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::sampler::{exact::ExactSampler, lazy_gumbel::LazyGumbelSampler};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::timing::{ascii_table, write_csv};
use crate::walk::{RandomWalk, WalkComparison};
use std::sync::Arc;

pub fn run(opts: &EvalOpts) -> WalkComparison {
    let mut cfg = Config::preset("imagenet").unwrap();
    // the exact chain is O(n·d) per step: scale jointly
    cfg.data.n = opts.n.min(20_000);
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    let steps = (4_000 * opts.queries.max(1)).min(100_000);
    let top = 200.min(cfg.data.n / 10);

    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(&cfg, &ds, backend.clone());
    let exact = ExactSampler::new(ds.clone(), backend.clone());
    let lazy = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), cfg.sampler_k(), 0.0);
    let walk = RandomWalk::new(ds, cfg.data.temperature);
    let cmp = walk.compare(&exact, &lazy, steps, top, opts.seed ^ 0x3A1C);
    report(&cmp, opts);
    cmp
}

fn report(cmp: &WalkComparison, opts: &EvalOpts) {
    let headers = ["metric", "value"];
    let table = vec![
        vec!["steps".into(), cmp.steps.to_string()],
        vec![format!("top-{} between-chain overlap", cmp.top), format!("{:.1}%", cmp.between_chain * 100.0)],
        vec!["within-exact overlap".into(), format!("{:.1}%", cmp.within_exact * 100.0)],
        vec!["within-ours overlap".into(), format!("{:.1}%", cmp.within_approx * 100.0)],
        vec!["exact rows scanned".into(), cmp.exact_scanned.to_string()],
        vec!["ours rows scanned".into(), cmp.approx_scanned.to_string()],
        vec![
            "chains equivalent (paper criterion)".into(),
            cmp.chains_equivalent(0.1).to_string(),
        ],
    ];
    println!("\n=== §4.2.2: random walk — exact vs lazy-Gumbel chain ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("walk_overlap", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn walk_comparison_sane() {
        let opts = EvalOpts { n: 2_000, queries: 2, seed: 8, write_csv: false };
        let cmp = run(&opts);
        assert!(cmp.between_chain >= 0.0 && cmp.between_chain <= 1.0);
        assert!(cmp.approx_scanned < cmp.exact_scanned, "ours must scan less");
    }
}
