//! Experiment drivers — one per paper table/figure (see DESIGN.md's
//! experiment index). Each driver returns structured rows, prints an
//! ASCII table, and writes a CSV under `results/`.

pub mod ablation;
pub mod fig2;
pub mod fig4;
pub mod fig7;
pub mod fig8;
pub mod table1;
pub mod table2;
pub mod walk_exp;

/// Common options for all drivers (scaled-down defaults; `--paper-scale`
/// from the CLI bumps them to the paper's sizes).
#[derive(Clone, Debug)]
pub struct EvalOpts {
    /// maximum dataset size for sweeps / the dataset size for fixed runs
    pub n: usize,
    /// queries (θ draws) per configuration
    pub queries: usize,
    /// random seed
    pub seed: u64,
    /// write CSVs under results/
    pub write_csv: bool,
}

impl Default for EvalOpts {
    fn default() -> Self {
        EvalOpts { n: 200_000, queries: 20, seed: 42, write_csv: true }
    }
}
