//! **Figure 2** — per-query sampling runtime vs dataset size, ours
//! (Algorithm 1 over IVF) vs brute-force enumeration.
//!
//! The paper sweeps ImageNet subsets from 10k to 1.28M rows and reports
//! per-query time (excluding preprocessing), finding speedup growing
//! roughly linearly in log n, reaching ~5× at full scale.

use super::EvalOpts;
use crate::config::Config;
use crate::data::{self, Dataset};
use crate::mips::{self, MipsIndex};
use crate::sampler::{exact::ExactSampler, lazy_gumbel::LazyGumbelSampler, Sampler};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::timing::{ascii_table, write_csv, Stopwatch};
use std::sync::Arc;

/// One sweep point.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub n: usize,
    pub brute_us: f64,
    pub ours_us: f64,
    pub speedup: f64,
    pub mean_tail_m: f64,
    pub index_build_s: f64,
}

/// Dataset-size ladder: 10k ×2 … capped at `max_n`.
pub fn size_ladder(max_n: usize) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut s = 10_000usize.min(max_n);
    while s < max_n {
        sizes.push(s);
        s *= 2;
    }
    sizes.push(max_n);
    sizes.dedup();
    sizes
}

pub fn run(opts: &EvalOpts) -> Vec<Fig2Row> {
    let mut cfg = Config::preset("imagenet").unwrap();
    cfg.data.n = opts.n;
    cfg.data.d = 64; // scaled-down default (paper: 256); see DESIGN.md
    cfg.data.seed = opts.seed;
    let full = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let mut rows = Vec::new();
    for n in size_ladder(opts.n) {
        let ds = Arc::new(full.prefix(n));
        rows.push(measure_one(&cfg, ds, backend.clone(), opts));
    }
    report(&rows, opts);
    rows
}

/// Build the Figure-2-style IVF index for a given subset size.
pub fn build_ivf(
    cfg: &Config,
    ds: &Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
) -> Arc<dyn MipsIndex> {
    let n = ds.n;
    let mut icfg = cfg.index.clone();
    icfg.n_clusters = 0; // auto 4√n
    icfg.n_probe = 0;
    icfg.kmeans_iters = 6;
    icfg.train_sample = (25 * (4.0 * (n as f64).sqrt()) as usize).min(n).min(30_000);
    mips::build_index(ds, &icfg, backend).unwrap()
}

fn measure_one(
    cfg: &Config,
    ds: Arc<Dataset>,
    backend: Arc<dyn ScoreBackend>,
    opts: &EvalOpts,
) -> Fig2Row {
    let n = ds.n;
    let sw = Stopwatch::start();
    let index = build_ivf(cfg, &ds, backend.clone());
    let index_build_s = sw.elapsed().as_secs_f64();

    let k = ((cfg.sampler.k_mult) * (n as f64).sqrt()) as usize;
    let ours = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), k.max(1), 0.0);
    let brute = ExactSampler::new(ds.clone(), backend);

    let mut rng = Pcg64::new(opts.seed ^ n as u64);
    let thetas: Vec<Vec<f32>> = (0..opts.queries.max(2))
        .map(|_| data::random_theta(&ds, cfg.data.temperature, &mut rng))
        .collect();

    // per-query time = fresh θ each query (the paper's setting: a
    // sequence of queries with different parameters)
    let sw = Stopwatch::start();
    let mut tail_m = 0usize;
    for q in &thetas {
        tail_m += ours.sample(q, &mut rng).work.m;
    }
    let ours_us = sw.micros() / thetas.len() as f64;

    let sw = Stopwatch::start();
    for q in &thetas {
        brute.sample(q, &mut rng);
    }
    let brute_us = sw.micros() / thetas.len() as f64;

    Fig2Row {
        n,
        brute_us,
        ours_us,
        speedup: brute_us / ours_us,
        mean_tail_m: tail_m as f64 / thetas.len() as f64,
        index_build_s,
    }
}

fn report(rows: &[Fig2Row], opts: &EvalOpts) {
    let headers = ["n", "brute_us", "ours_us", "speedup", "mean_m", "build_s"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                format!("{:.1}", r.brute_us),
                format!("{:.1}", r.ours_us),
                format!("{:.2}", r.speedup),
                format!("{:.1}", r.mean_tail_m),
                format!("{:.2}", r.index_build_s),
            ]
        })
        .collect();
    println!("\n=== Figure 2: per-query sampling time vs dataset size ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("fig2_sampling", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_shape() {
        assert_eq!(size_ladder(50_000), vec![10_000, 20_000, 40_000, 50_000]);
        assert_eq!(size_ladder(10_000), vec![10_000]);
        assert_eq!(size_ladder(5_000), vec![5_000]);
    }

    #[test]
    fn tiny_sweep_runs_and_speedup_positive() {
        let opts = EvalOpts { n: 12_000, queries: 4, seed: 1, write_csv: false };
        let rows = run(&opts);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.brute_us > 0.0 && r.ours_us > 0.0);
            assert!(r.mean_tail_m >= 0.0);
        }
        // at the largest size ours should beat brute force
        assert!(rows.last().unwrap().speedup > 1.0, "{:?}", rows.last().unwrap());
    }
}
