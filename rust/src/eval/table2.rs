//! **Table 2 + Figures 5/6** — learning a log-linear model by MLE.
//!
//! Paper (5000 iterations, α=10 halved per 1000, D = 16 water images):
//!
//! | method         | LL     | speedup |
//! |----------------|--------|---------|
//! | exact gradient | −3.170 | 1×      |
//! | top-k only     | −4.062 | 22.7×   |
//! | ours           | −3.175 | 9.6×    |
//!
//! Figure 5 = the learning curves (ours overlaps exact; top-k plateaus);
//! Figure 6 = the top-10 most probable held-out states are semantically
//! coherent — quantified here as latent-cluster purity.

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::learner::{GradMethod, Learner};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::timing::{ascii_table, write_csv};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Table2Row {
    pub method: String,
    pub final_ll: f64,
    pub speedup: f64,
    pub grad_seconds: f64,
    /// Figure 6 proxy: cluster purity of the top-10 held-out states
    pub top10_purity: f64,
}

pub fn run(opts: &EvalOpts) -> Vec<Table2Row> {
    let mut cfg = Config::preset("imagenet").unwrap();
    // exact gradients are O(n·d·iters): keep the driver tractable on one
    // core while preserving the paper's regime ratios (k = 10√n ≈ 2.2% of
    // n, top-k = 100√n would cover everything at this n, so scale it too)
    cfg.data.n = opts.n.min(50_000);
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    // broad latent classes so the learned distribution's support (D's
    // cluster, n/clusters ≈ 600 states) exceeds the top-k budget — the
    // regime where the paper's top-k gradient fails (its ImageNet "water"
    // concept spans far more images than 100√n covers)
    cfg.data.clusters = 50;
    cfg.learn.iters = 600;
    cfg.learn.eval_every = 25;
    cfg.learn.lr = 10.0;
    cfg.learn.lr_halve_every = 120;
    cfg.learn.train_size = 16;
    cfg.learn.k_mult = 10.0;
    cfg.learn.l_ratio = 10.0;
    // paper: top-k uses 100√n = 8.8% of n=1.28M. At bench scale the same
    // multiplier would cover most of the distribution's mass, hiding the
    // truncation bias; 2√n (≈1.3% of n) matches the paper's
    // fraction-of-mass regime instead.
    cfg.learn.topk_mult = 2.0;
    run_with_config(&cfg, opts)
}

pub fn run_with_config(cfg: &Config, opts: &EvalOpts) -> Vec<Table2Row> {
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(cfg, &ds, backend.clone());
    let learner = Learner::new(ds, index, backend, cfg.learn.clone()).unwrap();

    let mut rows = Vec::new();
    let mut curves: Vec<(String, Vec<(usize, f64)>)> = Vec::new();
    let mut exact_time = 0f64;
    for method in [GradMethod::Exact, GradMethod::TopK, GradMethod::Amortized] {
        let mut rng = Pcg64::new(cfg.learn.seed ^ 0x7AB2);
        let res = learner.train(method, &mut rng);
        if method == GradMethod::Exact {
            exact_time = res.grad_seconds;
        }
        let tops = learner.top_samples(&res.theta, 10);
        rows.push(Table2Row {
            method: method.name().to_string(),
            final_ll: res.final_ll,
            speedup: exact_time / res.grad_seconds,
            grad_seconds: res.grad_seconds,
            top10_purity: learner.cluster_purity(&tops),
        });
        curves.push((
            method.name().to_string(),
            res.curve.iter().map(|p| (p.iter, p.log_likelihood)).collect(),
        ));
    }
    report(&rows, &curves, opts);
    rows
}

fn report(rows: &[Table2Row], curves: &[(String, Vec<(usize, f64)>)], opts: &EvalOpts) {
    let headers = ["method", "log_likelihood", "speedup", "grad_s", "top10_purity"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                format!("{:.3}", r.final_ll),
                format!("{:.1}x", r.speedup),
                format!("{:.2}", r.grad_seconds),
                format!("{:.0}%", r.top10_purity * 100.0),
            ]
        })
        .collect();
    println!("\n=== Table 2: learning (MLE) — log-likelihood and speedup ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("table2_learning", &headers, &table) {
            println!("wrote {p}");
        }
        // Figure 5: learning curves
        let mut rows5 = Vec::new();
        for (name, pts) in curves {
            for (it, ll) in pts {
                rows5.push(vec![name.clone(), it.to_string(), format!("{ll:.5}")]);
            }
        }
        if let Ok(p) = write_csv("fig5_curves", &["method", "iter", "log_likelihood"], &rows5) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ordering_reproduced() {
        let mut cfg = Config::preset("imagenet").unwrap();
        cfg.data.n = 4_000;
        cfg.data.d = 32;
        cfg.data.seed = 5;
        cfg.learn.iters = 150;
        cfg.learn.eval_every = 50;
        cfg.learn.lr = 6.0;
        cfg.learn.lr_halve_every = 60;
        cfg.learn.train_size = 12;
        cfg.learn.k_mult = 5.0;
        cfg.learn.l_ratio = 5.0;
        cfg.learn.topk_mult = 1.0;
        let opts = EvalOpts { n: 4_000, queries: 1, seed: 5, write_csv: false };
        let rows = run_with_config(&cfg, &opts);
        let get = |m: &str| rows.iter().find(|r| r.method == m).unwrap().clone();
        let (exact, topk, ours) = (get("exact"), get("top-k"), get("ours"));
        // Table 2 orderings: ours ≈ exact in LL, top-k worse; both faster
        // than exact, with top-k fastest
        assert!((ours.final_ll - exact.final_ll).abs() < 0.3, "{rows:?}");
        assert!(topk.final_ll < exact.final_ll, "{rows:?}");
        assert!(ours.speedup > 1.0, "{rows:?}");
        assert!(topk.speedup > ours.speedup, "{rows:?}");
    }
}
