//! **Figure 7 (appendix)** — *amortized* sampling cost including
//! preprocessing, and the break-even sample count.
//!
//! The paper defines amortized cost as index-build time plus the runtime
//! of 10,000 samples, and reports that the method starts paying off after
//! ≈ 8,600 samples on full ImageNet.

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::sampler::{exact::ExactSampler, lazy_gumbel::LazyGumbelSampler, Sampler};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::timing::{ascii_table, write_csv, Stopwatch};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Fig7Row {
    pub n: usize,
    pub build_s: f64,
    pub ours_us: f64,
    pub brute_us: f64,
    /// samples needed before preprocessing pays for itself
    pub breakeven: f64,
    /// amortized per-sample cost at 10k samples (µs)
    pub amortized_10k_us: f64,
}

pub fn run(opts: &EvalOpts) -> Vec<Fig7Row> {
    let mut cfg = Config::preset("imagenet").unwrap();
    cfg.data.n = opts.n;
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    let full = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);

    let mut rows = Vec::new();
    for n in super::fig2::size_ladder(opts.n) {
        let ds = Arc::new(full.prefix(n));
        let sw = Stopwatch::start();
        let index = super::fig2::build_ivf(&cfg, &ds, backend.clone());
        let build_s = sw.elapsed().as_secs_f64();
        let k = crate::config::eff(cfg.sampler.k_mult, n);
        let ours = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), k, 0.0);
        let brute = ExactSampler::new(ds.clone(), backend.clone());
        let mut rng = Pcg64::new(opts.seed ^ n as u64 ^ 0xF167);
        let reps = opts.queries.max(3);
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);
            ours.sample(&q, &mut rng);
        }
        let ours_us = sw.micros() / reps as f64;
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);
            brute.sample(&q, &mut rng);
        }
        let brute_us = sw.micros() / reps as f64;
        let gain = (brute_us - ours_us).max(1e-9);
        let breakeven = build_s * 1e6 / gain;
        let amortized_10k_us = (build_s * 1e6 + 10_000.0 * ours_us) / 10_000.0;
        rows.push(Fig7Row { n, build_s, ours_us, brute_us, breakeven, amortized_10k_us });
    }
    report(&rows, opts);
    rows
}

fn report(rows: &[Fig7Row], opts: &EvalOpts) {
    let headers = ["n", "build_s", "ours_us", "brute_us", "breakeven_samples", "amortized@10k_us"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                format!("{:.2}", r.build_s),
                format!("{:.1}", r.ours_us),
                format!("{:.1}", r.brute_us),
                format!("{:.0}", r.breakeven),
                format!("{:.1}", r.amortized_10k_us),
            ]
        })
        .collect();
    println!("\n=== Figure 7: amortized cost incl. preprocessing ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("fig7_amortized", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakeven_finite_and_positive() {
        let opts = EvalOpts { n: 10_000, queries: 3, seed: 6, write_csv: false };
        let rows = run(&opts);
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.build_s > 0.0);
            assert!(r.breakeven.is_finite() && r.breakeven > 0.0);
        }
    }
}
