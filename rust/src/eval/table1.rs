//! **Table 1** — sampling speedup and total-variation bound per dataset.
//!
//! Paper: ImageNet 4.65× speedup, TV ≤ (2.5±1.4)e−4; Word Embeddings
//! 4.17×, TV ≤ (4.8±2.2)e−4 — averaged over 100 θ drawn from the dataset.

use super::EvalOpts;
use crate::config::Config;
use crate::data;
use crate::mips::brute::BruteForce;
use crate::sampler::{exact::ExactSampler, lazy_gumbel::LazyGumbelSampler, tv_bound, Sampler};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timing::{ascii_table, write_csv, Stopwatch};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub dataset: String,
    pub speedup: f64,
    pub tv_mean: f64,
    pub tv_std: f64,
}

pub fn run(opts: &EvalOpts) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for preset in ["imagenet", "wordemb"] {
        let mut cfg = Config::preset(preset).unwrap();
        cfg.data.n = opts.n;
        cfg.data.d = 64; // scaled (paper: 256/300)
        cfg.data.seed = opts.seed;
        rows.push(measure(preset, &cfg, opts));
    }
    report(&rows, opts);
    rows
}

fn measure(name: &str, cfg: &Config, opts: &EvalOpts) -> Table1Row {
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(cfg, &ds, backend.clone());
    let k = cfg.sampler_k();
    let ours = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
    let brute_sampler = ExactSampler::new(ds.clone(), backend.clone());
    let brute = BruteForce::new(ds.clone(), backend.clone());

    let mut rng = Pcg64::new(opts.seed ^ 0x7AB1);
    let thetas: Vec<Vec<f32>> = (0..opts.queries.max(3))
        .map(|_| data::random_theta(&ds, cfg.data.temperature, &mut rng))
        .collect();

    // speedup (per-query, like Fig 2)
    let sw = Stopwatch::start();
    for q in &thetas {
        ours.sample(q, &mut rng);
    }
    let ours_us = sw.micros() / thetas.len() as f64;
    let sw = Stopwatch::start();
    for q in &thetas {
        brute_sampler.sample(q, &mut rng);
    }
    let brute_us = sw.micros() / thetas.len() as f64;

    // TV-bound certificate per θ (§4.2.1): exact scan + closed form
    let mut bounds = Vec::new();
    let mut all = vec![0f32; ds.n];
    for q in &thetas {
        let top = index.top_k(q, k);
        brute.all_scores(q, &mut all);
        bounds.push(tv_bound::tv_bound(&all, &top));
    }
    let (tv_mean, tv_std) = stats::mean_std(&bounds);

    Table1Row { dataset: name.to_string(), speedup: brute_us / ours_us, tv_mean, tv_std }
}

fn report(rows: &[Table1Row], opts: &EvalOpts) {
    let headers = ["dataset", "speedup", "tv_bound_mean", "tv_bound_std"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                format!("{:.2}x", r.speedup),
                format!("{:.2e}", r.tv_mean),
                format!("{:.2e}", r.tv_std),
            ]
        })
        .collect();
    println!("\n=== Table 1: sampling speedup + TV bound ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("table1_accuracy", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_datasets_measured() {
        let opts = EvalOpts { n: 8_000, queries: 4, seed: 2, write_csv: false };
        let rows = run(&opts);
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.speedup > 0.0);
            assert!((0.0..=1.0).contains(&r.tv_mean), "{r:?}");
        }
    }
}
