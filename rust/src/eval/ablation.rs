//! Ablations beyond the paper's figures — the design-choice studies
//! DESIGN.md calls out:
//!
//! * **index families** (brute / IVF / SRP-LSH / tiered-LSH): recall@k,
//!   scan fraction, query latency, build time — on both dataset
//!   geometries (clustered vs Zipf) and the adversarial uniform sphere;
//! * **sampler variants** (Algorithm 1 vs Algorithm 2 vs frozen-Gumbel):
//!   per-query work (tail m), sample diversity, distribution error.

use super::EvalOpts;
use crate::config::{Config, IndexKind};
use crate::data::{self, Dataset};
use crate::mips::{self, brute::BruteForce, recall_at_k, MipsIndex};
use crate::sampler::{
    exact::ExactSampler, fixed_b::FixedBSampler, frozen::FrozenGumbel,
    lazy_gumbel::LazyGumbelSampler, Sampler,
};
use crate::scorer::{NativeScorer, ScoreBackend};
use crate::util::rng::Pcg64;
use crate::util::stats;
use crate::util::timing::{ascii_table, write_csv, Stopwatch};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct IndexAblationRow {
    pub dataset: String,
    pub index: String,
    pub build_s: f64,
    pub recall_at_k: f64,
    pub scan_frac: f64,
    pub query_us: f64,
}

/// Index-family ablation over three data geometries.
pub fn run_index(opts: &EvalOpts) -> Vec<IndexAblationRow> {
    let mut rows = Vec::new();
    for kind_name in ["imagenet", "wordemb", "uniform"] {
        let mut cfg = Config::default();
        cfg.data.kind = crate::config::DataKind::parse(match kind_name {
            "uniform" => "uniform-sphere",
            other => other,
        })
        .unwrap();
        cfg.data.n = opts.n.min(30_000);
        cfg.data.d = 64;
        cfg.data.seed = opts.seed;
        let ds = Arc::new(data::generate(&cfg.data));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let k = cfg.sampler_k();
        let brute = BruteForce::new(ds.clone(), backend.clone());
        let mut rng = Pcg64::new(opts.seed ^ 0xAB1A);
        let thetas: Vec<Vec<f32>> = (0..opts.queries.clamp(3, 10))
            .map(|_| data::random_theta(&ds, cfg.data.temperature, &mut rng))
            .collect();
        let truths: Vec<_> = thetas.iter().map(|q| brute.top_k(q, k)).collect();

        for ik in [IndexKind::Brute, IndexKind::Ivf, IndexKind::Lsh, IndexKind::Tiered] {
            let mut icfg = cfg.index.clone();
            icfg.kind = ik;
            icfg.n_clusters = 0;
            icfg.n_probe = 0;
            icfg.kmeans_iters = 6;
            icfg.train_sample = 15_000.min(ds.n);
            icfg.tables = 12;
            icfg.bits = 8;
            icfg.rungs = 8;
            let sw = Stopwatch::start();
            let index = mips::build_index(&ds, &icfg, backend.clone()).unwrap();
            let build_s = sw.elapsed().as_secs_f64();
            let sw = Stopwatch::start();
            let mut recall = 0.0;
            let mut scanned = 0usize;
            for (q, truth) in thetas.iter().zip(&truths) {
                let got = index.top_k(q, k);
                recall += recall_at_k(&got, truth);
                scanned += got.scanned;
            }
            rows.push(IndexAblationRow {
                dataset: kind_name.to_string(),
                index: ik.name().to_string(),
                build_s,
                recall_at_k: recall / thetas.len() as f64,
                scan_frac: scanned as f64 / (thetas.len() * ds.n) as f64,
                query_us: sw.micros() / thetas.len() as f64,
            });
        }
    }
    report_index(&rows, opts);
    rows
}

fn report_index(rows: &[IndexAblationRow], opts: &EvalOpts) {
    let headers = ["dataset", "index", "build_s", "recall@k", "scan_frac", "query_us"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.dataset.clone(),
                r.index.clone(),
                format!("{:.2}", r.build_s),
                format!("{:.3}", r.recall_at_k),
                format!("{:.3}", r.scan_frac),
                format!("{:.1}", r.query_us),
            ]
        })
        .collect();
    println!("\n=== Ablation: MIPS index families × data geometry ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("ablation_index", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

#[derive(Clone, Debug)]
pub struct SamplerAblationRow {
    pub sampler: String,
    pub query_us: f64,
    pub mean_tail_m: f64,
    pub distinct_frac: f64,
    pub tv_to_exact: f64,
}

/// Sampler-variant ablation: Alg 1 vs Alg 2 vs frozen-Gumbel vs exact.
pub fn run_sampler(opts: &EvalOpts) -> Vec<SamplerAblationRow> {
    let mut cfg = Config::default();
    cfg.data.n = opts.n.min(15_000);
    cfg.data.d = 64;
    cfg.data.seed = opts.seed;
    // moderate temperature so the distribution has real spread (makes
    // correlation/diversity differences visible)
    cfg.data.temperature = 0.3;
    let ds = Arc::new(data::generate(&cfg.data));
    let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
    let index = super::fig2::build_ivf(&cfg, &ds, backend.clone());
    let k = cfg.sampler_k();
    let exact = ExactSampler::new(ds.clone(), backend.clone());
    let alg1 = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
    let alg2 = FixedBSampler::new(ds.clone(), index.clone(), backend.clone(), k, k);
    let mut icfg = cfg.index.clone();
    icfg.n_clusters = 0;
    icfg.n_probe = 0;
    icfg.kmeans_iters = 4;
    icfg.train_sample = 8_000.min(ds.n);
    let frozen = FrozenGumbel::build(&ds, 16, &icfg, backend.clone(), opts.seed ^ 0xF0).unwrap();

    let mut rng = Pcg64::new(opts.seed ^ 0xAB5A);
    let q = data::random_theta(&ds, cfg.data.temperature, &mut rng);
    let true_probs = exact.probabilities(&q);
    let draws = 4_000usize;

    let mut rows = Vec::new();
    let samplers: Vec<(&str, &dyn Sampler)> =
        vec![("exact", &exact), ("alg1-lazy", &alg1), ("alg2-fixedB", &alg2), ("frozen", &frozen)];
    for (name, s) in samplers {
        let sw = Stopwatch::start();
        let outs = s.sample_many(&q, draws, &mut rng);
        let query_us = sw.micros() / draws as f64;
        let mean_m = outs.iter().map(|o| o.work.m as f64).sum::<f64>() / draws as f64;
        let mut counts = vec![0u64; ds.n];
        let mut distinct = rustc_hash::FxHashSet::default();
        for o in &outs {
            counts[o.id as usize] += 1;
            distinct.insert(o.id);
        }
        // empirical TV to the true distribution (includes finite-sample
        // noise; compare against the 'exact' row's own value)
        let emp: Vec<f64> = counts.iter().map(|&c| c as f64 / draws as f64).collect();
        let tv: f64 =
            0.5 * emp.iter().zip(&true_probs).map(|(a, b)| (a - b).abs()).sum::<f64>();
        rows.push(SamplerAblationRow {
            sampler: name.to_string(),
            query_us,
            mean_tail_m: mean_m,
            distinct_frac: distinct.len() as f64 / draws as f64,
            tv_to_exact: tv,
        });
    }
    report_sampler(&rows, opts);
    rows
}

fn report_sampler(rows: &[SamplerAblationRow], opts: &EvalOpts) {
    let headers = ["sampler", "per_draw_us", "mean_tail_m", "distinct_frac", "emp_TV"];
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.sampler.clone(),
                format!("{:.1}", r.query_us),
                format!("{:.1}", r.mean_tail_m),
                format!("{:.3}", r.distinct_frac),
                format!("{:.4}", r.tv_to_exact),
            ]
        })
        .collect();
    println!("\n=== Ablation: sampler variants (4k draws, one θ, τ=0.3) ===");
    println!("{}", ascii_table(&headers, &table));
    if opts.write_csv {
        if let Ok(p) = write_csv("ablation_sampler", &headers, &table) {
            println!("wrote {p}");
        }
    }
}

/// Helper shared with tests.
pub fn tv_of(rows: &[SamplerAblationRow], name: &str) -> f64 {
    rows.iter().find(|r| r.sampler == name).map(|r| r.tv_to_exact).unwrap_or(f64::NAN)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_ablation_orders_correctly() {
        let opts = EvalOpts { n: 3_000, queries: 2, seed: 9, write_csv: false };
        let rows = run_sampler(&opts);
        // Alg 1/2 empirical TV ≈ exact sampling's own finite-sample TV;
        // frozen is far off (correlated samples)
        let exact_tv = tv_of(&rows, "exact");
        assert!(tv_of(&rows, "alg1-lazy") < exact_tv * 1.5 + 0.02);
        assert!(tv_of(&rows, "alg2-fixedB") < exact_tv * 1.5 + 0.02);
        assert!(tv_of(&rows, "frozen") > tv_of(&rows, "alg1-lazy") * 2.0);
        // frozen produces few distinct samples
        let frozen_distinct =
            rows.iter().find(|r| r.sampler == "frozen").unwrap().distinct_frac;
        let ours_distinct =
            rows.iter().find(|r| r.sampler == "alg1-lazy").unwrap().distinct_frac;
        assert!(frozen_distinct < ours_distinct / 2.0);
    }

    #[test]
    fn index_ablation_covers_grid() {
        let opts = EvalOpts { n: 4_000, queries: 3, seed: 10, write_csv: false };
        let rows = run_index(&opts);
        assert_eq!(rows.len(), 12); // 3 datasets × 4 indexes
        // brute is always recall 1.0 at full scan
        for r in rows.iter().filter(|r| r.index == "brute") {
            assert!((r.recall_at_k - 1.0).abs() < 1e-9);
            assert!((r.scan_frac - 1.0).abs() < 1e-9);
        }
        // on clustered data, IVF must beat uniform-data IVF recall
        let ivf = |ds: &str| {
            rows.iter()
                .find(|r| r.index == "ivf" && r.dataset == ds)
                .unwrap()
                .recall_at_k
        };
        assert!(ivf("imagenet") >= ivf("uniform") - 0.05);
    }
}
