//! gmips CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   gen-data     generate a synthetic dataset and write it to disk
//!   build        build the index once and save a snapshot (--save PATH);
//!                serve/shard-serve/learn warm-open it via --index-path
//!   sample       draw samples for random θ and print them
//!   partition    estimate log Z for random θ (Algorithm 3) vs exact
//!   learn        run the §4.4 MLE experiment (exact / top-k / ours)
//!   walk         run the §4.2.2 random-walk comparison
//!   serve        start the TCP inference server (--remote: fan out to
//!                shard servers listed in remote.addrs)
//!   shard-serve  start one shard server (--shard-id S) for the remote tier
//!   metrics      scrape a running server's Prometheus exposition
//!                (--addr HOST:PORT; --shutdown stops the server after)
//!   eval <exp>   regenerate a paper table/figure
//!                (fig2|table1|fig4|table2|fig7|fig8|walk|all)
//!   selfcheck    load artifacts, compare PJRT vs native numerics
//!
//! Common options: --preset NAME --config FILE --set k=v,... --n N --d D
//! --seed S --backend native|pjrt --index ivf|lsh|tiered|brute

use gmips::config::{Backend, Config};
use gmips::coordinator::{Coordinator, Engine};
use gmips::data;
use gmips::error::{Error, Result};
use gmips::eval::{self, EvalOpts};
use gmips::learner::{GradMethod, Learner};
use gmips::runtime::PjrtScorer;
use gmips::sampler::Sampler;
use gmips::scorer::{NativeScorer, ScoreBackend};
use gmips::server::Server;
use gmips::util::cli::{Args, Spec};
use gmips::util::rng::Pcg64;
use std::sync::Arc;

const VALUE_KEYS: &[&str] = &[
    "preset", "config", "set", "n", "d", "seed", "backend", "index", "out", "count", "k", "l",
    "queries", "steps", "addr", "workers", "iters", "artifacts", "shard-id", "save", "index-path",
];

fn main() {
    let args = match Spec::new(VALUE_KEYS).parse(std::env::args()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.has_flag("help") || args.subcommand().is_none() {
        print_help();
        return;
    }
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "gmips — fast amortized inference in log-linear models (UAI 2017 reproduction)\n\n\
         usage: gmips <subcommand> [options]\n\n\
         subcommands:\n\
         \u{20}  gen-data --out data.bin [--preset imagenet|wordemb] [--n N] [--d D]\n\
         \u{20}  build --save index.gmips (or set index.path; snapshot is checksummed + atomic)\n\
         \u{20}  sample [--count C] [--queries Q] [--backend native|pjrt]\n\
         \u{20}  partition [--queries Q]\n\
         \u{20}  learn [--iters I]\n\
         \u{20}  walk [--n N] [--queries Q]\n\
         \u{20}  serve [--addr HOST:PORT] [--workers W] [--remote]\n\
         \u{20}  shard-serve --shard-id S [--addr HOST:PORT]\n\
         \u{20}  metrics [--addr HOST:PORT] [--shutdown]\n\
         \u{20}  eval fig2|table1|fig4|table2|fig7|fig8|walk|all [--n N] [--queries Q]\n\
         \u{20}  selfcheck [--artifacts DIR]\n\n\
         common options: --preset P --config FILE --set sec.key=v,... --n N --d D --seed S\n\
         \u{20}                --index ivf|lsh|tiered|brute --backend native|pjrt\n\
         \u{20}                --index-path FILE (warm-open a saved snapshot; missing file = build)"
    );
}

fn make_backend(cfg: &Config) -> Result<Arc<dyn ScoreBackend>> {
    Ok(match cfg.runtime.backend {
        Backend::Native => Arc::new(NativeScorer),
        Backend::Pjrt => {
            let scorer = PjrtScorer::load(&cfg.runtime.artifacts_dir)?;
            if scorer.d() != cfg.data.d {
                return Err(Error::runtime(format!(
                    "artifacts compiled for d={}, config wants d={} — re-run `make artifacts DIM={}`",
                    scorer.d(),
                    cfg.data.d,
                    cfg.data.d
                )));
            }
            Arc::new(scorer)
        }
    })
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand().unwrap() {
        "gen-data" => cmd_gen_data(args),
        "build" => cmd_build(args),
        "sample" => cmd_sample(args),
        "partition" => cmd_partition(args),
        "learn" => cmd_learn(args),
        "walk" => cmd_walk(args),
        "serve" => cmd_serve(args),
        "shard-serve" => cmd_shard_serve(args),
        "metrics" => cmd_metrics(args),
        "eval" => cmd_eval(args),
        "selfcheck" => cmd_selfcheck(args),
        other => Err(Error::Cli(format!("unknown subcommand '{other}' (try --help)"))),
    }
}

fn cmd_gen_data(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let out = args.require("out")?;
    let ds = data::generate(&cfg.data);
    ds.save(out)?;
    println!(
        "wrote {} ({} rows × {} dims, kind={})",
        out,
        ds.n,
        ds.d,
        cfg.data.kind.name()
    );
    Ok(())
}

fn cmd_build(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let path = args.get_str("save", &cfg.index.path);
    if path.is_empty() {
        return Err(Error::Cli(
            "build needs a destination: pass --save PATH (or set index.path)".into(),
        ));
    }
    let backend = make_backend(&cfg)?;
    eprintln!(
        "building index: n={} d={} index={} shards={} backend={} ...",
        cfg.data.n,
        cfg.data.d,
        cfg.index.kind.name(),
        cfg.index.shards,
        backend.name()
    );
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let index = gmips::mips::build_index_typed(&ds, &cfg.index, backend)?;
    gmips::store::save_index(&path, &cfg, &ds, &index)?;
    let bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
    println!("saved snapshot {path} ({bytes} bytes, {} rows × {} dims)", ds.n, ds.d);
    Ok(())
}

fn build_engine(args: &Args) -> Result<Arc<Engine>> {
    let cfg = Config::from_args(args)?;
    let backend = make_backend(&cfg)?;
    eprintln!(
        "building engine: n={} d={} index={} backend={} ...",
        cfg.data.n,
        cfg.data.d,
        cfg.index.kind.name(),
        backend.name()
    );
    let engine = Engine::from_config(&cfg, Some(backend))?;
    if engine.snapshot_degraded {
        eprintln!("warning: snapshot quantized sections corrupt — serving from the f32 tier");
    }
    eprintln!("{}", engine.index.describe());
    Ok(Arc::new(engine))
}

fn cmd_sample(args: &Args) -> Result<()> {
    let engine = build_engine(args)?;
    let count = args.get_usize("count", 5)?;
    let queries = args.get_usize("queries", 3)?;
    let mut rng = Pcg64::new(engine.config.data.seed ^ 0x5A);
    for qi in 0..queries {
        let theta = data::random_theta(&engine.ds, engine.config.data.temperature, &mut rng);
        let outs = engine.sampler.sample_many(&theta, count, &mut rng);
        let ids: Vec<u32> = outs.iter().map(|o| o.id).collect();
        let m: usize = outs.iter().map(|o| o.work.m).sum();
        println!(
            "θ[{qi}] → samples {ids:?} (scanned {} rows, {m} lazy tail Gumbels)",
            outs[0].work.scanned
        );
    }
    Ok(())
}

fn cmd_partition(args: &Args) -> Result<()> {
    let engine = build_engine(args)?;
    let queries = args.get_usize("queries", 5)?;
    let mut rng = Pcg64::new(engine.config.data.seed ^ 0x9B);
    for qi in 0..queries {
        let theta = data::random_theta(&engine.ds, engine.config.data.temperature, &mut rng);
        let est = engine.partition.estimate(&theta, &mut rng);
        let exact = gmips::estimator::partition::exact_log_partition(
            &engine.ds,
            engine.backend.as_ref(),
            &theta,
        );
        println!(
            "θ[{qi}] log Ẑ = {:.4} (exact {:.4}, rel err {:.4}, k={} l={})",
            est.log_z,
            exact,
            ((est.log_z - exact).exp() - 1.0).abs(),
            est.work.k,
            est.work.l
        );
    }
    Ok(())
}

fn cmd_learn(args: &Args) -> Result<()> {
    let mut cfg = Config::from_args(args)?;
    cfg.learn.iters = args.get_usize("iters", cfg.learn.iters)?;
    let backend = make_backend(&cfg)?;
    // typed load-or-build so `index.shards > 1` trains through the
    // sharded Algorithm 4 estimator, and `--index-path` warm-opens a
    // saved snapshot instead of rebuilding per run
    let opened = gmips::store::load_or_build(&cfg, backend.clone(), true)?;
    if opened.degraded {
        eprintln!("warning: snapshot quantized sections corrupt — training from the f32 tier");
    }
    let learner = Learner::new(opened.ds, opened.index, backend, cfg.learn.clone())?;
    let mut rng = Pcg64::new(cfg.learn.seed);
    for method in [GradMethod::Exact, GradMethod::TopK, GradMethod::Amortized] {
        let res = learner.train(method, &mut rng);
        println!(
            "{:<8} final LL {:.4}  grad time {:.2}s  ({} iters)",
            method.name(),
            res.final_ll,
            res.grad_seconds,
            res.iters
        );
    }
    Ok(())
}

fn cmd_walk(args: &Args) -> Result<()> {
    let opts = eval_opts(args)?;
    eval::walk_exp::run(&opts);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    gmips::obs::configure(&cfg.obs)?;
    let addr = args.get_str("addr", &cfg.serve.addr);
    let workers = args.get_usize("workers", cfg.serve.workers)?;
    let engine = if args.has_flag("remote") {
        let backend = make_backend(&cfg)?;
        eprintln!("connecting to shard servers at {} ...", cfg.remote.addrs);
        let engine = Engine::from_remote(&cfg, Some(backend))?;
        eprintln!("{}", engine.index.describe());
        Arc::new(engine)
    } else {
        build_engine(args)?
    };
    let coord = Arc::new(Coordinator::start_with_wait(
        engine,
        workers,
        cfg.serve.queue_depth,
        cfg.data.seed,
        cfg.serve.micro_wait_us,
    ));
    let server = Server::bind_with(coord, &addr, &cfg.serve)?;
    println!("gmips serving on {}", server.local_addr()?);
    server.serve()
}

fn cmd_shard_serve(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    gmips::obs::configure(&cfg.obs)?;
    let shard = args.get_usize("shard-id", 0)?;
    let addr = args.get_str("addr", &cfg.serve.addr);
    let backend = make_backend(&cfg)?;
    eprintln!("building shard engine {shard}/{} ...", cfg.index.shards);
    let engine = Arc::new(gmips::remote::ShardEngine::from_config(&cfg, shard, Some(backend))?);
    eprintln!("{}", engine.describe());
    let handler = Arc::new(gmips::remote::ShardHandler::new(engine));
    let server = Server::bind_handler(handler, &addr, &cfg.serve)?;
    println!("gmips shard {shard} serving on {}", server.local_addr()?);
    server.serve()
}

fn cmd_metrics(args: &Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    let addr = args.get_str("addr", &cfg.serve.addr);
    let mut client = gmips::server::Client::connect(&addr)?;
    match client.call(&gmips::coordinator::Request::Metrics)? {
        gmips::coordinator::Response::Metrics { exposition } => print!("{exposition}"),
        gmips::coordinator::Response::Degraded { inner, ok_shards, shards } => {
            eprintln!("warning: metrics aggregated over {ok_shards}/{shards} shards");
            match *inner {
                gmips::coordinator::Response::Metrics { exposition } => print!("{exposition}"),
                other => return Err(Error::serve(format!("unexpected reply: {other:?}"))),
            }
        }
        gmips::coordinator::Response::Error { message } => return Err(Error::serve(message)),
        other => return Err(Error::serve(format!("unexpected reply: {other:?}"))),
    }
    if args.has_flag("shutdown") {
        client.shutdown_server()?;
    }
    Ok(())
}

fn eval_opts(args: &Args) -> Result<EvalOpts> {
    let mut opts = EvalOpts::default();
    if args.has_flag("paper-scale") {
        opts.n = 1_281_167;
        opts.queries = 100;
    }
    opts.n = args.get_usize("n", opts.n)?;
    opts.queries = args.get_usize("queries", opts.queries)?;
    opts.seed = args.get_u64("seed", opts.seed)?;
    Ok(opts)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| Error::Cli("eval expects an experiment name (or 'all')".into()))?;
    let opts = eval_opts(args)?;
    let run_one = |name: &str, opts: &EvalOpts| -> Result<()> {
        match name {
            "fig2" => {
                eval::fig2::run(opts);
            }
            "table1" => {
                eval::table1::run(opts);
            }
            "fig4" => {
                eval::fig4::run(opts);
            }
            "table2" | "fig5" | "fig6" => {
                eval::table2::run(opts);
            }
            "fig7" => {
                eval::fig7::run(opts);
            }
            "fig8" => {
                eval::fig8::run(opts);
            }
            "walk" => {
                eval::walk_exp::run(opts);
            }
            "ablation" => {
                eval::ablation::run_index(opts);
                eval::ablation::run_sampler(opts);
            }
            other => return Err(Error::Cli(format!("unknown experiment '{other}'"))),
        }
        Ok(())
    };
    if which == "all" {
        for name in ["fig2", "table1", "fig4", "table2", "fig7", "fig8", "walk", "ablation"] {
            run_one(name, &opts)?;
        }
        Ok(())
    } else {
        run_one(which, &opts)
    }
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let scorer = PjrtScorer::load(&dir)?;
    println!("loaded artifacts from {dir}: block={} d={}", scorer.block(), scorer.d());
    let d = scorer.d();
    let n = 3_000;
    let ds = gmips::data::synth::imagenet_like(n, d, 16, 0.3, 1);
    let mut rng = Pcg64::new(2);
    let q = data::random_theta(&ds, 0.05, &mut rng);
    let mut pjrt_scores = vec![0f32; n];
    scorer.scores(&ds.data, d, &q, &mut pjrt_scores);
    let mut native_scores = vec![0f32; n];
    NativeScorer.scores(&ds.data, d, &q, &mut native_scores);
    let max_diff = pjrt_scores
        .iter()
        .zip(&native_scores)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0, f64::max);
    let p = scorer.max_sumexp(&ds.data, d, &q).logsumexp();
    let nl = NativeScorer.max_sumexp(&ds.data, d, &q).logsumexp();
    println!("scores   max |pjrt − native| = {max_diff:.2e}");
    println!("logZ     pjrt {p:.6} vs native {nl:.6} (Δ {:.2e})", (p - nl).abs());
    if max_diff < 1e-2 && (p - nl).abs() < 1e-3 {
        println!("selfcheck OK — all three layers agree");
        Ok(())
    } else {
        Err(Error::runtime("selfcheck numerical mismatch"))
    }
}
