//! Process-wide observability: a lock-free metrics registry + sampled
//! request tracing.
//!
//! The paper's headline claim is *sublinear amortized* cost per query;
//! this module is how the running system reports what it actually pays.
//! Every hot path increments plain relaxed atomics here (coarse,
//! per-query/per-block granularity — never per-row — so the overhead on
//! the brute-scan hot path stays under the 2% budget enforced by
//! `benches/bench_perf_hotpath.rs`), and the `metrics` wire op renders
//! the registry as Prometheus text exposition:
//!
//! * **tier ladder** — per-rung certificate hits/misses, rows screened
//!   vs re-ranked, f32 fallbacks ([`crate::mips::two_stage`]);
//! * **IVF** — probes ranked/scanned, pending-segment rows, tombstone
//!   filters ([`crate::mips::ivf`]);
//! * **samplers/estimators** — rounds, lazy-tail lengths, exact
//!   evaluations ([`crate::sampler`], [`crate::estimator`]);
//! * **remote** — per-shard call latency, retries, backoff waits,
//!   degraded merges, health transitions ([`crate::remote`]);
//! * **store** — snapshot open mode + degraded flag ([`crate::store`]);
//! * **coordinator/server** — queue wait, batch sizes, shed count,
//!   queue depth ([`crate::coordinator`], [`crate::server`]).
//!
//! The registry is a process singleton ([`registry`]): in-process shard
//! fleets (tests) share one registry, while real deployments give each
//! shard-server process its own — [`aggregate`] merges per-shard
//! expositions into coordinator-level families with `shard` labels.
//!
//! Tracing records a per-request span breakdown
//! (queue → encode → screen → re-rank → merge) for 1-in-N sampled
//! requests ([`trace_try_sample`], counter-based and deterministic) and
//! emits each as one JSON line to a configurable sink. The active trace
//! is thread-local: deep code marks stages with [`trace_stage`] without
//! any parameter plumbing, and only the sampled request pays for the
//! stopwatches (everything else sees one thread-local bool load).

use crate::error::{Error, Result};
use crate::util::json::Json;
use crate::util::timing::LatencyHistogram;
use std::cell::{Cell, RefCell};
use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Runtime enable gate for all registry writes (`[obs] enabled`).
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether registry instrumentation is on. One relaxed load; counters
/// check it themselves, histogram/stopwatch sites should check it before
/// doing non-trivial work.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Monotone counter (relaxed atomic; disabled registry → no-op).
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Default, Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        if enabled() {
            self.0.store(v, Ordering::Relaxed);
        }
    }
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Poison-tolerant lock (registry readers must survive a panicked
/// writer; the guarded Vec is only ever pushed to).
fn locked<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Labeled counter family. `handle` interns the label once and returns a
/// shared [`Counter`] the caller caches — the hot path then touches only
/// that atomic, never this lock.
#[derive(Default)]
pub struct CounterFamily {
    entries: Mutex<Vec<(String, Arc<Counter>)>>,
}

impl CounterFamily {
    pub fn handle(&self, label: &str) -> Arc<Counter> {
        let mut g = locked(&self.entries);
        if let Some((_, c)) = g.iter().find(|(l, _)| l == label) {
            return c.clone();
        }
        let c = Arc::new(Counter::default());
        g.push((label.to_string(), c.clone()));
        c
    }
    fn snapshot(&self) -> Vec<(String, u64)> {
        locked(&self.entries).iter().map(|(l, c)| (l.clone(), c.get())).collect()
    }
}

/// Labeled histogram family (same handle-caching contract as
/// [`CounterFamily`]).
#[derive(Default)]
pub struct HistFamily {
    entries: Mutex<Vec<(String, Arc<LatencyHistogram>)>>,
}

impl HistFamily {
    pub fn handle(&self, label: &str) -> Arc<LatencyHistogram> {
        let mut g = locked(&self.entries);
        if let Some((_, h)) = g.iter().find(|(l, _)| l == label) {
            return h.clone();
        }
        let h = Arc::new(LatencyHistogram::new());
        g.push((label.to_string(), h.clone()));
        h
    }
    fn labels(&self) -> Vec<(String, Arc<LatencyHistogram>)> {
        locked(&self.entries).clone()
    }
}

/// Index of a screening tier in the per-rung counter arrays.
pub fn tier_index(name: &str) -> usize {
    match name {
        "sq8" => 0,
        "sq4" => 1,
        _ => 2, // "pq"
    }
}

const TIER_NAMES: [&str; 3] = ["sq8", "sq4", "pq"];
const HEALTH_NAMES: [&str; 3] = ["up", "degraded", "down"];

/// The process-wide metric set. All fields are wait-free to update; the
/// labeled families take a short lock only when a NEW label is interned
/// (callers cache handles at construction time).
#[derive(Default)]
pub struct Registry {
    // --- tier ladder (mips/two_stage) ---------------------------------
    /// per-rung coverage-certificate successes, indexed by [`tier_index`]
    pub screen_cert_hits: [Counter; 3],
    /// per-rung coverage-certificate failures
    pub screen_cert_misses: [Counter; 3],
    /// rows offered to a quantized pass-1 screen
    pub screen_rows_screened: Counter,
    /// rows served by batched pass-1 scans per code layout (label:
    /// `plane` / `fastscan`) — the adaptive controller's signal for
    /// which scan path answered a request
    pub tier_rows_screened: CounterFamily,
    /// rows exact-re-ranked in pass 2
    pub screen_rows_reranked: Counter,
    /// screens where the whole ladder failed to certify (f32 fallback)
    pub screen_f32_fallbacks: Counter,
    // --- IVF (mips/ivf) -----------------------------------------------
    /// probe scans answered (single queries; batch entries count once
    /// per query)
    pub ivf_queries: Counter,
    /// clusters actually scanned
    pub ivf_probes_scanned: Counter,
    /// rows scanned in probed clusters (incl. screening passes)
    pub ivf_rows_scanned: Counter,
    /// pending-segment (LSM ingest) rows scanned
    pub ivf_pending_rows: Counter,
    /// rows skipped by the stale-tombstone filter
    pub ivf_tombstone_filtered: Counter,
    // --- samplers / estimators ----------------------------------------
    /// Algorithm 1/2 sampling rounds served
    pub sampler_rounds: Counter,
    /// lazily materialized tail Gumbels (Σ m)
    pub sampler_tail_gumbels: Counter,
    /// Algorithm 3/4 estimation rounds served
    pub estimator_rounds: Counter,
    /// uniform tail draws (Σ realized |T|)
    pub estimator_tail_draws: Counter,
    /// exact O(n) partition/expectation evaluations (the fallback the
    /// amortized path is supposed to avoid)
    pub estimator_exact_evals: Counter,
    // --- remote fan-out -----------------------------------------------
    /// per-shard retried attempts (label: shard id)
    pub remote_retries: CounterFamily,
    /// per-shard backoff sleep, milliseconds (label: shard id)
    pub remote_backoff_ms: CounterFamily,
    /// per-shard call latency incl. retries (label: shard id)
    pub remote_call_micros: HistFamily,
    /// merges that renormalized over a shard subset (degraded answers)
    pub remote_degraded_merges: Counter,
    /// health-state transitions, indexed up/degraded/down
    pub health_transitions: [Counter; 3],
    // --- store --------------------------------------------------------
    /// how the index came up: 0 = built fresh, 1 = snapshot (read),
    /// 2 = snapshot (mmap)
    pub store_open_mode: Gauge,
    /// 1 when quantized snapshot sections were corrupt (serving f32)
    pub store_snapshot_degraded: Gauge,
    // --- coordinator / server -----------------------------------------
    /// queue wait per request (enqueue → worker pop)
    pub queue_wait_micros: LatencyHistogram,
    /// batches drained by workers
    pub batches: Counter,
    /// requests inside those batches (ratio = mean batch depth)
    pub batched_requests: Counter,
    /// requests shed under saturation
    pub shed: Counter,
    /// coordinator queue depth at last request admission
    pub queue_depth: Gauge,
    /// requests answered by the engine
    pub requests: Counter,
    /// database rows scanned answering those requests
    pub request_rows_scanned: Counter,
    /// trace lines emitted
    pub traces_emitted: Counter,
}

impl Registry {
    /// Certificate hit rate across all rungs in `[0, 1]` (0 when no
    /// screens ran).
    pub fn cert_hit_rate(&self) -> f64 {
        let hits: u64 = self.screen_cert_hits.iter().map(|c| c.get()).sum();
        let misses: u64 = self.screen_cert_misses.iter().map(|c| c.get()).sum();
        if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        }
    }

    /// Mean database rows scanned per engine request (0 before traffic).
    pub fn rows_per_request(&self) -> f64 {
        let r = self.requests.get();
        if r == 0 {
            0.0
        } else {
            self.request_rows_scanned.get() as f64 / r as f64
        }
    }
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    REGISTRY.get_or_init(Registry::default)
}

// ----------------------------------------------------------------------
// Prometheus text exposition
// ----------------------------------------------------------------------

/// Escape a label value per the Prometheus text format.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", inner.join(","))
}

struct Renderer {
    out: String,
}

impl Renderer {
    fn new() -> Renderer {
        Renderer { out: String::with_capacity(4096) }
    }

    fn family(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(&format!("{name}{} {}\n", fmt_labels(labels), fmt_value(value)));
    }

    fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.family(name, help, "counter");
        self.sample(name, &[], value as f64);
    }

    fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.family(name, help, "gauge");
        self.sample(name, &[], value);
    }

    /// One histogram sample set under an already-emitted family header.
    fn hist_samples(&mut self, name: &str, labels: &[(&str, &str)], h: &LatencyHistogram) {
        let bucket = format!("{name}_bucket");
        let mut prev = 0u64;
        for (le, cum) in h.cumulative_buckets() {
            if cum != prev {
                let le_s = fmt_value(le);
                let mut ls: Vec<(&str, &str)> = labels.to_vec();
                ls.push(("le", &le_s));
                self.sample(&bucket, &ls, cum as f64);
                prev = cum;
            }
        }
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket, &ls, h.count() as f64);
        self.sample(&format!("{name}_sum"), labels, h.sum());
        self.sample(&format!("{name}_count"), labels, h.count() as f64);
    }
}

/// Render `v` the way Prometheus expects: integral values without a
/// fraction, everything else via the shortest `{}` float form.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Extra per-component metrics merged into one exposition alongside the
/// global registry (the engine's per-op latency histograms, a shard
/// engine's local request counter, ...).
#[derive(Default)]
pub struct ExtraMetrics<'a> {
    /// rendered as `gmips_engine_op_micros{op="<name>"}` histograms
    pub op_hists: Vec<(&'static str, &'a LatencyHistogram)>,
    /// standalone counter families: (name, help, value)
    pub counters: Vec<(&'static str, &'static str, u64)>,
    /// standalone gauge families: (name, help, value)
    pub gauges: Vec<(&'static str, &'static str, f64)>,
}

/// Render the global registry as Prometheus text exposition.
pub fn render() -> String {
    render_with(&ExtraMetrics::default())
}

/// [`render`] plus caller-scoped extras.
pub fn render_with(extra: &ExtraMetrics<'_>) -> String {
    let r = registry();
    let mut w = Renderer::new();

    // tier ladder
    w.family(
        "gmips_screen_certificate_hits_total",
        "Coverage-certificate successes per screening rung",
        "counter",
    );
    for (i, name) in TIER_NAMES.iter().enumerate() {
        w.sample(
            "gmips_screen_certificate_hits_total",
            &[("tier", name)],
            r.screen_cert_hits[i].get() as f64,
        );
    }
    w.family(
        "gmips_screen_certificate_misses_total",
        "Coverage-certificate failures per screening rung",
        "counter",
    );
    for (i, name) in TIER_NAMES.iter().enumerate() {
        w.sample(
            "gmips_screen_certificate_misses_total",
            &[("tier", name)],
            r.screen_cert_misses[i].get() as f64,
        );
    }
    w.counter(
        "gmips_screen_rows_screened_total",
        "Rows offered to quantized pass-1 screens",
        r.screen_rows_screened.get(),
    );
    w.family(
        "gmips_tier_rows_screened_total",
        "Rows served by batched pass-1 scans per code layout",
        "counter",
    );
    for (layout, v) in r.tier_rows_screened.snapshot() {
        w.sample("gmips_tier_rows_screened_total", &[("layout", &layout)], v as f64);
    }
    w.counter(
        "gmips_screen_rows_reranked_total",
        "Rows exact-re-ranked in pass 2",
        r.screen_rows_reranked.get(),
    );
    w.counter(
        "gmips_screen_f32_fallbacks_total",
        "Screens where no ladder rung certified (fell back to f32)",
        r.screen_f32_fallbacks.get(),
    );

    // IVF
    w.counter("gmips_ivf_queries_total", "IVF probe scans answered", r.ivf_queries.get());
    w.counter(
        "gmips_ivf_probes_scanned_total",
        "IVF clusters scanned",
        r.ivf_probes_scanned.get(),
    );
    w.counter(
        "gmips_ivf_rows_scanned_total",
        "Rows scanned inside probed IVF clusters",
        r.ivf_rows_scanned.get(),
    );
    w.counter(
        "gmips_ivf_pending_rows_total",
        "Pending-segment (unmerged ingest) rows scanned",
        r.ivf_pending_rows.get(),
    );
    w.counter(
        "gmips_ivf_tombstone_filtered_total",
        "Rows skipped by the stale-tombstone filter",
        r.ivf_tombstone_filtered.get(),
    );

    // samplers / estimators
    w.counter("gmips_sampler_rounds_total", "Sampling rounds served", r.sampler_rounds.get());
    w.counter(
        "gmips_sampler_tail_gumbels_total",
        "Lazily materialized tail Gumbels",
        r.sampler_tail_gumbels.get(),
    );
    w.counter(
        "gmips_estimator_rounds_total",
        "Partition/expectation estimation rounds served",
        r.estimator_rounds.get(),
    );
    w.counter(
        "gmips_estimator_tail_draws_total",
        "Uniform tail draws across estimation rounds",
        r.estimator_tail_draws.get(),
    );
    w.counter(
        "gmips_estimator_exact_evals_total",
        "Exact O(n) partition/expectation evaluations",
        r.estimator_exact_evals.get(),
    );

    // remote
    w.family("gmips_remote_retries_total", "Shard call retry attempts", "counter");
    for (shard, v) in r.remote_retries.snapshot() {
        w.sample("gmips_remote_retries_total", &[("shard", &shard)], v as f64);
    }
    w.family(
        "gmips_remote_backoff_ms_total",
        "Milliseconds slept in retry backoff",
        "counter",
    );
    for (shard, v) in r.remote_backoff_ms.snapshot() {
        w.sample("gmips_remote_backoff_ms_total", &[("shard", &shard)], v as f64);
    }
    w.family(
        "gmips_remote_call_micros",
        "Shard call latency incl. retries (microseconds)",
        "histogram",
    );
    for (shard, h) in r.remote_call_micros.labels() {
        w.hist_samples("gmips_remote_call_micros", &[("shard", &shard)], &h);
    }
    w.counter(
        "gmips_remote_degraded_merges_total",
        "Fan-out merges renormalized over a shard subset",
        r.remote_degraded_merges.get(),
    );
    w.family("gmips_health_transitions_total", "Shard health-state transitions", "counter");
    for (i, name) in HEALTH_NAMES.iter().enumerate() {
        w.sample(
            "gmips_health_transitions_total",
            &[("to", name)],
            r.health_transitions[i].get() as f64,
        );
    }

    // store
    w.gauge(
        "gmips_store_open_mode",
        "Index origin: 0 built fresh, 1 snapshot read, 2 snapshot mmap",
        r.store_open_mode.get() as f64,
    );
    w.gauge(
        "gmips_store_snapshot_degraded",
        "1 when corrupt quantized snapshot sections degraded to the f32 tier",
        r.store_snapshot_degraded.get() as f64,
    );

    // coordinator / server
    w.family(
        "gmips_queue_wait_micros",
        "Request wait in the coordinator queue (microseconds)",
        "histogram",
    );
    w.hist_samples("gmips_queue_wait_micros", &[], &r.queue_wait_micros);
    w.counter("gmips_batches_total", "Batches drained by coordinator workers", r.batches.get());
    w.counter(
        "gmips_batched_requests_total",
        "Requests inside drained batches",
        r.batched_requests.get(),
    );
    w.counter("gmips_shed_total", "Requests shed under saturation", r.shed.get());
    w.gauge(
        "gmips_queue_depth",
        "Coordinator queue depth at last admission",
        r.queue_depth.get() as f64,
    );
    w.counter("gmips_requests_total", "Requests answered by the engine", r.requests.get());
    w.counter(
        "gmips_request_rows_scanned_total",
        "Database rows scanned answering requests",
        r.request_rows_scanned.get(),
    );
    w.counter("gmips_traces_emitted_total", "Sampled trace lines emitted", r.traces_emitted.get());

    // caller extras
    if !extra.op_hists.is_empty() {
        w.family(
            "gmips_engine_op_micros",
            "Engine handle latency per operation (microseconds)",
            "histogram",
        );
        for (op, h) in &extra.op_hists {
            w.hist_samples("gmips_engine_op_micros", &[("op", op)], h);
        }
    }
    for (name, help, v) in &extra.counters {
        w.counter(name, help, *v);
    }
    for (name, help, v) in &extra.gauges {
        w.gauge(name, help, *v);
    }
    w.out
}

// ----------------------------------------------------------------------
// Exposition parsing + shard aggregation
// ----------------------------------------------------------------------

/// One parsed exposition sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed exposition: samples in document order plus the `# TYPE`
/// declarations in first-seen order.
#[derive(Default, Debug)]
pub struct Exposition {
    pub samples: Vec<Sample>,
    pub types: Vec<(String, String)>,
}

impl Exposition {
    /// First sample value matching `name` (and `label`, when given).
    pub fn value(&self, name: &str, label: Option<(&str, &str)>) -> Option<f64> {
        self.samples
            .iter()
            .find(|s| {
                s.name == name
                    && label
                        .map(|(k, v)| s.labels.iter().any(|(lk, lv)| lk == k && lv == v))
                        .unwrap_or(true)
            })
            .map(|s| s.value)
    }
}

fn unescape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    let mut chars = v.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some(other) => out.push(other),
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

/// Parse Prometheus text exposition into samples + types. Strict enough
/// for conformance tests (malformed lines are errors, not skips).
pub fn parse_exposition(text: &str) -> Result<Exposition> {
    let mut exp = Exposition::default();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.trim().is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim_start();
            if let Some(t) = rest.strip_prefix("TYPE ") {
                let mut it = t.split_whitespace();
                let name = it
                    .next()
                    .ok_or_else(|| Error::serve(format!("line {}: TYPE without name", ln + 1)))?;
                let kind = it.next().unwrap_or("untyped");
                exp.types.push((name.to_string(), kind.to_string()));
            }
            continue; // HELP and comments
        }
        exp.samples.push(parse_sample(line, ln + 1)?);
    }
    Ok(exp)
}

fn parse_sample(line: &str, ln: usize) -> Result<Sample> {
    let bad = |what: &str| Error::serve(format!("exposition line {ln}: {what}: {line}"));
    let (name_part, rest) = match line.find('{') {
        Some(b) => (&line[..b], &line[b..]),
        None => match line.find(char::is_whitespace) {
            Some(sp) => (&line[..sp], &line[sp..]),
            None => return Err(bad("no value")),
        },
    };
    let name = name_part.trim();
    if name.is_empty() {
        return Err(bad("empty metric name"));
    }
    let mut labels = Vec::new();
    let value_part = if let Some(body) = rest.strip_prefix('{') {
        // scan to the UNESCAPED closing brace (label values may contain
        // any character except a raw newline)
        let bytes = body.as_bytes();
        let mut i = 0usize;
        let mut in_str = false;
        let mut esc = false;
        let mut close = None;
        while i < bytes.len() {
            let c = bytes[i] as char;
            if esc {
                esc = false;
            } else if in_str && c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = !in_str;
            } else if !in_str && c == '}' {
                close = Some(i);
                break;
            }
            i += 1;
        }
        let close = close.ok_or_else(|| bad("unterminated label set"))?;
        let labels_src = &body[..close];
        let mut cursor = labels_src;
        while !cursor.trim().is_empty() {
            let eq = cursor.find('=').ok_or_else(|| bad("label without ="))?;
            let key = cursor[..eq].trim().to_string();
            let after = cursor[eq + 1..].trim_start();
            let after =
                after.strip_prefix('"').ok_or_else(|| bad("label value must be quoted"))?;
            // find the unescaped closing quote
            let mut end = None;
            let mut esc = false;
            for (i, c) in after.char_indices() {
                if esc {
                    esc = false;
                } else if c == '\\' {
                    esc = true;
                } else if c == '"' {
                    end = Some(i);
                    break;
                }
            }
            let end = end.ok_or_else(|| bad("unterminated label value"))?;
            labels.push((key, unescape_label(&after[..end])));
            let mut tail = &after[end + 1..];
            tail = tail.trim_start();
            if let Some(t) = tail.strip_prefix(',') {
                cursor = t;
            } else if tail.is_empty() {
                cursor = tail;
            } else {
                return Err(bad("labels must be comma-separated"));
            }
        }
        &body[close + 1..]
    } else {
        rest
    };
    let vstr = value_part.trim().split_whitespace().next().ok_or_else(|| bad("no value"))?;
    let value = match vstr {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        s => s.parse::<f64>().map_err(|_| bad("unparseable value"))?,
    };
    Ok(Sample { name: name.to_string(), labels, value })
}

/// Merge a coordinator's local exposition with per-shard expositions
/// into one document: families keep a single `# TYPE` header and every
/// shard sample gains a `shard="<id>"` label. Unparseable shard answers
/// are noted as comments instead of poisoning the whole document.
pub fn aggregate(local: &str, shards: &[(usize, String)]) -> String {
    let mut family_order: Vec<String> = Vec::new();
    let mut types: Vec<(String, String)> = Vec::new();
    // (family, sample-line) in arrival order
    let mut lines: Vec<(String, String)> = Vec::new();
    let mut notes: Vec<String> = Vec::new();

    let mut absorb = |text: &str, shard: Option<usize>, notes: &mut Vec<String>| {
        let exp = match parse_exposition(text) {
            Ok(e) => e,
            Err(e) => {
                if let Some(s) = shard {
                    notes.push(format!("# shard {s}: unparseable metrics: {e}\n"));
                }
                return;
            }
        };
        for (name, kind) in exp.types {
            if !types.iter().any(|(n, _)| *n == name) {
                types.push((name, kind));
            }
        }
        for s in exp.samples {
            // histogram series (`x_bucket`/`x_sum`/`x_count`) group under
            // their base family name
            let fam = ["_bucket", "_sum", "_count"]
                .iter()
                .find_map(|suf| {
                    s.name.strip_suffix(suf).filter(|base| {
                        types.iter().any(|(n, k)| n == base && k == "histogram")
                    })
                })
                .unwrap_or(&s.name)
                .to_string();
            if !family_order.contains(&fam) {
                family_order.push(fam.clone());
            }
            let mut labels: Vec<(String, String)> = s.labels;
            if let Some(id) = shard {
                labels.insert(0, ("shard".to_string(), id.to_string()));
            }
            let rendered: Vec<(&str, &str)> =
                labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            lines.push((
                fam,
                format!("{}{} {}\n", s.name, fmt_labels(&rendered), fmt_value(s.value)),
            ));
        }
    };

    absorb(local, None, &mut notes);
    for (id, text) in shards {
        absorb(text, Some(*id), &mut notes);
    }

    let mut out = String::with_capacity(local.len() * (shards.len() + 1));
    for note in &notes {
        out.push_str(note);
    }
    for fam in &family_order {
        if let Some((_, kind)) = types.iter().find(|(n, _)| n == fam) {
            out.push_str(&format!("# TYPE {fam} {kind}\n"));
        }
        for (f, line) in &lines {
            if f == fam {
                out.push_str(line);
            }
        }
    }
    out
}

// ----------------------------------------------------------------------
// Sampled request tracing
// ----------------------------------------------------------------------

/// Trace sampling rate: a request is traced iff its sequence number is
/// ≡ 0 (mod rate). 0 disables tracing.
static TRACE_RATE: AtomicU64 = AtomicU64::new(0);
static TRACE_SEQ: AtomicU64 = AtomicU64::new(0);

pub fn set_trace_rate(rate: u64) {
    TRACE_RATE.store(rate, Ordering::Relaxed);
}

/// Deterministic 1-in-N sampling decision for the next request (counter
/// based: rate 1 traces every request, rate 0 none).
pub fn trace_try_sample() -> bool {
    let rate = TRACE_RATE.load(Ordering::Relaxed);
    if rate == 0 {
        return false;
    }
    TRACE_SEQ.fetch_add(1, Ordering::Relaxed) % rate == 0
}

/// Stages of the per-request span breakdown.
#[derive(Clone, Copy, Debug)]
pub enum Stage {
    /// coordinator queue wait
    Queue,
    /// query encoding for the quantized screens
    Encode,
    /// quantized pass-1 screen
    Screen,
    /// exact pass-2 re-rank
    Rerank,
    /// fragment/top-k merge
    Merge,
}

const NSTAGES: usize = 5;
const STAGE_KEYS: [&str; NSTAGES] = ["queue_us", "encode_us", "screen_us", "rerank_us", "merge_us"];

thread_local! {
    static TRACE_ACTIVE: Cell<bool> = const { Cell::new(false) };
    static TRACE_STAGES: RefCell<[f64; NSTAGES]> = const { RefCell::new([0.0; NSTAGES]) };
}

/// Whether a trace is active on this thread — the only cost non-sampled
/// work pays at a stage mark.
#[inline]
pub fn trace_active() -> bool {
    TRACE_ACTIVE.with(|a| a.get())
}

/// Activate a trace on this thread (stages cleared). Pair with
/// [`trace_end`].
pub fn trace_begin() {
    TRACE_STAGES.with(|s| *s.borrow_mut() = [0.0; NSTAGES]);
    TRACE_ACTIVE.with(|a| a.set(true));
}

/// Add `micros` to a stage of the active trace (no-op otherwise).
pub fn trace_stage(stage: Stage, micros: f64) {
    if !trace_active() {
        return;
    }
    TRACE_STAGES.with(|s| s.borrow_mut()[stage as usize] += micros);
}

/// Finish the active trace: emit one JSON line
/// `{"op":..,"total_us":..,"batch":..,"queue_us":..,...}` to the sink.
pub fn trace_end(op: &str, total_micros: f64, batch: usize) {
    if !trace_active() {
        return;
    }
    TRACE_ACTIVE.with(|a| a.set(false));
    let stages = TRACE_STAGES.with(|s| *s.borrow());
    let mut fields: Vec<(&str, Json)> = vec![
        ("op", Json::str(op)),
        ("total_us", Json::num(total_micros)),
        ("batch", Json::num(batch as f64)),
    ];
    for (i, key) in STAGE_KEYS.iter().enumerate() {
        fields.push((key, Json::num(stages[i])));
    }
    emit_trace_line(&Json::obj(fields).to_string());
}

/// Where sampled trace lines go.
enum Sink {
    None,
    Memory(Vec<String>),
    File(std::io::BufWriter<std::fs::File>),
}

fn sink() -> &'static Mutex<Sink> {
    static SINK: OnceLock<Mutex<Sink>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Sink::None))
}

fn emit_trace_line(line: &str) {
    let mut g = locked(sink());
    match &mut *g {
        Sink::None => return,
        Sink::Memory(v) => v.push(line.to_string()),
        Sink::File(w) => {
            let _ = writeln!(w, "{line}");
            let _ = w.flush();
        }
    }
    registry().traces_emitted.inc();
}

/// Route traces to an in-memory buffer (tests).
pub fn set_trace_sink_memory() {
    *locked(sink()) = Sink::Memory(Vec::new());
}

/// Route traces to a JSON-lines file (append).
pub fn set_trace_sink_file(path: &str) -> Result<()> {
    let f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| Error::config(format!("cannot open obs.trace_sink '{path}': {e}")))?;
    *locked(sink()) = Sink::File(std::io::BufWriter::new(f));
    Ok(())
}

/// Drop the sink (traces discarded).
pub fn set_trace_sink_none() {
    *locked(sink()) = Sink::None;
}

/// Drain the in-memory sink (empty when the sink is not memory).
pub fn take_trace_lines() -> Vec<String> {
    match &mut *locked(sink()) {
        Sink::Memory(v) => std::mem::take(v),
        _ => Vec::new(),
    }
}

/// Apply the `[obs]` config: enable flag, trace sample rate, sink path.
pub fn configure(cfg: &crate::config::ObsConfig) -> Result<()> {
    set_enabled(cfg.enabled);
    set_trace_rate(cfg.trace_sample);
    if !cfg.trace_sink.is_empty() {
        set_trace_sink_file(&cfg.trace_sink)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool;

    /// Serializes tests that read or mutate process-global obs state
    /// (the ENABLED gate, the trace rate/sink, the shared registry):
    /// without it, `disabled_registry_drops_writes` could drop another
    /// test's increments mid-flight.
    fn global_state_guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn exposition_renders_and_parses_with_unique_families() {
        let _g = global_state_guard();
        let r = registry();
        r.screen_cert_hits[0].inc();
        r.tier_rows_screened.handle("fastscan").add(4);
        r.ivf_rows_scanned.add(100);
        r.remote_retries.handle("0").add(2);
        r.remote_call_micros.handle("0").record(350.0);
        r.queue_wait_micros.record(42.0);
        let text = render();
        let exp = parse_exposition(&text).unwrap();
        // every emitted TYPE is unique
        for (i, (n, _)) in exp.types.iter().enumerate() {
            assert!(
                !exp.types[i + 1..].iter().any(|(m, _)| m == n),
                "duplicate family {n}"
            );
        }
        // headline families present with sane values
        assert!(
            exp.value("gmips_screen_certificate_hits_total", Some(("tier", "sq8"))).unwrap()
                >= 1.0
        );
        assert!(exp.value("gmips_ivf_rows_scanned_total", None).unwrap() >= 100.0);
        assert!(
            exp.value("gmips_tier_rows_screened_total", Some(("layout", "fastscan"))).unwrap()
                >= 4.0
        );
        assert!(
            exp.value("gmips_remote_retries_total", Some(("shard", "0"))).unwrap() >= 2.0
        );
        // histogram series parse: +Inf bucket equals _count
        let inf = exp
            .samples
            .iter()
            .find(|s| {
                s.name == "gmips_queue_wait_micros_bucket"
                    && s.labels.iter().any(|(k, v)| k == "le" && v == "+Inf")
            })
            .unwrap()
            .value;
        let count = exp.value("gmips_queue_wait_micros_count", None).unwrap();
        assert_eq!(inf, count);
        let sum = exp.value("gmips_queue_wait_micros_sum", None).unwrap();
        assert!(sum > 0.0);
    }

    #[test]
    fn label_escaping_roundtrips() {
        let weird = "a\\b\"c\nd";
        let rendered = format!("m{} 1\n", fmt_labels(&[("k", weird)]));
        let exp = parse_exposition(&rendered).unwrap();
        assert_eq!(exp.samples.len(), 1);
        assert_eq!(exp.samples[0].labels, vec![("k".to_string(), weird.to_string())]);
        assert_eq!(exp.samples[0].value, 1.0);
    }

    #[test]
    fn parser_rejects_malformed_lines() {
        assert!(parse_exposition("justaname").is_err());
        assert!(parse_exposition("m{k=\"unterminated} 1").is_err());
        assert!(parse_exposition("m{k=unquoted} 1").is_err());
        assert!(parse_exposition("m 1 2 ok").is_ok()); // timestamp tolerated
        assert!(parse_exposition("m nope").is_err());
    }

    #[test]
    fn trace_sampling_is_deterministic_at_the_rate_extremes() {
        // one test on purpose: TRACE_RATE is process-global, so the two
        // extremes must not run concurrently from separate #[test]s
        let _g = global_state_guard();
        set_trace_sink_memory();
        set_trace_rate(0);
        for _ in 0..50 {
            assert!(!trace_try_sample());
        }
        set_trace_rate(1);
        for i in 0..50 {
            assert!(trace_try_sample(), "request {i} must be sampled at rate 1");
            trace_begin();
            trace_stage(Stage::Screen, 10.0);
            trace_stage(Stage::Rerank, 5.0);
            trace_end("topk", 20.0, 1);
        }
        let lines = take_trace_lines();
        assert_eq!(lines.len(), 50);
        let j = Json::parse(&lines[0]).unwrap();
        assert_eq!(j.req("op").unwrap().as_str().unwrap(), "topk");
        assert_eq!(j.req("screen_us").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(j.req("rerank_us").unwrap().as_f64().unwrap(), 5.0);
        assert_eq!(j.req("total_us").unwrap().as_f64().unwrap(), 20.0);
        set_trace_rate(0);
        set_trace_sink_none();
    }

    #[test]
    fn stage_marks_without_active_trace_are_noops() {
        assert!(!trace_active());
        trace_stage(Stage::Merge, 1.0); // must not panic or record
        trace_end("noop", 1.0, 1); // inactive: no line emitted
    }

    #[test]
    fn counters_are_exact_under_pool_threads() {
        let _g = global_state_guard();
        let c = Counter::default();
        let h = LatencyHistogram::new();
        pool::parallel_chunks(8, 8, |_, s, e| {
            for _ in s..e {
                for _ in 0..10_000 {
                    c.inc();
                    h.record(1.5);
                }
            }
        });
        assert_eq!(c.get(), 80_000);
        assert_eq!(h.count(), 80_000);
        assert!((h.sum() - 120_000.0).abs() < 1.0);
    }

    #[test]
    fn disabled_registry_drops_writes() {
        let _g = global_state_guard();
        let c = Counter::default();
        set_enabled(false);
        c.add(5);
        set_enabled(true);
        c.add(3);
        assert_eq!(c.get(), 3);
    }

    #[test]
    fn family_handles_are_shared() {
        let _g = global_state_guard();
        let fam = CounterFamily::default();
        let a = fam.handle("7");
        let b = fam.handle("7");
        a.add(2);
        b.add(3);
        assert_eq!(fam.snapshot(), vec![("7".to_string(), 5u64)]);
    }

    #[test]
    fn aggregate_labels_shards_and_keeps_one_type_per_family() {
        let local = "# TYPE m counter\nm 1\n# TYPE h histogram\nh_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 2\n";
        let s0 = "# TYPE m counter\nm 10\n".to_string();
        let s1 = "# TYPE m counter\nm 20\n# TYPE extra counter\nextra 7\n".to_string();
        let agg = aggregate(local, &[(0, s0), (1, s1)]);
        let exp = parse_exposition(&agg).unwrap();
        for (i, (n, _)) in exp.types.iter().enumerate() {
            assert!(!exp.types[i + 1..].iter().any(|(m, _)| m == n), "dup family {n}");
        }
        assert_eq!(exp.value("m", None).unwrap(), 1.0); // local first, unlabeled
        assert_eq!(exp.value("m", Some(("shard", "0"))).unwrap(), 10.0);
        assert_eq!(exp.value("m", Some(("shard", "1"))).unwrap(), 20.0);
        assert_eq!(exp.value("extra", Some(("shard", "1"))).unwrap(), 7.0);
        // histogram series survived grouped under one TYPE header
        assert_eq!(exp.value("h_count", None).unwrap(), 2.0);
        let unparseable = aggregate(local, &[(3, "%%%garbage 1 2 3{".to_string())]);
        assert!(unparseable.contains("# shard 3"), "{unparseable}");
    }

    #[test]
    fn tier_index_covers_ladder_names() {
        assert_eq!(tier_index("sq8"), 0);
        assert_eq!(tier_index("sq4"), 1);
        assert_eq!(tier_index("pq"), 2);
    }
}
