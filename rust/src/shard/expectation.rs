//! [`ShardedExpectationEstimator`] — Algorithm 4 decomposed over a row
//! partition, merged by **weighted log-sum-exp**.
//!
//! The unnormalized feature expectation factors over a partition of the
//! state space exactly like the partition function:
//!
//! ```text
//! Z·μ = Σ_x e^{θ·φ(x)} φ(x) = Σ_s Z_s·μ_s,   Z = Σ_s Z_s
//! ```
//!
//! Each shard runs its own Algorithm 4 against its sub-index — exact
//! head over its local top-k `S_s` (via
//! [`ShardedIndex::shard_top_k_local_in`]), upweighted uniform tail
//! `T_s ⊂ X_s \ S_s` from a keyed stream — producing a fragment
//! `(log Ẑ_s, μ̂_s)` whose numerator `Ẑ_s·μ̂_s` is unbiased for
//! `Z_s·μ_s` (Theorem 3.5 applied to `X_s`) and whose `Ẑ_s` is unbiased
//! for `Z_s` (Theorem 3.4). The merge is a weighted log-sum-exp:
//!
//! ```text
//! log Ẑ = LSE_s(log Ẑ_s),   μ̂ = Σ_s e^{log Ẑ_s − m} μ̂_s / Σ_s e^{log Ẑ_s − m}
//! ```
//!
//! (`m = max_s log Ẑ_s`), so the merged numerator `Ẑ·μ̂ = Σ_s Ẑ_s·μ̂_s`
//! stays unbiased for `Z·μ` — the same ratio-estimator contract the
//! monolithic `F̂ = Ĵ/Ẑ` has, with the `(ε, δ)` budget of Theorem 3.5
//! split across shards by [`apportion`] (largest remainder, exact
//! totals).
//!
//! Tail draws come from streams keyed by `(seed, round, shard)`
//! ([`Pcg64::keyed`], Algorithm 4's salt), so an estimate at a given
//! round is replayable and [`expect_features_batch`] is bit-identical to
//! the corresponding sequence of single-query calls.
//!
//! [`expect_features_batch`]: ShardedExpectationEstimator::expect_features_batch

use super::{apportion, ShardedIndex};
use crate::data::Dataset;
use crate::estimator::expectation::FeatureExpectation;
use crate::estimator::{effective_tail_len, EstimateWork};
use crate::mips::MipsIndex;
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Stream-salt for the Algorithm 4 per-shard tail draws (`idx` = shard).
/// Distinct from the sampler's and Algorithm 3's salts so all three
/// sharded subsystems can share one seed with independent streams.
const SALT_ALG4_TAIL: u64 = 0xA1_94;

/// One shard's Algorithm 4 fragment: `log Ẑ_s`, the shard-normalized
/// feature mean `μ̂_s` (f64 so the merge keeps full precision), and the
/// work it cost. Public (with public fields) because it is also the unit
/// a remote shard server ships over the wire.
#[derive(Clone, Debug)]
pub struct ShardFragment {
    pub log_z: f64,
    pub mean: Vec<f64>,
    pub work: EstimateWork,
}

/// Weighted log-sum-exp merge: `log Ẑ = LSE_s(log Ẑ_s)` and
/// `μ̂ = Σ_s Ẑ_s μ̂_s / Σ_s Ẑ_s`, carried relative to the max partial so
/// no shard's weight can overflow. Free function so the remote
/// coordinator merges wire fragments bit-identically to the in-process
/// path (`coarse_cost` comes from the shard handshake there).
pub fn merge_shard_fragments(
    d: usize,
    coarse_cost: usize,
    frags: Vec<ShardFragment>,
) -> FeatureExpectation {
    let mut work = EstimateWork { scanned: coarse_cost, k: 0, l: 0 };
    let mut m = f64::NEG_INFINITY;
    for f in &frags {
        m = m.max(f.log_z);
        work.scanned += f.work.scanned;
        work.k += f.work.k;
        work.l += f.work.l;
    }
    if !m.is_finite() {
        // only reachable for an all-empty partition, which build paths
        // never construct — stay well-formed regardless
        return FeatureExpectation { mean: vec![0f32; d], log_z: f64::NEG_INFINITY, work };
    }
    let mut z = 0f64;
    let mut wsum = vec![0f64; d];
    for f in &frags {
        if f.log_z == f64::NEG_INFINITY {
            continue;
        }
        let w = (f.log_z - m).exp();
        z += w;
        for (acc, &x) in wsum.iter_mut().zip(&f.mean) {
            *acc += w * x;
        }
    }
    let mean: Vec<f32> = wsum.iter().map(|&x| (x / z) as f32).collect();
    FeatureExpectation { mean, log_z: m + z.ln(), work }
}

/// Algorithm 4 over a [`ShardedIndex`]: per-shard head+tail fragments in
/// parallel, weighted log-sum-exp merge.
pub struct ShardedExpectationEstimator {
    /// the **global** dataset (head/tail rows are resolved through the
    /// shard map, so no per-shard row copies need to be retained)
    ds: Arc<Dataset>,
    index: Arc<ShardedIndex>,
    backend: Arc<dyn ScoreBackend>,
    /// global head size k (split across shards by largest remainder)
    pub k: usize,
    /// global tail sample size l (split across shards by largest remainder)
    pub l: usize,
    seed: u64,
    round: AtomicU64,
}

impl ShardedExpectationEstimator {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<ShardedIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        l: usize,
        seed: u64,
    ) -> Self {
        let k = k.clamp(1, index.n().max(1));
        let l = l.max(1);
        ShardedExpectationEstimator { ds, index, backend, k, l, seed, round: AtomicU64::new(0) }
    }

    /// `E_θ[φ]` at an explicit round (replayable; distinct rounds draw
    /// independent tails).
    pub fn expect_features_at(&self, q: &[f32], round: u64) -> FeatureExpectation {
        let order = self.index.coarse_order(q);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        let frags = self.index.fan_out(|s| {
            self.shard_fragment(s, q, round, k_split[s], l_split[s], order.as_deref())
        });
        self.merge_fragments(frags)
    }

    /// Convenience: estimate at the next internal round.
    pub fn expect_features(&self, q: &[f32]) -> FeatureExpectation {
        let r = self.round.fetch_add(1, Ordering::Relaxed);
        self.expect_features_at(q, r)
    }

    /// Batched Algorithm 4 over the shards: **one fan-out for the whole
    /// batch** (each shard computes its fragment for every query before
    /// any merge, scanning the shared per-query IVF probe lists), query
    /// `i` served at round `r0 + i` — bit-identical to the corresponding
    /// sequence of [`expect_features_at`](Self::expect_features_at)
    /// calls. The engine drains concurrent `expect_features` requests
    /// through this so the fan-out amortizes across users.
    pub fn expect_features_batch(&self, qs: &[&[f32]]) -> Vec<FeatureExpectation> {
        let r0 = self.round.fetch_add(qs.len() as u64, Ordering::Relaxed);
        self.expect_features_batch_at(qs, r0)
    }

    /// [`expect_features_batch`](Self::expect_features_batch) at an
    /// explicit base round.
    pub fn expect_features_batch_at(&self, qs: &[&[f32]], r0: u64) -> Vec<FeatureExpectation> {
        if qs.is_empty() {
            return Vec::new();
        }
        let orders = self.index.coarse_orders_batch(qs);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        // [shard][query] fragments from a single fan-out
        let per_shard: Vec<Vec<ShardFragment>> = self.index.fan_out(|s| {
            qs.iter()
                .enumerate()
                .map(|(i, q)| {
                    let order = orders.as_ref().map(|o| o[i].as_slice());
                    self.shard_fragment(s, q, r0 + i as u64, k_split[s], l_split[s], order)
                })
                .collect()
        });
        // transpose by value: each fragment is consumed exactly once
        let mut iters: Vec<std::vec::IntoIter<ShardFragment>> =
            per_shard.into_iter().map(|v| v.into_iter()).collect();
        (0..qs.len())
            .map(|_| {
                let frags: Vec<ShardFragment> = iters
                    .iter_mut()
                    .map(|it| it.next().expect("each shard answers every query"))
                    .collect();
                self.merge_fragments(frags)
            })
            .collect()
    }

    /// One shard's Algorithm 4 on `X_s`: local top-k head, keyed
    /// upweighted uniform tail, producing the `(log Ẑ_s, μ̂_s)` fragment.
    fn shard_fragment(
        &self,
        s: usize,
        q: &[f32],
        round: u64,
        k_s: usize,
        l_s: usize,
        order: Option<&[u32]>,
    ) -> ShardFragment {
        let d = self.ds.d;
        let map = self.index.map();
        let n_s = map.shard_len(s);
        if n_s == 0 {
            return ShardFragment {
                log_z: f64::NEG_INFINITY,
                mean: Vec::new(),
                work: EstimateWork::default(),
            };
        }
        // head: shard-local top-k (shared probe list on IVF shards)
        let top = self.index.shard_top_k_local_in(s, q, k_s.clamp(1, n_s), order);
        let k_eff = top.items.len();
        let exclude: FxHashSet<u32> = top.items.iter().map(|it| it.id).collect();
        // tail: keyed uniform draw over X_s \ S_s, shared cap rule
        let mut rng = Pcg64::keyed(self.seed, round, SALT_ALG4_TAIL, s as u64);
        let l_eff = effective_tail_len(l_s, n_s, k_eff);
        let t_ids: Vec<u32> = if l_eff > 0 {
            rng.with_replacement_excluding(n_s as u64, l_eff, &exclude)
                .into_iter()
                .map(|local| map.to_global(s, local))
                .collect()
        } else {
            Vec::new()
        };
        let t_scores = self.score_ids(&t_ids, q);
        let weight =
            if t_ids.is_empty() { 0.0 } else { (n_s - k_eff) as f64 / t_ids.len() as f64 };

        // log-space combine relative to the shard's own reference score
        let m = top
            .s_max()
            .max(t_scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64);
        let mut z_hat = 0f64;
        let mut wsum = vec![0f64; d];
        for it in &top.items {
            let w = ((it.score as f64) - m).exp();
            z_hat += w;
            let row = self.ds.row(map.to_global(s, it.id) as usize);
            for (acc, &x) in wsum.iter_mut().zip(row) {
                *acc += w * x as f64;
            }
        }
        for (&id, &y) in t_ids.iter().zip(&t_scores) {
            let w = ((y as f64) - m).exp() * weight;
            z_hat += w;
            let row = self.ds.row(id as usize);
            for (acc, &x) in wsum.iter_mut().zip(row) {
                *acc += w * x as f64;
            }
        }
        for x in wsum.iter_mut() {
            *x /= z_hat;
        }
        ShardFragment {
            log_z: m + z_hat.ln(),
            mean: wsum,
            work: EstimateWork { scanned: top.scanned, k: k_eff, l: t_ids.len() },
        }
    }

    /// Weighted log-sum-exp merge with the centroid-ranking work
    /// accounted once, like the sharded top_k — delegates to
    /// [`merge_shard_fragments`].
    fn merge_fragments(&self, frags: Vec<ShardFragment>) -> FeatureExpectation {
        merge_shard_fragments(self.ds.d, self.index.coarse_cost(), frags)
    }

    /// One shard's fragment at an explicit round — the unit a remote
    /// shard server exports over the wire. Ranks the shared coarse probe
    /// order and apportions the global `(k, l)` budget internally, so the
    /// result is bit-identical to the closure the in-process fan-out
    /// would run for shard `s`.
    pub fn shard_fragment_at(&self, s: usize, q: &[f32], round: u64) -> ShardFragment {
        let order = self.index.coarse_order(q);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        self.shard_fragment(s, q, round, k_split[s], l_split[s], order.as_deref())
    }

    /// Batched per-shard fragments: query `i` at round `r0 + i`, coarse
    /// orders ranked once for the whole batch — matches the per-shard
    /// closure of
    /// [`expect_features_batch_at`](Self::expect_features_batch_at).
    pub fn shard_fragments_batch_at(
        &self,
        s: usize,
        qs: &[&[f32]],
        r0: u64,
    ) -> Vec<ShardFragment> {
        if qs.is_empty() {
            return Vec::new();
        }
        if qs.len() == 1 {
            // single-query path ranks its own coarse order, exactly like
            // the engine's unbatched route through expect_features_at
            return vec![self.shard_fragment_at(s, qs[0], r0)];
        }
        let orders = self.index.coarse_orders_batch(qs);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        qs.iter()
            .enumerate()
            .map(|(i, q)| {
                let order = orders.as_ref().map(|o| o[i].as_slice());
                self.shard_fragment(s, q, r0 + i as u64, k_split[s], l_split[s], order)
            })
            .collect()
    }

    /// Score global ids via the shared [`crate::scorer::score_ids`]
    /// fast path.
    fn score_ids(&self, ids: &[u32], q: &[f32]) -> Vec<f32> {
        crate::scorer::score_ids(&self.ds, self.backend.as_ref(), ids, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::data::synth;
    use crate::estimator::expectation::exact_feature_expectation;
    use crate::estimator::partition::exact_log_partition;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;

    fn sharded(
        ds: &Arc<Dataset>,
        shards: usize,
        backend: &Arc<dyn ScoreBackend>,
    ) -> Arc<ShardedIndex> {
        let mut cfg = Config::default().index;
        cfg.kind = IndexKind::Brute;
        cfg.shards = shards;
        Arc::new(ShardedIndex::build(ds, &cfg, backend.clone()).unwrap())
    }

    #[test]
    fn degenerate_heads_make_the_merge_exact() {
        // k ≥ n: every shard's head covers its whole partition, so the
        // merged mean must equal the exact E_θ[φ] for ANY shard count —
        // a deterministic check of the Z·μ = Σ_s Z_s·μ_s decomposition.
        let ds = Arc::new(synth::imagenet_like(600, 8, 10, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let (want_mean, want_log_z) = exact_feature_expectation(&ds, backend.as_ref(), &q);
        for shards in [1usize, 3, 7] {
            let est = ShardedExpectationEstimator::new(
                ds.clone(),
                sharded(&ds, shards, &backend),
                backend.clone(),
                ds.n,
                5,
                3,
            );
            let got = est.expect_features_at(&q, 0);
            assert!(
                (got.log_z - want_log_z).abs() < 1e-5,
                "shards={shards}: log_z {} vs {want_log_z}",
                got.log_z
            );
            for (j, (&g, &w)) in got.mean.iter().zip(&want_mean).enumerate() {
                assert!(
                    (g - w).abs() < 1e-5,
                    "shards={shards} coord {j}: {g} vs {w}"
                );
            }
            assert_eq!(got.work.k, ds.n);
        }
    }

    #[test]
    fn sharded_numerator_is_unbiased_and_shard_count_consistent() {
        // E[Ẑ·μ̂] = Z·μ: average exp(log Ẑ − log Z)·μ̂ (the normalized
        // numerator) in the linear domain and compare against the exact
        // expectation, for several shard counts
        let ds = Arc::new(synth::imagenet_like(800, 8, 10, 0.3, 4));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut rng = Pcg64::new(5);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let (want_mean, true_log_z) = exact_feature_expectation(&ds, backend.as_ref(), &q);
        for shards in [1usize, 3, 7] {
            let est = ShardedExpectationEstimator::new(
                ds.clone(),
                sharded(&ds, shards, &backend),
                backend.clone(),
                80,
                120,
                6,
            );
            let reps = 300u64;
            let mut num = vec![0f64; ds.d];
            let mut ratio = 0f64;
            for r in 0..reps {
                let e = est.expect_features_at(&q, r);
                let w = (e.log_z - true_log_z).exp();
                ratio += w / reps as f64;
                for (acc, &x) in num.iter_mut().zip(&e.mean) {
                    *acc += w * x as f64 / reps as f64;
                }
            }
            assert!((ratio - 1.0).abs() < 0.08, "shards={shards}: E[Ẑ]/Z = {ratio}");
            let err = num
                .iter()
                .zip(&want_mean)
                .map(|(&a, &b)| (a - b as f64).abs())
                .fold(0.0, f64::max);
            assert!(err < 0.05, "shards={shards}: max coord error {err}");
        }
    }

    #[test]
    fn shared_st_draw_gives_a_valid_alg3_log_z() {
        let ds = Arc::new(synth::imagenet_like(700, 8, 10, 0.3, 7));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let est = ShardedExpectationEstimator::new(
            ds.clone(),
            sharded(&ds, 3, &backend),
            backend.clone(),
            90,
            140,
            8,
        );
        let mut rng = Pcg64::new(9);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let want = exact_log_partition(&ds, backend.as_ref(), &q);
        let e = est.expect_features_at(&q, 0);
        assert!((e.log_z - want).abs() < 0.3, "{} vs {}", e.log_z, want);
        assert!(e.work.l > 0);
    }

    #[test]
    fn rounds_replayable_and_batch_matches_singles() {
        let ds = Arc::new(synth::imagenet_like(500, 8, 10, 0.3, 10));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let est = ShardedExpectationEstimator::new(
            ds.clone(),
            sharded(&ds, 4, &backend),
            backend.clone(),
            40,
            60,
            11,
        );
        let mut rng = Pcg64::new(12);
        let q1 = synth::random_theta(&ds, 0.1, &mut rng);
        let q2 = synth::random_theta(&ds, 0.1, &mut rng);
        // replayable
        let a = est.expect_features_at(&q1, 5);
        let b = est.expect_features_at(&q1, 5);
        assert_eq!(a.log_z.to_bits(), b.log_z.to_bits());
        assert_eq!(a.mean, b.mean);
        let c = est.expect_features_at(&q1, 6);
        assert_ne!(a.log_z.to_bits(), c.log_z.to_bits(), "rounds must draw fresh tails");
        // batch at base round r0 ≡ singles at rounds r0, r0+1
        let batch = est.expect_features_batch_at(&[&q1, &q2], 20);
        let s1 = est.expect_features_at(&q1, 20);
        let s2 = est.expect_features_at(&q2, 21);
        assert_eq!(batch[0].mean, s1.mean);
        assert_eq!(batch[0].log_z.to_bits(), s1.log_z.to_bits());
        assert_eq!(batch[1].mean, s2.mean);
        assert_eq!(batch[1].log_z.to_bits(), s2.log_z.to_bits());
    }
}
