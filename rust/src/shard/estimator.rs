//! [`ShardedPartitionEstimator`] — Algorithm 3 decomposed over a row
//! partition, merged by **log-sum-exp**.
//!
//! The partition function is additive over a partition of the state
//! space: `Z = Σ_x e^{θ·φ(x)} = Σ_s Z_s`. Each shard runs its own
//! Algorithm 3 against its sub-index — exact head over its local top-k
//! `S_s`, upweighted uniform tail `T_s` of its remaining rows — giving
//! an unbiased `Ẑ_s` (Theorem 3.4 applied to `X_s`). The merge
//!
//! ```text
//! log Ẑ = LSE_s(log Ẑ_s) = m + ln Σ_s e^{log Ẑ_s − m},  m = max_s log Ẑ_s
//! ```
//!
//! is numerically the same log-space combination the monolithic
//! estimator uses internally, so `E[Ẑ] = Σ_s E[Ẑ_s] = Σ_s Z_s = Z`
//! stays unbiased, and the `(ε, δ)` budget of Theorem 3.4 splits across
//! shards in proportion to their `k_s · l_s` products (both `k` and `l`
//! are apportioned to shard size by largest remainder —
//! [`super::apportion`] — so the global totals are preserved exactly,
//! up to a floor of one per shard).
//!
//! Tail samples come from streams keyed by `(seed, round, shard)`, so an
//! estimate at a given round is replayable.

use super::{apportion, ShardedIndex};
use crate::data::Dataset;
use crate::estimator::partition::{combine_head_tail, PartitionEstimate};
use crate::estimator::{effective_tail_len, EstimateWork};
use crate::linalg::MaxSumExp;
use crate::mips::MipsIndex;
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Stream-salt for the Algorithm 3 per-shard tail draws (`idx` = shard).
/// Distinct from the sharded sampler's `SALT_TOP`/`SALT_TAIL` and the
/// sharded expectation estimator's salt, so the three subsystems can
/// share one seed with independent streams.
const SALT_ALG3_TAIL: u64 = 0xA1_93;

/// Merge per-shard `log Ẑ_s` partials: `log Σ_s Ẑ_s` — exactly
/// [`crate::linalg::logsumexp`], named for the shard-merge role it plays
/// here (`Z = Σ_s Z_s` under a row partition).
pub fn merge_log_partials(partials: &[f64]) -> f64 {
    crate::linalg::logsumexp(partials)
}

/// Log-sum-exp merge of per-shard `(log Ẑ_s, work)` partials with the
/// coarse-ranking cost accounted once. Free function so the remote
/// coordinator (which knows `coarse_cost` from the shard handshake but
/// holds no local index) merges wire partials bit-identically to the
/// in-process path.
pub fn merge_partials_with(
    coarse_cost: usize,
    parts: Vec<(f64, EstimateWork)>,
) -> PartitionEstimate {
    let mut partials = Vec::with_capacity(parts.len());
    let mut work = EstimateWork { scanned: coarse_cost, k: 0, l: 0 };
    for (log_z_s, w) in parts {
        partials.push(log_z_s);
        work.scanned += w.scanned;
        work.k += w.k;
        work.l += w.l;
    }
    PartitionEstimate { log_z: merge_log_partials(&partials), work }
}

/// Algorithm 3 over a [`ShardedIndex`]: per-shard head+tail estimates in
/// parallel, log-sum-exp merge.
pub struct ShardedPartitionEstimator {
    /// the **global** dataset (tail rows are scored through the shard
    /// map, so no per-shard row copies need to be retained)
    ds: Arc<Dataset>,
    index: Arc<ShardedIndex>,
    backend: Arc<dyn ScoreBackend>,
    /// global head size k (split across shards by row count)
    pub k: usize,
    /// global tail sample size l (split across shards by row count)
    pub l: usize,
    seed: u64,
    round: AtomicU64,
}

impl ShardedPartitionEstimator {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<ShardedIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        l: usize,
        seed: u64,
    ) -> Self {
        let k = k.clamp(1, index.n().max(1));
        let l = l.max(1);
        ShardedPartitionEstimator { ds, index, backend, k, l, seed, round: AtomicU64::new(0) }
    }

    /// Estimate at an explicit round (replayable; distinct rounds draw
    /// independent tails).
    pub fn estimate_at(&self, q: &[f32], round: u64) -> PartitionEstimate {
        // rank the shared IVF probe structure ONCE per query (None for
        // non-IVF kinds) — every shard scans the same cluster list
        let order = self.index.coarse_order(q);
        // proportional (ε, δ)-budget split with exact largest-remainder
        // totals (Σ k_s = k, Σ l_s = l, up to the ≥1-per-shard floor)
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        // one (log Ẑ_s, work) partial per shard, in shard order — the
        // index's fan-out so `shard_parallel` governs this path too
        let parts = self.index.fan_out(|s| {
            self.shard_partial(s, q, round, k_split[s], l_split[s], order.as_deref())
        });
        self.merge_partials(parts)
    }

    /// Convenience: estimate at the next internal round.
    pub fn estimate(&self, q: &[f32]) -> PartitionEstimate {
        let r = self.round.fetch_add(1, Ordering::Relaxed);
        self.estimate_at(q, r)
    }

    /// Batched Algorithm 3 over the shards: **one fan-out for the whole
    /// batch** (each shard computes its partials for every query before
    /// any merge), query `i` served at round `r0 + i` — bit-identical to
    /// the corresponding sequence of [`estimate_at`](Self::estimate_at)
    /// calls.
    pub fn estimate_batch(&self, qs: &[&[f32]]) -> Vec<PartitionEstimate> {
        let r0 = self.round.fetch_add(qs.len() as u64, Ordering::Relaxed);
        self.estimate_batch_at(qs, r0)
    }

    /// [`estimate_batch`](Self::estimate_batch) at an explicit base round.
    pub fn estimate_batch_at(&self, qs: &[&[f32]], r0: u64) -> Vec<PartitionEstimate> {
        if qs.is_empty() {
            return Vec::new();
        }
        let orders = self.index.coarse_orders_batch(qs);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        // [shard][query] partials from a single fan-out
        let per_shard: Vec<Vec<(f64, EstimateWork)>> = self.index.fan_out(|s| {
            qs.iter()
                .enumerate()
                .map(|(i, q)| {
                    let order = orders.as_ref().map(|o| o[i].as_slice());
                    self.shard_partial(s, q, r0 + i as u64, k_split[s], l_split[s], order)
                })
                .collect()
        });
        (0..qs.len())
            .map(|i| self.merge_partials(per_shard.iter().map(|sh| sh[i]).collect()))
            .collect()
    }

    /// Log-sum-exp merge of per-shard `(log Ẑ_s, work)` partials, with
    /// the centroid-ranking work accounted once, like the sharded top_k.
    fn merge_partials(&self, parts: Vec<(f64, EstimateWork)>) -> PartitionEstimate {
        merge_partials_with(self.index.coarse_cost(), parts)
    }

    /// One shard's partial at an explicit round — the unit a remote shard
    /// server exports over the wire. Ranks the shared coarse probe order
    /// and apportions the global `(k, l)` budget internally, so the
    /// result is bit-identical to the closure the in-process fan-out
    /// would run for shard `s`.
    pub fn shard_partial_at(&self, s: usize, q: &[f32], round: u64) -> (f64, EstimateWork) {
        let order = self.index.coarse_order(q);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        self.shard_partial(s, q, round, k_split[s], l_split[s], order.as_deref())
    }

    /// Batched per-shard partials: query `i` at round `r0 + i`, coarse
    /// orders ranked once for the whole batch — matches the per-shard
    /// closure of [`estimate_batch_at`](Self::estimate_batch_at).
    pub fn shard_partials_batch_at(
        &self,
        s: usize,
        qs: &[&[f32]],
        r0: u64,
    ) -> Vec<(f64, EstimateWork)> {
        if qs.is_empty() {
            return Vec::new();
        }
        if qs.len() == 1 {
            // single-query path ranks its own coarse order, exactly like
            // the engine's unbatched route through estimate_at
            return vec![self.shard_partial_at(s, qs[0], r0)];
        }
        let orders = self.index.coarse_orders_batch(qs);
        let k_split = apportion(self.k, self.index.map());
        let l_split = apportion(self.l, self.index.map());
        qs.iter()
            .enumerate()
            .map(|(i, q)| {
                let order = orders.as_ref().map(|o| o[i].as_slice());
                self.shard_partial(s, q, r0 + i as u64, k_split[s], l_split[s], order)
            })
            .collect()
    }

    /// One shard's Algorithm 3: local top-k head (scanning the shared
    /// probe list on IVF shards), keyed uniform tail, log-space combine —
    /// an unbiased estimate of `Z_s`.
    fn shard_partial(
        &self,
        s: usize,
        q: &[f32],
        round: u64,
        k_s: usize,
        l_s: usize,
        order: Option<&[u32]>,
    ) -> (f64, EstimateWork) {
        let n_s = self.index.map().shard_len(s);
        if n_s == 0 {
            return (f64::NEG_INFINITY, EstimateWork::default());
        }
        let top = self.index.shard_top_k_local_in(s, q, k_s.clamp(1, n_s), order);
        let k_eff = top.items.len();
        let exclude: FxHashSet<u32> = top.items.iter().map(|it| it.id).collect();
        let mut rng = Pcg64::keyed(self.seed, round, SALT_ALG3_TAIL, s as u64);
        let l_eff = effective_tail_len(l_s, n_s, k_eff);
        // tail ids drawn in shard-local space (uniform over X_s \ S_s),
        // scored from the global dataset through the shard map
        let t_ids: Vec<u32> = if l_eff > 0 {
            rng.with_replacement_excluding(n_s as u64, l_eff, &exclude)
                .into_iter()
                .map(|local| self.index.map().to_global(s, local))
                .collect()
        } else {
            Vec::new()
        };
        let t_scores = crate::scorer::score_ids(&self.ds, self.backend.as_ref(), &t_ids, q);
        let mut head = MaxSumExp::default();
        for it in &top.items {
            head.push(it.score as f64);
        }
        let mut tail = MaxSumExp::default();
        tail.push_all(&t_scores);
        let log_z_s = combine_head_tail(&head, &tail, n_s, k_eff, t_ids.len());
        (
            log_z_s,
            EstimateWork { scanned: top.scanned, k: k_eff, l: t_ids.len() },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::data::synth;
    use crate::data::Dataset;
    use crate::estimator::partition::exact_log_partition;
    use crate::scorer::NativeScorer;

    fn sharded(
        ds: &Arc<Dataset>,
        shards: usize,
        backend: &Arc<dyn ScoreBackend>,
    ) -> Arc<ShardedIndex> {
        let mut cfg = Config::default().index;
        cfg.kind = IndexKind::Brute;
        cfg.shards = shards;
        Arc::new(ShardedIndex::build(ds, &cfg, backend.clone()).unwrap())
    }

    #[test]
    fn merge_log_partials_is_logsumexp() {
        let xs = [0.0f64, 1.0, -2.0];
        let want = xs.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!((merge_log_partials(&xs) - want).abs() < 1e-12);
        assert_eq!(merge_log_partials(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
        assert_eq!(merge_log_partials(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn degenerate_heads_make_the_merge_exact() {
        // k ≥ n: every shard's head covers its whole partition, so each
        // partial is its exact log Z_s and the LSE merge must equal the
        // exact global log-partition for ANY shard count — a
        // deterministic check of the Z = Σ_s Z_s decomposition.
        let ds = Arc::new(synth::imagenet_like(600, 8, 10, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let want = exact_log_partition(&ds, backend.as_ref(), &{
            let mut rng = Pcg64::new(2);
            synth::random_theta(&ds, 0.2, &mut rng)
        });
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        for shards in [1usize, 3, 7] {
            let est = ShardedPartitionEstimator::new(
                ds.clone(),
                sharded(&ds, shards, &backend),
                backend.clone(),
                ds.n,
                5,
                3,
            );
            let got = est.estimate_at(&q, 0);
            assert!(
                (got.log_z - want).abs() < 1e-5,
                "shards={shards}: {} vs {want}",
                got.log_z
            );
            assert_eq!(got.work.k, ds.n);
        }
    }

    #[test]
    fn sharded_estimate_is_unbiased() {
        // E[Ẑ] = Σ_s E[Ẑ_s] = Z: average Ẑ/Z in the linear domain
        let ds = Arc::new(synth::imagenet_like(800, 8, 10, 0.3, 4));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let est = ShardedPartitionEstimator::new(
            ds.clone(),
            sharded(&ds, 4, &backend),
            backend.clone(),
            60,
            60,
            5,
        );
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let true_log_z = exact_log_partition(&ds, backend.as_ref(), &q);
        let reps = 600u64;
        let mean_ratio: f64 = (0..reps)
            .map(|r| (est.estimate_at(&q, r).log_z - true_log_z).exp())
            .sum::<f64>()
            / reps as f64;
        assert!((mean_ratio - 1.0).abs() < 0.07, "E[Ẑ]/Z = {mean_ratio}");
    }

    #[test]
    fn rounds_replayable() {
        let ds = Arc::new(synth::imagenet_like(500, 8, 10, 0.3, 7));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let est = ShardedPartitionEstimator::new(
            ds.clone(),
            sharded(&ds, 3, &backend),
            backend.clone(),
            40,
            40,
            8,
        );
        let mut rng = Pcg64::new(9);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let a = est.estimate_at(&q, 5).log_z;
        let b = est.estimate_at(&q, 5).log_z;
        assert_eq!(a, b);
        let c = est.estimate_at(&q, 6).log_z;
        assert_ne!(a, c, "distinct rounds must draw fresh tails");
    }

    use crate::util::rng::Pcg64;
}
