//! [`ShardedGumbelSampler`] — Algorithm 1 with **frozen, id-keyed Gumbel
//! streams**, decomposed per shard.
//!
//! The plain [`LazyGumbelSampler`](crate::sampler::lazy_gumbel) draws
//! Gumbels from one sequential RNG, which ties the realized noise to the
//! iteration order. Here every random quantity is a deterministic
//! function of `(seed, draw round, global id)`:
//!
//! * each top-set element `i` gets `G_{r,i}` from its own keyed stream,
//!   so the per-shard maxima `M_s = max_{i ∈ S ∩ X_s}(y_i + G_{r,i})`
//!   depend only on shard *content* and merge by argmax:
//!   `argmax_i = argmax_s M_s`;
//! * the lazy tail is materialized per fixed-size **id block** (block
//!   size `⌈√n⌉`, independent of the shard count): each block `β` has
//!   its own keyed stream drawing `m_β ~ Binomial(live_β, 1 − F(B))`,
//!   uniform positions among the block's non-top ids, and truncated
//!   Gumbels above `B` — exactly the lazy-tail construction of
//!   [`crate::gumbel::sample_tail`], applied blockwise (a sum of
//!   per-block binomials with per-block uniform positions is the global
//!   binomial with global uniform positions).
//!
//! Since the merged top set `S`, the cutoff
//! `B = max_{i∈S}(y_i + G_{r,i}) − S_min − c`, and the block partition
//! are all shard-count invariant (the sharded index's top-k is
//! bit-identical across shard counts), the **sample itself is
//! bit-identical for `shard=1` and `shard=N`** — enforced by tests. The
//! distribution is unchanged from Algorithm 1 (Theorem 3.1: exact
//! softmax samples when `S_min + c` bounds the tail), because keying
//! streams by id only re-indexes which i.i.d. Gumbel goes where.

use super::{ShardMap, ShardedIndex};
use crate::data::Dataset;
use crate::gumbel;
use crate::mips::{MipsIndex, TopKResult};
use crate::sampler::{SampleOutcome, SampleWork, Sampler};
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Stream-salt for top-set Gumbels (`idx` = global id).
const SALT_TOP: u64 = 0x517;
/// Stream-salt for tail blocks (`idx` = block index).
const SALT_TAIL: u64 = 0x7A11;

/// Build the per-θ session state from a merged top set. Free function so
/// the remote coordinator (which holds a [`ShardMap`] but no local
/// [`ShardedIndex`]) can run the same construction bit-identically.
pub fn build_session(map: &ShardMap, n: usize, top: TopKResult) -> ShardedSession {
    let ns = map.shards();
    let mut by_shard: Vec<Vec<(u32, f64)>> = vec![Vec::new(); ns];
    for it in &top.items {
        let (s, _) = map.to_local(it.id);
        by_shard[s].push((it.id, it.score as f64));
    }
    let mut s_ids: Vec<u32> = top.items.iter().map(|s| s.id).collect();
    s_ids.sort_unstable();
    let block = (n as f64).sqrt().ceil().max(1.0) as usize;
    let nblocks = n.div_ceil(block);
    let mut live: Vec<u32> =
        (0..nblocks).map(|b| (((b + 1) * block).min(n) - b * block) as u32).collect();
    for &id in &s_ids {
        live[id as usize / block] -= 1;
    }
    ShardedSession { top, by_shard, s_ids, block, live }
}

/// Per-shard perturbed maxima over the top set `S`, merged by argmax:
/// `argmax_{i∈S}(y_i + G_{r,i})` with each `G_{r,i}` from its id-keyed
/// frozen stream. Returns `(best_id, best_value)`.
pub fn perturbed_argmax(sess: &ShardedSession, seed: u64, round: u64) -> (u32, f64) {
    debug_assert!(!sess.top.items.is_empty());
    let mut best_id = sess.top.items[0].id;
    let mut best = f64::NEG_INFINITY;
    for part in &sess.by_shard {
        // shard max M_s = max_{i ∈ S ∩ X_s} (y_i + G_{r,i})
        let mut shard_best_id = 0u32;
        let mut shard_best = f64::NEG_INFINITY;
        for &(id, y) in part {
            let g = Pcg64::keyed(seed, round, SALT_TOP, id as u64).gumbel();
            let v = y + g;
            if v > shard_best {
                shard_best = v;
                shard_best_id = id;
            }
        }
        if shard_best > best {
            best = shard_best;
            best_id = shard_best_id;
        }
    }
    (best_id, best)
}

/// Materialize the blockwise lazy tail above cutoff `b`: per-block keyed
/// streams draw `m_β ~ Binomial(live_β, 1 − F(b))`, uniform non-top
/// positions, and truncated Gumbels. Returns `(tail_ids, tail_gumbels)`
/// in matched order.
pub fn lazy_tail_draws(
    sess: &ShardedSession,
    n: usize,
    seed: u64,
    round: u64,
    b: f64,
) -> (Vec<u32>, Vec<f64>) {
    let p = gumbel::tail_prob(b);
    let mut tail_ids: Vec<u32> = Vec::new();
    let mut tail_gumbels: Vec<f64> = Vec::new();
    for (blk, &live) in sess.live.iter().enumerate() {
        if live == 0 {
            continue;
        }
        let lo = blk * sess.block;
        let hi = ((blk + 1) * sess.block).min(n);
        let mut rng = Pcg64::keyed(seed, round, SALT_TAIL, blk as u64);
        let mb = rng.binomial(live as u64, p) as usize;
        if mb == 0 {
            continue;
        }
        // block-local exclusion: top ids inside [lo, hi), rebased
        let a = sess.s_ids.partition_point(|&x| (x as usize) < lo);
        let z = sess.s_ids.partition_point(|&x| (x as usize) < hi);
        let excl: FxHashSet<u32> = sess.s_ids[a..z].iter().map(|&x| x - lo as u32).collect();
        let picks = rng.distinct_excluding((hi - lo) as u64, mb, &excl);
        for pick in picks {
            tail_ids.push(lo as u32 + pick);
        }
        for _ in 0..mb {
            tail_gumbels.push(rng.gumbel_above(b));
        }
    }
    (tail_ids, tail_gumbels)
}

/// Fold scored tail candidates into the running argmax (tail-id order, as
/// the in-process sampler does). Returns the updated `(best_id, best)`.
pub fn fold_tail(
    mut best_id: u32,
    mut best: f64,
    tail_ids: &[u32],
    tail_gumbels: &[f64],
    scores: &[f32],
) -> (u32, f64) {
    for ((&id, &g), &y) in tail_ids.iter().zip(tail_gumbels).zip(scores) {
        let v = y as f64 + g;
        if v > best {
            best = v;
            best_id = id;
        }
    }
    (best_id, best)
}

/// Algorithm 1 over a [`ShardedIndex`] with id-keyed frozen Gumbel
/// streams: per-shard perturbed maxima merged by argmax, blockwise lazy
/// tail.
pub struct ShardedGumbelSampler {
    ds: Arc<Dataset>,
    index: Arc<ShardedIndex>,
    backend: Arc<dyn ScoreBackend>,
    /// top-set size k (paper: O(√n))
    pub k: usize,
    /// approximate-MIPS gap allowance c ≥ 0
    pub gap_c: f64,
    seed: u64,
    /// next draw round (each round has its own frozen Gumbel field)
    round: AtomicU64,
}

/// Reusable per-θ state: merged top set, its per-shard partition, and the
/// tail-block bookkeeping.
pub struct ShardedSession {
    /// merged global top-k (shard-count invariant)
    pub top: TopKResult,
    /// `top.items` partitioned by owning shard (global ids kept)
    by_shard: Vec<Vec<(u32, f64)>>,
    /// sorted global ids of the top set (per-block exclusion ranges)
    s_ids: Vec<u32>,
    /// tail block size `⌈√n⌉` (shard-count invariant)
    block: usize,
    /// per block: number of non-top ids
    live: Vec<u32>,
}

impl ShardedGumbelSampler {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<ShardedIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        gap_c: f64,
        seed: u64,
    ) -> Self {
        let k = k.clamp(1, ds.n);
        ShardedGumbelSampler { ds, index, backend, k, gap_c, seed, round: AtomicU64::new(0) }
    }

    /// Open a per-θ session: one sharded MIPS retrieval, reused across
    /// every draw for this θ (the paper's "access the MIPS structure once
    /// per parameter value").
    pub fn session(&self, q: &[f32]) -> ShardedSession {
        let top = self.index.top_k(q, self.k);
        self.session_from_top(top)
    }

    /// Build the per-θ session state from an already-retrieved merged top
    /// set (the batch path retrieves all tops in one fan-out first).
    pub fn session_from_top(&self, top: TopKResult) -> ShardedSession {
        build_session(self.index.map(), self.ds.n, top)
    }

    /// Batched sampling: draw `counts[i]` samples for `qs[i]`. ONE
    /// batched sharded retrieval ([`MipsIndex::top_k_batch`], fan-out +
    /// merge shared across the whole batch) opens every session; draws
    /// then consume rounds from the internal counter exactly like
    /// [`sample_many`](Sampler::sample_many).
    pub fn sample_batch(&self, qs: &[&[f32]], counts: &[usize]) -> Vec<Vec<SampleOutcome>> {
        debug_assert_eq!(qs.len(), counts.len());
        let tops = self.index.top_k_batch(qs, self.k);
        let mut all = Vec::with_capacity(qs.len());
        for ((top, q), &count) in tops.into_iter().zip(qs).zip(counts) {
            let sess = self.session_from_top(top);
            let count = count.max(1);
            let r0 = self.round.fetch_add(count as u64, Ordering::Relaxed);
            all.push((r0..r0 + count as u64).map(|r| self.sample_at(&sess, q, r)).collect());
        }
        all
    }

    /// One draw at an explicit round index (rounds are the replayable
    /// coordinate of the frozen streams; distinct rounds are independent
    /// draws).
    pub fn sample_at(&self, sess: &ShardedSession, q: &[f32], round: u64) -> SampleOutcome {
        // ---- per-shard perturbed maxima over S, merged by argmax --------
        let (mut best_id, best) = perturbed_argmax(sess, self.seed, round);
        let b = best - sess.top.s_min() - self.gap_c;

        // ---- blockwise lazy tail ----------------------------------------
        let (tail_ids, tail_gumbels) =
            lazy_tail_draws(sess, self.ds.n, self.seed, round, b);
        let m = tail_ids.len();
        if m > 0 {
            let scores = self.score_ids(&tail_ids, q);
            (best_id, _) = fold_tail(best_id, best, &tail_ids, &tail_gumbels, &scores);
        }
        SampleOutcome {
            id: best_id,
            work: SampleWork { scanned: sess.top.scanned, k: sess.top.items.len(), m },
        }
    }

    /// Score global ids via the shared [`crate::scorer::score_ids`]
    /// fast path.
    fn score_ids(&self, ids: &[u32], q: &[f32]) -> Vec<f32> {
        crate::scorer::score_ids(&self.ds, self.backend.as_ref(), ids, q)
    }
}

impl Sampler for ShardedGumbelSampler {
    /// The `rng` parameter is unused: all randomness comes from the
    /// frozen keyed streams; the internal round counter advances per
    /// draw.
    fn sample(&self, q: &[f32], _rng: &mut Pcg64) -> SampleOutcome {
        let sess = self.session(q);
        let r = self.round.fetch_add(1, Ordering::Relaxed);
        self.sample_at(&sess, q, r)
    }

    fn sample_many(&self, q: &[f32], count: usize, _rng: &mut Pcg64) -> Vec<SampleOutcome> {
        let sess = self.session(q);
        let r0 = self.round.fetch_add(count as u64, Ordering::Relaxed);
        (r0..r0 + count as u64).map(|r| self.sample_at(&sess, q, r)).collect()
    }

    fn name(&self) -> &'static str {
        "sharded-gumbel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, IndexKind};
    use crate::data::synth;
    use crate::sampler::exact::ExactSampler;
    use crate::scorer::NativeScorer;
    use crate::util::stats::gof_ok;

    fn sharded(
        ds: &Arc<Dataset>,
        shards: usize,
        backend: &Arc<dyn ScoreBackend>,
    ) -> Arc<ShardedIndex> {
        let mut cfg = Config::default().index;
        cfg.kind = IndexKind::Brute;
        cfg.shards = shards;
        Arc::new(ShardedIndex::build(ds, &cfg, backend.clone()).unwrap())
    }

    #[test]
    fn exact_softmax_sampling_via_keyed_streams() {
        // Theorem 3.1 still holds with id-keyed frozen streams: chi-square
        // GOF against the true softmax distribution.
        let ds = Arc::new(synth::imagenet_like(300, 8, 10, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index = sharded(&ds, 3, &backend);
        let sampler =
            ShardedGumbelSampler::new(ds.clone(), index, backend.clone(), 30, 0.0, 99);
        let exact = ExactSampler::new(ds.clone(), backend);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let probs = exact.probabilities(&q);
        let total = 40_000u64;
        let mut counts = vec![0u64; ds.n];
        let sess = sampler.session(&q);
        for r in 0..total {
            counts[sampler.sample_at(&sess, &q, r).id as usize] += 1;
        }
        assert!(gof_ok(&counts, &probs, total, 5.0), "sharded sampler GOF failed");
    }

    #[test]
    fn tail_work_stays_sublinear() {
        let ds = Arc::new(synth::imagenet_like(4000, 8, 10, 0.3, 3));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index = sharded(&ds, 4, &backend);
        let k = (ds.n as f64).sqrt() as usize;
        let sampler = ShardedGumbelSampler::new(ds.clone(), index, backend, k, 0.0, 7);
        let mut rng = Pcg64::new(4);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let outs = sampler.sample_many(&q, 100, &mut rng);
        let mean_m: f64 = outs.iter().map(|o| o.work.m as f64).sum::<f64>() / 100.0;
        // Theorem 3.2 with k = √n: E[m] ≤ √n (generous slack)
        assert!(mean_m <= 2.5 * (ds.n as f64).sqrt(), "mean_m={mean_m}");
    }

    #[test]
    fn rounds_are_replayable_and_distinct() {
        let ds = Arc::new(synth::imagenet_like(500, 8, 10, 0.3, 5));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index = sharded(&ds, 2, &backend);
        let sampler = ShardedGumbelSampler::new(ds.clone(), index, backend, 25, 0.0, 11);
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let sess = sampler.session(&q);
        // same round → same sample; different rounds → fresh draws
        assert_eq!(sampler.sample_at(&sess, &q, 3).id, sampler.sample_at(&sess, &q, 3).id);
        let distinct: FxHashSet<u32> =
            (0..200).map(|r| sampler.sample_at(&sess, &q, r).id).collect();
        assert!(distinct.len() > 1, "draws must vary across rounds");
    }
}
