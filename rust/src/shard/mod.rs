//! Data-parallel sharding — the fan-out/merge layer between the MIPS
//! indexes and the engine.
//!
//! The amortization story of the paper is a serving story: preprocess
//! once, then answer a stream of `top_k(θ)` queries sublinearly. A
//! single monolithic index caps throughput at one scan's rate; this
//! module splits the database into `N` disjoint row partitions, each
//! behind its own sub-index (any [`crate::config::IndexKind`]), fans
//! queries out across the shards in parallel, and k-way-merges the
//! per-shard results ([`crate::util::topk::merge_topk`]).
//!
//! ## Why the math decomposes
//!
//! Every estimator in this system is built from quantities that are
//! **associative over a partition of the state space** `X = ⊔_s X_s`:
//!
//! * **top-k**: the global top-k of `⊔_s X_s` is the k-way merge of the
//!   per-shard top-k sets (each shard's top-k contains its members of the
//!   global top-k). With deterministic `(score, id)` tie-breaking the
//!   merge is *bit-identical* to the monolithic scan — enforced by tests
//!   for brute, IVF (shared coarse quantizer, see below) and SRP-LSH
//!   (shared norm bound).
//! * **Gumbel-max sampling** (Algorithm 1): `argmax_{i∈X}(y_i + G_i) =
//!   argmax_s [ argmax_{i∈X_s}(y_i + G_i) ]` — per-shard perturbed
//!   maxima merge by argmax. With the Gumbel stream *keyed by global id*
//!   (a frozen `G_{r,i}` per draw round `r`), the per-shard maxima are
//!   functions of shard content only, so `N = 1` and `N = k` produce the
//!   same sample ([`sampler::ShardedGumbelSampler`]).
//! * **partition function** (Algorithm 3): `Z = Σ_s Z_s`, so per-shard
//!   estimates merge by log-sum-exp:
//!   `log Ẑ = LSE_s(log Ẑ_s)` — each `Ẑ_s` unbiased for `Z_s` makes the
//!   merged `Ẑ` unbiased for `Z`
//!   ([`estimator::ShardedPartitionEstimator`]).
//! * **feature expectation** (Algorithm 4): the unnormalized moment
//!   factors the same way, `Z·μ = Σ_s Z_s·μ_s`, so per-shard
//!   `(log Ẑ_s, μ̂_s)` fragments merge by *weighted* log-sum-exp
//!   ([`expectation::ShardedExpectationEstimator`]). Estimation budgets
//!   `k`/`l` split across shards by [`apportion`] (largest remainder —
//!   global totals preserved exactly, up to a floor of one per shard).
//!
//! ## Shard-count invariance
//!
//! Two per-kind ingredients make `shard=N` bit-identical to `shard=1`:
//!
//! * **IVF**: the coarse quantizer is trained once on the *global*
//!   dataset ([`crate::mips::ivf::train_coarse`]) and shared by every
//!   shard; the shard layer ranks probes once per query and fans the
//!   same cluster list out, so the per-shard probed rows union to
//!   exactly the monolithic probed rows (and the centroid-ranking work
//!   is accounted once).
//! * **SRP-LSH**: the Neyshabur–Srebro norm bound `M² = max‖v‖²` is
//!   computed on the global dataset and shared, and the projection
//!   planes are seed-derived (data-independent) — so every row hashes to
//!   the same buckets it would in the monolithic index.
//!
//! Tiered LSH shards too, but its ladder walk stops on a shard-local
//! candidate count, so it is *approximate* under sharding (per-shard
//! gap bounds merge by max) — exactly like the monolithic ladder is
//! approximate; no parity is claimed or tested for it.
//!
//! Row partitions come in two strategies
//! ([`crate::config::ShardStrategy`]): round-robin (`shard = id mod N`)
//! and balanced contiguous ranges. [`ShardMap`] owns the global-id ↔
//! `(shard, local-id)` bijection; both directions are cheap (O(1)
//! arithmetic for round-robin, O(log N) bound search for contiguous)
//! and monotone in the local id, which is what preserves tie-breaking
//! under the merge.

pub mod estimator;
pub mod expectation;
pub mod index;
pub mod sampler;

pub use estimator::ShardedPartitionEstimator;
pub use expectation::ShardedExpectationEstimator;
pub use index::ShardedIndex;
pub use sampler::ShardedGumbelSampler;

use crate::config::ShardStrategy;
use crate::data::Dataset;

/// The global-id ↔ (shard, local-id) bijection for a row partition.
#[derive(Clone, Debug)]
pub struct ShardMap {
    n: usize,
    shards: usize,
    strategy: ShardStrategy,
    /// contiguous strategy: shard `s` owns global ids
    /// `bounds[s] .. bounds[s+1]` (balanced `⌊s·n/N⌋` splits)
    bounds: Vec<usize>,
}

impl ShardMap {
    /// Partition `[0, n)` into `shards` parts (clamped to `[1, n]` so no
    /// shard is empty).
    pub fn new(n: usize, shards: usize, strategy: ShardStrategy) -> ShardMap {
        let shards = shards.clamp(1, n.max(1));
        let mut bounds = vec![0usize; shards + 1];
        for (s, b) in bounds.iter_mut().enumerate() {
            *b = s * n / shards;
        }
        bounds[shards] = n;
        ShardMap { n, shards, strategy, bounds }
    }

    /// Total number of rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    /// Rows owned by shard `s`.
    pub fn shard_len(&self, s: usize) -> usize {
        debug_assert!(s < self.shards);
        match self.strategy {
            // |{ i < n : i ≡ s (mod N) }| = ⌈(n − s)/N⌉
            ShardStrategy::RoundRobin => (self.n + self.shards - 1 - s) / self.shards,
            ShardStrategy::Contiguous => self.bounds[s + 1] - self.bounds[s],
        }
    }

    /// Global id → (shard, local id).
    #[inline]
    pub fn to_local(&self, gid: u32) -> (usize, u32) {
        debug_assert!((gid as usize) < self.n);
        match self.strategy {
            ShardStrategy::RoundRobin => {
                let s = gid as usize % self.shards;
                (s, gid / self.shards as u32)
            }
            ShardStrategy::Contiguous => {
                let s = self.bounds.partition_point(|&b| b <= gid as usize) - 1;
                (s, gid - self.bounds[s] as u32)
            }
        }
    }

    /// (shard, local id) → global id. Strictly increasing in `local` for
    /// both strategies — per-shard `(score, local-id)` tie-breaking
    /// therefore agrees with global `(score, global-id)` tie-breaking,
    /// which the bit-parity of the sharded merge relies on.
    #[inline]
    pub fn to_global(&self, s: usize, local: u32) -> u32 {
        debug_assert!(s < self.shards);
        match self.strategy {
            ShardStrategy::RoundRobin => local * self.shards as u32 + s as u32,
            ShardStrategy::Contiguous => self.bounds[s] as u32 + local,
        }
    }

    /// Materialize the per-shard datasets (row `l` of shard `s` is global
    /// row `to_global(s, l)`; labels travel along).
    pub fn split(&self, ds: &Dataset) -> Vec<Dataset> {
        let d = ds.d;
        (0..self.shards)
            .map(|s| {
                let len = self.shard_len(s);
                let mut data = Vec::with_capacity(len * d);
                let mut labels = Vec::with_capacity(if ds.labels.is_empty() { 0 } else { len });
                for l in 0..len {
                    let g = self.to_global(s, l as u32) as usize;
                    data.extend_from_slice(ds.row(g));
                    if !ds.labels.is_empty() {
                        labels.push(ds.labels[g]);
                    }
                }
                let mut shard = Dataset::new(data, len, d).expect("shard split sizes are exact");
                shard.labels = labels;
                shard
            })
            .collect()
    }
}

/// Split a global sample budget (the estimators' `k` or `l`) across the
/// row partition: every non-empty shard gets a floor of 1 (so its
/// per-shard head/tail estimator stays well-formed), and the residual
/// `total − #non-empty` is apportioned proportionally to shard size by
/// **largest remainder** (Hamilton's method) — shard `s` gets
/// `⌊R·n_s/n⌋` plus one of the `R − Σ⌊·⌋` leftover units, awarded in
/// decreasing fractional-remainder order (ties to the lower shard id,
/// so the split is deterministic).
///
/// Unlike the previous per-shard `div_ceil` / `floor+max(1)` rounding —
/// whose sum could drift `O(#shards)` above the global budget — the
/// totals here are exact: `Σ_s quota_s = total` whenever
/// `total ≥ #non-empty shards`, and `= #non-empty shards` below that
/// (the floor is the only source of excess, and it is what keeps every
/// shard's estimate defined).
pub fn apportion(total: usize, map: &ShardMap) -> Vec<usize> {
    let n = map.n();
    let ns = map.shards();
    // floor: every non-empty shard serves ≥ 1 so its estimator stays
    // well-formed
    let mut quota: Vec<usize> =
        (0..ns).map(|s| usize::from(map.shard_len(s) > 0)).collect();
    let nonempty: usize = quota.iter().sum();
    let residual = total.saturating_sub(nonempty);
    if n == 0 || residual == 0 {
        return quota;
    }
    let mut assigned = 0usize;
    // (remainder, shard) — `residual·n_s` fits u128 far beyond any n
    let mut rems: Vec<(u128, usize)> = Vec::with_capacity(ns);
    for (s, q) in quota.iter_mut().enumerate() {
        let exact = residual as u128 * map.shard_len(s) as u128;
        let share = (exact / n as u128) as usize;
        *q += share;
        assigned += share;
        rems.push((exact % n as u128, s));
    }
    rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    for &(_, s) in rems.iter().take(residual - assigned) {
        quota[s] += 1;
    }
    debug_assert_eq!(
        quota.iter().sum::<usize>(),
        nonempty + residual,
        "apportion must preserve the global budget"
    );
    quota
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Pcg64;

    #[test]
    fn map_is_a_bijection_for_both_strategies() {
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            for (n, shards) in [(1usize, 1usize), (10, 3), (1000, 7), (5, 8), (64, 64)] {
                let map = ShardMap::new(n, shards, strategy);
                assert!(map.shards() >= 1 && map.shards() <= n);
                let total: usize = (0..map.shards()).map(|s| map.shard_len(s)).sum();
                assert_eq!(total, n, "{strategy:?} n={n} shards={shards}");
                let mut seen = vec![false; n];
                for s in 0..map.shards() {
                    for l in 0..map.shard_len(s) {
                        let g = map.to_global(s, l as u32);
                        assert!(!seen[g as usize], "{strategy:?}: duplicate gid {g}");
                        seen[g as usize] = true;
                        assert_eq!(map.to_local(g), (s, l as u32), "{strategy:?}");
                    }
                }
                assert!(seen.iter().all(|&x| x), "{strategy:?}: rows missing");
            }
        }
    }

    #[test]
    fn to_global_is_monotone_in_local() {
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            let map = ShardMap::new(101, 4, strategy);
            for s in 0..map.shards() {
                let len = map.shard_len(s);
                for l in 1..len {
                    assert!(
                        map.to_global(s, l as u32) > map.to_global(s, l as u32 - 1),
                        "{strategy:?} shard {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn split_preserves_rows_and_labels() {
        let ds = synth::imagenet_like(300, 8, 5, 0.3, 3);
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            let map = ShardMap::new(ds.n, 4, strategy);
            let parts = map.split(&ds);
            assert_eq!(parts.len(), 4);
            for (s, part) in parts.iter().enumerate() {
                assert_eq!(part.n, map.shard_len(s));
                assert_eq!(part.d, ds.d);
                for l in 0..part.n {
                    let g = map.to_global(s, l as u32) as usize;
                    assert_eq!(part.row(l), ds.row(g), "{strategy:?} shard {s} row {l}");
                    if !ds.labels.is_empty() {
                        assert_eq!(part.labels[l], ds.labels[g]);
                    }
                }
            }
        }
    }

    #[test]
    fn shards_clamped_to_n() {
        let map = ShardMap::new(3, 10, ShardStrategy::RoundRobin);
        assert_eq!(map.shards(), 3);
        for s in 0..3 {
            assert_eq!(map.shard_len(s), 1);
        }
        // n = 0 stays well-formed (no shard, no rows — build paths never
        // construct this, but the map must not panic)
        let map = ShardMap::new(0, 4, ShardStrategy::Contiguous);
        assert_eq!(map.shards(), 1);
        assert_eq!(map.shard_len(0), 0);
    }

    #[test]
    fn apportion_preserves_totals() {
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            for (n, shards) in [(100usize, 3usize), (1000, 7), (97, 13), (64, 64), (5, 8)] {
                let map = ShardMap::new(n, shards, strategy);
                for total in [1usize, 2, 5, 40, 97, n, 3 * n] {
                    let q = apportion(total, &map);
                    let sum: usize = q.iter().sum();
                    let want = total.max(map.shards());
                    assert_eq!(sum, want, "{strategy:?} n={n} N={shards} total={total}");
                    for (s, &qs) in q.iter().enumerate() {
                        assert!(qs >= 1, "shard {s} starved");
                        // proportional up to the ±1 remainder unit + floor
                        let exact = total as f64 * map.shard_len(s) as f64 / n as f64;
                        assert!(
                            (qs as f64 - exact).abs() <= 2.0,
                            "{strategy:?} shard {s}: quota {qs} vs exact share {exact}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn apportion_beats_divceil_drift() {
        // the bug this replaces: ⌈k·n_s/n⌉ per shard overshoots by up to
        // one per shard — with 64 shards and k=70 that's nearly 2×
        let map = ShardMap::new(640, 64, ShardStrategy::RoundRobin);
        let k = 70usize;
        let divceil: usize = (0..64).map(|s| (k * map.shard_len(s)).div_ceil(640)).sum();
        assert!(divceil > k + 30, "premise: div_ceil drifts ({divceil})");
        let sum: usize = apportion(k, &map).iter().sum();
        assert_eq!(sum, k);
    }

    #[test]
    fn random_gids_roundtrip() {
        let mut rng = Pcg64::new(7);
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            let map = ShardMap::new(12345, 11, strategy);
            for _ in 0..2000 {
                let g = rng.next_below(12345) as u32;
                let (s, l) = map.to_local(g);
                assert_eq!(map.to_global(s, l), g, "{strategy:?}");
            }
        }
    }
}
