//! [`ShardedIndex`] — `N` sub-indexes behind one [`MipsIndex`]: parallel
//! per-shard fan-out, k-way merge, global-id mapping, and per-query
//! `scanned` accounting that matches the monolithic index exactly.
//!
//! See the [module docs](crate::shard) for the decomposition math and
//! the per-kind ingredients (shared IVF coarse quantizer, shared LSH
//! norm bound) that make `shard=N` bit-identical to `shard=1` on
//! brute/IVF/LSH.

use super::ShardMap;
use crate::config::{IndexConfig, IndexKind, ShardStrategy};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::mips::brute::BruteForce;
use crate::mips::ivf::{self, IvfIndex};
use crate::mips::kmeans::Kmeans;
use crate::mips::lsh::{self, SrpLsh};
use crate::mips::tiered::TieredLsh;
use crate::mips::{MipsIndex, TopKResult};
use crate::scorer::ScoreBackend;
use crate::store::format::{sec_arg, tag, ByteWriter, Snapshot, SnapshotWriter, SHARED_SHARD};
use crate::util::pool;
use crate::util::topk::{merge_topk, Scored};
use std::sync::Arc;

/// One shard's sub-index (concrete, so sparse updates can route through
/// without trait-object downcasting).
enum SubIndex {
    Brute(BruteForce),
    Ivf(IvfIndex),
    Lsh(SrpLsh),
    Tiered(TieredLsh),
}

impl SubIndex {
    fn as_dyn(&self) -> &dyn MipsIndex {
        match self {
            SubIndex::Brute(i) => i,
            SubIndex::Ivf(i) => i,
            SubIndex::Lsh(i) => i,
            SubIndex::Tiered(i) => i,
        }
    }
}

/// Shared IVF probe structure: the globally trained coarse quantizer the
/// shard layer ranks against (once per query), plus the resolved probe
/// count.
struct CoarseProbe {
    km: Kmeans,
    n_probe: usize,
}

/// A [`MipsIndex`] over `N` disjoint row partitions, each behind its own
/// sub-index of the configured kind.
pub struct ShardedIndex {
    map: ShardMap,
    shards: Vec<SubIndex>,
    /// IVF only: rank probes once per query, fan the cluster list out
    coarse: Option<CoarseProbe>,
    parallel: bool,
    kind: IndexKind,
    n: usize,
    d: usize,
    /// merged gap bound (max over shards; None for heuristic kinds)
    gap: Option<f64>,
}

impl ShardedIndex {
    /// Partition `ds` per `cfg.shard_strategy` into `cfg.shards` parts
    /// (clamped to `[1, n]`) and build one sub-index of `cfg.kind` per
    /// part. IVF shards share a coarse quantizer trained on the global
    /// dataset; SRP-LSH shards share the global norm bound.
    pub fn build(
        ds: &Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
    ) -> Result<ShardedIndex> {
        let map = ShardMap::new(ds.n, cfg.shards, cfg.shard_strategy);
        // per-shard row copies: brute/LSH/tiered sub-indexes keep the Arc
        // themselves; IVF re-copies rows into its grouped storage and the
        // Arcs drop at the end of this function, so a sharded IVF engine
        // holds the same two data copies the monolithic one does
        let shard_ds: Vec<Arc<Dataset>> =
            map.split(ds).into_iter().map(Arc::new).collect();
        let mut shards = Vec::with_capacity(map.shards());
        let mut coarse = None;
        match cfg.kind {
            IndexKind::Brute => {
                for sd in &shard_ds {
                    let mut idx = BruteForce::new(sd.clone(), backend.clone());
                    if cfg.quant.enabled() {
                        idx = idx.with_tier_cfg(cfg);
                    }
                    shards.push(SubIndex::Brute(idx));
                }
            }
            IndexKind::Ivf => {
                let (n_clusters, n_probe) = ivf::resolve_sizes(cfg, ds.n);
                let km = ivf::train_coarse(ds, cfg, n_clusters);
                for sd in &shard_ds {
                    shards.push(SubIndex::Ivf(IvfIndex::build_with_kmeans(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        km.clone(),
                        n_probe,
                    )));
                }
                coarse = Some(CoarseProbe { km, n_probe });
            }
            IndexKind::Lsh => {
                let m2 = lsh::max_sq_norm(ds);
                for sd in &shard_ds {
                    shards.push(SubIndex::Lsh(SrpLsh::build_scaled(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        Some(m2),
                    )?));
                }
            }
            IndexKind::Tiered => {
                for sd in &shard_ds {
                    shards.push(SubIndex::Tiered(TieredLsh::build(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                    )?));
                }
            }
        }
        let gap = match cfg.kind {
            IndexKind::Brute => Some(0.0),
            IndexKind::Tiered => Some(
                shards
                    .iter()
                    .map(|s| s.as_dyn().gap_bound().unwrap_or(0.0))
                    .fold(0.0, f64::max),
            ),
            _ => None,
        };
        Ok(ShardedIndex {
            map,
            shards,
            coarse,
            parallel: cfg.shard_parallel,
            kind: cfg.kind,
            n: ds.n,
            d: ds.d,
            gap,
        })
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    // ---- snapshot persistence ------------------------------------------

    /// Write every shard's sections plus the shared structure: partition
    /// shape + merged gap under `SHARD_META`, the shared IVF coarse
    /// quantizer exactly once (at the `SHARED_SHARD` slot — per-shard IVF
    /// bodies deliberately skip their own copy), and one section group
    /// per shard under its shard id. A `shard-serve --shard-id S`
    /// process opens the same file and reads only shard `S`'s group.
    pub fn save_sections_all(&self, w: &mut SnapshotWriter) -> Result<()> {
        if self.shards.len() >= SHARED_SHARD as usize {
            return Err(Error::index(format!(
                "cannot snapshot {} shards: the section id space caps at {}",
                self.shards.len(),
                SHARED_SHARD - 1
            )));
        }
        let mut m = ByteWriter::default();
        m.u64(self.n as u64);
        m.u64(self.shards.len() as u64);
        m.u8(match self.map.strategy() {
            ShardStrategy::RoundRobin => 0,
            ShardStrategy::Contiguous => 1,
        });
        match self.gap {
            Some(g) => {
                m.u8(1);
                m.f64(g);
            }
            None => {
                m.u8(0);
                m.f64(0.0);
            }
        }
        m.u8(self.coarse.is_some() as u8);
        w.section(tag::SHARD_META, sec_arg(SHARED_SHARD, 0), m.bytes())?;
        if let Some(cp) = &self.coarse {
            crate::store::write_kmeans(w, sec_arg(SHARED_SHARD, 0), &cp.km)?;
        }
        for (s, sub) in self.shards.iter().enumerate() {
            let shard = s as u32;
            match sub {
                SubIndex::Brute(i) => i.save_sections(w, shard)?,
                SubIndex::Ivf(i) => i.save_body(w, shard)?,
                SubIndex::Lsh(i) => i.save_sections(w, shard)?,
                SubIndex::Tiered(i) => i.save_sections(w, shard)?,
            }
        }
        Ok(())
    }

    /// Rebuild the full sharded index from a snapshot written by
    /// [`save_sections_all`](Self::save_sections_all). The partition is
    /// re-derived from the config and cross-checked against the stored
    /// shape (the fingerprint already pins `shards`/`shard_strategy`, so
    /// a mismatch here means corruption, not misconfiguration). Shard
    /// datasets are re-split from the global rows; per-shard structures
    /// open from their own section groups, IVF shards sharing the single
    /// stored coarse quantizer exactly as the build path shares it.
    pub fn open_from(
        snap: &Snapshot,
        ds: &Arc<Dataset>,
        cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        degraded: &mut bool,
    ) -> Result<ShardedIndex> {
        let bad = |why: &str| {
            Error::data(format!("snapshot {}: shard map is inconsistent: {why}", snap.path()))
        };
        let mut r = snap.reader(tag::SHARD_META, sec_arg(SHARED_SHARD, 0))?;
        let n = r.usize()?;
        let n_shards = r.usize()?;
        let strategy = match r.u8()? {
            0 => ShardStrategy::RoundRobin,
            1 => ShardStrategy::Contiguous,
            _ => return Err(bad("unknown shard strategy")),
        };
        let has_gap = r.u8()? != 0;
        let gap_value = r.f64()?;
        let has_coarse = r.u8()? != 0;

        let map = ShardMap::new(ds.n, cfg.shards, cfg.shard_strategy);
        if n != ds.n || n_shards != map.shards() || strategy != cfg.shard_strategy {
            return Err(bad("stored partition does not match the configured one"));
        }
        let shard_ds: Vec<Arc<Dataset>> = map.split(ds).into_iter().map(Arc::new).collect();

        let mut coarse = None;
        let mut shards = Vec::with_capacity(map.shards());
        match cfg.kind {
            IndexKind::Brute => {
                for (s, sd) in shard_ds.iter().enumerate() {
                    shards.push(SubIndex::Brute(BruteForce::open_from(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        snap,
                        s as u32,
                        degraded,
                    )?));
                }
            }
            IndexKind::Ivf => {
                if !has_coarse {
                    return Err(bad("IVF shards need the shared coarse quantizer section"));
                }
                let km = crate::store::read_kmeans(snap, sec_arg(SHARED_SHARD, 0))?;
                let (_, n_probe) = ivf::resolve_sizes(cfg, ds.n);
                for (s, sd) in shard_ds.iter().enumerate() {
                    shards.push(SubIndex::Ivf(IvfIndex::open_shard(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        snap,
                        km.clone(),
                        n_probe,
                        s as u32,
                        degraded,
                    )?));
                }
                let n_probe = n_probe.clamp(1, km.c);
                coarse = Some(CoarseProbe { km, n_probe });
            }
            IndexKind::Lsh => {
                for (s, sd) in shard_ds.iter().enumerate() {
                    shards.push(SubIndex::Lsh(SrpLsh::open_from(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        snap,
                        s as u32,
                        degraded,
                    )?));
                }
            }
            IndexKind::Tiered => {
                for (s, sd) in shard_ds.iter().enumerate() {
                    shards.push(SubIndex::Tiered(TieredLsh::open_from(
                        sd.clone(),
                        cfg,
                        backend.clone(),
                        snap,
                        s as u32,
                        degraded,
                    )?));
                }
            }
        }
        Ok(ShardedIndex {
            map,
            shards,
            coarse,
            parallel: cfg.shard_parallel,
            kind: cfg.kind,
            n: ds.n,
            d: ds.d,
            gap: has_gap.then_some(gap_value),
        })
    }

    /// The row partition.
    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    /// Fan a per-shard closure out across the shards — parallel over
    /// scoped pool threads when `shard_parallel` is set (and there is
    /// more than one shard), sequential otherwise. Results come back in
    /// shard order either way. The sharded sampler/estimator reuse this
    /// so the `shard_parallel` knob governs every sharded entry point.
    pub(crate) fn fan_out<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let ns = self.shards.len();
        let nthreads = if self.parallel { pool::default_threads().min(ns) } else { 1 };
        let parts = pool::parallel_chunks(ns, nthreads, |_, s, e| {
            (s..e).map(&f).collect::<Vec<T>>()
        });
        parts.into_iter().flatten().collect()
    }

    /// Merge per-shard results (shard-local ids) into the global top-k:
    /// map ids through [`ShardMap::to_global`], k-way merge with the
    /// deterministic `(score, id)` tie-break, sum the `scanned` work.
    fn merge(&self, parts: Vec<TopKResult>, k: usize) -> TopKResult {
        let kk = k.min(self.n).max(1);
        let scanned = parts.iter().map(|r| r.scanned).sum();
        let frags = parts.into_iter().enumerate().map(|(s, r)| {
            r.items
                .into_iter()
                .map(|it| Scored { id: self.map.to_global(s, it.id), score: it.score })
                .collect::<Vec<Scored>>()
        });
        TopKResult { items: merge_topk(frags, kk).into_sorted(), scanned }
    }

    /// The shared probe ranking for `q` (`None` for non-IVF kinds). The
    /// sharded estimator ranks once per query and hands the list to every
    /// shard through [`shard_top_k_local_in`](Self::shard_top_k_local_in)
    /// — the same rank-once discipline [`top_k`](MipsIndex::top_k) uses.
    pub fn coarse_order(&self, q: &[f32]) -> Option<Vec<u32>> {
        self.coarse
            .as_ref()
            .map(|cp| ivf::rank_clusters(&cp.km, q, cp.n_probe.clamp(1, cp.km.c)))
    }

    /// Centroid-ranking work behind [`coarse_order`](Self::coarse_order)
    /// (0 for non-IVF kinds) — callers account it once per query.
    pub fn coarse_cost(&self) -> usize {
        self.coarse.as_ref().map(|cp| cp.km.c).unwrap_or(0)
    }

    /// Per-shard top-k in **shard-local** id space (what the sharded
    /// estimator decomposes over). IVF shards scan the given shared probe
    /// list; `scanned` counts scored rows only — centroid work is the
    /// caller's, via [`coarse_cost`](Self::coarse_cost).
    pub fn shard_top_k_local_in(
        &self,
        s: usize,
        q: &[f32],
        k: usize,
        order: Option<&[u32]>,
    ) -> TopKResult {
        match (order, &self.shards[s]) {
            (Some(ord), SubIndex::Ivf(idx)) => idx.top_k_clusters(q, k, ord),
            (_, sub) => sub.as_dyn().top_k(q, k),
        }
    }

    /// Route a sparse row update to its shard (IVF shards only, matching
    /// the monolithic [`IvfIndex::update_row`]): the global id maps to
    /// `(shard, local)` and the shard's tombstone/pending machinery takes
    /// over.
    ///
    /// # Panics
    /// If the sub-indexes are not IVF.
    pub fn update_row(&mut self, gid: u32, new_vec: &[f32]) {
        debug_assert_eq!(new_vec.len(), self.d);
        let (s, local) = self.map.to_local(gid);
        match &mut self.shards[s] {
            SubIndex::Ivf(idx) => idx.update_row(local, new_vec),
            _ => panic!("update_row requires ivf shards (kind = {})", self.kind.name()),
        }
    }

    /// Total rows awaiting compaction across shards.
    pub fn pending_len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| match s {
                SubIndex::Ivf(idx) => idx.pending_len(),
                _ => 0,
            })
            .sum()
    }

    /// Compact every IVF shard (fold pending updates back into
    /// cluster-contiguous storage; no-op for other kinds).
    pub fn compact(&mut self) {
        for s in &mut self.shards {
            if let SubIndex::Ivf(idx) = s {
                idx.compact();
            }
        }
    }

    /// The per-query shared probe rankings for a batch (`None` for
    /// non-IVF kinds) — the batch analogue of
    /// [`coarse_order`](Self::coarse_order). The sharded estimators rank
    /// once per batch and hand each shard its per-query cluster lists.
    pub(crate) fn coarse_orders_batch(&self, qs: &[&[f32]]) -> Option<Vec<Vec<u32>>> {
        self.coarse
            .as_ref()
            .map(|cp| ivf::rank_clusters_batch(&cp.km, qs, cp.n_probe.clamp(1, cp.km.c)))
    }

    /// One shard's answers to a whole query batch, in **shard-local** id
    /// space — the per-shard closure of
    /// [`top_k_batch`](MipsIndex::top_k_batch) as a standalone entry
    /// point (what a remote shard server runs). Centroid ranking is
    /// shared per batch; `scanned` counts scored rows only, matching the
    /// in-process fan-out exactly.
    pub fn shard_top_k_batch(&self, s: usize, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        if qs.len() <= 1 {
            return qs
                .iter()
                .map(|q| {
                    let order = self.coarse_order(q);
                    self.shard_top_k_local_in(s, q, k, order.as_deref())
                })
                .collect();
        }
        match (self.coarse_orders_batch(qs), &self.shards[s]) {
            (Some(ords), SubIndex::Ivf(idx)) => idx.scan_clusters_batch(qs, k, &ords),
            (_, sub) => sub.as_dyn().top_k_batch(qs, k),
        }
    }
}

impl MipsIndex for ShardedIndex {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        // rank probes ONCE against the shared centroids (IVF); every
        // shard scans its members of the same cluster list
        let order = self.coarse_order(q);
        let per_shard = self.fan_out(|s| self.shard_top_k_local_in(s, q, k, order.as_deref()));
        let mut merged = self.merge(per_shard, k);
        merged.scanned += self.coarse_cost(); // centroid ranking, counted once
        merged
    }

    /// Batched fan-out: every shard answers the whole batch with its own
    /// batch-aware scan (merged probe scans, candidate-union gathers),
    /// then results merge per query. Per-query results are exactly what
    /// per-query [`top_k`](MipsIndex::top_k) calls would return.
    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        let nq = qs.len();
        if nq <= 1 {
            return qs.iter().map(|q| self.top_k(q, k)).collect();
        }
        let orders = self.coarse_orders_batch(qs);
        let per_shard: Vec<Vec<TopKResult>> = match &orders {
            Some(ords) => self.fan_out(|s| match &self.shards[s] {
                SubIndex::Ivf(idx) => idx.scan_clusters_batch(qs, k, ords),
                _ => unreachable!("coarse orders imply ivf shards"),
            }),
            None => self.fan_out(|s| self.shards[s].as_dyn().top_k_batch(qs, k)),
        };
        // transpose by value: each per-shard result is consumed exactly
        // once, no fragment cloning on the batched hot path
        let mut iters: Vec<std::vec::IntoIter<TopKResult>> =
            per_shard.into_iter().map(|v| v.into_iter()).collect();
        (0..nq)
            .map(|_| {
                let parts: Vec<TopKResult> = iters
                    .iter_mut()
                    .map(|it| it.next().expect("each shard answers every query"))
                    .collect();
                let mut merged = self.merge(parts, k);
                merged.scanned += self.coarse_cost();
                merged
            })
            .collect()
    }

    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn gap_bound(&self) -> Option<f64> {
        self.gap
    }

    fn name(&self) -> &'static str {
        "sharded"
    }

    fn describe(&self) -> String {
        format!(
            "sharded[{}×{}, {}{}] over n={} d={}: {}",
            self.shards.len(),
            self.kind.name(),
            self.map.strategy().name(),
            if self.parallel { ", parallel" } else { "" },
            self.n,
            self.d,
            self.shards
                .first()
                .map(|s| s.as_dyn().describe())
                .unwrap_or_default()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, ShardStrategy};
    use crate::data::synth;
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;

    fn cfg(kind: IndexKind, shards: usize) -> IndexConfig {
        let mut c = Config::default().index;
        c.kind = kind;
        c.shards = shards;
        c.n_clusters = 32;
        c.n_probe = 6;
        c.kmeans_iters = 4;
        c.train_sample = 1500;
        c.tables = 8;
        c.bits = 7;
        c.rungs = 6;
        c
    }

    #[test]
    fn sharded_brute_equals_monolithic() {
        let ds = Arc::new(synth::imagenet_like(2000, 12, 20, 0.3, 1));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mono = BruteForce::new(ds.clone(), backend.clone());
        let mut rng = Pcg64::new(2);
        for strategy in [ShardStrategy::RoundRobin, ShardStrategy::Contiguous] {
            let mut c = cfg(IndexKind::Brute, 3);
            c.shard_strategy = strategy;
            let sharded = ShardedIndex::build(&ds, &c, backend.clone()).unwrap();
            assert_eq!(sharded.n_shards(), 3);
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let got = sharded.top_k(&q, 25);
            let want = mono.top_k(&q, 25);
            assert_eq!(got.ids(), want.ids(), "{strategy:?}");
            for (g, w) in got.items.iter().zip(&want.items) {
                assert_eq!(g.score, w.score, "{strategy:?}");
            }
            assert_eq!(got.scanned, want.scanned, "{strategy:?}");
        }
    }

    #[test]
    fn fan_out_parallel_and_sequential_agree() {
        let ds = Arc::new(synth::imagenet_like(1500, 8, 10, 0.3, 4));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let mut cp = cfg(IndexKind::Ivf, 4);
        cp.shard_parallel = true;
        let mut cs = cfg(IndexKind::Ivf, 4);
        cs.shard_parallel = false;
        let a = ShardedIndex::build(&ds, &cp, backend.clone()).unwrap();
        let b = ShardedIndex::build(&ds, &cs, backend).unwrap();
        let mut rng = Pcg64::new(5);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let ra = a.top_k(&q, 30);
        let rb = b.top_k(&q, 30);
        assert_eq!(ra.ids(), rb.ids());
        assert_eq!(ra.scanned, rb.scanned);
        assert!(a.describe().contains("sharded[4×ivf"));
    }

    #[test]
    fn k_larger_than_shard_sizes() {
        // k exceeding every shard's row count must still return the
        // global top-k (clamped to n)
        let ds = Arc::new(synth::uniform_sphere(40, 4, 6));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let sharded = ShardedIndex::build(&ds, &cfg(IndexKind::Brute, 8), backend.clone()).unwrap();
        let mono = BruteForce::new(ds.clone(), backend);
        let q = [1.0f32, 0.0, 0.0, 0.0];
        let got = sharded.top_k(&q, 100);
        let want = mono.top_k(&q, 100);
        assert_eq!(got.items.len(), 40);
        assert_eq!(got.ids(), want.ids());
    }

    #[test]
    fn gap_bound_per_kind() {
        let ds = Arc::new(synth::imagenet_like(1200, 8, 10, 0.3, 7));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let b = ShardedIndex::build(&ds, &cfg(IndexKind::Brute, 2), backend.clone()).unwrap();
        assert_eq!(b.gap_bound(), Some(0.0));
        let i = ShardedIndex::build(&ds, &cfg(IndexKind::Ivf, 2), backend.clone()).unwrap();
        assert_eq!(i.gap_bound(), None);
        let t = ShardedIndex::build(&ds, &cfg(IndexKind::Tiered, 2), backend).unwrap();
        assert!(t.gap_bound().unwrap() >= 0.0);
        assert_eq!(t.name(), "sharded");
    }
}
