//! Crate-wide error type.
//!
//! Every fallible public API in `gmips` returns [`Result<T>`](Result) with
//! this [`Error`] enum. Variants are grouped by subsystem so callers can
//! match on the failure domain (config vs. data vs. runtime vs. protocol).

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors produced by the gmips library.
#[derive(Error, Debug)]
pub enum Error {
    /// I/O failure (dataset files, artifact files, sockets).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Malformed configuration (TOML parse error, bad value, missing key).
    #[error("config error: {0}")]
    Config(String),

    /// Malformed or inconsistent dataset (bad magic, shape mismatch).
    #[error("data error: {0}")]
    Data(String),

    /// JSON parse/serialize failure (manifest, wire protocol).
    #[error("json error: {0}")]
    Json(String),

    /// CLI argument error.
    #[error("cli error: {0}")]
    Cli(String),

    /// MIPS index construction/query failure.
    #[error("index error: {0}")]
    Index(String),

    /// XLA/PJRT runtime failure (artifact load, compile, execute).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Sampler/estimator precondition violation (e.g. k >= n).
    #[error("inference error: {0}")]
    Inference(String),

    /// Learner failure (divergence, bad hyperparameters).
    #[error("learn error: {0}")]
    Learn(String),

    /// Coordinator/server failure (queue closed, protocol violation).
    #[error("serve error: {0}")]
    Serve(String),
}

impl Error {
    /// Shorthand constructor used throughout the crate.
    pub fn config<S: Into<String>>(s: S) -> Self {
        Error::Config(s.into())
    }
    /// Shorthand constructor.
    pub fn data<S: Into<String>>(s: S) -> Self {
        Error::Data(s.into())
    }
    /// Shorthand constructor.
    pub fn json<S: Into<String>>(s: S) -> Self {
        Error::Json(s.into())
    }
    /// Shorthand constructor.
    pub fn index<S: Into<String>>(s: S) -> Self {
        Error::Index(s.into())
    }
    /// Shorthand constructor.
    pub fn runtime<S: Into<String>>(s: S) -> Self {
        Error::Runtime(s.into())
    }
    /// Shorthand constructor.
    pub fn inference<S: Into<String>>(s: S) -> Self {
        Error::Inference(s.into())
    }
    /// Shorthand constructor.
    pub fn serve<S: Into<String>>(s: S) -> Self {
        Error::Serve(s.into())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = Error::config("missing [data] section");
        assert!(e.to_string().contains("config error"));
        let e = Error::runtime("no artifacts");
        assert!(e.to_string().contains("runtime error"));
    }

    #[test]
    fn io_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
