//! Crate-wide error type.
//!
//! Every fallible public API in `gmips` returns [`Result<T>`](Result) with
//! this [`Error`] enum. Variants are grouped by subsystem so callers can
//! match on the failure domain (config vs. data vs. runtime vs. protocol).
//!
//! `Display`/`Error` are hand-implemented: the offline registry the crate
//! must build against carries no proc-macro crates (no `thiserror`).

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All errors produced by the gmips library.
#[derive(Debug)]
pub enum Error {
    /// I/O failure (dataset files, artifact files, sockets).
    Io(std::io::Error),
    /// Malformed configuration (TOML parse error, bad value, missing key).
    Config(String),
    /// Malformed or inconsistent dataset (bad magic, shape mismatch).
    Data(String),
    /// JSON parse/serialize failure (manifest, wire protocol).
    Json(String),
    /// CLI argument error.
    Cli(String),
    /// MIPS index construction/query failure.
    Index(String),
    /// XLA/PJRT runtime failure (artifact load, compile, execute).
    Runtime(String),
    /// Sampler/estimator precondition violation (e.g. k >= n).
    Inference(String),
    /// Learner failure (divergence, bad hyperparameters).
    Learn(String),
    /// Coordinator/server failure (queue closed, protocol violation).
    Serve(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Data(s) => write!(f, "data error: {s}"),
            Error::Json(s) => write!(f, "json error: {s}"),
            Error::Cli(s) => write!(f, "cli error: {s}"),
            Error::Index(s) => write!(f, "index error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::Inference(s) => write!(f, "inference error: {s}"),
            Error::Learn(s) => write!(f, "learn error: {s}"),
            Error::Serve(s) => write!(f, "serve error: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used throughout the crate.
    pub fn config<S: Into<String>>(s: S) -> Self {
        Error::Config(s.into())
    }
    /// Shorthand constructor.
    pub fn data<S: Into<String>>(s: S) -> Self {
        Error::Data(s.into())
    }
    /// Shorthand constructor.
    pub fn json<S: Into<String>>(s: S) -> Self {
        Error::Json(s.into())
    }
    /// Shorthand constructor.
    pub fn index<S: Into<String>>(s: S) -> Self {
        Error::Index(s.into())
    }
    /// Shorthand constructor.
    pub fn runtime<S: Into<String>>(s: S) -> Self {
        Error::Runtime(s.into())
    }
    /// Shorthand constructor.
    pub fn inference<S: Into<String>>(s: S) -> Self {
        Error::Inference(s.into())
    }
    /// Shorthand constructor.
    pub fn serve<S: Into<String>>(s: S) -> Self {
        Error::Serve(s.into())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = Error::config("missing [data] section");
        assert!(e.to_string().contains("config error"));
        let e = Error::runtime("no artifacts");
        assert!(e.to_string().contains("runtime error"));
    }

    #[test]
    fn io_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
