//! Naive exact sampler — the paper's brute-force baseline: score every
//! state, perturb every state with a fresh Gumbel, take the argmax.
//! `O(n·d)` scoring + `O(n)` Gumbels per sample.

use super::{SampleOutcome, SampleWork, Sampler};
use crate::data::Dataset;
use crate::mips::brute::BruteForce;
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Brute-force Gumbel-max sampler (Proposition 2.1 applied literally).
pub struct ExactSampler {
    scan: BruteForce,
    n: usize,
}

impl ExactSampler {
    pub fn new(ds: Arc<Dataset>, backend: Arc<dyn ScoreBackend>) -> Self {
        let n = ds.n;
        ExactSampler { scan: BruteForce::new(ds, backend), n }
    }

    /// Exact scores for all states (shared with evaluation code).
    pub fn all_scores(&self, q: &[f32], out: &mut [f32]) {
        self.scan.all_scores(q, out);
    }

    /// Exact softmax probabilities (evaluation only; `O(n)` + exp).
    pub fn probabilities(&self, q: &[f32]) -> Vec<f64> {
        let mut scores = vec![0f32; self.n];
        self.scan.all_scores(q, &mut scores);
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let mut probs: Vec<f64> = scores.iter().map(|&s| ((s as f64) - m).exp()).collect();
        let z: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= z;
        }
        probs
    }
}

impl Sampler for ExactSampler {
    fn sample(&self, q: &[f32], rng: &mut Pcg64) -> SampleOutcome {
        let mut scores = vec![0f32; self.n];
        self.scan.all_scores(q, &mut scores);
        let mut best = f64::NEG_INFINITY;
        let mut best_id = 0u32;
        for (i, &s) in scores.iter().enumerate() {
            let v = s as f64 + rng.gumbel();
            if v > best {
                best = v;
                best_id = i as u32;
            }
        }
        SampleOutcome { id: best_id, work: SampleWork { scanned: self.n, k: 0, m: 0 } }
    }

    fn sample_many(&self, q: &[f32], count: usize, rng: &mut Pcg64) -> Vec<SampleOutcome> {
        // amortize the scoring pass across draws for the same θ (the
        // Gumbel perturbations stay fresh per draw, so samples remain
        // i.i.d.) — this is the strongest version of the baseline.
        let mut scores = vec![0f32; self.n];
        self.scan.all_scores(q, &mut scores);
        (0..count)
            .map(|_| {
                let mut best = f64::NEG_INFINITY;
                let mut best_id = 0u32;
                for (i, &s) in scores.iter().enumerate() {
                    let v = s as f64 + rng.gumbel();
                    if v > best {
                        best = v;
                        best_id = i as u32;
                    }
                }
                SampleOutcome { id: best_id, work: SampleWork { scanned: self.n, k: 0, m: 0 } }
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "exact"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::scorer::NativeScorer;

    use crate::util::stats::gof_ok;

    #[test]
    fn samples_follow_softmax() {
        let ds = Arc::new(synth::imagenet_like(200, 8, 5, 0.3, 1));
        let s = ExactSampler::new(ds.clone(), Arc::new(NativeScorer));
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let probs = s.probabilities(&q);
        assert!((probs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let total = 40_000u64;
        let mut counts = vec![0u64; 200];
        for o in s.sample_many(&q, total as usize, &mut rng) {
            counts[o.id as usize] += 1;
        }
        assert!(gof_ok(&counts, &probs, total, 5.0), "GOF failed");
    }

    #[test]
    fn sample_work_reports_full_scan() {
        let ds = Arc::new(synth::uniform_sphere(100, 4, 3));
        let s = ExactSampler::new(ds, Arc::new(NativeScorer));
        let mut rng = Pcg64::new(4);
        let o = s.sample(&[1.0, 0.0, 0.0, 0.0], &mut rng);
        assert_eq!(o.work.scanned, 100);
    }

    use crate::util::rng::Pcg64;
}
