//! The **frozen-Gumbel baseline** — Mussmann & Ermon (2016), the prior
//! work the paper compares against in §4.3/§5.
//!
//! That method appends `t` *fixed* Gumbel noise coordinates to every
//! database vector at preprocessing time:
//! `v'_i = [φ(x_i); G_{i,1}, …, G_{i,t}]`. A query picks a noise slot `j`
//! and asks MIPS for `argmax_i (θ·φ(x_i) + G_{i,j})` with the augmented
//! query `q' = [θ; e_j]`. Its flaws — reproduced faithfully here:
//!
//! * samples are **correlated**: only `t` distinct perturbations exist
//!   per θ (re-querying slot `j` returns the same element),
//! * the partition estimate `log Ẑ = mean_j max_i(y_i + G_{i,j}) − γ`
//!   has relative error ~`π/√(6t)` — ~15% even at `t = 64` (Figure 4),
//! * the appended noise **destroys the metric structure** MIPS indexes
//!   exploit, so accuracy degrades further as `t` grows.

use super::{SampleOutcome, SampleWork, Sampler};
use crate::config::IndexConfig;
use crate::data::Dataset;
use crate::error::Result;
use crate::mips::{ivf::IvfIndex, MipsIndex};
use crate::scorer::ScoreBackend;
use crate::util::rng::{Pcg64, EULER_GAMMA};
use std::sync::Arc;

/// Frozen-Gumbel MIPS structure (the 2016 baseline).
pub struct FrozenGumbel {
    /// augmented database `[n × (d + t)]` wrapped as a Dataset
    aug_ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    pub t: usize,
    d: usize,
    n: usize,
}

impl FrozenGumbel {
    /// Preprocess: append `t` frozen Gumbel columns and build an IVF index
    /// over the augmented vectors.
    pub fn build(
        ds: &Dataset,
        t: usize,
        index_cfg: &IndexConfig,
        backend: Arc<dyn ScoreBackend>,
        seed: u64,
    ) -> Result<Self> {
        let (n, d) = (ds.n, ds.d);
        let t = t.max(1);
        let mut rng = Pcg64::new(seed ^ 0xF407E);
        let d_aug = d + t;
        let mut aug = vec![0f32; n * d_aug];
        for i in 0..n {
            aug[i * d_aug..i * d_aug + d].copy_from_slice(ds.row(i));
            for j in 0..t {
                aug[i * d_aug + d + j] = rng.gumbel() as f32;
            }
        }
        let aug_ds = Arc::new(Dataset::new(aug, n, d_aug)?);
        let index: Arc<dyn MipsIndex> =
            Arc::new(IvfIndex::build(aug_ds.clone(), index_cfg, backend)?);
        Ok(FrozenGumbel { aug_ds, index, t, d, n })
    }

    /// Augmented query `[θ; e_j]`.
    fn aug_query(&self, q: &[f32], slot: usize) -> Vec<f32> {
        let mut out = vec![0f32; self.d + self.t];
        out[..self.d].copy_from_slice(q);
        out[self.d + slot] = 1.0;
        out
    }

    /// The 2016 partition estimator: `log Ẑ = mean_j M_j − γ` where `M_j`
    /// is the (MIPS-approximate) perturbed max for slot `j`. Returns
    /// `(log Ẑ, rows scanned)`.
    pub fn log_partition_estimate(&self, q: &[f32]) -> (f64, usize) {
        let mut total = 0f64;
        let mut scanned = 0usize;
        for j in 0..self.t {
            let aq = self.aug_query(q, j);
            let top = self.index.top_k(&aq, 1);
            total += top.s_max();
            scanned += top.scanned;
        }
        (total / self.t as f64 - EULER_GAMMA, scanned)
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of augmented dims (diagnostics).
    pub fn d_aug(&self) -> usize {
        self.aug_ds.d
    }
}

impl Sampler for FrozenGumbel {
    fn sample(&self, q: &[f32], rng: &mut Pcg64) -> SampleOutcome {
        let slot = rng.next_below(self.t as u64) as usize;
        let aq = self.aug_query(q, slot);
        let top = self.index.top_k(&aq, 1);
        let id = top.items.first().map(|s| s.id).unwrap_or(0);
        SampleOutcome { id, work: SampleWork { scanned: top.scanned, k: 1, m: 0 } }
    }

    fn name(&self) -> &'static str {
        "frozen-gumbel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::data::synth;
    use crate::linalg::MaxSumExp;
    use crate::mips::brute::BruteForce;
    use crate::scorer::NativeScorer;

    fn index_cfg() -> IndexConfig {
        let mut c = Config::default().index;
        c.n_clusters = 24;
        c.n_probe = 6;
        c.kmeans_iters = 4;
        c.train_sample = 1000;
        c
    }

    #[test]
    fn samples_are_correlated_across_draws() {
        // The defining flaw: with t slots there are at most t distinct
        // samples per θ.
        let ds = synth::imagenet_like(1000, 8, 10, 0.3, 1);
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let t = 4;
        let fg = FrozenGumbel::build(&ds, t, &index_cfg(), backend, 2).unwrap();
        let mut rng = Pcg64::new(3);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let distinct: rustc_hash::FxHashSet<u32> =
            (0..200).map(|_| fg.sample(&q, &mut rng).id).collect();
        assert!(distinct.len() <= t, "at most t distinct samples, got {}", distinct.len());
    }

    #[test]
    fn partition_estimate_error_shrinks_with_t_but_floors() {
        let ds = synth::imagenet_like(2000, 8, 20, 0.3, 4);
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let brute = BruteForce::new(Arc::new(ds.clone()), backend.clone());
        let mut rng = Pcg64::new(5);
        // average relative error of exp(logZ_est) over several θ
        let mut errs = Vec::new();
        for &t in &[4usize, 64] {
            let fg = FrozenGumbel::build(&ds, t, &index_cfg(), backend.clone(), 6).unwrap();
            let mut sum_err = 0f64;
            let trials = 6;
            for _ in 0..trials {
                let q = synth::random_theta(&ds, 0.3, &mut rng);
                let mut all = vec![0f32; ds.n];
                brute.all_scores(&q, &mut all);
                let mut acc = MaxSumExp::default();
                acc.push_all(&all);
                let true_log_z = acc.logsumexp();
                let (est, _) = fg.log_partition_estimate(&q);
                sum_err += ((est - true_log_z).exp() - 1.0).abs();
            }
            errs.push(sum_err / 6.0);
        }
        // error decreases with t …
        assert!(errs[1] < errs[0] * 1.1, "errs={errs:?}");
        // … but never becomes accurate (the paper's point: ≥ ~10% even at
        // t=64; allow a loose floor here)
        assert!(errs[1] > 0.02, "frozen baseline should not be accurate: {errs:?}");
    }

    #[test]
    fn augmented_dims() {
        let ds = synth::uniform_sphere(300, 8, 7);
        let fg =
            FrozenGumbel::build(&ds, 5, &index_cfg(), Arc::new(NativeScorer), 8).unwrap();
        assert_eq!(fg.d_aug(), 13);
        assert_eq!(fg.n(), 300);
    }

    use crate::util::rng::Pcg64;
}
