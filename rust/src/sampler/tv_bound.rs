//! Closed-form total-variation certificate (paper §4.2.1, Table 1).
//!
//! The lazy strategy is exact unless the true perturbed argmax lies
//! outside `S ∪ T`. For a threshold `x`, the event
//!
//! ```text
//! E_x = { max_{i∉S} y_i + G_i < x }  ∧  { max_{i∈S} y_i + G_i > x }
//! ```
//!
//! implies success (every tail point — sampled into `T` or not — is
//! beaten by a member of `S`), and its probability factorizes over the
//! independent Gumbels. Using `F(z) = exp(−exp(−z))`:
//!
//! ```text
//! P(E_x) = exp(−e^{−x} Z_tail) · (1 − exp(−e^{−x} Z_S))
//! ```
//!
//! where `Z_S = Σ_{i∈S} e^{y_i}` and `Z_tail = Σ_{i∉S} e^{y_i}`. The
//! optimizer over `x` is closed-form: with `r = Z_tail / Z_S`,
//!
//! ```text
//! TV ≤ 1 − max_x P(E_x) = 1 − (1 + 1/r)^{−r} / (1 + r)
//! ```
//!
//! (maximum at `e^{−x*} = ln(1 + 1/r)/Z_S`). The certificate needs one
//! exact scan per θ — it is an *offline* accuracy audit, exactly how the
//! paper evaluates Table 1 (averaged over 100 θ drawn from the dataset).

use crate::linalg::MaxSumExp;
use crate::mips::TopKResult;

/// TV upper bound from the log-partition masses of the top set and tail.
///
/// `log_z_s = log Σ_{i∈S} e^{y_i}`, `log_z_tail = log Σ_{i∉S} e^{y_i}`.
pub fn tv_bound_from_masses(log_z_s: f64, log_z_tail: f64) -> f64 {
    if log_z_tail == f64::NEG_INFINITY {
        return 0.0; // no tail mass at all
    }
    if log_z_s == f64::NEG_INFINITY {
        return 1.0; // no top mass: certificate is vacuous
    }
    let r = (log_z_tail - log_z_s).exp();
    // 1 − (1+1/r)^{−r} / (1+r), computed in log space for extreme r
    // ln[(1+1/r)^{−r}] = −r·ln(1+1/r) = −r·ln_1p(1/r)
    let log_term = -r * (1.0 / r).ln_1p() - (1.0 + r).ln();
    let p_star = log_term.exp();
    (1.0 - p_star).clamp(0.0, 1.0)
}

/// Compute the certificate for a retrieved top set `S` against exact
/// scores of the *whole* database (`all_scores.len() == n`).
pub fn tv_bound(all_scores: &[f32], top: &TopKResult) -> f64 {
    let in_s: rustc_hash::FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
    let mut z_s = MaxSumExp::default();
    let mut z_tail = MaxSumExp::default();
    for (i, &y) in all_scores.iter().enumerate() {
        if in_s.contains(&(i as u32)) {
            z_s.push(y as f64);
        } else {
            z_tail.push(y as f64);
        }
    }
    tv_bound_from_masses(z_s.logsumexp(), z_tail.logsumexp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::{brute::BruteForce, MipsIndex};
    use crate::scorer::NativeScorer;
    use crate::util::rng::Pcg64;
    use std::sync::Arc;

    #[test]
    fn limits() {
        // all mass in S → bound 0; no mass in S → bound 1
        assert_eq!(tv_bound_from_masses(0.0, f64::NEG_INFINITY), 0.0);
        assert_eq!(tv_bound_from_masses(f64::NEG_INFINITY, 0.0), 1.0);
        // r = 1: TV ≤ 1 − 2^{−1}/2 = 0.75
        let b = tv_bound_from_masses(0.0, 0.0);
        assert!((b - 0.75).abs() < 1e-12, "b={b}");
    }

    #[test]
    fn monotone_in_tail_mass() {
        let mut last = 0.0;
        for log_tail in [-20.0, -10.0, -5.0, -1.0, 0.0, 2.0] {
            let b = tv_bound_from_masses(0.0, log_tail);
            assert!(b >= last, "bound must increase with tail mass");
            last = b;
        }
    }

    #[test]
    fn closed_form_optimum_beats_grid_search() {
        // the closed-form max must dominate any grid point of
        // 1 − P(E_x): verify TV_closed ≤ 1 − P(E_x) for all x on a grid
        let (log_z_s, log_z_tail) = (2.0, -1.5);
        let closed = tv_bound_from_masses(log_z_s, log_z_tail);
        let (z_s, z_t) = (log_z_s.exp(), log_z_tail.exp());
        for i in -100..100 {
            let x = i as f64 * 0.1;
            let u = (-x).exp();
            let p = (-u * z_t).exp() * (1.0 - (-u * z_s).exp());
            assert!(closed <= 1.0 - p + 1e-9, "x={x}: closed={closed} grid={}", 1.0 - p);
        }
    }

    #[test]
    fn small_bound_for_peaked_distributions() {
        // τ = 0.05 ⇒ scores in [−20, 20]; with a good top set the bound
        // should be tiny (paper reports ~1e−4 on real data)
        let ds = Arc::new(synth::imagenet_like(5000, 16, 50, 0.25, 1));
        let brute = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let mut rng = Pcg64::new(2);
        let k = (5.0 * (ds.n as f64).sqrt()) as usize;
        let mut worst: f64 = 0.0;
        for _ in 0..5 {
            let q = synth::random_theta(&ds, 0.05, &mut rng);
            let top = brute.top_k(&q, k);
            let mut all = vec![0f32; ds.n];
            brute.all_scores(&q, &mut all);
            let b = tv_bound(&all, &top);
            worst = worst.max(b);
        }
        // the paper reports ~1e-4 at n ≈ 1.3M; at this toy scale (n=5000)
        // the top-k set holds proportionally less mass, so the certificate
        // is looser — but must still be small in absolute terms
        assert!(worst < 5e-2, "peaked TV bound should be small, got {worst}");
    }

    #[test]
    fn bound_reflects_missing_top_elements() {
        // a top set that misses the argmax should have a visibly larger
        // bound than the exact one
        let ds = Arc::new(synth::imagenet_like(2000, 8, 20, 0.3, 3));
        let brute = BruteForce::new(ds.clone(), Arc::new(NativeScorer));
        let mut rng = Pcg64::new(4);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let mut all = vec![0f32; ds.n];
        brute.all_scores(&q, &mut all);
        let good = brute.top_k(&q, 100);
        let mut bad = good.clone();
        bad.items.drain(..10); // drop the 10 largest
        let b_good = tv_bound(&all, &good);
        let b_bad = tv_bound(&all, &bad);
        assert!(b_bad > b_good, "good={b_good} bad={b_bad}");
    }
}
