//! **Algorithm 2 — Fast Sampling with Fixed B** (paper §3.1.2).
//!
//! Instead of the data-dependent cutoff of Algorithm 1, fix
//! `B = −ln(−ln(1 − l/n))` so that the expected number of tail Gumbels
//! above `B` is exactly `l`. This concentrates the per-query work
//! (`m ~ Binomial(n−k, l/n)`, so `m < 2l` w.h.p.) and tolerates MIPS
//! errors gracefully: the sample is exact with probability
//! `1 − exp(−(kl/n)·e^{−c})` (Theorem 3.3), failing only when the top
//! set's perturbed max happens to be small.

use super::{SampleOutcome, SampleWork, Sampler};
use crate::data::Dataset;
use crate::gumbel;
use crate::mips::{MipsIndex, TopKResult};
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Algorithm 2 sampler.
pub struct FixedBSampler {
    ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    backend: Arc<dyn ScoreBackend>,
    pub k: usize,
    /// expected tail count l (paper: O(√n); Theorem 3.3 wants kl ≥ n·ln(1/δ))
    pub l: usize,
}

impl FixedBSampler {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<dyn MipsIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        l: usize,
    ) -> Self {
        let k = k.clamp(1, ds.n);
        let l = l.clamp(1, ds.n);
        FixedBSampler { ds, index, backend, k, l }
    }

    /// Failure probability bound of Theorem 3.3 (c = 0):
    /// `δ = exp(−kl/n)`.
    pub fn failure_bound(&self) -> f64 {
        (-(self.k as f64) * (self.l as f64) / (self.ds.n as f64)).exp()
    }

    /// Steps after top-k retrieval (reusable across draws per θ).
    pub fn sample_given_top(&self, top: &TopKResult, q: &[f32], rng: &mut Pcg64) -> SampleOutcome {
        let n = self.ds.n;
        let b = gumbel::fixed_cutoff(n, self.l);

        let mut best_id = top.items[0].id;
        let mut best = f64::NEG_INFINITY;
        for it in &top.items {
            let v = it.score as f64 + rng.gumbel();
            if v > best {
                best = v;
                best_id = it.id;
            }
        }

        let exclude: FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
        let tail = gumbel::sample_tail(n, &exclude, b, rng);
        let m = tail.m();
        if m > 0 {
            let d = self.ds.d;
            let mut rows = vec![0f32; m * d];
            self.ds.gather(&tail.ids, &mut rows);
            let mut scores = vec![0f32; m];
            self.backend.scores(&rows, d, q, &mut scores);
            for ((&id, &g), &y) in tail.ids.iter().zip(&tail.gumbels).zip(&scores) {
                let v = y as f64 + g;
                if v > best {
                    best = v;
                    best_id = id;
                }
            }
        }
        SampleOutcome { id: best_id, work: SampleWork { scanned: top.scanned, k: top.items.len(), m } }
    }
}

impl Sampler for FixedBSampler {
    fn sample(&self, q: &[f32], rng: &mut Pcg64) -> SampleOutcome {
        let top = self.index.top_k(q, self.k);
        self.sample_given_top(&top, q, rng)
    }

    fn sample_many(&self, q: &[f32], count: usize, rng: &mut Pcg64) -> Vec<SampleOutcome> {
        let top = self.index.top_k(q, self.k);
        (0..count).map(|_| self.sample_given_top(&top, q, rng)).collect()
    }

    fn name(&self) -> &'static str {
        "fixed-b"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::sampler::exact::ExactSampler;
    use crate::util::stats::gof_ok;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn MipsIndex>, Arc<dyn ScoreBackend>) {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.3, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(crate::scorer::NativeScorer);
        let index: Arc<dyn MipsIndex> = Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        (ds, index, backend)
    }

    #[test]
    fn samples_follow_softmax_when_kl_large() {
        let (ds, index, backend) = setup(300, 1);
        // kl/n = 40·60/300 = 8 → δ ≈ 3e-4: effectively exact
        let sampler = FixedBSampler::new(ds.clone(), index, backend.clone(), 40, 60);
        assert!(sampler.failure_bound() < 1e-3);
        let exact = ExactSampler::new(ds.clone(), backend);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let probs = exact.probabilities(&q);
        let total = 30_000u64;
        let mut counts = vec![0u64; ds.n];
        for o in sampler.sample_many(&q, total as usize, &mut rng) {
            counts[o.id as usize] += 1;
        }
        assert!(gof_ok(&counts, &probs, total, 5.0), "Alg 2 GOF failed");
    }

    #[test]
    fn theorem_3_3_work_concentrated_around_l() {
        let (ds, index, backend) = setup(5_000, 3);
        let l = 80;
        let sampler = FixedBSampler::new(ds.clone(), index, backend, 70, l);
        let mut rng = Pcg64::new(4);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let outs = sampler.sample_many(&q, 300, &mut rng);
        let ms: Vec<f64> = outs.iter().map(|o| o.work.m as f64).collect();
        let mean = ms.iter().sum::<f64>() / ms.len() as f64;
        assert!((mean - l as f64).abs() < 0.25 * l as f64, "mean m={mean} want ≈{l}");
        // "with very high probability, m < 2l"
        let violations = ms.iter().filter(|&&m| m >= 2.0 * l as f64).count();
        assert!(violations <= 1, "{violations} draws with m ≥ 2l");
    }

    #[test]
    fn failure_bound_formula() {
        let (ds, index, backend) = setup(1_000, 5);
        let s = FixedBSampler::new(ds, index, backend, 50, 40);
        assert!((s.failure_bound() - (-2.0f64).exp()).abs() < 1e-12);
    }

    use crate::util::rng::Pcg64;
}
