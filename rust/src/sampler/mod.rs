//! Sampling from `Pr(i) ∝ exp(y_i)` (paper §3.1).
//!
//! * [`exact::ExactSampler`] — the naive `O(n)` Gumbel-max baseline,
//! * [`lazy_gumbel::LazyGumbelSampler`] — **Algorithm 1** (data-dependent
//!   cutoff `B = M − S_min − c`, exact sample, `E[m] ≤ n·e^c/k`),
//! * [`fixed_b::FixedBSampler`] — **Algorithm 2** (constant cutoff,
//!   exact with probability `1 − exp(−kl/n·e^{−c})`, concentrated work),
//! * [`frozen::FrozenGumbel`] — the Mussmann & Ermon (2016) baseline with
//!   frozen Gumbel noise appended to the database (correlated samples;
//!   §5 discusses why it fails),
//! * [`tv_bound`] — the closed-form total-variation certificate of
//!   §4.2.1 (Table 1's accuracy column).

pub mod exact;
pub mod fixed_b;
pub mod frozen;
pub mod lazy_gumbel;
pub mod tv_bound;

use crate::util::rng::Pcg64;

/// Work accounting for one sampling query.
#[derive(Clone, Copy, Debug, Default)]
pub struct SampleWork {
    /// rows scored by the MIPS retrieval (index scan)
    pub scanned: usize,
    /// top-set size k
    pub k: usize,
    /// lazily materialized tail Gumbels m
    pub m: usize,
}

/// One sampling query's result.
#[derive(Clone, Copy, Debug)]
pub struct SampleOutcome {
    /// the sampled state id
    pub id: u32,
    pub work: SampleWork,
}

/// A sampler over a fixed database answering queries with changing θ.
pub trait Sampler: Send + Sync {
    /// Draw one sample for parameter vector `q` (temperature already
    /// folded in).
    fn sample(&self, q: &[f32], rng: &mut Pcg64) -> SampleOutcome;

    /// Draw many samples (default: loop; implementations may amortize the
    /// top-k retrieval across draws for the same θ, which is the paper's
    /// "sequence of queries" setting).
    fn sample_many(&self, q: &[f32], count: usize, rng: &mut Pcg64) -> Vec<SampleOutcome> {
        (0..count).map(|_| self.sample(q, rng)).collect()
    }

    fn name(&self) -> &'static str;
}
