//! **Algorithm 1 — Fast Sampling with Lazy Gumbels** (paper §3.1).
//!
//! 1. retrieve the (approximate) top-k set `S` via MIPS,
//! 2. perturb `S` with fresh Gumbels → `M = max_{i∈S} y_i + G_i`,
//! 3. cutoff `B = M − S_min − c` (`S_min = min_{i∈S} y_i`; `c` absorbs
//!    the approximate-MIPS gap, §3.4),
//! 4. lazily materialize the tail Gumbels above `B`
//!    (`m ~ Binomial(n−k, 1−F(B))`, positions uniform, values truncated
//!    Gumbel — [`crate::gumbel::sample_tail`]),
//! 5. return `argmax_{i∈S∪T} y_i + G_i`.
//!
//! Theorem 3.1: the result is an exact softmax sample (when `S_min + c`
//! truly bounds tail scores). Theorem 3.2: `E[m] ≤ n·e^c/k`.

use super::{SampleOutcome, SampleWork, Sampler};
use crate::data::Dataset;
use crate::gumbel;
use crate::mips::{MipsIndex, TopKResult};
use crate::scorer::ScoreBackend;
use crate::util::rng::Pcg64;
use rustc_hash::FxHashSet;
use std::sync::Arc;

/// Algorithm 1 sampler.
pub struct LazyGumbelSampler {
    ds: Arc<Dataset>,
    index: Arc<dyn MipsIndex>,
    backend: Arc<dyn ScoreBackend>,
    /// top-set size k (paper: O(√n))
    pub k: usize,
    /// approximate-MIPS gap allowance c ≥ 0
    pub gap_c: f64,
}

impl LazyGumbelSampler {
    pub fn new(
        ds: Arc<Dataset>,
        index: Arc<dyn MipsIndex>,
        backend: Arc<dyn ScoreBackend>,
        k: usize,
        gap_c: f64,
    ) -> Self {
        let k = k.clamp(1, ds.n);
        LazyGumbelSampler { ds, index, backend, k, gap_c }
    }

    /// Score a set of rows by id via the shared
    /// [`crate::scorer::score_ids`] fast path (§Perf iteration 1: the
    /// gather+block-score path copied `m·d` floats per draw; per-row
    /// dots read the dataset in place).
    fn score_ids(&self, ids: &[u32], q: &[f32]) -> Vec<f32> {
        crate::scorer::score_ids(&self.ds, self.backend.as_ref(), ids, q)
    }

    /// Open a per-θ sampling session: one MIPS retrieval + one exclusion
    /// set, reused across every draw for this θ (§Perf iteration 2 — the
    /// exclusion set was previously rebuilt per draw).
    pub fn session(&self, q: &[f32]) -> SampleSession {
        let top = self.index.top_k(q, self.k);
        SampleSession::new(top)
    }

    /// Run steps 2–5 of Algorithm 1 within a session.
    pub fn sample_in_session(
        &self,
        session: &SampleSession,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> SampleOutcome {
        let top = &session.top;
        let n = self.ds.n;
        debug_assert!(!top.items.is_empty());

        // fresh Gumbels on S, tracking the perturbed max
        let mut best_id = top.items[0].id;
        let mut best = f64::NEG_INFINITY;
        for it in &top.items {
            let v = it.score as f64 + rng.gumbel();
            if v > best {
                best = v;
                best_id = it.id;
            }
        }
        let s_min = top.s_min();
        let b = best - s_min - self.gap_c;

        // lazy tail
        let tail = gumbel::sample_tail(n, &session.exclude, b, rng);
        let m = tail.m();
        if m > 0 {
            let tail_scores = self.score_ids(&tail.ids, q);
            for ((&id, &g), &y) in tail.ids.iter().zip(&tail.gumbels).zip(&tail_scores) {
                let v = y as f64 + g;
                if v > best {
                    best = v;
                    best_id = id;
                }
            }
        }
        let obs = crate::obs::registry();
        obs.sampler_rounds.inc();
        obs.sampler_tail_gumbels.add(m as u64);
        SampleOutcome {
            id: best_id,
            work: SampleWork { scanned: top.scanned, k: top.items.len(), m },
        }
    }

    /// Back-compat single-shot form: builds a throwaway session.
    pub fn sample_given_top(
        &self,
        top: &TopKResult,
        q: &[f32],
        rng: &mut Pcg64,
    ) -> SampleOutcome {
        let session = SampleSession::new(top.clone());
        self.sample_in_session(&session, q, rng)
    }

    /// Open sessions for a whole batch of θs with ONE batched MIPS
    /// retrieval ([`MipsIndex::top_k_batch`]) — the multi-user analogue of
    /// [`session`](Self::session): concurrent queries share every index
    /// row-block scan instead of re-streaming the database per θ.
    pub fn sessions_batch(&self, qs: &[&[f32]]) -> Vec<SampleSession> {
        self.index
            .top_k_batch(qs, self.k)
            .into_iter()
            .map(SampleSession::new)
            .collect()
    }

    /// Batched Algorithm 1: draw `counts[i]` samples for `qs[i]`. One
    /// batched top-k retrieval amortizes the scan across all queries;
    /// draws then proceed per session exactly as in the single-θ path.
    pub fn sample_batch(
        &self,
        qs: &[&[f32]],
        counts: &[usize],
        rng: &mut Pcg64,
    ) -> Vec<Vec<SampleOutcome>> {
        debug_assert_eq!(qs.len(), counts.len());
        let sessions = self.sessions_batch(qs);
        let mut all = Vec::with_capacity(qs.len());
        for ((session, q), &count) in sessions.iter().zip(qs).zip(counts) {
            let mut outs = Vec::with_capacity(count.max(1));
            for _ in 0..count.max(1) {
                outs.push(self.sample_in_session(session, q, rng));
            }
            all.push(outs);
        }
        all
    }
}

/// Reusable per-θ state for Algorithm 1 (top set + exclusion set).
pub struct SampleSession {
    pub top: TopKResult,
    exclude: FxHashSet<u32>,
}

impl SampleSession {
    pub fn new(top: TopKResult) -> Self {
        let exclude: FxHashSet<u32> = top.items.iter().map(|s| s.id).collect();
        SampleSession { top, exclude }
    }
}

impl Sampler for LazyGumbelSampler {
    fn sample(&self, q: &[f32], rng: &mut Pcg64) -> SampleOutcome {
        let top = self.index.top_k(q, self.k);
        self.sample_given_top(&top, q, rng)
    }

    fn sample_many(&self, q: &[f32], count: usize, rng: &mut Pcg64) -> Vec<SampleOutcome> {
        // ONE MIPS retrieval per θ, fresh Gumbels per draw — the paper's
        // "only require accessing the MIPS data structure once per
        // parameter value" (§5).
        let session = self.session(q);
        (0..count).map(|_| self.sample_in_session(&session, q, rng)).collect()
    }

    fn name(&self) -> &'static str {
        "lazy-gumbel"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::mips::brute::BruteForce;
    use crate::sampler::exact::ExactSampler;
    use crate::util::stats::gof_ok;
    use crate::scorer::NativeScorer;

    fn setup(n: usize, seed: u64) -> (Arc<Dataset>, Arc<dyn MipsIndex>, Arc<dyn ScoreBackend>) {
        let ds = Arc::new(synth::imagenet_like(n, 8, 10, 0.3, seed));
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let index: Arc<dyn MipsIndex> =
            Arc::new(BruteForce::new(ds.clone(), backend.clone()));
        (ds, index, backend)
    }

    #[test]
    fn theorem_3_1_exact_sampling_with_exact_mips() {
        // With an exact top-k, Algorithm 1 must produce exact softmax
        // samples: chi-square GOF against the true distribution.
        let (ds, index, backend) = setup(300, 1);
        let k = 30; // ~√n·1.7
        let sampler = LazyGumbelSampler::new(ds.clone(), index, backend.clone(), k, 0.0);
        let exact = ExactSampler::new(ds.clone(), backend);
        let mut rng = Pcg64::new(2);
        let q = synth::random_theta(&ds, 0.2, &mut rng);
        let probs = exact.probabilities(&q);
        let total = 40_000u64;
        let mut counts = vec![0u64; ds.n];
        for o in sampler.sample_many(&q, total as usize, &mut rng) {
            counts[o.id as usize] += 1;
        }
        assert!(gof_ok(&counts, &probs, total, 5.0), "Alg 1 GOF failed");
    }

    #[test]
    fn theorem_3_2_expected_tail_count() {
        // E[m] ≤ n/k (c = 0). Average m over many draws.
        let (ds, index, backend) = setup(2_000, 3);
        for k in [20, 45, 90] {
            let sampler = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), k, 0.0);
            let mut rng = Pcg64::new(4);
            let q = synth::random_theta(&ds, 0.1, &mut rng);
            let reps = 400;
            let mean_m: f64 = sampler
                .sample_many(&q, reps, &mut rng)
                .iter()
                .map(|o| o.work.m as f64)
                .sum::<f64>()
                / reps as f64;
            let bound = ds.n as f64 / k as f64;
            // 4σ-ish slack: m is exponential-tailed with mean ≤ bound
            assert!(
                mean_m <= bound * 1.5 + 4.0 * (bound / reps as f64).sqrt() + 1.0,
                "k={k}: E[m]={mean_m} bound={bound}"
            );
        }
    }

    #[test]
    fn gap_c_increases_tail_work() {
        let (ds, index, backend) = setup(2_000, 5);
        let mut rng = Pcg64::new(6);
        let q = synth::random_theta(&ds, 0.1, &mut rng);
        let m_of = |c: f64, rng: &mut Pcg64| -> f64 {
            let s = LazyGumbelSampler::new(ds.clone(), index.clone(), backend.clone(), 40, c);
            s.sample_many(&q, 200, rng).iter().map(|o| o.work.m as f64).sum::<f64>() / 200.0
        };
        let m0 = m_of(0.0, &mut rng);
        let m1 = m_of(1.0, &mut rng);
        // Theorem 3.2 with c: E[m] ≤ n·e^c/k — expect roughly e× more work
        assert!(m1 > m0 * 1.5, "m0={m0} m1={m1}");
    }

    #[test]
    fn work_is_sublinear() {
        let (ds, index, backend) = setup(5_000, 7);
        let k = (ds.n as f64).sqrt() as usize;
        let sampler = LazyGumbelSampler::new(ds.clone(), index, backend, k, 0.0);
        let mut rng = Pcg64::new(8);
        let q = synth::random_theta(&ds, 0.05, &mut rng);
        let outs = sampler.sample_many(&q, 100, &mut rng);
        let mean_m: f64 = outs.iter().map(|o| o.work.m as f64).sum::<f64>() / 100.0;
        // with k = √n, E[m] ≤ √n
        assert!(mean_m <= 2.5 * (ds.n as f64).sqrt(), "mean_m={mean_m}");
        assert!(outs.iter().all(|o| o.work.k == k));
    }

    use crate::util::rng::Pcg64;
}
