//! The on-disk snapshot format and its atomic writer / validating
//! reader.
//!
//! ## Layout
//!
//! ```text
//! offset 0    header (48 bytes):
//!               [0..8)   magic  "GMIPSNP1"
//!               [8..12)  format version (u32 LE)
//!               [12..16) reserved (zero)
//!               [16..24) config fingerprint (u64 LE)
//!               [24..32) section-table offset (u64 LE)
//!               [32..40) section count (u64 LE)
//!               [40..48) FNV-1a-64 of bytes [0..40)
//! offset 64   first section (every section starts 64-byte aligned,
//!             zero-padded gaps between sections)
//! ...
//! table_off   section table: 32-byte entries
//!               { tag u32, arg u32, off u64, len u64, checksum u64 }
//! ```
//!
//! All integers are little-endian; opening asserts a little-endian
//! target (the same contract as the dataset codec). `arg` carries
//! `shard << 16 | slot` so one file holds per-shard copies of a section
//! (shard `0xFFFF` marks shard-shared sections such as the coarse
//! quantizer). Checksums are FNV-1a-64 over the exact section bytes.
//!
//! ## Crash safety
//!
//! [`SnapshotWriter`] writes everything to `<path>.tmp`, `fsync`s it,
//! then atomically renames over `<path>` and `fsync`s the directory. A
//! crash at any point leaves the previous snapshot untouched; a stale
//! `.tmp` from a crashed save is simply overwritten by the next one.
//!
//! ## Validation
//!
//! [`Snapshot::open`] eagerly validates magic, version, header
//! checksum, table bounds, and every section's bounds and alignment.
//! Per-section content checksums are verified on access: required
//! sections fail the open with a descriptive error, while the quantized
//! shadow sections use the `_soft` accessors so the caller can degrade
//! to the f32 tier instead of refusing to serve.

// Wire-codec truncation policy: this module decodes untrusted on-disk
// integers, so every narrowing `as` cast is banned in favor of
// `usize::try_from`/checked conversions that surface corruption as
// errors instead of silently wrapping. Enforced here at deny level (the
// lint is allow-by-default pedantic) and re-checked textually by
// `cargo xtask lint`.
#![deny(clippy::cast_possible_truncation)]

use std::fs::{self, File};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::store::blob::{Blob, Mmap, Pod};

/// File magic: "GMIPS sNaPshot", format family 1.
pub const MAGIC: [u8; 8] = *b"GMIPSNP1";
/// Current format version. Bump on any incompatible layout change.
pub const VERSION: u32 = 1;
/// Section alignment: cache-line sized, covering every SIMD load width
/// the scan kernels use, so mapped sections feed them directly.
pub const ALIGN: usize = 64;
/// Header length in bytes.
pub const HEADER_LEN: usize = 48;
/// Serialized section-table entry length in bytes.
pub const ENTRY_LEN: usize = 32;
/// Shard value in `arg` marking a section shared by all shards.
pub const SHARED_SHARD: u32 = 0xFFFF;

const ZEROS: [u8; ALIGN] = [0u8; ALIGN];
// backstop against absurd section counts from corrupt headers
const MAX_SECTIONS: u64 = 1 << 20;

/// Section tags. Kept dense and append-only: renumbering is a format
/// version bump.
pub mod tag {
    pub const CONFIG_STR: u32 = 1;
    pub const DATASET_META: u32 = 2;
    pub const DATASET_ROWS: u32 = 3;
    pub const SHARD_META: u32 = 4;
    pub const KMEANS: u32 = 5;
    pub const BRUTE_META: u32 = 6;
    pub const IVF_META: u32 = 7;
    pub const IVF_GROUPED: u32 = 8;
    pub const LSH_META: u32 = 9;
    pub const TIERED_META: u32 = 10;
    pub const SQ8_META: u32 = 11;
    pub const SQ8_CODES: u32 = 12;
    pub const SQ4_META: u32 = 13;
    pub const SQ4_CODES: u32 = 14;
    pub const PQ_META: u32 = 15;
    pub const PQ_CODES: u32 = 16;
    /// Fast-scan tile-major PQ codes (PR 10). Optional: readers re-block
    /// from `PQ_CODES` when absent, so pre-tiles snapshots open unchanged.
    pub const PQ_TILES: u32 = 17;
}

/// Human name for a tag, for error messages.
pub fn tag_name(t: u32) -> &'static str {
    match t {
        tag::CONFIG_STR => "config-string",
        tag::DATASET_META => "dataset-meta",
        tag::DATASET_ROWS => "dataset-rows",
        tag::SHARD_META => "shard-meta",
        tag::KMEANS => "kmeans",
        tag::BRUTE_META => "brute-meta",
        tag::IVF_META => "ivf-meta",
        tag::IVF_GROUPED => "ivf-grouped-rows",
        tag::LSH_META => "lsh-meta",
        tag::TIERED_META => "tiered-meta",
        tag::SQ8_META => "sq8-meta",
        tag::SQ8_CODES => "sq8-codes",
        tag::SQ4_META => "sq4-meta",
        tag::SQ4_CODES => "sq4-codes",
        tag::PQ_META => "pq-meta",
        tag::PQ_CODES => "pq-codes",
        tag::PQ_TILES => "pq-fastscan-tiles",
        _ => "unknown-section",
    }
}

/// Pack a shard id and a per-shard slot into a section `arg`.
pub fn sec_arg(shard: u32, slot: u32) -> u32 {
    (shard << 16) | (slot & 0xFFFF)
}

/// FNV-1a 64-bit hash — the format's checksum and fingerprint hash.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Reinterpret a Pod slice as its raw little-endian bytes.
pub fn as_bytes<T: Pod>(v: &[T]) -> &[u8] {
    le_guard();
    // SAFETY: the byte view covers exactly the slice's own allocation
    // (`size_of_val` bytes at its base); T is Pod (no padding, fixed
    // layout, every byte initialized); u8 has no alignment requirement;
    // the borrow ties the view's lifetime to `v`.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), std::mem::size_of_val(v)) }
}

/// The format stores native little-endian bytes; refuse to run
/// elsewhere (same contract as the GMD1 dataset codec).
fn le_guard() {
    assert!(cfg!(target_endian = "little"), "snapshot format requires a little-endian target");
}

/// One entry of the section table.
#[derive(Clone, Copy, Debug)]
pub struct SectionEntry {
    pub tag: u32,
    pub arg: u32,
    pub off: u64,
    pub len: u64,
    pub checksum: u64,
}

// ---------------------------------------------------------------------------
// writer

/// Streams sections into `<path>.tmp`, then commits atomically in
/// [`SnapshotWriter::finish`]. Dropping an unfinished writer removes
/// the temp file.
pub struct SnapshotWriter {
    file: File,
    tmp: PathBuf,
    dest: PathBuf,
    pos: u64,
    entries: Vec<SectionEntry>,
    finished: bool,
}

impl SnapshotWriter {
    /// Start a snapshot destined for `path`.
    pub fn create(path: &str) -> Result<SnapshotWriter> {
        le_guard();
        let dest = PathBuf::from(path);
        if let Some(dir) = dest.parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)?;
            }
        }
        let tmp = PathBuf::from(format!("{path}.tmp"));
        let mut file = File::create(&tmp)?;
        // placeholder header; the real one lands in finish() once the
        // table offset and fingerprint are known
        file.write_all(&[0u8; HEADER_LEN])?;
        Ok(SnapshotWriter {
            file,
            tmp,
            dest,
            pos: HEADER_LEN as u64,
            entries: Vec::new(),
            finished: false,
        })
    }

    fn pad_to_align(&mut self) -> Result<()> {
        let rem = usize::try_from(self.pos % ALIGN as u64).expect("x mod 64 fits usize");
        if rem != 0 {
            let pad = ALIGN - rem;
            self.file.write_all(&ZEROS[..pad])?;
            self.pos += pad as u64;
        }
        Ok(())
    }

    /// Append one section (64-byte aligned, checksummed).
    pub fn section(&mut self, tag: u32, arg: u32, bytes: &[u8]) -> Result<()> {
        self.pad_to_align()?;
        self.entries.push(SectionEntry {
            tag,
            arg,
            off: self.pos,
            len: bytes.len() as u64,
            checksum: fnv1a64(bytes),
        });
        self.file.write_all(bytes)?;
        self.pos += bytes.len() as u64;
        Ok(())
    }

    /// Write the section table and header, fsync, and atomically rename
    /// over the destination. `fingerprint` is the config fingerprint
    /// recorded in the header.
    pub fn finish(mut self, fingerprint: u64) -> Result<()> {
        self.pad_to_align()?;
        let table_off = self.pos;
        let mut bw = ByteWriter::default();
        for e in &self.entries {
            bw.u32(e.tag);
            bw.u32(e.arg);
            bw.u64(e.off);
            bw.u64(e.len);
            bw.u64(e.checksum);
        }
        self.file.write_all(bw.bytes())?;

        let mut header = [0u8; HEADER_LEN];
        header[0..8].copy_from_slice(&MAGIC);
        header[8..12].copy_from_slice(&VERSION.to_le_bytes());
        // [12..16) reserved, zero
        header[16..24].copy_from_slice(&fingerprint.to_le_bytes());
        header[24..32].copy_from_slice(&table_off.to_le_bytes());
        header[32..40].copy_from_slice(&(self.entries.len() as u64).to_le_bytes());
        let hsum = fnv1a64(&header[..40]);
        header[40..48].copy_from_slice(&hsum.to_le_bytes());
        self.file.seek(SeekFrom::Start(0))?;
        self.file.write_all(&header)?;

        // durability: file contents first, then the rename, then the
        // directory entry
        self.file.sync_all()?;
        fs::rename(&self.tmp, &self.dest)?;
        self.finished = true;
        #[cfg(unix)]
        {
            let dir = match self.dest.parent() {
                Some(d) if !d.as_os_str().is_empty() => d,
                _ => Path::new("."),
            };
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }
}

impl Drop for SnapshotWriter {
    fn drop(&mut self) {
        if !self.finished {
            let _ = fs::remove_file(&self.tmp);
        }
    }
}

// ---------------------------------------------------------------------------
// reader

/// How to bring snapshot bytes into the process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpenMode {
    /// Read the whole file into RAM.
    Read,
    /// Zero-copy `mmap`; falls back to [`OpenMode::Read`] on targets
    /// without mmap support.
    Mmap,
}

enum SnapBytes {
    Owned(Vec<u8>),
    Mapped(Arc<Mmap>),
}

/// An opened, header-validated snapshot.
pub struct Snapshot {
    bytes: SnapBytes,
    /// config fingerprint from the header
    pub fingerprint: u64,
    sections: Vec<SectionEntry>,
    path: String,
}

impl Snapshot {
    /// Open and validate header + section table. Content checksums are
    /// verified on section access.
    pub fn open(path: &str, mode: OpenMode) -> Result<Snapshot> {
        le_guard();
        let bytes = match mode {
            OpenMode::Read => SnapBytes::Owned(read_file(path)?),
            OpenMode::Mmap => {
                let file = File::open(path)
                    .map_err(|e| Error::data(format!("snapshot {path}: {e}")))?;
                match Mmap::map(&file) {
                    Ok(m) => SnapBytes::Mapped(Arc::new(m)),
                    // unsupported target — identical behavior, owned bytes
                    Err(e) if e.kind() == std::io::ErrorKind::Unsupported => {
                        SnapBytes::Owned(read_file(path)?)
                    }
                    Err(e) => {
                        return Err(Error::data(format!("snapshot {path}: mmap failed: {e}")))
                    }
                }
            }
        };
        let mut snap = Snapshot {
            bytes,
            fingerprint: 0,
            sections: Vec::new(),
            path: path.to_string(),
        };
        snap.validate_layout()?;
        Ok(snap)
    }

    fn validate_layout(&mut self) -> Result<()> {
        let data = self.data();
        let path = &self.path;
        if data.len() < HEADER_LEN {
            return Err(Error::data(format!(
                "snapshot {path}: file is {} bytes, smaller than the {HEADER_LEN}-byte header \
                 (truncated?)",
                data.len()
            )));
        }
        if data[0..8] != MAGIC {
            return Err(Error::data(format!(
                "snapshot {path}: bad magic — not a gmips snapshot file"
            )));
        }
        let version = u32::from_le_bytes(data[8..12].try_into().unwrap());
        if version != VERSION {
            return Err(Error::data(format!(
                "snapshot {path}: format version {version} is not supported by this binary \
                 (expected {VERSION}); rebuild the snapshot with `gmips build --save`"
            )));
        }
        let hsum = u64::from_le_bytes(data[40..48].try_into().unwrap());
        if fnv1a64(&data[..40]) != hsum {
            return Err(Error::data(format!(
                "snapshot {path}: header checksum mismatch — file is corrupt or truncated"
            )));
        }
        let fingerprint = u64::from_le_bytes(data[16..24].try_into().unwrap());
        let table_off = u64::from_le_bytes(data[24..32].try_into().unwrap());
        let n_sections = u64::from_le_bytes(data[32..40].try_into().unwrap());
        let flen = data.len() as u64;
        if n_sections > MAX_SECTIONS {
            return Err(Error::data(format!(
                "snapshot {path}: implausible section count {n_sections} — file is corrupt"
            )));
        }
        let table_len = n_sections * ENTRY_LEN as u64;
        let table_end = table_off.checked_add(table_len).unwrap_or(u64::MAX);
        if table_off < HEADER_LEN as u64 || table_off % ALIGN as u64 != 0 || table_end > flen {
            return Err(Error::data(format!(
                "snapshot {path}: section table out of bounds (offset {table_off}, \
                 {n_sections} entries, file {flen} bytes) — file is corrupt or truncated"
            )));
        }
        // lossless: n_sections ≤ MAX_SECTIONS and table_off < flen =
        // data.len() (a usize) were both checked above
        let n_sections = usize::try_from(n_sections).expect("bounded by MAX_SECTIONS");
        let table_base = usize::try_from(table_off).expect("bounded by file length");
        let mut sections = Vec::with_capacity(n_sections);
        for i in 0..n_sections {
            let b = &data[table_base + i * ENTRY_LEN..][..ENTRY_LEN];
            let e = SectionEntry {
                tag: u32::from_le_bytes(b[0..4].try_into().unwrap()),
                arg: u32::from_le_bytes(b[4..8].try_into().unwrap()),
                off: u64::from_le_bytes(b[8..16].try_into().unwrap()),
                len: u64::from_le_bytes(b[16..24].try_into().unwrap()),
                checksum: u64::from_le_bytes(b[24..32].try_into().unwrap()),
            };
            let end = e.off.checked_add(e.len).unwrap_or(u64::MAX);
            if e.off < HEADER_LEN as u64 || e.off % ALIGN as u64 != 0 || end > table_off {
                return Err(Error::data(format!(
                    "snapshot {path}: section {} (arg {:#x}) out of bounds \
                     (offset {}, len {}) — file is corrupt or truncated",
                    tag_name(e.tag),
                    e.arg,
                    e.off,
                    e.len
                )));
            }
            sections.push(e);
        }
        self.fingerprint = fingerprint;
        self.sections = sections;
        Ok(())
    }

    fn data(&self) -> &[u8] {
        match &self.bytes {
            SnapBytes::Owned(v) => v,
            SnapBytes::Mapped(m) => m.bytes(),
        }
    }

    /// Whether the snapshot is served from a memory map.
    pub fn is_mapped(&self) -> bool {
        matches!(self.bytes, SnapBytes::Mapped(_))
    }

    /// The snapshot's path, for error/log messages.
    pub fn path(&self) -> &str {
        &self.path
    }

    /// All section-table entries (corruption drills introspect these).
    pub fn sections(&self) -> &[SectionEntry] {
        &self.sections
    }

    fn find(&self, tag: u32, arg: u32) -> Option<&SectionEntry> {
        self.sections.iter().find(|e| e.tag == tag && e.arg == arg)
    }

    fn section_slice(&self, e: &SectionEntry) -> &[u8] {
        // lossless: validate_layout checked off + len ≤ table_off ≤
        // data.len() (a usize), so both endpoints fit usize
        let off = usize::try_from(e.off).expect("validated section offset");
        let end = usize::try_from(e.off + e.len).expect("validated section end");
        &self.data()[off..end]
    }

    /// Checksum-verified bytes of a required section; missing or
    /// corrupt → descriptive error.
    pub fn bytes(&self, tag: u32, arg: u32) -> Result<&[u8]> {
        let e = self.find(tag, arg).ok_or_else(|| {
            Error::data(format!(
                "snapshot {}: missing required section {} (arg {:#x}) — file was built by an \
                 incompatible configuration or is corrupt",
                self.path,
                tag_name(tag),
                arg
            ))
        })?;
        let b = self.section_slice(e);
        if fnv1a64(b) != e.checksum {
            return Err(Error::data(format!(
                "snapshot {}: checksum mismatch in section {} (arg {:#x}) — file is corrupt",
                self.path,
                tag_name(tag),
                arg
            )));
        }
        Ok(b)
    }

    /// Like [`Snapshot::bytes`], but missing/corrupt → `None` so the
    /// caller can degrade (quantized shadow sections).
    pub fn bytes_soft(&self, tag: u32, arg: u32) -> Option<&[u8]> {
        let e = self.find(tag, arg)?;
        let b = self.section_slice(e);
        if fnv1a64(b) != e.checksum {
            return None;
        }
        Some(b)
    }

    fn blob_from_entry<T: Pod>(&self, e: &SectionEntry) -> Option<Blob<T>> {
        match &self.bytes {
            SnapBytes::Owned(_) => {
                let b = self.section_slice(e);
                let size = std::mem::size_of::<T>();
                if b.len() % size != 0 {
                    return None;
                }
                let len = b.len() / size;
                let mut v: Vec<T> = Vec::with_capacity(len);
                // SAFETY: the fresh Vec's buffer holds capacity ≥ len
                // elements = b.len() bytes, aligned for T; the source and
                // the new allocation cannot overlap; T is Pod so the
                // copied bytes form valid values, making set_len(len)
                // sound after the copy.
                unsafe {
                    std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr().cast::<u8>(), b.len());
                    v.set_len(len);
                }
                Some(Blob::Owned(v))
            }
            SnapBytes::Mapped(m) => {
                // lossless: validate_layout bounded off + len by the
                // mapped file length (a usize)
                let off = usize::try_from(e.off).ok()?;
                let len = usize::try_from(e.len).ok()?;
                Blob::from_map(m.clone(), off, len)
            }
        }
    }

    /// A typed view of an aligned-blob section: zero-copy when mapped,
    /// copied into an owned `Vec` otherwise. Checksum-verified.
    pub fn blob<T: Pod>(&self, tag: u32, arg: u32) -> Result<Blob<T>> {
        self.bytes(tag, arg)?; // presence + checksum
        let e = *self.find(tag, arg).expect("section present: bytes() succeeded");
        self.blob_from_entry(&e).ok_or_else(|| {
            Error::data(format!(
                "snapshot {}: section {} (arg {:#x}) has a ragged length for its element type \
                 — file is corrupt",
                self.path,
                tag_name(tag),
                arg
            ))
        })
    }

    /// Soft variant of [`Snapshot::blob`] for degradable sections.
    pub fn blob_soft<T: Pod>(&self, tag: u32, arg: u32) -> Option<Blob<T>> {
        self.bytes_soft(tag, arg)?;
        let e = *self.find(tag, arg)?;
        self.blob_from_entry(&e)
    }

    /// A cursor over a required meta section's bytes.
    pub fn reader(&self, tag: u32, arg: u32) -> Result<ByteReader<'_>> {
        Ok(ByteReader::new(self.bytes(tag, arg)?, tag_name(tag)))
    }

    /// Soft cursor for degradable meta sections.
    pub fn reader_soft(&self, tag: u32, arg: u32) -> Option<ByteReader<'_>> {
        Some(ByteReader::new(self.bytes_soft(tag, arg)?, tag_name(tag)))
    }
}

fn read_file(path: &str) -> Result<Vec<u8>> {
    let mut f = File::open(path).map_err(|e| Error::data(format!("snapshot {path}: {e}")))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf).map_err(|e| Error::data(format!("snapshot {path}: {e}")))?;
    Ok(buf)
}

// ---------------------------------------------------------------------------
// meta-section codecs

/// Little-endian append-only buffer for meta sections.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    /// Length-prefixed Pod slice.
    pub fn slice<T: Pod>(&mut self, v: &[T]) {
        self.u64(v.len() as u64);
        self.buf.extend_from_slice(as_bytes(v));
    }
    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    pub fn bytes(&self) -> &[u8] {
        &self.buf
    }
}

/// Bounds-checked cursor over a meta section. Every read that would run
/// past the end returns a descriptive error instead of panicking, which
/// is what makes bit-flipped length prefixes safe.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
    what: &'static str,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8], what: &'static str) -> ByteReader<'a> {
        le_guard();
        ByteReader { buf, pos: 0, what }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).unwrap_or(usize::MAX);
        if end > self.buf.len() {
            return Err(Error::data(format!(
                "snapshot section {}: truncated (needed {} bytes at offset {}, have {})",
                self.what,
                n,
                self.pos,
                self.buf.len()
            )));
        }
        let b = &self.buf[self.pos..end];
        self.pos = end;
        Ok(b)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| {
            Error::data(format!(
                "snapshot section {}: value {v} does not fit in usize on this target",
                self.what
            ))
        })
    }

    /// Length-prefixed Pod vector.
    pub fn vec<T: Pod>(&mut self) -> Result<Vec<T>> {
        let len = self.usize()?;
        let size = std::mem::size_of::<T>();
        let nbytes = len.checked_mul(size).ok_or_else(|| {
            Error::data(format!(
                "snapshot section {}: implausible vector length {len} — corrupt",
                self.what
            ))
        })?;
        let b = self.take(nbytes)?;
        let mut v: Vec<T> = Vec::with_capacity(len);
        // SAFETY: the fresh Vec's buffer holds capacity ≥ len elements =
        // nbytes bytes (b.len() == nbytes by `take`), aligned for T and
        // disjoint from the source section; T is Pod so the copied bytes
        // form valid values, making set_len(len) sound after the copy.
        unsafe {
            std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr().cast::<u8>(), nbytes);
            v.set_len(len);
        }
        Ok(v)
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let len = self.usize()?;
        let b = self.take(len)?;
        String::from_utf8(b.to_vec()).map_err(|_| {
            Error::data(format!("snapshot section {}: invalid UTF-8 string — corrupt", self.what))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("gmips_fmt_{}_{}", std::process::id(), name))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn round_trip_and_alignment() {
        let path = tmp_path("rt");
        let mut w = SnapshotWriter::create(&path).unwrap();
        w.section(tag::CONFIG_STR, 0, b"hello").unwrap();
        let rows: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        w.section(tag::DATASET_ROWS, 0, as_bytes(&rows)).unwrap();
        w.finish(fnv1a64(b"hello")).unwrap();

        for mode in [OpenMode::Read, OpenMode::Mmap] {
            let snap = Snapshot::open(&path, mode).unwrap();
            assert_eq!(snap.fingerprint, fnv1a64(b"hello"));
            assert_eq!(snap.bytes(tag::CONFIG_STR, 0).unwrap(), b"hello");
            let blob: Blob<f32> = snap.blob(tag::DATASET_ROWS, 0).unwrap();
            assert_eq!(&blob[..], &rows[..]);
            for e in snap.sections() {
                assert_eq!(e.off % ALIGN as u64, 0, "section {} misaligned", tag_name(e.tag));
            }
            assert!(snap.bytes(tag::KMEANS, 0).is_err(), "missing section must error");
        }
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn byte_codec_round_trip_and_truncation() {
        let mut bw = ByteWriter::default();
        bw.u64(42);
        bw.f64(-1.25);
        bw.slice(&[7u32, 8, 9]);
        bw.str("gmips");
        let buf = bw.bytes().to_vec();

        let mut r = ByteReader::new(&buf, "test");
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(r.f64().unwrap(), -1.25);
        assert_eq!(r.vec::<u32>().unwrap(), vec![7, 8, 9]);
        assert_eq!(r.str().unwrap(), "gmips");

        // truncated buffer: reads error, never panic
        let mut r = ByteReader::new(&buf[..10], "test");
        assert_eq!(r.u64().unwrap(), 42);
        assert!(r.f64().is_err());
        // corrupt length prefix: huge value errors cleanly
        let mut bad = buf.clone();
        bad[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut r = ByteReader::new(&bad, "test");
        let _ = r.u64().unwrap();
        let _ = r.f64().unwrap();
        assert!(r.vec::<u32>().is_err());
    }

    #[test]
    fn miri_byte_codec_roundtrip() {
        // Miri-lane subset: the ByteWriter/ByteReader pair, including the
        // Pod-slice reinterpretation in `slice`/`vec`
        let mut bw = ByteWriter::default();
        bw.u8(3);
        bw.u32(0xdead_beef);
        bw.u64(1 << 40);
        bw.f32(2.5);
        bw.f64(-0.125);
        bw.slice(&[1.0f32, -2.0, 3.5]);
        bw.slice(&[9u64, 10]);
        bw.str("φ(x)·θ");
        let buf = bw.bytes().to_vec();
        let mut r = ByteReader::new(&buf, "miri");
        assert_eq!(r.u8().unwrap(), 3);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f32().unwrap(), 2.5);
        assert_eq!(r.f64().unwrap(), -0.125);
        assert_eq!(r.vec::<f32>().unwrap(), vec![1.0, -2.0, 3.5]);
        assert_eq!(r.vec::<u64>().unwrap(), vec![9, 10]);
        assert_eq!(r.str().unwrap(), "φ(x)·θ");
        // the cursor is exactly drained: one more read must error
        assert!(r.u8().is_err());
    }

    #[test]
    fn miri_byte_reader_truncation_and_corrupt_lengths() {
        // every read past the end must error (not panic), including
        // adversarial length prefixes that would overflow len·size
        let mut bw = ByteWriter::default();
        bw.slice(&[1u32, 2, 3]);
        let buf = bw.bytes().to_vec();
        for cut in 0..buf.len() {
            let mut r = ByteReader::new(&buf[..cut], "miri");
            assert!(r.vec::<u32>().is_err(), "cut={cut}");
        }
        // length prefix claiming usize::MAX elements: checked_mul catches
        let mut bad = buf.clone();
        bad[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        let mut r = ByteReader::new(&bad, "miri");
        assert!(r.vec::<u32>().is_err());
        // length prefix that fits u64 but not the buffer
        let mut bad2 = buf.clone();
        bad2[..8].copy_from_slice(&1024u64.to_le_bytes());
        let mut r = ByteReader::new(&bad2, "miri");
        assert!(r.vec::<u32>().is_err());
        // empty buffer: every typed read errors
        let mut r = ByteReader::new(&[], "miri");
        assert!(r.u8().is_err());
        assert!(r.u32().is_err());
        assert!(r.u64().is_err());
        assert!(r.str().is_err());
    }

    #[test]
    fn interrupted_save_leaves_previous_snapshot_intact() {
        let path = tmp_path("atomic");
        let mut w = SnapshotWriter::create(&path).unwrap();
        w.section(tag::CONFIG_STR, 0, b"v1").unwrap();
        w.finish(fnv1a64(b"v1")).unwrap();

        // simulate a crash mid-save: garbage temp file next to the
        // snapshot, never renamed
        fs::write(format!("{path}.tmp"), b"garbage from a crashed save").unwrap();
        let snap = Snapshot::open(&path, OpenMode::Read).unwrap();
        assert_eq!(snap.bytes(tag::CONFIG_STR, 0).unwrap(), b"v1");

        // a later save overwrites the stale temp file and commits
        let mut w = SnapshotWriter::create(&path).unwrap();
        w.section(tag::CONFIG_STR, 0, b"v2").unwrap();
        w.finish(fnv1a64(b"v2")).unwrap();
        let snap = Snapshot::open(&path, OpenMode::Read).unwrap();
        assert_eq!(snap.bytes(tag::CONFIG_STR, 0).unwrap(), b"v2");
        assert!(!Path::new(&format!("{path}.tmp")).exists());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn dropped_writer_removes_temp_file() {
        let path = tmp_path("drop");
        {
            let mut w = SnapshotWriter::create(&path).unwrap();
            w.section(tag::CONFIG_STR, 0, b"x").unwrap();
            // dropped without finish()
        }
        assert!(!Path::new(&format!("{path}.tmp")).exists());
        assert!(!Path::new(&path).exists());
    }
}
