//! Crash-safe persistent index store.
//!
//! The amortization argument of the paper rests on paying the MIPS
//! preprocessing cost *once* — this module makes that literal across
//! process restarts. A snapshot is a single file holding everything a
//! serving process needs: the dataset rows, the built index structure
//! for any of the four kinds (brute/IVF/LSH/tiered, monolithic or
//! sharded), and the SQ8/SQ4/PQ quantized shadow codes, all in one
//! checksummed, versioned container (see [`format`] for the layout and
//! the crash-safety story, [`blob`] for the mmap alignment contract).
//!
//! Design points:
//!
//! * **Atomic save** — [`save_index`] writes `<path>.tmp` and renames;
//!   a crash mid-save never clobbers the previous good snapshot.
//! * **Zero-copy open** — with `index.mmap = true` (default) the big
//!   sections (f32 rows, IVF grouped rows, quantized code planes) are
//!   served straight from the mapped file through [`blob::Blob`]; the
//!   f32 and integer scan kernels run against the mapped bytes with no
//!   deserialization. `index.mmap = false` reads into RAM instead.
//! * **Config fingerprint** — the build-affecting config fields are
//!   serialized to a human-readable string, hashed into the header, and
//!   stored verbatim; opening under a different build config fails with
//!   both strings in the error instead of silently serving stale data.
//!   Query-time knobs (`n_probe`, `overscan`, `shard_parallel`, `path`,
//!   `mmap`, temperature) are deliberately excluded so they can change
//!   between save and open.
//! * **Degrade over refuse** — a corrupt quantized shadow section drops
//!   the tier ladder and serves from the f32 tier (answers stay
//!   bit-identical by the coverage-certificate contract), with a log
//!   line and a stats flag. Corruption anywhere else is a descriptive
//!   error; truncated or bit-flipped files never panic.
//! * **Reopen ≙ rebuild** — a reopened index is bit-identical to a
//!   fresh build under the same config, including the IVF pending
//!   ingest segment, so `update_row` + `compact()` keep working and
//!   `compact()` can re-snapshot.

pub mod blob;
pub mod format;

use std::sync::Arc;

use crate::config::{Config, IndexKind};
use crate::data::{self, Dataset};
use crate::error::{Error, Result};
use crate::mips::kmeans::Kmeans;
use crate::mips::{self, BuiltIndex, MipsIndex};
use crate::scorer::ScoreBackend;
use crate::shard::ShardedIndex;

pub use blob::{Blob, Mmap};
pub use format::{
    fnv1a64, sec_arg, tag, ByteReader, ByteWriter, OpenMode, SectionEntry, Snapshot,
    SnapshotWriter, SHARED_SHARD, VERSION,
};

/// Result of [`open_index`] / [`load_or_build`].
pub struct Opened {
    pub ds: Arc<Dataset>,
    pub index: BuiltIndex,
    /// a quantized shadow section was corrupt and the index serves from
    /// the f32 tier (answers unchanged, bandwidth savings lost)
    pub degraded: bool,
    /// the index was built fresh (no usable snapshot at `index.path`)
    pub built: bool,
}

/// The build-affecting config fields, serialized deterministically.
/// Stored verbatim in the snapshot and hashed into the header; any
/// difference at open time is a descriptive config-mismatch error.
pub fn fingerprint_string(cfg: &Config) -> String {
    let d = &cfg.data;
    let i = &cfg.index;
    format!(
        "gmips-snapshot-v{VERSION} \
         data(kind={} n={} d={} clusters={} noise={} zipf_s={} seed={} path={:?}) \
         index(kind={} n_clusters={} kmeans_iters={} train_sample={} tables={} bits={} \
         rungs={} quant={} quant_block={} pq_m={} pq_bits={} shards={} shard_strategy={} \
         seed={})",
        d.kind.name(),
        d.n,
        d.d,
        d.clusters,
        d.noise,
        d.zipf_s,
        d.seed,
        d.path,
        i.kind.name(),
        i.n_clusters,
        i.kmeans_iters,
        i.train_sample,
        i.tables,
        i.bits,
        i.rungs,
        i.quant.name(),
        i.quant_block,
        i.pq_m,
        i.pq_bits,
        i.shards,
        i.shard_strategy.name(),
        i.seed,
    )
}

/// Save a built index (any kind, monolithic or sharded) together with
/// its dataset as one atomic snapshot file at `path`.
pub fn save_index(path: &str, cfg: &Config, ds: &Dataset, index: &BuiltIndex) -> Result<()> {
    let fp = fingerprint_string(cfg);
    let mut w = SnapshotWriter::create(path)?;
    w.section(tag::CONFIG_STR, 0, fp.as_bytes())?;
    let mut bw = ByteWriter::default();
    bw.u64(ds.n as u64);
    bw.u64(ds.d as u64);
    bw.slice(&ds.labels);
    w.section(tag::DATASET_META, 0, bw.bytes())?;
    w.section(tag::DATASET_ROWS, 0, format::as_bytes(&ds.data))?;
    match index {
        BuiltIndex::Mono(ix) => ix.save_sections(&mut w, 0)?,
        BuiltIndex::Sharded(sx) => sx.save_sections_all(&mut w)?,
    }
    w.finish(fnv1a64(fp.as_bytes()))
}

/// Open a snapshot saved by [`save_index`], validating version,
/// fingerprint, bounds, and checksums. The index kind and shard count
/// come from `cfg` and must match what was saved (enforced through the
/// fingerprint).
pub fn open_index(path: &str, cfg: &Config, backend: Arc<dyn ScoreBackend>) -> Result<Opened> {
    let mode = if cfg.index.mmap { OpenMode::Mmap } else { OpenMode::Read };
    let snap = Snapshot::open(path, mode)?;

    let stored = std::str::from_utf8(snap.bytes(tag::CONFIG_STR, 0)?)
        .map_err(|_| {
            Error::data(format!("snapshot {path}: config string is not UTF-8 — file is corrupt"))
        })?
        .to_string();
    if snap.fingerprint != fnv1a64(stored.as_bytes()) {
        return Err(Error::data(format!(
            "snapshot {path}: header fingerprint disagrees with the stored config string — \
             file is corrupt"
        )));
    }
    let expect = fingerprint_string(cfg);
    if stored != expect {
        return Err(Error::config(format!(
            "snapshot {path} was built under a different configuration:\n  snapshot: {stored}\n  \
             current:  {expect}\nrebuild it with `gmips build --save {path}` (or point \
             index.path elsewhere)"
        )));
    }

    let mut r = snap.reader(tag::DATASET_META, 0)?;
    let n = r.usize()?;
    let d = r.usize()?;
    let labels: Vec<u32> = r.vec()?;
    let rows: Blob<f32> = snap.blob(tag::DATASET_ROWS, 0)?;
    let ds = Arc::new(Dataset::from_blob(rows, n, d, labels)?);

    let mut degraded = false;
    let index = if cfg.index.shards > 1 {
        BuiltIndex::Sharded(Arc::new(ShardedIndex::open_from(
            &snap,
            &ds,
            &cfg.index,
            backend,
            &mut degraded,
        )?))
    } else {
        let icfg = &cfg.index;
        BuiltIndex::Mono(match icfg.kind {
            IndexKind::Brute => Arc::new(mips::brute::BruteForce::open_from(
                ds.clone(),
                icfg,
                backend,
                &snap,
                0,
                &mut degraded,
            )?) as Arc<dyn MipsIndex>,
            IndexKind::Ivf => Arc::new(mips::ivf::IvfIndex::open_from(
                ds.clone(),
                icfg,
                backend,
                &snap,
                &mut degraded,
            )?) as Arc<dyn MipsIndex>,
            IndexKind::Lsh => Arc::new(mips::lsh::SrpLsh::open_from(
                ds.clone(),
                icfg,
                backend,
                &snap,
                0,
                &mut degraded,
            )?) as Arc<dyn MipsIndex>,
            IndexKind::Tiered => Arc::new(mips::tiered::TieredLsh::open_from(
                ds.clone(),
                icfg,
                backend,
                &snap,
                0,
                &mut degraded,
            )?) as Arc<dyn MipsIndex>,
        })
    };
    if degraded {
        eprintln!(
            "warning: snapshot {path}: quantized shadow section corrupt or unreadable — \
             serving from the f32 tier (answers unchanged, screening bandwidth lost)"
        );
    }
    let obs = crate::obs::registry();
    obs.store_open_mode.set(if cfg.index.mmap { 2 } else { 1 });
    obs.store_snapshot_degraded.set(degraded as i64);
    Ok(Opened { ds, index, degraded, built: false })
}

/// The engine/learner/shard-server entry point: warm-open the snapshot
/// at `cfg.index.path` when it exists, otherwise build fresh (and, when
/// `save_on_build` is set and a path is configured, persist the build so
/// the next start is warm).
pub fn load_or_build(
    cfg: &Config,
    backend: Arc<dyn ScoreBackend>,
    save_on_build: bool,
) -> Result<Opened> {
    let path = cfg.index.path.clone();
    if !path.is_empty() && std::path::Path::new(&path).exists() {
        return open_index(&path, cfg, backend);
    }
    let ds = Arc::new(data::load_or_generate(&cfg.data));
    let index = mips::build_index_typed(&ds, &cfg.index, backend)?;
    if !path.is_empty() && save_on_build {
        save_index(&path, cfg, &ds, &index)?;
    }
    let obs = crate::obs::registry();
    obs.store_open_mode.set(0); // built fresh
    obs.store_snapshot_degraded.set(0);
    Ok(Opened { ds, index, degraded: false, built: true })
}

// ---------------------------------------------------------------------------
// shared sub-structure codecs

/// Serialize a trained k-means quantizer into a `KMEANS` section.
pub(crate) fn write_kmeans(w: &mut SnapshotWriter, arg: u32, km: &Kmeans) -> Result<()> {
    let mut bw = ByteWriter::default();
    bw.u64(km.c as u64);
    bw.u64(km.d as u64);
    bw.f64(km.inertia);
    bw.slice(&km.centroids);
    w.section(tag::KMEANS, arg, bw.bytes())
}

/// Read a `KMEANS` section back.
pub(crate) fn read_kmeans(snap: &Snapshot, arg: u32) -> Result<Kmeans> {
    let mut r = snap.reader(tag::KMEANS, arg)?;
    let c = r.usize()?;
    let d = r.usize()?;
    let inertia = r.f64()?;
    let centroids: Vec<f32> = r.vec()?;
    let want = c.checked_mul(d).unwrap_or(usize::MAX);
    if centroids.len() != want {
        return Err(Error::data(format!(
            "snapshot {}: kmeans section shape mismatch (c={c} d={d} but {} centroid values)",
            snap.path(),
            centroids.len()
        )));
    }
    Ok(Kmeans { centroids, c, d, inertia })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::scorer::NativeScorer;

    #[test]
    fn fingerprint_tracks_build_knobs_only() {
        let cfg = Config::default();
        let base = fingerprint_string(&cfg);
        assert_eq!(base, fingerprint_string(&cfg), "deterministic");

        let mut c = cfg.clone();
        c.index.n_clusters = 999;
        assert_ne!(base, fingerprint_string(&c), "build knob must change the fingerprint");
        let mut c = cfg.clone();
        c.data.seed = 999;
        assert_ne!(base, fingerprint_string(&c));

        // query-time knobs must NOT change it
        let mut c = cfg.clone();
        c.index.n_probe = 99;
        c.index.overscan = 9;
        c.index.shard_parallel = false;
        c.index.path = "/tmp/x.idx".to_string();
        c.index.mmap = false;
        assert_eq!(base, fingerprint_string(&c));
    }

    #[test]
    fn save_open_round_trip_and_config_mismatch() {
        let path = std::env::temp_dir()
            .join(format!("gmips_store_rt_{}.idx", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let mut cfg = Config::default();
        cfg.data.n = 400;
        cfg.data.d = 8;
        cfg.data.clusters = 10;
        cfg.index.kind = IndexKind::Brute;
        let backend: Arc<dyn ScoreBackend> = Arc::new(NativeScorer);
        let ds = Arc::new(synth::generate(&cfg.data));
        let index = mips::build_index_typed(&ds, &cfg.index, backend.clone()).unwrap();
        save_index(&path, &cfg, &ds, &index).unwrap();

        let opened = open_index(&path, &cfg, backend.clone()).unwrap();
        assert!(!opened.degraded);
        assert_eq!(opened.ds.n, ds.n);
        assert_eq!(opened.ds.data, ds.data);
        let q = ds.row(0);
        let fresh = index.as_dyn().top_k(q, 5);
        let warm = opened.index.as_dyn().top_k(q, 5);
        assert_eq!(fresh.items, warm.items);

        // a changed build knob must be rejected with both fingerprints
        let mut other = cfg.clone();
        other.index.seed ^= 1;
        let err = format!("{}", open_index(&path, &other, backend).unwrap_err());
        assert!(err.contains("different configuration"), "{err}");
        assert!(err.contains("snapshot:") && err.contains("current:"), "{err}");
        let _ = std::fs::remove_file(&path);
    }
}
