//! Owned-vs-mapped storage for the big flat arrays behind the index
//! structures (f32 rows, SQ8/SQ4/PQ code planes, IVF grouped rows).
//!
//! [`Blob<T>`] is a drop-in replacement for `Vec<T>` in struct fields:
//! it derefs to `&[T]`, so every existing read-side call site (slicing,
//! `as_ptr`, iteration, coercion to `&[T]` arguments) compiles
//! unchanged, while the storage behind it is either an owned vector or
//! a range of a shared read-only memory map ([`Mmap`]). Writers go
//! through [`Blob::to_mut`], which transparently copies a mapped range
//! into an owned vector first (copy-on-write) — mutation never touches
//! the mapped file.
//!
//! ## Alignment contract
//!
//! A mapped `Blob<T>` is only constructed ([`Blob::from_map`]) when the
//! byte offset is a multiple of `align_of::<T>()` and the byte length is
//! a multiple of `size_of::<T>()`. The snapshot format guarantees much
//! more: every section starts on a 64-byte boundary (cache-line sized,
//! covering every SIMD load the scan kernels issue), so `mmap`-backed
//! code planes and row storage feed the AVX2/NEON kernels directly with
//! no copy and no realignment. `mmap` itself returns page-aligned
//! addresses, so section offset alignment is preserved in memory.
//!
//! Only plain-old-data element types are permitted ([`Pod`]): every bit
//! pattern is a valid value and the in-file layout equals the in-memory
//! layout on little-endian targets (asserted at snapshot open, mirroring
//! the dataset codec).

use std::fs::File;
use std::io;
use std::ops::Deref;
use std::sync::Arc;

/// Marker for element types that can be reinterpreted from raw bytes:
/// fixed layout, no padding, no invalid bit patterns, no drop glue.
pub trait Pod: Copy + Send + Sync + 'static {}

impl Pod for u8 {}
impl Pod for i16 {}
impl Pod for u32 {}
impl Pod for u64 {}
impl Pod for f32 {}
impl Pod for f64 {}

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    // Raw libc bindings: the offline registry carries no `libc` crate,
    // and these two calls (identical signatures on Linux/macOS 64-bit,
    // where `off_t` is i64) are all the store needs.
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// A shared read-only memory map of a whole file. Unmapped on drop.
///
/// On non-unix or non-64-bit targets [`Mmap::map`] returns
/// `ErrorKind::Unsupported` and callers fall back to reading the file
/// into RAM — the snapshot format works identically either way.
pub struct Mmap {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

// SAFETY: the mapping is created PROT_READ and never remapped, so it is
// immutable for its entire lifetime; the raw pointer is only ever read
// through `bytes()`. Immutable data is safe to share and send across
// threads, and unmapping happens exactly once (Drop takes `&mut self`).
unsafe impl Send for Mmap {}
// SAFETY: same immutability argument as Send — concurrent `&Mmap` access
// only performs reads of read-only pages.
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `file` read-only in its entirety.
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map(file: &File) -> io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "file too large to map"));
        }
        let len = len as usize;
        if len == 0 {
            return Ok(Mmap { ptr: std::ptr::null_mut(), len: 0 });
        }
        // SAFETY: plain FFI syscall with a live fd (borrowed from `file`
        // for the duration of the call), a null addr hint, and len > 0
        // checked above; the kernel validates the rest and reports
        // failure via MAP_FAILED, handled below.
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap { ptr, len })
    }

    /// Stub for targets without the raw mmap bindings.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map(_file: &File) -> io::Result<Mmap> {
        Err(io::Error::new(io::ErrorKind::Unsupported, "mmap unavailable on this target"))
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            // SAFETY: `map()` succeeded, so `ptr` points at a live
            // read-only mapping of exactly `len` bytes that outlives
            // `&self` (unmapped only in Drop); u8 has no alignment or
            // validity requirements.
            unsafe { std::slice::from_raw_parts(self.ptr.cast::<u8>(), self.len) }
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            // SAFETY: `(ptr, len)` is exactly the region returned by the
            // successful `mmap` in `map()`, unmapped only here (Drop runs
            // once); no `&[u8]` view can outlive `self` by borrow rules.
            // Failure leaks the mapping; there is no recovery path and
            // the process is usually exiting anyway.
            let _ = unsafe { sys::munmap(self.ptr, self.len) };
        }
    }
}

/// `Vec<T>`-or-mapped-range storage. See the module docs.
pub enum Blob<T: Pod> {
    /// Heap-owned storage — what builds and copy-on-write produce.
    Owned(Vec<T>),
    /// A `[off, off + len·size_of::<T>())` byte range of a shared map.
    Mapped {
        map: Arc<Mmap>,
        /// byte offset into the map (multiple of `align_of::<T>()`)
        off: usize,
        /// element count
        len: usize,
    },
}

impl<T: Pod> Blob<T> {
    /// View a byte range of `map` as `[T]`. `None` when the range is out
    /// of bounds, misaligned for `T`, or not a whole number of elements
    /// — the caller turns that into a descriptive open error.
    pub fn from_map(map: Arc<Mmap>, off: usize, bytes: usize) -> Option<Blob<T>> {
        let size = std::mem::size_of::<T>();
        if size == 0 || bytes % size != 0 || off % std::mem::align_of::<T>() != 0 {
            return None;
        }
        let end = off.checked_add(bytes)?;
        if end > map.bytes().len() {
            return None;
        }
        Some(Blob::Mapped { map, off, len: bytes / size })
    }

    /// Whether this blob serves directly from a memory map.
    pub fn is_mapped(&self) -> bool {
        matches!(self, Blob::Mapped { .. })
    }

    /// Mutable access to the elements, converting a mapped range into an
    /// owned copy first (copy-on-write).
    pub fn to_mut(&mut self) -> &mut Vec<T> {
        if let Blob::Mapped { .. } = self {
            *self = Blob::Owned(self.as_slice().to_vec());
        }
        match self {
            Blob::Owned(v) => v,
            Blob::Mapped { .. } => unreachable!("mapped blob was just converted to owned"),
        }
    }

    fn as_slice(&self) -> &[T] {
        match self {
            Blob::Owned(v) => v,
            Blob::Mapped { map, off, len } => {
                // SAFETY: bounds (`off + len·size_of::<T>() ≤ map len`),
                // alignment (`off % align_of::<T>() == 0` on a
                // page-aligned base), and element-size divisibility were
                // validated in `from_map`; `T: Pod` means every bit
                // pattern is a valid value; the map is immutable and kept
                // alive by the Arc for at least the borrow's lifetime.
                unsafe {
                    std::slice::from_raw_parts(map.bytes().as_ptr().add(*off).cast::<T>(), *len)
                }
            }
        }
    }
}

impl<T: Pod> Deref for Blob<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> From<Vec<T>> for Blob<T> {
    fn from(v: Vec<T>) -> Blob<T> {
        Blob::Owned(v)
    }
}

impl<T: Pod> Default for Blob<T> {
    fn default() -> Blob<T> {
        Blob::Owned(Vec::new())
    }
}

impl<T: Pod> Clone for Blob<T> {
    fn clone(&self) -> Blob<T> {
        match self {
            Blob::Owned(v) => Blob::Owned(v.clone()),
            // cloning a mapped blob clones the Arc, not the bytes
            Blob::Mapped { map, off, len } => {
                Blob::Mapped { map: map.clone(), off: *off, len: *len }
            }
        }
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for Blob<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // print like the Vec this replaced so derived Debug output on
        // containing structs stays familiar
        f.debug_list().entries(self.iter()).finish()
    }
}

impl<T: Pod + PartialEq> PartialEq for Blob<T> {
    fn eq(&self, other: &Blob<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn owned_blob_behaves_like_vec() {
        let mut b: Blob<u32> = vec![1u32, 2, 3].into();
        assert_eq!(&b[..], &[1, 2, 3]);
        assert_eq!(b.len(), 3);
        assert!(!b.is_mapped());
        b.to_mut().push(4);
        assert_eq!(&b[..], &[1, 2, 3, 4]);
        assert_eq!(b.clone(), b);
    }

    #[test]
    fn mapped_blob_reads_and_copies_on_write() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("gmips_blob_test_{}", std::process::id()));
        {
            let mut f = File::create(&path).unwrap();
            // 64 zero bytes of "header", then 4 f32 values
            f.write_all(&[0u8; 64]).unwrap();
            for v in [1.5f32, -2.0, 0.0, 3.25] {
                f.write_all(&v.to_le_bytes()).unwrap();
            }
        }
        let file = File::open(&path).unwrap();
        match Mmap::map(&file) {
            Ok(map) => {
                let map = Arc::new(map);
                let mut b: Blob<f32> = Blob::from_map(map.clone(), 64, 16).unwrap();
                assert!(b.is_mapped());
                assert_eq!(&b[..], &[1.5, -2.0, 0.0, 3.25]);
                // misaligned / out-of-bounds / ragged ranges are rejected
                assert!(Blob::<f32>::from_map(map.clone(), 65, 8).is_none());
                assert!(Blob::<f32>::from_map(map.clone(), 64, 17).is_none());
                assert!(Blob::<f32>::from_map(map.clone(), 64, 1 << 30).is_none());
                // copy-on-write detaches from the map
                b.to_mut()[0] = 9.0;
                assert!(!b.is_mapped());
                assert_eq!(b[0], 9.0);
            }
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::Unsupported),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn from_map_rejects_every_misaligned_offset() {
        // adversarial alignment sweep: for each Pod width, every offset
        // that is not a multiple of the alignment must be rejected —
        // from_map is the sole gate between untrusted snapshot offsets
        // and the `from_raw_parts` reinterpretation in as_slice
        let dir = std::env::temp_dir();
        let path = dir.join(format!("gmips_blob_align_{}", std::process::id()));
        {
            let mut f = File::create(&path).unwrap();
            f.write_all(&[0xabu8; 256]).unwrap();
        }
        let file = File::open(&path).unwrap();
        if let Ok(map) = Mmap::map(&file) {
            let map = Arc::new(map);
            for off in 0..16usize {
                let ok_u32 = Blob::<u32>::from_map(map.clone(), off, 4).is_some();
                assert_eq!(ok_u32, off % 4 == 0, "u32 off={off}");
                let ok_u64 = Blob::<u64>::from_map(map.clone(), off, 8).is_some();
                assert_eq!(ok_u64, off % 8 == 0, "u64 off={off}");
                let ok_i16 = Blob::<i16>::from_map(map.clone(), off, 2).is_some();
                assert_eq!(ok_i16, off % 2 == 0, "i16 off={off}");
                // u8 has alignment 1: every offset is fine
                assert!(Blob::<u8>::from_map(map.clone(), off, 1).is_some(), "u8 off={off}");
            }
            // ragged byte lengths (not a whole number of elements)
            for bytes in [1usize, 2, 3, 5, 6, 7] {
                assert!(Blob::<u32>::from_map(map.clone(), 0, bytes).is_none(), "bytes={bytes}");
            }
            // off + bytes overflow must not wrap past the bounds check
            assert!(Blob::<u8>::from_map(map.clone(), usize::MAX, 2).is_none());
            assert!(Blob::<u8>::from_map(map, 8, usize::MAX - 4).is_none());
        }
        let _ = std::fs::remove_file(&path);
    }

    // Miri-lane subset: owned-mode views only (the mmap syscall is
    // outside Miri's supported FFI surface, so mapped mode is covered by
    // the ASan lane instead).
    #[test]
    fn miri_owned_blob_views_and_cow() {
        let mut b: Blob<f32> = vec![0.5f32, -1.0, 2.0].into();
        assert!(!b.is_mapped());
        assert_eq!(b.len(), 3);
        assert_eq!(&b[1..], &[-1.0, 2.0]);
        assert_eq!(b.iter().copied().sum::<f32>(), 1.5);
        b.to_mut()[2] = 4.0;
        assert_eq!(b[2], 4.0);
        let c = b.clone();
        assert_eq!(c, b);
        let empty: Blob<u64> = Blob::default();
        assert!(empty.is_empty());
        assert_eq!(format!("{empty:?}"), "[]");
    }
}
