//! Minimal JSON parser/writer.
//!
//! The offline registry has no `serde`, so the artifact manifest
//! (`artifacts/manifest.json`) and the TCP wire protocol use this small,
//! dependency-free JSON implementation. It supports the full JSON value
//! model (null/bool/number/string/array/object) with the restrictions that
//! numbers are parsed as `f64` and object key order is preserved
//! (insertion order) for deterministic round-trips.

use crate::error::{Error, Result};
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::json(format!("trailing garbage at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field, typed error otherwise.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::json(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::json(format!("expected non-negative integer, got {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::json(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(xs) => Ok(xs),
            _ => Err(Error::json(format!("expected array, got {self:?}"))),
        }
    }

    /// Parse an array of numbers into `Vec<f32>` (theta vectors on the wire).
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|x| x.as_f64().map(|v| v as f32))
            .collect()
    }

    /// Parse an array of numbers into `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::json(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::json(format!("bad number '{s}'")))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::json("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(Error::json("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap();
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::json("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(Error::json("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        Error::json("invalid utf-8 in string")
                    })?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(Error::json(format!("expected ',' or ']' at byte {}", self.i))),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(Error::json(format!("expected ',' or '}}' at byte {}", self.i))),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested_roundtrip() {
        let s = r#"{"op":"sample","theta":[0.1,-2,3e-4],"n":5,"tags":{"a":[true,null]}}"#;
        let v = Json::parse(s).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "sample");
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 5);
        let theta = v.get("theta").unwrap().as_f32_vec().unwrap();
        assert_eq!(theta.len(), 3);
        assert!((theta[2] - 3e-4).abs() < 1e-9);
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a":1.5,"b":"x"}"#).unwrap();
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("b").unwrap().as_f64().is_err());
        assert!(v.req("zz").is_err());
        assert!(v.get("a").unwrap().as_arr().is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
        let esc = Json::parse("\"\\u2603\"").unwrap();
        assert_eq!(esc.as_str().unwrap(), "☃");
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.25).to_string(), "5.25");
    }

    #[test]
    fn miri_parse_roundtrip_and_malformed() {
        // Miri-lane subset: the byte-cursor parser over nesting, escapes,
        // and malformed input (the wire protocol's trust boundary)
        let s = r#"{"ids":[1,2,3],"s":"a\"b\\\n\u2603","neg":-0.5,"deep":[[[]]],"t":true}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a\"b\\\n☃");
        assert_eq!(
            v.get("ids").unwrap().as_arr().unwrap().len(),
            3
        );
        for bad in ["", "{", "[1,", "\"\\u12", "\"\\q\"", "truX", "1e", "{\"a\":}", "nul"] {
            assert!(Json::parse(bad).is_err(), "input {bad:?} must error");
        }
    }
}
