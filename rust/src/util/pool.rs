//! Data-parallel helpers built on `crossbeam_utils::thread::scope` (the
//! offline registry ships neither rayon nor tokio).
//!
//! [`parallel_chunks`] splits an index range into contiguous chunks, one per
//! worker, and runs a closure per chunk on scoped threads; results are
//! returned in chunk order so deterministic reductions are possible.
//! [`WorkQueue`] is a tiny MPMC work-stealing-free queue used by the
//! coordinator's worker pool.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Number of worker threads to use by default: respects
/// `GMIPS_THREADS` env var, else `available_parallelism`.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("GMIPS_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `nthreads` contiguous chunks of
/// `[0, n)` on scoped threads, returning per-chunk results in order.
///
/// If `nthreads <= 1` or the range is small, runs inline (no threads).
pub fn parallel_chunks<T, F>(n: usize, nthreads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, usize, usize) -> T + Sync,
{
    let nthreads = nthreads.max(1).min(n.max(1));
    if nthreads == 1 {
        return vec![f(0, 0, n)];
    }
    let chunk = n.div_ceil(nthreads);
    crossbeam_utils::thread::scope(|s| {
        let mut handles = Vec::with_capacity(nthreads);
        for t in 0..nthreads {
            // clamp BOTH ends: with chunk = ceil(n/nthreads), a late
            // chunk's start can exceed n (e.g. n=5, nthreads=4 → t=3
            // starts at 6), which must become an empty [n, n) range, not
            // an inverted one
            let start = (t * chunk).min(n);
            let end = ((t + 1) * chunk).min(n);
            let f = &f;
            handles.push(s.spawn(move |_| f(t, start, end)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
    .unwrap()
}

/// Atomically-indexed dynamic scheduler: workers repeatedly claim the next
/// block of `block` indices until `n` is exhausted. Better load balance
/// than static chunks when per-item cost varies (e.g. IVF probes).
pub fn parallel_blocks<F>(n: usize, block: usize, nthreads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let nthreads = nthreads.max(1);
    if nthreads == 1 || n <= block {
        let mut s = 0;
        while s < n {
            f(s, (s + block).min(n));
            s += block;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    crossbeam_utils::thread::scope(|s| {
        for _ in 0..nthreads {
            let cursor = &cursor;
            let f = &f;
            s.spawn(move |_| loop {
                let start = cursor.fetch_add(block, Ordering::Relaxed);
                if start >= n {
                    return;
                }
                f(start, (start + block).min(n));
            });
        }
    })
    .unwrap();
}

/// A bounded blocking FIFO queue (MPMC) — the coordinator's submission
/// queue. `push` blocks when full (backpressure); `pop` blocks when empty;
/// `close` wakes all waiters and makes subsequent `pop` return `None` once
/// drained.
pub struct WorkQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct QueueInner<T> {
    items: std::collections::VecDeque<T>,
    closed: bool,
}

impl<T> WorkQueue<T> {
    pub fn new(capacity: usize) -> Self {
        WorkQueue {
            inner: Mutex::new(QueueInner { items: std::collections::VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Lock the queue state, recovering from poisoning. A worker that
    /// panicked while holding the lock can only have left the queue in a
    /// structurally valid state (every critical section mutates the
    /// `VecDeque` through safe, panic-free operations), so propagating
    /// the poison would turn one worker's panic into a wedged server.
    fn locked(&self) -> std::sync::MutexGuard<'_, QueueInner<T>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Blocking push. Returns `false` if the queue is closed.
    pub fn push(&self, item: T) -> bool {
        let mut g = self.locked();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap_or_else(|p| p.into_inner());
        }
        if g.closed {
            return false;
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking push. `Err(item)` when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut g = self.locked();
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop. `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut g = self.locked();
        loop {
            if let Some(item) = g.items.pop_front() {
                drop(g);
                self.not_full.notify_one();
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Blocking batch pop: waits for at least one item, then drains up to
    /// `max` items that are already queued **without waiting for more**.
    /// This is the coordinator's batching primitive — under load the
    /// queue fills while workers are busy and whole batches come off at
    /// once (amortized index scans); when idle it degrades to per-item
    /// pops with no added latency. `None` once closed *and* drained.
    pub fn pop_batch(&self, max: usize) -> Option<Vec<T>> {
        let max = max.max(1);
        let mut g = self.locked();
        loop {
            if !g.items.is_empty() {
                let take = g.items.len().min(max);
                let items: Vec<T> = g.items.drain(..take).collect();
                drop(g);
                self.not_full.notify_all();
                return Some(items);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// [`pop_batch`](Self::pop_batch) with a bounded micro-wait: after
    /// the first item arrives, keep waiting up to `wait` for the batch to
    /// deepen toward `max` before serving it. Under moderate load this
    /// trades a little p50 latency for markedly deeper batches (and thus
    /// better scan amortization); `wait == 0` is exactly `pop_batch`.
    /// `None` once closed *and* drained.
    pub fn pop_batch_wait(&self, max: usize, wait: std::time::Duration) -> Option<Vec<T>> {
        if wait.is_zero() {
            return self.pop_batch(max);
        }
        let max = max.max(1);
        let mut g = self.locked();
        loop {
            // block until the first item (or close)
            while g.items.is_empty() {
                if g.closed {
                    return None;
                }
                g = self.not_empty.wait(g).unwrap_or_else(|p| p.into_inner());
            }
            // micro-wait: deepen the batch until `max`, close, or the deadline
            let deadline = std::time::Instant::now() + wait;
            while g.items.len() < max && !g.closed {
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self
                    .not_empty
                    .wait_timeout(g, deadline - now)
                    .unwrap_or_else(|p| p.into_inner());
                g = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let take = g.items.len().min(max);
            if take == 0 {
                // a concurrent consumer drained the queue during our
                // micro-wait; go back to waiting for a first item so we
                // uphold pop_batch's never-empty contract
                continue;
            }
            let items: Vec<T> = g.items.drain(..take).collect();
            drop(g);
            self.not_full.notify_all();
            return Some(items);
        }
    }

    /// Close the queue; wakes all blocked producers/consumers.
    pub fn close(&self) {
        let mut g = self.locked();
        g.closed = true;
        drop(g);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn len(&self) -> usize {
        self.locked().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn parallel_chunks_covers_range() {
        let parts = parallel_chunks(1003, 4, |_, s, e| (s, e));
        assert_eq!(parts.first().unwrap().0, 0);
        assert_eq!(parts.last().unwrap().1, 1003);
        for w in parts.windows(2) {
            assert_eq!(w[0].1, w[1].0, "chunks must be contiguous");
        }
    }

    #[test]
    fn parallel_chunks_single_thread_inline() {
        let parts = parallel_chunks(10, 1, |t, s, e| (t, s, e));
        assert_eq!(parts, vec![(0, 0, 10)]);
    }

    #[test]
    fn parallel_chunks_overshooting_chunks_are_empty_not_inverted() {
        // n=5, 4 threads → chunk=2 → thread 3 would start at 6 > n; it
        // must receive the empty range [5, 5), never an inverted slice
        let parts = parallel_chunks(5, 4, |_, s, e| (s, e));
        assert_eq!(parts.len(), 4);
        for &(s, e) in &parts {
            assert!(s <= e, "inverted range ({s}, {e})");
        }
        assert_eq!(parts.iter().map(|&(s, e)| e - s).sum::<usize>(), 5);
        assert_eq!(parts.last().unwrap(), &(5, 5));
    }

    #[test]
    fn parallel_chunks_sums_correctly() {
        let parts = parallel_chunks(10_000, 4, |_, s, e| (s..e).map(|i| i as u64).sum::<u64>());
        let total: u64 = parts.iter().sum();
        assert_eq!(total, 9999u64 * 10_000 / 2);
    }

    #[test]
    fn parallel_blocks_visits_everything_once() {
        let n = 5000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_blocks(n, 128, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn queue_fifo_and_close() {
        let q = WorkQueue::new(4);
        assert!(q.push(1));
        assert!(q.push(2));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_backpressure_and_threads() {
        let q = Arc::new(WorkQueue::new(2));
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..100 {
                assert!(qc.push(i));
            }
            qc.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<i32>>());
    }

    #[test]
    fn pop_batch_drains_queued_items_without_waiting() {
        let q = WorkQueue::new(16);
        for i in 0..5 {
            assert!(q.push(i));
        }
        assert_eq!(q.pop_batch(3), Some(vec![0, 1, 2]));
        assert_eq!(q.pop_batch(8), Some(vec![3, 4]));
        q.close();
        assert_eq!(q.pop_batch(4), None);
    }

    #[test]
    fn pop_batch_blocks_until_first_item() {
        let q = Arc::new(WorkQueue::new(4));
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert!(qc.push(7));
            qc.close();
        });
        assert_eq!(q.pop_batch(4), Some(vec![7]));
        assert_eq!(q.pop_batch(4), None);
        producer.join().unwrap();
    }

    #[test]
    fn pop_batch_wait_deepens_the_batch() {
        use std::time::Duration;
        let q = Arc::new(WorkQueue::new(16));
        let qc = q.clone();
        let producer = std::thread::spawn(move || {
            assert!(qc.push(1));
            // second item lands well inside the consumer's micro-wait
            std::thread::sleep(Duration::from_millis(30));
            assert!(qc.push(2));
            qc.close();
        });
        // generous wait so the test is robust on slow CI machines
        let got = q.pop_batch_wait(8, Duration::from_secs(5));
        assert_eq!(got, Some(vec![1, 2]));
        assert_eq!(q.pop_batch_wait(8, Duration::from_secs(5)), None);
        producer.join().unwrap();
    }

    #[test]
    fn pop_batch_wait_zero_is_pop_batch() {
        use std::time::Duration;
        let q = WorkQueue::new(8);
        for i in 0..3 {
            assert!(q.push(i));
        }
        assert_eq!(q.pop_batch_wait(2, Duration::ZERO), Some(vec![0, 1]));
        assert_eq!(q.pop_batch_wait(8, Duration::ZERO), Some(vec![2]));
        q.close();
        assert_eq!(q.pop_batch_wait(8, Duration::ZERO), None);
    }

    #[test]
    fn pop_batch_wait_returns_at_max_without_waiting_out_the_clock() {
        use std::time::{Duration, Instant};
        let q = WorkQueue::new(16);
        for i in 0..5 {
            assert!(q.push(i));
        }
        let t0 = Instant::now();
        // max already queued → must return immediately despite a long wait
        assert_eq!(q.pop_batch_wait(5, Duration::from_secs(30)), Some(vec![0, 1, 2, 3, 4]));
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn try_push_full() {
        let q = WorkQueue::new(1);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_err());
        assert_eq!(q.pop(), Some(1));
        assert!(q.try_push(3).is_ok());
    }
}
