//! Bounded top-k collection — the score-selection primitive behind every
//! MIPS scan.
//!
//! [`TopK`] is a fixed-capacity min-heap over `(score, id)` pairs: pushing
//! is `O(log k)` only when the candidate beats the current k-th best, and a
//! cheap `O(1)` threshold rejection otherwise. On the brute/IVF scan hot
//! path the overwhelming majority of candidates fail the threshold test, so
//! amortized cost per candidate is a single compare.

/// A scored element.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub id: u32,
    pub score: f32,
}

/// Fixed-capacity top-k collector (largest scores win).
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// min-heap on score: `heap[0]` is the *worst* retained element.
    heap: Vec<Scored>,
}

impl TopK {
    /// Create a collector retaining the `k` largest-scored elements.
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "k must be positive");
        TopK { k, heap: Vec::with_capacity(k) }
    }

    /// Current admission threshold: a candidate must strictly beat this to
    /// enter once the collector is full. `-inf` while not full.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::NEG_INFINITY
        } else {
            self.heap[0].score
        }
    }

    /// Number of retained elements (`<= k`).
    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been pushed.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer a candidate. Ties are broken toward smaller ids so the
    /// retained set is deterministic regardless of push order.
    #[inline]
    pub fn push(&mut self, id: u32, score: f32) {
        if self.heap.len() < self.k {
            self.heap.push(Scored { id, score });
            self.sift_up(self.heap.len() - 1);
        } else {
            let worst = self.heap[0];
            if score > worst.score || (score == worst.score && id < worst.id) {
                self.heap[0] = Scored { id, score };
                self.sift_down(0);
            }
        }
    }

    /// Offer a whole block of contiguous ids `[base, base + scores.len())`.
    /// This is the form the scorer backends produce.
    pub fn push_block(&mut self, base: u32, scores: &[f32]) {
        let mut thr = self.threshold();
        for (j, &s) in scores.iter().enumerate() {
            // >= so score ties are offered to push(), which tie-breaks by id
            if s >= thr || self.heap.len() < self.k {
                self.push(base + j as u32, s);
                thr = self.threshold();
            }
        }
    }

    /// Offer a block of scores for explicit (gathered) ids.
    pub fn push_ids(&mut self, ids: &[u32], scores: &[f32]) {
        debug_assert_eq!(ids.len(), scores.len());
        let mut thr = self.threshold();
        for (&id, &s) in ids.iter().zip(scores) {
            if s >= thr || self.heap.len() < self.k {
                self.push(id, s);
                thr = self.threshold();
            }
        }
    }

    /// Consume the collector, returning elements sorted by descending score
    /// (ties broken by ascending id for determinism).
    pub fn into_sorted(mut self) -> Vec<Scored> {
        self.heap.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        self.heap
    }

    /// Clear retained elements, keeping capacity (scratch reuse).
    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Heap order: `a` is worse than `b` if it has a lower score, or an
    /// equal score with a larger id (so ties evict the largest id first).
    #[inline]
    fn worse(a: Scored, b: Scored) -> bool {
        a.score < b.score || (a.score == b.score && a.id > b.id)
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && Self::worse(self.heap[l], self.heap[smallest]) {
                smallest = l;
            }
            if r < n && Self::worse(self.heap[r], self.heap[smallest]) {
                smallest = r;
            }
            if smallest == i {
                return;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }
}

/// K-way merge of independently collected top-k fragments into one global
/// top-k collector — the reduction step shared by the sharded fan-out
/// ([`crate::shard`]) and the IVF merged-probe batch scan. Because
/// [`TopK`] retention is push-order independent (deterministic
/// `(score, id)` tie-break), the merged collector retains exactly the `k`
/// best elements of the fragment union regardless of fragment boundaries
/// or ordering — which is what makes sharded and unsharded scans
/// bit-identical.
pub fn merge_topk<I>(fragments: I, k: usize) -> TopK
where
    I: IntoIterator<Item = Vec<Scored>>,
{
    let mut tk = TopK::new(k);
    for frag in fragments {
        for s in frag {
            tk.push(s.id, s.score);
        }
    }
    tk
}

/// Exact top-k by full sort — the reference implementation used in tests
/// and for small inputs.
pub fn topk_reference(scores: &[f32], k: usize) -> Vec<Scored> {
    let mut all: Vec<Scored> = scores
        .iter()
        .enumerate()
        .map(|(i, &s)| Scored { id: i as u32, score: s })
        .collect();
    all.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    all.truncate(k);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn matches_reference_on_random_inputs() {
        let mut rng = Pcg64::new(5);
        for trial in 0..50 {
            let n = 1 + (rng.next_below(2000) as usize);
            let k = 1 + (rng.next_below(64) as usize);
            let scores: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let mut tk = TopK::new(k);
            tk.push_block(0, &scores);
            let got = tk.into_sorted();
            let want = topk_reference(&scores, k);
            assert_eq!(got.len(), want.len(), "trial {trial}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.score, w.score, "trial {trial}");
            }
        }
    }

    #[test]
    fn threshold_semantics() {
        let mut tk = TopK::new(2);
        assert_eq!(tk.threshold(), f32::NEG_INFINITY);
        tk.push(0, 1.0);
        tk.push(1, 3.0);
        assert_eq!(tk.threshold(), 1.0);
        tk.push(2, 2.0); // evicts 1.0
        assert_eq!(tk.threshold(), 2.0);
        tk.push(3, 0.5); // rejected
        let out = tk.into_sorted();
        assert_eq!(out[0].score, 3.0);
        assert_eq!(out[1].score, 2.0);
    }

    #[test]
    fn fewer_than_k_elements() {
        let mut tk = TopK::new(10);
        tk.push_block(100, &[1.0, 2.0]);
        let out = tk.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, 101);
    }

    #[test]
    fn push_ids_gathers() {
        let mut tk = TopK::new(2);
        tk.push_ids(&[7, 3, 9], &[0.5, 2.0, 1.0]);
        let out = tk.into_sorted();
        assert_eq!(out[0].id, 3);
        assert_eq!(out[1].id, 9);
    }

    #[test]
    fn deterministic_tiebreak() {
        let mut tk = TopK::new(3);
        tk.push_ids(&[5, 1, 9, 2], &[1.0, 1.0, 1.0, 1.0]);
        let out = tk.into_sorted();
        let ids: Vec<u32> = out.iter().map(|s| s.id).collect();
        assert_eq!(ids, vec![1, 2, 5]);
    }

    #[test]
    fn merge_topk_equals_flat_collection() {
        // merging arbitrary fragmentations of a score stream must equal
        // collecting the stream directly (push-order independence)
        let mut rng = Pcg64::new(9);
        for trial in 0..20 {
            let n = 50 + rng.next_below(500) as usize;
            let k = 1 + rng.next_below(32) as usize;
            let scored: Vec<Scored> = (0..n)
                .map(|i| Scored { id: i as u32, score: (rng.gaussian() as f32 * 10.0).round() })
                .collect();
            let mut flat = TopK::new(k);
            for s in &scored {
                flat.push(s.id, s.score);
            }
            // split into ragged fragments
            let nfrag = 1 + rng.next_below(7) as usize;
            let mut frags: Vec<Vec<Scored>> = vec![Vec::new(); nfrag];
            for (i, s) in scored.into_iter().enumerate() {
                frags[i % nfrag].push(s);
            }
            let merged = merge_topk(frags, k).into_sorted();
            let want = flat.into_sorted();
            assert_eq!(merged.len(), want.len(), "trial {trial}");
            for (g, w) in merged.iter().zip(&want) {
                assert_eq!(g.id, w.id, "trial {trial}");
                assert_eq!(g.score, w.score, "trial {trial}");
            }
        }
    }

    #[test]
    fn clear_reuses() {
        let mut tk = TopK::new(4);
        tk.push_block(0, &[1.0, 2.0, 3.0]);
        tk.clear();
        assert!(tk.is_empty());
        tk.push_block(0, &[5.0]);
        assert_eq!(tk.into_sorted()[0].score, 5.0);
    }
}
