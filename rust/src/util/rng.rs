//! Deterministic pseudo-random number generation and exact samplers.
//!
//! The offline crate registry ships no `rand` crate, so gmips carries its
//! own generator: **PCG64 (XSL-RR 128/64)**, seeded through SplitMix64.
//! On top of the raw generator we implement every distribution the paper's
//! algorithms need, all *exact* (no approximate samplers on the hot path):
//!
//! * `Uniform(0,1)` with 53-bit mantissas,
//! * `Gumbel(0,1)` via inverse CDF `G = -ln(-ln U)` (paper Eq. 4–5),
//! * **truncated Gumbel** `G | G > B` via inverse CDF on the conditioned
//!   uniform (`U ~ Uniform(exp(-exp(-B)), 1)`), the core of the paper's
//!   lazy-instantiation trick (Algorithm 1, step 7),
//! * `Binomial(n, p)` via exact **geometric-skip** counting, `O(np)`
//!   expected time — ideal here because Algorithm 1/2 always draw
//!   `m ~ Binomial(n - k, p)` with `np ≈ l = O(√n)`,
//! * Gaussian via Marsaglia polar (data generators),
//! * distinct uniform subsets (tail sample `T ⊂ X \ S`).

use rustc_hash::FxHashSet;

/// SplitMix64 — used only to expand user seeds into PCG state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG64 XSL-RR 128/64 generator.
///
/// 128-bit LCG state, 64-bit output via xor-shift-low + random rotation.
/// Passes PractRand/BigCrush per the PCG paper; cheap on 64-bit targets.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second Gaussian from the polar method
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream 0).
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0)
    }

    /// Create a generator with an explicit stream id. Distinct streams from
    /// the same seed are independent — used to give each coordinator worker
    /// its own stream.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let s1 = splitmix64(&mut sm);
        let mut sm2 = stream ^ 0xDEAD_BEEF_CAFE_F00D;
        let i0 = splitmix64(&mut sm2);
        let i1 = splitmix64(&mut sm2);
        let mut rng = Pcg64 {
            state: ((s0 as u128) << 64) | s1 as u128,
            // increment must be odd
            inc: (((i0 as u128) << 64) | i1 as u128) | 1,
            gauss_spare: None,
        };
        // burn-in so low-entropy seeds decorrelate
        rng.next_u64();
        rng.next_u64();
        rng
    }

    /// A generator keyed by `(seed, round, salt, idx)` — the **one**
    /// keyed-stream derivation shared by every sharded subsystem (sampler
    /// top/tail streams, Algorithm 3/4 tail draws). Distinct keys give
    /// independent streams: `round` and `salt` are mixed into the SplitMix
    /// seed expansion with different odd multipliers, `idx` selects the
    /// PCG stream (so e.g. per-id or per-shard streams from one
    /// `(seed, round, salt)` family are independent), and
    /// [`new_stream`](Self::new_stream)'s burn-in decorrelates low-entropy
    /// keys. Callers distinguish *what* the stream drives via `salt` and
    /// *which instance* via `idx`; replayability comes from passing the
    /// same `round` again.
    #[inline]
    pub fn keyed(seed: u64, round: u64, salt: u64, idx: u64) -> Self {
        let mut h = seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h = h.wrapping_add(salt.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        Pcg64::new_stream(h, idx)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with full 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe as a log argument.
    #[inline]
    pub fn next_f64_open0(&mut self) -> f64 {
        1.0 - self.next_f64()
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift with
    /// rejection (unbiased).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= lo.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard Gumbel sample, `G = -ln(-ln U)` (paper Eq. 4–5).
    #[inline]
    pub fn gumbel(&mut self) -> f64 {
        let u = self.next_f64_open0();
        -(-u.ln()).ln()
    }

    /// Gumbel conditioned on `G > b`, by inverse CDF on the conditioned
    /// uniform: `U ~ Uniform(F(b), 1)`, `G = F⁻¹(U)` with
    /// `F(x) = exp(-exp(-x))` (paper Algorithm 1, lazy tail Gumbels).
    ///
    /// Numerically careful form: `-ln(E)` where
    /// `E ~ Uniform(0, exp(-b))`-ish is handled in log-space so that very
    /// large `b` (deep truncation) stays finite.
    #[inline]
    pub fn gumbel_above(&mut self, b: f64) -> f64 {
        // F(b) = exp(-exp(-b)); want U in (F(b), 1), G = -ln(-ln U).
        // Write -ln U = E with E ~ Uniform(0, exp(-b)) in distribution?
        // Not exactly: if U ~ Unif(F(b),1) then -ln U is NOT uniform, so do
        // the straightforward inverse transform but guard the endpoints.
        let fb = (-(-b).exp()).exp(); // F(b) in [0,1)
        if fb >= 1.0 {
            // b so large that F(b) rounds to 1: fall back to the asymptotic
            // exponential-tail representation: G ≈ b - ln(1 - V·...) ≈
            // b + Exp(1)·e^{-?}. For F(b)→1, (G - b) | G > b converges to
            // an exponential with rate e^{-b}·e^{...}; in the regime where
            // f64 saturates (b ≳ 36), P(G>b) < 2e-16 and callers never
            // take this branch with meaningful probability mass; return b
            // plus a standard exponential scaled conservatively.
            return b + self.exponential(1.0);
        }
        let u = self.uniform(fb, 1.0).max(fb + f64::EPSILON * fb.max(1e-300));
        let neg_ln_u = -u.ln(); // in (0, exp(-b))
        let neg_ln_u = neg_ln_u.max(f64::MIN_POSITIVE);
        -neg_ln_u.ln()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64_open0().ln() / lambda
    }

    /// Standard Gaussian via Marsaglia's polar method (caches the spare).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let x = 2.0 * self.next_f64() - 1.0;
            let y = 2.0 * self.next_f64() - 1.0;
            let s = x * x + y * y;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(y * f);
                return x * f;
            }
        }
    }

    /// Exact `Binomial(n, p)` via geometric-skip counting.
    ///
    /// Expected time `O(np + 1)`: we jump between successes with geometric
    /// gaps `g = floor(ln U / ln(1-p))`. Exact for all `p ∈ [0,1]`; for
    /// `p > 1/2` we count failures instead (symmetry) so the bound becomes
    /// `O(n·min(p,1-p) + 1)`.
    ///
    /// This is the sampler behind Algorithms 1 and 2, where
    /// `m ~ Binomial(n - k, 1 - exp(-exp(-B)))` with success probability
    /// `≈ l/n`, so expected cost `O(l) = O(√n)`.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let log_q = (1.0 - p).ln_1p_neg(); // ln(1-p), stable for small p
        let mut count = 0u64;
        let mut i: u64 = 0;
        loop {
            let u = self.next_f64_open0();
            let skip = (u.ln() / log_q).floor();
            // skip can exceed u64 range when p is astronomically small
            if !skip.is_finite() || skip >= (n - i) as f64 {
                return count;
            }
            i += skip as u64 + 1;
            if i > n {
                return count;
            }
            count += 1;
            if i == n {
                return count;
            }
        }
    }

    /// Sample `m` *distinct* indices uniformly from `[0, n)` excluding the
    /// set `exclude`. Rejection sampling — cheap because in our use
    /// `m + |exclude| << n` (both are `O(√n)`).
    ///
    /// Panics (debug) if `m > n - exclude.len()`.
    pub fn distinct_excluding(
        &mut self,
        n: u64,
        m: usize,
        exclude: &FxHashSet<u32>,
    ) -> Vec<u32> {
        debug_assert!((m as u64) <= n - exclude.len() as u64);
        let mut out = Vec::with_capacity(m);
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        seen.reserve(m);
        while out.len() < m {
            let c = self.next_below(n) as u32;
            if exclude.contains(&c) || !seen.insert(c) {
                continue;
            }
            out.push(c);
        }
        out
    }

    /// Sample `m` indices uniformly *with replacement* from `[0, n)`
    /// excluding `exclude` (Algorithm 3/4 sample the tail with
    /// replacement).
    pub fn with_replacement_excluding(
        &mut self,
        n: u64,
        m: usize,
        exclude: &FxHashSet<u32>,
    ) -> Vec<u32> {
        let mut out = Vec::with_capacity(m);
        while out.len() < m {
            let c = self.next_below(n) as u32;
            if exclude.contains(&c) {
                continue;
            }
            out.push(c);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Draw an index from explicit (unnormalized, non-negative) weights.
    /// Linear scan inverse-CDF — used only off the hot path (tests, data
    /// generators).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// `ln(1-p)` computed stably; tiny helper trait so the binomial code reads
/// cleanly.
trait Ln1pNeg {
    fn ln_1p_neg(self) -> f64;
}
impl Ln1pNeg for f64 {
    #[inline]
    fn ln_1p_neg(self) -> f64 {
        // self is (1 - p); compute ln(self) via ln_1p on (self - 1) = -p
        (self - 1.0).ln_1p()
    }
}

/// Standard Gumbel CDF `F(x) = exp(-exp(-x))`.
#[inline]
pub fn gumbel_cdf(x: f64) -> f64 {
    (-(-x).exp()).exp()
}

/// Standard Gumbel quantile `F⁻¹(u) = -ln(-ln u)`.
#[inline]
pub fn gumbel_quantile(u: f64) -> f64 {
    -(-u.ln()).ln()
}

/// Euler–Mascheroni constant (mean of the standard Gumbel).
pub const EULER_GAMMA: f64 = 0.577_215_664_901_532_9;

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_var(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, v)
    }

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::new_stream(42, 1);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3, "streams should not collide");
    }

    #[test]
    fn keyed_streams_deterministic_and_distinct() {
        // same key → same stream; changing ANY coordinate → a different one
        let mut a = Pcg64::keyed(7, 3, 0x517, 42);
        let mut b = Pcg64::keyed(7, 3, 0x517, 42);
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        for other in [
            Pcg64::keyed(8, 3, 0x517, 42),
            Pcg64::keyed(7, 4, 0x517, 42),
            Pcg64::keyed(7, 3, 0x518, 42),
            Pcg64::keyed(7, 3, 0x517, 43),
        ] {
            let mut a = Pcg64::keyed(7, 3, 0x517, 42);
            let mut o = other;
            let same = (0..100).filter(|_| a.next_u64() == o.next_u64()).count();
            assert!(same < 3, "keyed streams should not collide");
        }
    }

    #[test]
    fn miri_keyed_stream_derivation() {
        // Miri-lane subset: keyed derivation is pure integer mixing, so
        // the full determinism/distinctness contract runs cheaply —
        // identical keys replay, each coordinate perturbs the stream
        let mut a = Pcg64::keyed(1, 2, 3, 4);
        let mut b = Pcg64::keyed(1, 2, 3, 4);
        let draws: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        for &x in &draws {
            assert_eq!(x, b.next_u64());
        }
        for other in [
            Pcg64::keyed(2, 2, 3, 4),
            Pcg64::keyed(1, 3, 3, 4),
            Pcg64::keyed(1, 2, 4, 4),
            Pcg64::keyed(1, 2, 3, 5),
        ] {
            let mut o = other;
            let first: Vec<u64> = (0..16).map(|_| o.next_u64()).collect();
            assert_ne!(draws, first, "keyed stream must differ");
        }
        // bounded draw stays in range under Miri too
        let mut r = Pcg64::keyed(9, 9, 9, 9);
        for _ in 0..32 {
            assert!(r.next_below(10) < 10);
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Pcg64::new(7);
        let xs: Vec<f64> = (0..200_000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let (m, v) = mean_var(&xs);
        assert!((m - 0.5).abs() < 5e-3, "mean={m}");
        assert!((v - 1.0 / 12.0).abs() < 5e-3, "var={v}");
    }

    #[test]
    fn next_below_unbiased() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gumbel_moments() {
        // mean = γ ≈ 0.5772, var = π²/6 ≈ 1.6449
        let mut r = Pcg64::new(11);
        let xs: Vec<f64> = (0..400_000).map(|_| r.gumbel()).collect();
        let (m, v) = mean_var(&xs);
        assert!((m - EULER_GAMMA).abs() < 1e-2, "mean={m}");
        assert!((v - std::f64::consts::PI.powi(2) / 6.0).abs() < 3e-2, "var={v}");
    }

    #[test]
    fn truncated_gumbel_matches_rejection() {
        // Compare gumbel_above(b) against brute-force rejection sampling.
        let mut r = Pcg64::new(13);
        for &b in &[-1.0, 0.0, 1.5, 3.0] {
            let fast: Vec<f64> = (0..60_000).map(|_| r.gumbel_above(b)).collect();
            assert!(fast.iter().all(|&g| g > b), "b={b}");
            let mut rej = Vec::with_capacity(60_000);
            while rej.len() < 60_000 {
                let g = r.gumbel();
                if g > b {
                    rej.push(g);
                }
            }
            let (mf, vf) = mean_var(&fast);
            let (mr, vr) = mean_var(&rej);
            assert!((mf - mr).abs() < 0.03, "b={b} mf={mf} mr={mr}");
            assert!((vf - vr).abs() < 0.08, "b={b} vf={vf} vr={vr}");
        }
    }

    #[test]
    fn gumbel_above_extreme_threshold_finite() {
        let mut r = Pcg64::new(17);
        for &b in &[20.0, 40.0, 100.0] {
            let g = r.gumbel_above(b);
            assert!(g.is_finite() && g > b);
        }
    }

    #[test]
    fn binomial_moments_small_p() {
        let mut r = Pcg64::new(19);
        let (n, p) = (1_000_000u64, 2e-4);
        let xs: Vec<f64> = (0..20_000).map(|_| r.binomial(n, p) as f64).collect();
        let (m, v) = mean_var(&xs);
        let (em, ev) = (n as f64 * p, n as f64 * p * (1.0 - p));
        assert!((m - em).abs() < 0.35, "m={m} want {em}");
        assert!((v - ev).abs() < ev * 0.06, "v={v} want {ev}");
    }

    #[test]
    fn binomial_edge_cases() {
        let mut r = Pcg64::new(23);
        assert_eq!(r.binomial(0, 0.5), 0);
        assert_eq!(r.binomial(10, 0.0), 0);
        assert_eq!(r.binomial(10, 1.0), 10);
        // p > 1/2 symmetry path
        let xs: Vec<f64> = (0..30_000).map(|_| r.binomial(20, 0.9) as f64).collect();
        let (m, _) = mean_var(&xs);
        assert!((m - 18.0).abs() < 0.1, "m={m}");
        // all results within range
        for _ in 0..1000 {
            let b = r.binomial(5, 0.3);
            assert!(b <= 5);
        }
    }

    #[test]
    fn binomial_matches_bernoulli_reference() {
        // chi-square-ish check against direct Bernoulli summation
        let mut r = Pcg64::new(29);
        let (n, p) = (50u64, 0.13);
        let mut hist_fast = [0f64; 51];
        let mut hist_ref = [0f64; 51];
        for _ in 0..40_000 {
            hist_fast[r.binomial(n, p) as usize] += 1.0;
            let direct = (0..n).filter(|_| r.next_f64() < p).count();
            hist_ref[direct] += 1.0;
        }
        for i in 0..20 {
            let (a, b) = (hist_fast[i], hist_ref[i]);
            if a + b > 200.0 {
                assert!(
                    (a - b).abs() / (a + b).sqrt() < 4.5,
                    "bin {i}: fast={a} ref={b}"
                );
            }
        }
    }

    #[test]
    fn distinct_excluding_properties() {
        let mut r = Pcg64::new(31);
        let exclude: FxHashSet<u32> = (0..50u32).collect();
        let s = r.distinct_excluding(1000, 100, &exclude);
        assert_eq!(s.len(), 100);
        let uniq: FxHashSet<u32> = s.iter().copied().collect();
        assert_eq!(uniq.len(), 100, "must be distinct");
        assert!(s.iter().all(|&i| i >= 50 && i < 1000));
    }

    #[test]
    fn with_replacement_excluding_properties() {
        let mut r = Pcg64::new(37);
        let exclude: FxHashSet<u32> = [3u32, 4, 5].into_iter().collect();
        let s = r.with_replacement_excluding(10, 5000, &exclude);
        assert_eq!(s.len(), 5000);
        assert!(s.iter().all(|&i| i < 10 && !exclude.contains(&i)));
        // all 7 allowed values should appear
        let uniq: FxHashSet<u32> = s.iter().copied().collect();
        assert_eq!(uniq.len(), 7);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(41);
        let xs: Vec<f64> = (0..400_000).map(|_| r.gaussian()).collect();
        let (m, v) = mean_var(&xs);
        assert!(m.abs() < 8e-3, "m={m}");
        assert!((v - 1.0).abs() < 1.5e-2, "v={v}");
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Pcg64::new(43);
        let w = [1.0, 2.0, 7.0];
        let mut counts = [0f64; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1.0;
        }
        assert!((counts[2] / 100_000.0 - 0.7).abs() < 0.01);
        assert!((counts[1] / 100_000.0 - 0.2).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(47);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn cdf_quantile_roundtrip() {
        for &u in &[0.01, 0.3, 0.5, 0.9, 0.999] {
            let x = gumbel_quantile(u);
            assert!((gumbel_cdf(x) - u).abs() < 1e-12);
        }
    }
}
