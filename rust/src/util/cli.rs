//! Tiny CLI argument parser (clap is not in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands (the first positional). Typed accessors with defaults
//! keep call sites short.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// program name (argv[0])
    pub program: String,
    /// `--key value` / `--key=value` options
    pub options: BTreeMap<String, String>,
    /// bare `--flag`s
    pub flags: Vec<String>,
    /// positionals in order (subcommand is `positional[0]` by convention)
    pub positional: Vec<String>,
}

/// Option keys that take a value; everything else starting `--` is a flag.
/// Parsers need this to disambiguate `--flag positional` from
/// `--key value`.
pub struct Spec {
    value_keys: Vec<&'static str>,
}

impl Spec {
    pub fn new(value_keys: &[&'static str]) -> Self {
        Spec { value_keys: value_keys.to_vec() }
    }

    /// Parse from an iterator of arguments (excluding argv[0] handling —
    /// pass the full argv).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args> {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_default();
        let mut args = Args { program, ..Default::default() };
        let mut rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = std::mem::take(&mut rest[i]);
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if self.value_keys.contains(&body) {
                    i += 1;
                    let v = rest.get_mut(i).map(std::mem::take).ok_or_else(|| {
                        Error::Cli(format!("option --{body} expects a value"))
                    })?;
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(a);
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    /// Subcommand = first positional.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .replace('_', "")
                .parse::<usize>()
                .map_err(|_| Error::Cli(format!("--{key} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| Error::Cli(format!("--{key} expects a number, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.get_usize(key, default as usize)? as u64)
    }

    /// Required option.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| Error::Cli(format!("missing required option --{key}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let spec = Spec::new(&["n", "config", "out"]);
        let a = spec
            .parse(argv("gmips sample --n 100 --config=conf.toml --verbose extra"))
            .unwrap();
        assert_eq!(a.subcommand(), Some("sample"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 100);
        assert_eq!(a.get("config"), Some("conf.toml"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["sample", "extra"]);
    }

    #[test]
    fn equals_form_and_defaults() {
        let spec = Spec::new(&["k"]);
        let a = spec.parse(argv("prog run --k=5")).unwrap();
        assert_eq!(a.get_usize("k", 1).unwrap(), 5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_f64("alpha", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_str("name", "x"), "x");
    }

    #[test]
    fn missing_value_is_error() {
        let spec = Spec::new(&["n"]);
        assert!(spec.parse(argv("prog cmd --n")).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let spec = Spec::new(&["n"]);
        let a = spec.parse(argv("prog cmd --n abc")).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn underscore_separators_in_ints() {
        let spec = Spec::new(&["n"]);
        let a = spec.parse(argv("prog cmd --n 1_280_000")).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 1_280_000);
    }

    #[test]
    fn require_errors() {
        let spec = Spec::new(&["x"]);
        let a = spec.parse(argv("prog cmd")).unwrap();
        assert!(a.require("x").is_err());
    }
}
