//! Mini property-based testing harness (the offline registry has no
//! proptest/quickcheck).
//!
//! [`Checker`] runs a property over many randomized cases and, on failure,
//! performs *shrinking* for the built-in generator types, reporting the
//! smallest failing case it can find. It is intentionally small: seeded,
//! deterministic, and sufficient for the invariant tests this crate needs
//! (routing/batching/state invariants, estimator bounds, index recall).
//!
//! ```no_run
//! use gmips::util::check::Checker;
//! Checker::new(123).cases(200).check_vec_f32(64, |xs| {
//!     let s: f32 = xs.iter().sum();
//!     // property: sum of absolute values bounds the absolute sum
//!     s.abs() <= xs.iter().map(|x| x.abs()).sum::<f32>() + 1e-4
//! });
//! ```

use crate::util::rng::Pcg64;

/// Property-check driver.
pub struct Checker {
    seed: u64,
    cases: usize,
    max_shrink: usize,
}

impl Checker {
    /// New checker with a fixed seed (deterministic).
    pub fn new(seed: u64) -> Self {
        Checker { seed, cases: 100, max_shrink: 500 }
    }

    /// Number of random cases to run.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Check a property over random `Vec<f32>` (standard normal entries,
    /// random length in `[1, max_len]`). Panics with the shrunk
    /// counterexample on failure.
    pub fn check_vec_f32<F>(&self, max_len: usize, prop: F)
    where
        F: Fn(&[f32]) -> bool,
    {
        let mut rng = Pcg64::new(self.seed);
        for case in 0..self.cases {
            let len = 1 + rng.next_below(max_len as u64) as usize;
            let xs: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            if !prop(&xs) {
                let shrunk = self.shrink_vec(xs, &prop);
                panic!(
                    "property failed (case {case}, seed {}): shrunk counterexample ({} elems): {:?}",
                    self.seed,
                    shrunk.len(),
                    &shrunk[..shrunk.len().min(16)]
                );
            }
        }
    }

    /// Check a property over `(Vec<f32>, usize)` pairs — vectors plus a
    /// parameter in `[1, max_param]` (e.g. scores + k).
    pub fn check_vec_with_param<F>(&self, max_len: usize, max_param: usize, prop: F)
    where
        F: Fn(&[f32], usize) -> bool,
    {
        let mut rng = Pcg64::new(self.seed);
        for case in 0..self.cases {
            let len = 1 + rng.next_below(max_len as u64) as usize;
            let p = 1 + rng.next_below(max_param as u64) as usize;
            let xs: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            if !prop(&xs, p) {
                // shrink vector with fixed param, then shrink param
                let shrunk = self.shrink_vec(xs, &|v: &[f32]| prop(v, p));
                let mut sp = p;
                while sp > 1 && !prop(&shrunk, sp - 1) {
                    sp -= 1;
                }
                panic!(
                    "property failed (case {case}, seed {}): vec ({} elems) {:?} param {}",
                    self.seed,
                    shrunk.len(),
                    &shrunk[..shrunk.len().min(16)],
                    sp
                );
            }
        }
    }

    /// Check a property over random u64s drawn below `bound`.
    pub fn check_u64<F>(&self, bound: u64, prop: F)
    where
        F: Fn(u64) -> bool,
    {
        let mut rng = Pcg64::new(self.seed);
        for case in 0..self.cases {
            let x = rng.next_below(bound);
            if !prop(x) {
                // shrink toward zero by halving
                let mut cur = x;
                for _ in 0..self.max_shrink {
                    let smaller = cur / 2;
                    if smaller != cur && !prop(smaller) {
                        cur = smaller;
                    } else {
                        break;
                    }
                }
                panic!("property failed (case {case}, seed {}): shrunk x = {cur}", self.seed);
            }
        }
    }

    /// Greedy shrink: try removing halves, then chunks, then zeroing
    /// elements, keeping any variant that still fails.
    fn shrink_vec<F>(&self, mut xs: Vec<f32>, prop: &F) -> Vec<f32>
    where
        F: Fn(&[f32]) -> bool,
    {
        let mut budget = self.max_shrink;
        // phase 1: structural shrink (drop chunks)
        let mut chunk = xs.len() / 2;
        while chunk > 0 && budget > 0 {
            let mut i = 0;
            while i + chunk <= xs.len() && budget > 0 {
                let mut candidate = xs.clone();
                candidate.drain(i..i + chunk);
                budget -= 1;
                if !candidate.is_empty() && !prop(&candidate) {
                    xs = candidate; // keep failing smaller case
                } else {
                    i += chunk;
                }
            }
            chunk /= 2;
        }
        // phase 2: value shrink (move entries toward 0)
        for i in 0..xs.len() {
            if budget == 0 {
                break;
            }
            for _ in 0..8 {
                if xs[i] == 0.0 {
                    break;
                }
                let old = xs[i];
                xs[i] = if old.abs() < 1e-3 { 0.0 } else { old / 2.0 };
                budget -= 1;
                if prop(&xs) {
                    xs[i] = old; // revert: must keep failing
                    break;
                }
            }
        }
        xs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_clean() {
        Checker::new(1).cases(50).check_vec_f32(32, |xs| !xs.is_empty());
        Checker::new(2).cases(50).check_u64(1000, |x| x < 1000);
        Checker::new(3).cases(20).check_vec_with_param(16, 8, |xs, p| p >= 1 && !xs.is_empty());
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        Checker::new(4).cases(200).check_vec_f32(64, |xs| xs.len() < 10);
    }

    #[test]
    fn shrinking_finds_small_case() {
        // capture the panic message and verify the shrunk length is minimal
        let result = std::panic::catch_unwind(|| {
            Checker::new(5).cases(100).check_vec_f32(64, |xs| xs.len() < 7);
        });
        let msg = match result {
            Err(e) => *e.downcast::<String>().unwrap(),
            Ok(()) => panic!("should have failed"),
        };
        // the minimal failing case has exactly 7 elements
        assert!(msg.contains("(7 elems)"), "msg: {msg}");
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn u64_shrinks() {
        Checker::new(6).cases(100).check_u64(1 << 40, |x| x < 1000);
    }
}
