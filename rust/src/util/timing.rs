//! Timers, latency histograms, and throughput counters for the coordinator
//! metrics and the bench harness (the offline registry ships no criterion,
//! so benches use [`Bench`] below).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
    pub fn micros(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
    pub fn millis(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// Log-bucketed latency histogram (thread-safe, lock-free record path).
///
/// Buckets are powers of √2 over microseconds, covering ~1µs … ~74s in 52
/// buckets. Quantile queries are approximate to bucket resolution (≤ ~41%
/// relative error worst case, far tighter in practice) — adequate for
/// p50/p95/p99 service metrics.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Accumulated in tenths of a microsecond: a plain `micros as u64`
    /// add truncates every sub-microsecond observation to 0, skewing
    /// `mean()` toward zero on fast paths.
    sum_tenth_micros: AtomicU64,
    max_tenth_micros: AtomicU64,
}

const NBUCKETS: usize = 52;

fn bucket_of(micros: f64) -> usize {
    if micros <= 1.0 {
        return 0;
    }
    // log base sqrt(2)
    let b = (micros.ln() / std::f64::consts::LN_2 * 2.0).floor() as isize;
    (b.max(0) as usize).min(NBUCKETS - 1)
}

fn bucket_upper(i: usize) -> f64 {
    // upper edge of bucket i in micros
    (2.0f64).powf((i as f64 + 1.0) / 2.0)
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_tenth_micros: AtomicU64::new(0),
            max_tenth_micros: AtomicU64::new(0),
        }
    }

    /// Record one observation in microseconds.
    pub fn record(&self, micros: f64) {
        let b = bucket_of(micros);
        let tenths = (micros * 10.0).round().max(0.0) as u64;
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_tenth_micros.fetch_add(tenths, Ordering::Relaxed);
        self.max_tenth_micros.fetch_max(tenths, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_secs_f64() * 1e6);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds.
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum() / c as f64
    }

    /// Sum of all observations in microseconds (Prometheus `_sum`).
    pub fn sum(&self) -> f64 {
        self.sum_tenth_micros.load(Ordering::Relaxed) as f64 / 10.0
    }

    pub fn max(&self) -> f64 {
        self.max_tenth_micros.load(Ordering::Relaxed) as f64 / 10.0
    }

    /// Cumulative bucket snapshot for exposition: `(upper_edge_micros,
    /// cumulative_count)` per bucket, in ascending edge order. The last
    /// entry's count equals [`count`](Self::count) (the `+Inf` bucket is
    /// the renderer's job).
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(NBUCKETS);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            out.push((bucket_upper(i), seen));
        }
        out
    }

    /// Approximate quantile (`q` in [0,1]) in microseconds.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target.max(1) {
                return bucket_upper(i);
            }
        }
        self.max()
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count(),
            self.mean(),
            self.quantile(0.5),
            self.quantile(0.95),
            self.quantile(0.99),
            self.max()
        )
    }
}

/// Result of a benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u64,
    /// mean wall time per iteration, seconds
    pub mean_s: f64,
    /// sample standard deviation of per-batch means, seconds
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_s * 1e6
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
    /// Pretty unit-scaled mean.
    pub fn human(&self) -> String {
        let s = self.mean_s;
        if s < 1e-6 {
            format!("{:.1} ns", s * 1e9)
        } else if s < 1e-3 {
            format!("{:.2} µs", s * 1e6)
        } else if s < 1.0 {
            format!("{:.3} ms", s * 1e3)
        } else {
            format!("{:.3} s", s)
        }
    }
}

/// Minimal benchmark harness (criterion stand-in).
///
/// Warms up, then runs timed batches until `budget` wall time or
/// `max_batches` is reached; reports mean/std/min/max of per-iteration time.
pub struct Bench {
    /// total measurement budget
    pub budget: Duration,
    /// warmup time before measurement
    pub warmup: Duration,
    /// max measured batches
    pub max_batches: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { budget: Duration::from_secs(2), warmup: Duration::from_millis(200), max_batches: 64 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { budget: Duration::from_millis(500), warmup: Duration::from_millis(50), max_batches: 16 }
    }

    /// Measure `f`, which performs ONE iteration of the workload per call.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // warmup + calibrate batch size
        let cal_start = Instant::now();
        let mut cal_iters = 0u64;
        while cal_start.elapsed() < self.warmup {
            f();
            cal_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / cal_iters.max(1) as f64;
        // aim for ~batches of >= 10ms or 1 iter, whichever larger
        let batch = ((0.01 / per_iter).ceil() as u64).max(1);

        let mut means = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget && means.len() < self.max_batches {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            means.push(t.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        let n = means.len() as f64;
        let mean = means.iter().sum::<f64>() / n;
        // sample variance: /(n-1), zero when a single batch gives no
        // spread information (the old /max(n,2) was neither estimator)
        let var = if means.len() < 2 {
            0.0
        } else {
            means.iter().map(|m| (m - mean) * (m - mean)).sum::<f64>() / (n - 1.0)
        };
        BenchStats {
            name: name.to_string(),
            iters: total_iters,
            mean_s: mean,
            std_s: var.sqrt(),
            min_s: means.iter().cloned().fold(f64::INFINITY, f64::min),
            max_s: means.iter().cloned().fold(0.0, f64::max),
        }
    }
}

/// Render rows of `(label, cells...)` as an aligned ASCII table — the
/// output format of every eval/bench driver.
pub fn ascii_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push('|');
    for w in &widths {
        out.push_str(&"-".repeat(w + 2));
        out.push('|');
    }
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Write rows as CSV into `results/<name>.csv` (creating the directory).
pub fn write_csv(name: &str, headers: &[&str], rows: &[Vec<String>]) -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}.csv");
    let mut s = headers.join(",");
    s.push('\n');
    for r in rows {
        s.push_str(&r.join(","));
        s.push('\n');
    }
    std::fs::write(&path, s)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        // within bucket resolution of true values
        assert!(p50 > 250.0 && p50 < 1000.0, "p50={p50}");
        assert!((h.mean() - 500.0).abs() < 5.0);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn histogram_submicro_observations_are_not_truncated() {
        // regression: `micros as u64` truncated every sub-µs observation
        // to 0, dragging mean() to zero on fast paths
        let h = LatencyHistogram::new();
        for _ in 0..1000 {
            h.record(0.4);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.4).abs() < 0.05, "mean={}", h.mean());
        assert!((h.sum() - 400.0).abs() < 1.0, "sum={}", h.sum());
        assert!((h.max() - 0.4).abs() < 0.05, "max={}", h.max());
    }

    #[test]
    fn cumulative_buckets_end_at_total_count() {
        let h = LatencyHistogram::new();
        for us in [0.5, 3.0, 40.0, 900.0, 2e5] {
            h.record(us);
        }
        let cum = h.cumulative_buckets();
        assert_eq!(cum.last().unwrap().1, h.count());
        for w in cum.windows(2) {
            assert!(w[0].0 < w[1].0, "edges ascend");
            assert!(w[0].1 <= w[1].1, "counts cumulative");
        }
    }

    #[test]
    fn bench_variance_is_sample_variance() {
        // n < 2 batches must report zero spread, not a bogus /2 estimate
        let b = Bench { budget: Duration::ZERO, warmup: Duration::from_millis(5), max_batches: 1 };
        let stats = b.run("noop", || {
            std::hint::black_box(1u64);
        });
        assert!(stats.std_s >= 0.0);
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for &us in &[1.0, 2.0, 5.0, 10.0, 100.0, 1e4, 1e6] {
            let b = bucket_of(us);
            assert!(b >= last);
            last = b;
        }
        assert!(bucket_of(1e12) < NBUCKETS);
    }

    #[test]
    fn bench_measures_sleep() {
        let b = Bench { budget: Duration::from_millis(200), warmup: Duration::from_millis(20), max_batches: 8 };
        let stats = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(stats.mean_s > 0.0);
        assert!(stats.iters > 0);
        assert!(!stats.human().is_empty());
    }

    #[test]
    fn ascii_table_alignment() {
        let t = ascii_table(
            &["name", "value"],
            &[vec!["a".into(), "1".into()], vec!["long-name".into(), "2.5".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].starts_with("| a"));
    }
}
