//! Small statistics helpers shared by tests and evaluation drivers:
//! chi-square goodness-of-fit, empirical distribution comparisons, and
//! summary moments.

/// Chi-square statistic of observed counts vs expected probabilities,
/// pooling bins with expected count < `min_expected`. Returns
/// `(chi2, dof)`.
pub fn chi_square(counts: &[u64], probs: &[f64], total: u64, min_expected: f64) -> (f64, f64) {
    assert_eq!(counts.len(), probs.len());
    let mut chi2 = 0f64;
    let mut dof = 0f64;
    let mut pool_obs = 0f64;
    let mut pool_exp = 0f64;
    for (c, p) in counts.iter().zip(probs) {
        let e = p * total as f64;
        if e >= min_expected {
            chi2 += (*c as f64 - e).powi(2) / e;
            dof += 1.0;
        } else {
            pool_obs += *c as f64;
            pool_exp += e;
        }
    }
    if pool_exp >= min_expected {
        chi2 += (pool_obs - pool_exp).powi(2) / pool_exp;
        dof += 1.0;
    }
    (chi2, (dof - 1.0).max(1.0))
}

/// Quick goodness-of-fit acceptance: chi2 within `sigmas` standard
/// deviations of its mean under H0 (chi2 ≈ dof ± √(2·dof)).
pub fn gof_ok(counts: &[u64], probs: &[f64], total: u64, sigmas: f64) -> bool {
    let (chi2, dof) = chi_square(counts, probs, total, 5.0);
    chi2 < dof + sigmas * (2.0 * dof).sqrt()
}

/// Empirical total variation distance between two count histograms.
pub fn tv_distance(a: &[u64], b: &[u64]) -> f64 {
    let sa: f64 = a.iter().map(|&x| x as f64).sum();
    let sb: f64 = b.iter().map(|&x| x as f64).sum();
    if sa == 0.0 || sb == 0.0 {
        return 1.0;
    }
    0.5 * a
        .iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 / sa - y as f64 / sb).abs())
        .sum::<f64>()
}

/// Mean and sample standard deviation.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let m = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1.0);
    (m, v.sqrt())
}

/// Relative error `|got − want| / |want|`.
pub fn rel_err(got: f64, want: f64) -> f64 {
    if want == 0.0 {
        return got.abs();
    }
    (got - want).abs() / want.abs()
}

/// Overlap fraction of the top-`k` ids of two count histograms — the
/// paper's random-walk metric (§4.2.2: "share 73.6% of the top 1000
/// elements").
pub fn topk_overlap(a: &[u64], b: &[u64], k: usize) -> f64 {
    let top_ids = |h: &[u64]| -> rustc_hash::FxHashSet<usize> {
        let mut idx: Vec<usize> = (0..h.len()).collect();
        idx.sort_unstable_by(|&x, &y| h[y].cmp(&h[x]).then(x.cmp(&y)));
        idx.into_iter().take(k).collect()
    };
    let ta = top_ids(a);
    let tb = top_ids(b);
    if k == 0 {
        return 1.0;
    }
    ta.intersection(&tb).count() as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn chi_square_accepts_true_distribution() {
        let mut rng = Pcg64::new(1);
        let probs = vec![0.5, 0.3, 0.15, 0.05];
        let total = 10_000u64;
        let mut counts = vec![0u64; 4];
        for _ in 0..total {
            counts[rng.categorical(&probs)] += 1;
        }
        assert!(gof_ok(&counts, &probs, total, 5.0));
    }

    #[test]
    fn chi_square_rejects_wrong_distribution() {
        let probs = vec![0.5, 0.3, 0.15, 0.05];
        let counts = vec![2500u64, 2500, 2500, 2500];
        assert!(!gof_ok(&counts, &probs, 10_000, 5.0));
    }

    #[test]
    fn tv_identical_zero() {
        let a = vec![10u64, 20, 30];
        assert_eq!(tv_distance(&a, &a), 0.0);
        let b = vec![60u64, 0, 0];
        assert!(tv_distance(&a, &b) > 0.5);
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn topk_overlap_bounds() {
        let a = vec![5u64, 4, 3, 2, 1];
        let b = vec![1u64, 2, 3, 4, 5];
        assert_eq!(topk_overlap(&a, &a, 3), 1.0);
        let o = topk_overlap(&a, &b, 2);
        assert!(o < 0.6);
    }

    #[test]
    fn rel_err_zero_want() {
        assert_eq!(rel_err(0.5, 0.0), 0.5);
        assert_eq!(rel_err(2.0, 4.0), 0.5);
    }
}
