//! Infrastructure substrates built from scratch for the offline
//! environment: RNG + exact samplers, JSON, CLI parsing, thread pools,
//! timing/metrics, bounded top-k, and a mini property-testing harness.

pub mod check;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod timing;
pub mod topk;
