//! The shard-server side of the remote tier: a full sharded stack that
//! answers for **one** shard.
//!
//! A [`ShardEngine`] builds the same deterministic artifacts the
//! coordinator's in-process sharded stack builds — the seed-generated
//! dataset, the [`ShardedIndex`] with its globally trained IVF coarse
//! quantizer / shared LSH norm bound, the sharded estimators with the
//! same `k`/`l` budgets and stream seed — from the same [`Config`], then
//! serves only the per-shard entry points for its assigned shard
//! (`shard_top_k_batch`, `shard_partials_batch_at`,
//! `shard_fragments_batch_at`). Per-shard answers are therefore produced
//! by *literally the same code paths* the in-process fan-out closures
//! run, which is what makes the cross-process conformance tests
//! bit-exact: the remote coordinator merges wire fragments with the same
//! merge functions over the same per-shard values.
//!
//! Building the full stack per shard costs memory proportional to the
//! whole dataset on each server. That is the simplest deployment that
//! preserves bit-parity (the IVF coarse quantizer and LSH norm bound are
//! *global* artifacts by design — see [`crate::shard`]); the fan-out
//! still divides the *scan* work `N` ways, which is where the time goes.

use super::protocol::{ShardRequest, ShardResponse};
use crate::config::Config;
use crate::data::{self, Dataset};
use crate::error::{Error, Result};
use crate::mips::{BuiltIndex, MipsIndex};
use crate::scorer::{self, NativeScorer, ScoreBackend};
use crate::server::ServeHandler;
use crate::shard::{ShardedExpectationEstimator, ShardedIndex, ShardedPartitionEstimator};
use crate::util::json::Json;
use std::sync::Arc;

/// One shard's serving engine.
pub struct ShardEngine {
    ds: Arc<Dataset>,
    index: Arc<ShardedIndex>,
    backend: Arc<dyn ScoreBackend>,
    partition: ShardedPartitionEstimator,
    expectation: ShardedExpectationEstimator,
    shard: usize,
    /// True when the index came from a snapshot whose quantized shadow
    /// sections were corrupt (answers unchanged, served from f32).
    snapshot_degraded: bool,
    /// Work requests handled by *this* engine (ping and metrics scrapes
    /// excluded, so a scrape reads a quiescent value). A plain atomic,
    /// not the process-global registry: in-process test fleets share one
    /// registry, but each engine's own count must stay distinct — and
    /// exact regardless of the registry enable flag.
    ops: std::sync::atomic::AtomicU64,
}

impl ShardEngine {
    /// Build the full sharded stack from `cfg` (dataset regenerated from
    /// the config seeds, so every shard server and the coordinator agree
    /// on the data without shipping it), answering for shard `shard` of
    /// `cfg.index.shards`.
    ///
    /// When `index.path` points at an existing snapshot the stack is
    /// warm-opened from it instead of rebuilt — every shard server
    /// mapping the same file shares one cold build. A missing file falls
    /// back to building (without saving: concurrent shard servers racing
    /// to write one path would be worse than one explicit `gmips build`).
    pub fn from_config(
        cfg: &Config,
        shard: usize,
        backend: Option<Arc<dyn ScoreBackend>>,
    ) -> Result<ShardEngine> {
        let backend = backend.unwrap_or_else(|| Arc::new(NativeScorer));
        let path = cfg.index.path.clone();
        let (ds, index, snapshot_degraded) =
            if !path.is_empty() && std::path::Path::new(&path).exists() {
                let opened = crate::store::load_or_build(cfg, backend.clone(), false)?;
                match opened.index {
                    BuiltIndex::Sharded(sx) => (opened.ds, sx, opened.degraded),
                    BuiltIndex::Mono(_) => {
                        return Err(Error::config(format!(
                            "snapshot {path} holds a monolithic index — a shard server needs \
                             index.shards > 1 at build time"
                        )))
                    }
                }
            } else {
                let ds = Arc::new(data::load_or_generate(&cfg.data));
                let index = Arc::new(ShardedIndex::build(&ds, &cfg.index, backend.clone())?);
                (ds, index, false)
            };
        if shard >= index.n_shards() {
            return Err(Error::config(format!(
                "shard id {shard} out of range: index has {} shards",
                index.n_shards()
            )));
        }
        let (k, l) = (cfg.estimator_k(), cfg.estimator_l());
        let partition = ShardedPartitionEstimator::new(
            ds.clone(),
            index.clone(),
            backend.clone(),
            k,
            l,
            cfg.index.seed,
        );
        let expectation = ShardedExpectationEstimator::new(
            ds.clone(),
            index.clone(),
            backend.clone(),
            k,
            l,
            cfg.index.seed,
        );
        Ok(ShardEngine {
            ds,
            index,
            backend,
            partition,
            expectation,
            shard,
            snapshot_degraded,
            ops: std::sync::atomic::AtomicU64::new(0),
        })
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// One-line identity for logs.
    pub fn describe(&self) -> String {
        format!(
            "shard {}/{} ({} index, n={} d={}){}",
            self.shard,
            self.index.n_shards(),
            self.index.name(),
            self.ds.n,
            self.ds.d,
            if self.snapshot_degraded { " [snapshot degraded: serving f32 tier]" } else { "" }
        )
    }

    /// Answer one shard request. Never panics on malformed input —
    /// dimension/range problems come back as [`ShardResponse::Error`].
    pub fn handle(&self, req: &ShardRequest) -> ShardResponse {
        if !matches!(req, ShardRequest::Ping | ShardRequest::Metrics) {
            self.ops.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
        match req {
            ShardRequest::Metrics => ShardResponse::Metrics {
                exposition: crate::obs::render_with(&crate::obs::ExtraMetrics {
                    counters: vec![(
                        "gmips_shard_requests_total",
                        "Work requests handled by this shard engine",
                        self.ops.load(std::sync::atomic::Ordering::Relaxed),
                    )],
                    ..Default::default()
                }),
            },
            ShardRequest::Ping => ShardResponse::Pong {
                shard: self.shard,
                shards: self.index.n_shards(),
                n: self.ds.n,
                d: self.ds.d,
                coarse_cost: self.index.coarse_cost(),
                gap: self.index.gap_bound(),
            },
            ShardRequest::TopK { thetas, k } => {
                let qs = match self.borrow_thetas(thetas) {
                    Ok(qs) => qs,
                    Err(e) => return ShardResponse::Error { message: e },
                };
                let mut results = self.index.shard_top_k_batch(self.shard, &qs, (*k).max(1));
                // local → global ids before they cross the wire, so the
                // coordinator merges fragments exactly like the
                // in-process `ShardedIndex::merge` does
                for r in &mut results {
                    for it in &mut r.items {
                        it.id = self.index.map().to_global(self.shard, it.id);
                    }
                }
                ShardResponse::TopK { results }
            }
            ShardRequest::Alg3 { thetas, r0 } => match self.borrow_thetas(thetas) {
                Ok(qs) => ShardResponse::Alg3 {
                    partials: self.partition.shard_partials_batch_at(self.shard, &qs, *r0),
                },
                Err(e) => ShardResponse::Error { message: e },
            },
            ShardRequest::Alg4 { thetas, r0 } => match self.borrow_thetas(thetas) {
                Ok(qs) => ShardResponse::Alg4 {
                    frags: self.expectation.shard_fragments_batch_at(self.shard, &qs, *r0),
                },
                Err(e) => ShardResponse::Error { message: e },
            },
            ShardRequest::ScoreIds { theta, ids } => {
                if theta.len() != self.ds.d {
                    return ShardResponse::Error {
                        message: format!(
                            "theta has dim {}, database has dim {}",
                            theta.len(),
                            self.ds.d
                        ),
                    };
                }
                if let Some(&bad) = ids.iter().find(|&&i| i as usize >= self.ds.n) {
                    return ShardResponse::Error {
                        message: format!("id {bad} out of range (n={})", self.ds.n),
                    };
                }
                // the engine holds the full (seed-regenerated) dataset, so
                // any global id is scoreable; the coordinator routes ids
                // by owning shard to divide the work
                ShardResponse::Scores {
                    scores: scorer::score_ids(&self.ds, self.backend.as_ref(), ids, theta),
                }
            }
        }
    }

    fn borrow_thetas<'a>(
        &self,
        thetas: &'a [Vec<f32>],
    ) -> std::result::Result<Vec<&'a [f32]>, String> {
        for t in thetas {
            if t.len() != self.ds.d {
                return Err(format!(
                    "theta has dim {}, database has dim {}",
                    t.len(),
                    self.ds.d
                ));
            }
        }
        Ok(thetas.iter().map(|t| t.as_slice()).collect())
    }
}

/// [`ServeHandler`] adapter: parse [`ShardRequest`], answer, serialize.
pub struct ShardHandler {
    engine: Arc<ShardEngine>,
}

impl ShardHandler {
    pub fn new(engine: Arc<ShardEngine>) -> ShardHandler {
        ShardHandler { engine }
    }
}

impl ServeHandler for ShardHandler {
    fn respond(&self, j: &Json) -> Json {
        match ShardRequest::from_json(j) {
            Ok(req) => self.engine.handle(&req).to_json(),
            Err(e) => self.error(&e.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::IndexKind;

    fn tiny_cfg(shards: usize) -> Config {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.data.n = 600;
        cfg.data.d = 8;
        cfg.data.clusters = 10;
        cfg.index.kind = IndexKind::Brute;
        cfg.index.shards = shards;
        cfg
    }

    #[test]
    fn shard_engine_answers_all_ops() {
        let cfg = tiny_cfg(2);
        let eng = ShardEngine::from_config(&cfg, 1, None).unwrap();
        let theta = vec![0.1f32; 8];
        match eng.handle(&ShardRequest::Ping) {
            ShardResponse::Pong { shard, shards, n, d, .. } => {
                assert_eq!((shard, shards, n, d), (1, 2, 600, 8));
            }
            other => panic!("{other:?}"),
        }
        match eng.handle(&ShardRequest::TopK { thetas: vec![theta.clone()], k: 5 }) {
            ShardResponse::TopK { results } => {
                assert_eq!(results.len(), 1);
                assert_eq!(results[0].items.len(), 5);
                // ids must be global ids owned by shard 1
                for it in &results[0].items {
                    assert_eq!(eng.index.map().to_local(it.id).0, 1);
                }
            }
            other => panic!("{other:?}"),
        }
        match eng.handle(&ShardRequest::Alg3 { thetas: vec![theta.clone()], r0: 0 }) {
            ShardResponse::Alg3 { partials } => {
                assert_eq!(partials.len(), 1);
                assert!(partials[0].0.is_finite());
            }
            other => panic!("{other:?}"),
        }
        match eng.handle(&ShardRequest::Alg4 { thetas: vec![theta.clone()], r0: 0 }) {
            ShardResponse::Alg4 { frags } => {
                assert_eq!(frags.len(), 1);
                assert_eq!(frags[0].mean.len(), 8);
            }
            other => panic!("{other:?}"),
        }
        match eng.handle(&ShardRequest::ScoreIds { theta, ids: vec![0, 3, 599] }) {
            ShardResponse::Scores { scores } => assert_eq!(scores.len(), 3),
            other => panic!("{other:?}"),
        }
        // four work ops above (ping excluded); the metrics op reports
        // them without counting itself
        match eng.handle(&ShardRequest::Metrics) {
            ShardResponse::Metrics { exposition } => {
                assert!(
                    exposition.contains("gmips_shard_requests_total 4"),
                    "{exposition}"
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_requests_error_not_panic() {
        let cfg = tiny_cfg(2);
        let eng = ShardEngine::from_config(&cfg, 0, None).unwrap();
        match eng.handle(&ShardRequest::TopK { thetas: vec![vec![1.0; 3]], k: 5 }) {
            ShardResponse::Error { message } => assert!(message.contains("dim"), "{message}"),
            other => panic!("{other:?}"),
        }
        match eng.handle(&ShardRequest::ScoreIds { theta: vec![0.0; 8], ids: vec![600] }) {
            ShardResponse::Error { message } => assert!(message.contains("range"), "{message}"),
            other => panic!("{other:?}"),
        }
        assert!(ShardEngine::from_config(&cfg, 5, None).is_err(), "shard id out of range");
    }
}
