//! Fault-tolerant client for one shard server.
//!
//! [`ShardClient`] wraps the JSON-lines [`crate::server::Client`] with
//! the robustness contract of the remote tier:
//!
//! * every call runs under a **deadline** covering all attempts
//!   (connect + write + read timeouts are all capped by the time left);
//! * transport failures (connect refusal, IO error, EOF, corrupt frame)
//!   are retried up to `remote.retries` times with **exponential backoff
//!   plus deterministic jitter**, reconnecting from scratch each time;
//! * protocol-level errors (`{"ok":false}` from a healthy server) are
//!   returned immediately — the server answered, retrying is pointless.
//!
//! The connection is cached between calls and dropped on any failure, so
//! a restarted shard server is picked up by the next attempt without any
//! explicit reconnect step.

use super::protocol::{ShardRequest, ShardResponse};
use crate::config::RemoteConfig;
use crate::error::{Error, Result};
use crate::server::Client;
use crate::util::json::Json;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Longest backoff doubling (2^6 · backoff_ms); keeps the exponential
/// from overflowing or dwarfing any sane deadline.
const MAX_BACKOFF_SHIFT: u32 = 6;

/// Deadline/retry-aware connection to one shard server.
pub struct ShardClient {
    addr: String,
    shard: usize,
    deadline: Duration,
    connect_timeout: Duration,
    retries: u32,
    backoff_ms: u64,
    conn: Mutex<Option<Client>>,
    // obs handles interned once here so the call path never touches the
    // registry's family lock
    obs_retries: std::sync::Arc<crate::obs::Counter>,
    obs_backoff_ms: std::sync::Arc<crate::obs::Counter>,
    obs_call_micros: std::sync::Arc<crate::util::timing::LatencyHistogram>,
}

impl ShardClient {
    pub fn new(addr: &str, shard: usize, cfg: &RemoteConfig) -> ShardClient {
        let obs = crate::obs::registry();
        let label = shard.to_string();
        ShardClient {
            addr: addr.to_string(),
            shard,
            deadline: Duration::from_millis(cfg.deadline_ms.max(1)),
            connect_timeout: Duration::from_millis(cfg.connect_timeout_ms.max(1)),
            retries: cfg.retries,
            backoff_ms: cfg.backoff_ms,
            conn: Mutex::new(None),
            obs_retries: obs.remote_retries.handle(&label),
            obs_backoff_ms: obs.remote_backoff_ms.handle(&label),
            obs_call_micros: obs.remote_call_micros.handle(&label),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// One call under the configured per-request deadline.
    pub fn call(&self, req: &ShardRequest) -> Result<ShardResponse> {
        self.call_with_deadline(req, Instant::now() + self.deadline)
    }

    /// One call that must finish (including all retries and backoff
    /// sleeps) before `deadline`.
    pub fn call_with_deadline(
        &self,
        req: &ShardRequest,
        deadline: Instant,
    ) -> Result<ShardResponse> {
        let sw = crate::util::timing::Stopwatch::start();
        let r = self.call_attempts(req, deadline);
        if crate::obs::enabled() {
            self.obs_call_micros.record(sw.micros());
        }
        r
    }

    fn call_attempts(&self, req: &ShardRequest, deadline: Instant) -> Result<ShardResponse> {
        let line = req.to_json().to_string();
        let mut last: Option<Error> = None;
        for attempt in 0..=self.retries {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.attempt(&line, deadline - now) {
                Ok(ShardResponse::Error { message }) => {
                    // the server is up and answered: a protocol error is
                    // not transient, so fail fast without retries
                    return Err(Error::serve(format!("shard {}: {message}", self.shard)));
                }
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // drop the cached connection; the next attempt
                    // reconnects (a restarted server rejoins here). A
                    // poisoned lock just means another thread panicked
                    // mid-call — the connection is dropped either way,
                    // so recover the guard instead of propagating.
                    *self.conn.lock().unwrap_or_else(|p| p.into_inner()) = None;
                    last = Some(e);
                }
            }
            if attempt < self.retries {
                let sleep = self.backoff(attempt);
                if Instant::now() + sleep >= deadline {
                    break; // backoff would blow the deadline: give up now
                }
                self.obs_retries.inc();
                self.obs_backoff_ms.add(sleep.as_millis() as u64);
                std::thread::sleep(sleep);
            }
        }
        Err(Error::serve(format!(
            "shard {} at {} unreachable: {}",
            self.shard,
            self.addr,
            last.map(|e| e.to_string()).unwrap_or_else(|| "deadline expired".into())
        )))
    }

    /// Background-probe the shard (same path as a request, so a ping
    /// exercising connect + call + parse is an honest health signal).
    pub fn ping(&self) -> Result<ShardResponse> {
        self.call(&ShardRequest::Ping)
    }

    fn attempt(&self, line: &str, remaining: Duration) -> Result<ShardResponse> {
        let floor = Duration::from_millis(1);
        // recover from poisoning: the panicked holder may have left the
        // connection mid-frame, so treat it as dead and reconnect
        let mut guard = self.conn.lock().unwrap_or_else(|p| {
            let mut g = p.into_inner();
            *g = None;
            g
        });
        if guard.is_none() {
            let t = self.connect_timeout.min(remaining).max(floor);
            *guard = Some(Client::connect_timeout(&self.addr, t)?);
        }
        let client = guard.as_mut().expect("connection was just established");
        client.set_io_timeout(Some(remaining.max(floor)))?;
        let reply = client.call_line(line)?;
        ShardResponse::from_json(&Json::parse(&reply)?)
    }

    /// Deterministic backoff: `backoff_ms · 2^attempt` plus a
    /// `(shard, attempt)`-keyed jitter so concurrent shard retries don't
    /// run in lockstep, without any global RNG state.
    fn backoff(&self, attempt: u32) -> Duration {
        let base = self.backoff_ms << attempt.min(MAX_BACKOFF_SHIFT);
        let jitter = if self.backoff_ms == 0 {
            0
        } else {
            (self.shard as u64 * 7 + attempt as u64 * 13) % self.backoff_ms
        };
        Duration::from_millis(base + jitter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client(shard: usize, backoff_ms: u64, retries: u32) -> ShardClient {
        let mut cfg = crate::config::Config::default().remote;
        cfg.backoff_ms = backoff_ms;
        cfg.retries = retries;
        ShardClient::new("127.0.0.1:1", shard, &cfg)
    }

    #[test]
    fn backoff_is_deterministic_and_grows() {
        let c = client(2, 20, 3);
        let b: Vec<u64> = (0..3).map(|a| c.backoff(a).as_millis() as u64).collect();
        assert_eq!(b, (0..3).map(|a| c.backoff(a).as_millis() as u64).collect::<Vec<_>>());
        assert!(b[0] >= 20 && b[1] >= 40 && b[2] >= 80, "{b:?}");
        for (a, &ms) in b.iter().enumerate() {
            assert!(ms < (20u64 << a) + 20, "jitter must stay under one base unit: {b:?}");
        }
        // zero base backoff must not divide by zero
        assert_eq!(client(0, 0, 1).backoff(0), Duration::from_millis(0));
    }

    #[test]
    fn unreachable_shard_fails_within_deadline_budget() {
        // nothing listens on the address: the call must return an error
        // (not hang) and respect the retry budget
        let mut cfg = crate::config::Config::default().remote;
        cfg.deadline_ms = 300;
        cfg.connect_timeout_ms = 30;
        cfg.retries = 1;
        cfg.backoff_ms = 5;
        let c = ShardClient::new("127.0.0.1:1", 0, &cfg);
        let t0 = Instant::now();
        let err = c.call(&ShardRequest::Ping).unwrap_err();
        assert!(err.to_string().contains("unreachable"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "bounded by deadline + retries");
    }
}
