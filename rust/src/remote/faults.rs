//! Deterministic fault injection for the networked serving path.
//!
//! A [`FaultPlan`] is a set of atomic knobs the server front-end
//! consults at well-defined points; tests (and the `GMIPS_FAULTS` env
//! var) flip them at runtime to drive the failure drills without any
//! nondeterministic machinery:
//!
//! * `delay_ms` — hold every response for a fixed delay (deadline /
//!   backoff exercises);
//! * `drop_conns` — a budget of connections to sever instead of
//!   answering (retry/reconnect exercises; each drop decrements the
//!   budget, so a test injects exactly N failures);
//! * `corrupt_frames` — a budget of responses replaced by a garbage
//!   line (frame-level corruption; the client treats it like an IO
//!   fault and retries on a fresh connection);
//! * `down` — the kill switch: the acceptor refuses new connections and
//!   every open connection closes mid-stream. Clearing it "restarts"
//!   the shard in place, which is how the degraded-then-recovered drill
//!   runs without process juggling.
//!
//! All knobs are plain atomics: flipping them is race-free, and a plan
//! shared with a live [`crate::server::Server`] takes effect on the
//! next request.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Runtime-adjustable fault switches for one server.
#[derive(Debug, Default)]
pub struct FaultPlan {
    delay_ms: AtomicU64,
    drop_conns: AtomicU64,
    corrupt_frames: AtomicU64,
    down: AtomicBool,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse `GMIPS_FAULTS` (`"delay_ms=5,drop_conns=3,corrupt_frames=2,
    /// down=1"`); unknown or malformed entries are ignored so a stray
    /// env var can't take a server down by accident.
    pub fn from_env() -> Self {
        let plan = Self::new();
        if let Ok(spec) = std::env::var("GMIPS_FAULTS") {
            for part in spec.split(',') {
                let Some((key, val)) = part.split_once('=') else { continue };
                let Ok(x) = val.trim().parse::<u64>() else { continue };
                match key.trim() {
                    "delay_ms" => plan.set_delay_ms(x),
                    "drop_conns" => plan.set_drop_conns(x),
                    "corrupt_frames" => plan.set_corrupt_frames(x),
                    "down" => plan.set_down(x != 0),
                    _ => {}
                }
            }
        }
        plan
    }

    /// True when any knob is active (lets the server skip the fault
    /// checks entirely in the common case).
    pub fn armed(&self) -> bool {
        self.delay_ms.load(Ordering::Relaxed) > 0
            || self.drop_conns.load(Ordering::Relaxed) > 0
            || self.corrupt_frames.load(Ordering::Relaxed) > 0
            || self.down.load(Ordering::Relaxed)
    }

    pub fn set_delay_ms(&self, ms: u64) {
        self.delay_ms.store(ms, Ordering::Relaxed);
    }

    pub fn delay_ms(&self) -> u64 {
        self.delay_ms.load(Ordering::Relaxed)
    }

    /// Arm a budget of `n` dropped connections.
    pub fn set_drop_conns(&self, n: u64) {
        self.drop_conns.store(n, Ordering::Relaxed);
    }

    /// Consume one unit of the drop budget; true → sever this connection.
    pub fn take_drop(&self) -> bool {
        take_budget(&self.drop_conns)
    }

    /// Arm a budget of `n` corrupted response frames.
    pub fn set_corrupt_frames(&self, n: u64) {
        self.corrupt_frames.store(n, Ordering::Relaxed);
    }

    /// Consume one unit of the corruption budget; true → garble this reply.
    pub fn take_corrupt(&self) -> bool {
        take_budget(&self.corrupt_frames)
    }

    /// Kill (true) or restart (false) the served shard in place.
    pub fn set_down(&self, down: bool) {
        self.down.store(down, Ordering::Relaxed);
    }

    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::Relaxed)
    }
}

/// Decrement-if-positive on an atomic budget counter.
fn take_budget(counter: &AtomicU64) -> bool {
    counter
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |x| x.checked_sub(1))
        .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_deplete_exactly() {
        let plan = FaultPlan::new();
        assert!(!plan.armed());
        plan.set_drop_conns(2);
        assert!(plan.armed());
        assert!(plan.take_drop());
        assert!(plan.take_drop());
        assert!(!plan.take_drop(), "budget of 2 must allow exactly 2 drops");
        plan.set_corrupt_frames(1);
        assert!(plan.take_corrupt());
        assert!(!plan.take_corrupt());
    }

    #[test]
    fn down_toggles() {
        let plan = FaultPlan::new();
        assert!(!plan.is_down());
        plan.set_down(true);
        assert!(plan.is_down() && plan.armed());
        plan.set_down(false);
        assert!(!plan.is_down());
    }
}
