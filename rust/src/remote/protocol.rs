//! The coordinator ↔ shard-server wire protocol.
//!
//! Same transport as the public API (`server/`): one JSON object per
//! line over TCP. The ops are the *per-shard* units of the sharded
//! decomposition — exactly the closures the in-process
//! [`crate::shard::ShardedIndex::fan_out`] runs, so a remote shard's
//! answer is bit-identical to its in-process counterpart:
//!
//! | op           | answers                                              |
//! |--------------|------------------------------------------------------|
//! | `ping`       | handshake: shard id, shard count, `n`, `d`, coarse cost, gap bound |
//! | `shard_topk` | this shard's top-k fragments (global ids) for a θ-batch |
//! | `shard_alg3` | this shard's `(log Ẑ_s, work)` partials at rounds `r0+i` |
//! | `shard_alg4` | this shard's `(log Ẑ_s, μ̂_s, work)` fragments at rounds `r0+i` |
//! | `score_ids`  | exact scores `θ·φ(x)` for the requested global ids   |
//!
//! Numbers survive the trip exactly: the JSON writer emits
//! shortest-roundtrip decimal for `f64` (and integers as integers), so
//! `f32` scores and `f64` log-partials parse back to the identical bits
//! — the foundation of the cross-process conformance guarantee.
//! Non-finite values (an empty shard's `log Ẑ_s = -∞`) are tagged as
//! strings since JSON has no literal for them.

// Wire-codec truncation policy (see `store::format` and
// rust/UNSAFE_POLICY.md): decoded integers come off an untrusted wire,
// so narrowing `as` casts are banned in favor of checked conversions
// that turn out-of-range values into protocol errors. Enforced here at
// deny level and re-checked textually by `cargo xtask lint`.
#![deny(clippy::cast_possible_truncation)]

use crate::error::{Error, Result};
use crate::estimator::EstimateWork;
use crate::mips::TopKResult;
use crate::shard::expectation::ShardFragment;
use crate::util::json::Json;
use crate::util::topk::Scored;

/// Encode a possibly non-finite `f64` (JSON has no `inf`/`nan`).
fn num_tagged(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else if x == f64::INFINITY {
        Json::str("inf")
    } else if x == f64::NEG_INFINITY {
        Json::str("-inf")
    } else {
        Json::str("nan")
    }
}

/// Decode [`num_tagged`].
fn f64_tagged(j: &Json) -> Result<f64> {
    match j {
        Json::Num(x) => Ok(*x),
        Json::Str(s) => match s.as_str() {
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            "nan" => Ok(f64::NAN),
            other => Err(Error::json(format!("expected number, got '{other}'"))),
        },
        other => Err(Error::json(format!("expected number, got {other:?}"))),
    }
}

fn arr_u32(ids: &[u32]) -> Json {
    Json::Arr(ids.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn as_u32_vec(j: &Json) -> Result<Vec<u32>> {
    j.as_arr()?
        .iter()
        .map(|x| {
            let v = x.as_usize()?;
            u32::try_from(v).map_err(|_| Error::json(format!("id {v} exceeds u32 range")))
        })
        .collect()
}

fn as_f64_vec(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(|x| x.as_f64()).collect()
}

fn thetas_json(thetas: &[Vec<f32>]) -> Json {
    Json::Arr(thetas.iter().map(|t| Json::arr_f32(t)).collect())
}

fn thetas_from(j: &Json) -> Result<Vec<Vec<f32>>> {
    j.as_arr()?.iter().map(|t| t.as_f32_vec()).collect()
}

/// A request from the coordinator's fan-out client to one shard server.
#[derive(Clone, Debug, PartialEq)]
pub enum ShardRequest {
    /// Handshake + heartbeat probe.
    Ping,
    /// This shard's top-k fragments (global ids) for each θ.
    TopK { thetas: Vec<Vec<f32>>, k: usize },
    /// This shard's Algorithm-3 partials; θ `i` is served at round `r0 + i`.
    Alg3 { thetas: Vec<Vec<f32>>, r0: u64 },
    /// This shard's Algorithm-4 fragments; θ `i` is served at round `r0 + i`.
    Alg4 { thetas: Vec<Vec<f32>>, r0: u64 },
    /// Exact scores `θ·φ(x)` for global ids owned by this shard.
    ScoreIds { theta: Vec<f32>, ids: Vec<u32> },
    /// This shard's metrics registry as Prometheus text (aggregated by
    /// the coordinator under `shard="<id>"` labels).
    Metrics,
}

impl ShardRequest {
    pub fn op_name(&self) -> &'static str {
        match self {
            ShardRequest::Ping => "ping",
            ShardRequest::TopK { .. } => "shard_topk",
            ShardRequest::Alg3 { .. } => "shard_alg3",
            ShardRequest::Alg4 { .. } => "shard_alg4",
            ShardRequest::ScoreIds { .. } => "score_ids",
            ShardRequest::Metrics => "metrics",
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ShardRequest::Ping => Json::obj(vec![("op", Json::str("ping"))]),
            ShardRequest::TopK { thetas, k } => Json::obj(vec![
                ("op", Json::str("shard_topk")),
                ("k", Json::num(*k as f64)),
                ("thetas", thetas_json(thetas)),
            ]),
            ShardRequest::Alg3 { thetas, r0 } => Json::obj(vec![
                ("op", Json::str("shard_alg3")),
                ("r0", Json::num(*r0 as f64)),
                ("thetas", thetas_json(thetas)),
            ]),
            ShardRequest::Alg4 { thetas, r0 } => Json::obj(vec![
                ("op", Json::str("shard_alg4")),
                ("r0", Json::num(*r0 as f64)),
                ("thetas", thetas_json(thetas)),
            ]),
            ShardRequest::ScoreIds { theta, ids } => Json::obj(vec![
                ("op", Json::str("score_ids")),
                ("theta", Json::arr_f32(theta)),
                ("ids", arr_u32(ids)),
            ]),
            ShardRequest::Metrics => Json::obj(vec![("op", Json::str("metrics"))]),
        }
    }

    pub fn from_json(v: &Json) -> Result<ShardRequest> {
        let op = v.req("op")?.as_str()?;
        match op {
            "ping" => Ok(ShardRequest::Ping),
            "shard_topk" => Ok(ShardRequest::TopK {
                thetas: thetas_from(v.req("thetas")?)?,
                k: v.req("k")?.as_usize()?,
            }),
            "shard_alg3" => Ok(ShardRequest::Alg3 {
                thetas: thetas_from(v.req("thetas")?)?,
                r0: v.req("r0")?.as_usize()? as u64,
            }),
            "shard_alg4" => Ok(ShardRequest::Alg4 {
                thetas: thetas_from(v.req("thetas")?)?,
                r0: v.req("r0")?.as_usize()? as u64,
            }),
            "score_ids" => Ok(ShardRequest::ScoreIds {
                theta: v.req("theta")?.as_f32_vec()?,
                ids: as_u32_vec(v.req("ids")?)?,
            }),
            "metrics" => Ok(ShardRequest::Metrics),
            other => Err(Error::serve(format!("unknown shard op '{other}'"))),
        }
    }
}

/// A shard server's reply.
#[derive(Debug)]
pub enum ShardResponse {
    /// Handshake: identity and the shared merge parameters.
    Pong {
        shard: usize,
        shards: usize,
        n: usize,
        d: usize,
        /// centroid-ranking work the coordinator accounts once per query
        coarse_cost: usize,
        /// merged gap bound of the underlying index (None for heuristic kinds)
        gap: Option<f64>,
    },
    /// Per-θ top-k fragments in **global** id space.
    TopK { results: Vec<TopKResult> },
    /// Per-θ `(log Ẑ_s, work)` Algorithm-3 partials.
    Alg3 { partials: Vec<(f64, EstimateWork)> },
    /// Per-θ Algorithm-4 fragments.
    Alg4 { frags: Vec<ShardFragment> },
    /// Scores aligned with the requested ids.
    Scores { scores: Vec<f32> },
    /// This shard's metrics registry as Prometheus text.
    Metrics { exposition: String },
    /// Shard-side failure.
    Error { message: String },
}

fn work_fields(w: &EstimateWork) -> Vec<(&'static str, Json)> {
    vec![
        ("scanned", Json::num(w.scanned as f64)),
        ("k", Json::num(w.k as f64)),
        ("l", Json::num(w.l as f64)),
    ]
}

fn work_from(v: &Json) -> Result<EstimateWork> {
    Ok(EstimateWork {
        scanned: v.req("scanned")?.as_usize()?,
        k: v.req("k")?.as_usize()?,
        l: v.req("l")?.as_usize()?,
    })
}

impl ShardResponse {
    pub fn to_json(&self) -> Json {
        let ok = |mut kvs: Vec<(&str, Json)>| {
            kvs.insert(0, ("ok", Json::Bool(true)));
            Json::obj(kvs)
        };
        match self {
            ShardResponse::Pong { shard, shards, n, d, coarse_cost, gap } => ok(vec![
                ("pong", Json::Bool(true)),
                ("shard", Json::num(*shard as f64)),
                ("shards", Json::num(*shards as f64)),
                ("n", Json::num(*n as f64)),
                ("d", Json::num(*d as f64)),
                ("coarse_cost", Json::num(*coarse_cost as f64)),
                ("gap", gap.map(Json::Num).unwrap_or(Json::Null)),
            ]),
            ShardResponse::TopK { results } => ok(vec![(
                "results",
                Json::Arr(
                    results
                        .iter()
                        .map(|r| {
                            let ids: Vec<u32> = r.items.iter().map(|it| it.id).collect();
                            let scores: Vec<f32> = r.items.iter().map(|it| it.score).collect();
                            Json::obj(vec![
                                ("ids", arr_u32(&ids)),
                                ("scores", Json::arr_f32(&scores)),
                                ("scanned", Json::num(r.scanned as f64)),
                            ])
                        })
                        .collect(),
                ),
            )]),
            ShardResponse::Alg3 { partials } => ok(vec![(
                "partials",
                Json::Arr(
                    partials
                        .iter()
                        .map(|(log_z, w)| {
                            let mut kvs = vec![("log_z", num_tagged(*log_z))];
                            kvs.extend(work_fields(w));
                            Json::obj(kvs)
                        })
                        .collect(),
                ),
            )]),
            ShardResponse::Alg4 { frags } => ok(vec![(
                "frags",
                Json::Arr(
                    frags
                        .iter()
                        .map(|f| {
                            let mut kvs = vec![
                                ("log_z", num_tagged(f.log_z)),
                                (
                                    "mean",
                                    Json::Arr(f.mean.iter().map(|&x| Json::Num(x)).collect()),
                                ),
                            ];
                            kvs.extend(work_fields(&f.work));
                            Json::obj(kvs)
                        })
                        .collect(),
                ),
            )]),
            ShardResponse::Scores { scores } => ok(vec![("scores", Json::arr_f32(scores))]),
            ShardResponse::Metrics { exposition } => {
                ok(vec![("exposition", Json::str(exposition.clone()))])
            }
            ShardResponse::Error { message } => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(message.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<ShardResponse> {
        if let Some(ok) = v.get("ok") {
            if !ok.as_bool()? {
                let message = v
                    .get("error")
                    .and_then(|e| e.as_str().ok())
                    .unwrap_or("unknown shard error")
                    .to_string();
                return Ok(ShardResponse::Error { message });
            }
        }
        if v.get("pong").is_some() {
            return Ok(ShardResponse::Pong {
                shard: v.req("shard")?.as_usize()?,
                shards: v.req("shards")?.as_usize()?,
                n: v.req("n")?.as_usize()?,
                d: v.req("d")?.as_usize()?,
                coarse_cost: v.req("coarse_cost")?.as_usize()?,
                gap: match v.req("gap")? {
                    Json::Null => None,
                    g => Some(g.as_f64()?),
                },
            });
        }
        if let Some(rs) = v.get("results") {
            let results = rs
                .as_arr()?
                .iter()
                .map(|r| {
                    let ids = as_u32_vec(r.req("ids")?)?;
                    let scores = r.req("scores")?.as_f32_vec()?;
                    if ids.len() != scores.len() {
                        return Err(Error::serve("ids/scores length mismatch"));
                    }
                    Ok(TopKResult {
                        items: ids
                            .into_iter()
                            .zip(scores)
                            .map(|(id, score)| Scored { id, score })
                            .collect(),
                        scanned: r.req("scanned")?.as_usize()?,
                    })
                })
                .collect::<Result<Vec<TopKResult>>>()?;
            return Ok(ShardResponse::TopK { results });
        }
        if let Some(ps) = v.get("partials") {
            let partials = ps
                .as_arr()?
                .iter()
                .map(|p| Ok((f64_tagged(p.req("log_z")?)?, work_from(p)?)))
                .collect::<Result<Vec<(f64, EstimateWork)>>>()?;
            return Ok(ShardResponse::Alg3 { partials });
        }
        if let Some(fs) = v.get("frags") {
            let frags = fs
                .as_arr()?
                .iter()
                .map(|f| {
                    Ok(ShardFragment {
                        log_z: f64_tagged(f.req("log_z")?)?,
                        mean: as_f64_vec(f.req("mean")?)?,
                        work: work_from(f)?,
                    })
                })
                .collect::<Result<Vec<ShardFragment>>>()?;
            return Ok(ShardResponse::Alg4 { frags });
        }
        if let Some(sc) = v.get("scores") {
            return Ok(ShardResponse::Scores { scores: sc.as_f32_vec()? });
        }
        if let Some(e) = v.get("exposition") {
            return Ok(ShardResponse::Metrics { exposition: e.as_str()?.to_string() });
        }
        Err(Error::serve("unrecognized shard response shape"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: ShardRequest) {
        let j = r.to_json();
        let back = ShardRequest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_req(ShardRequest::Ping);
        roundtrip_req(ShardRequest::TopK {
            thetas: vec![vec![0.25, -1.5], vec![3.0, 0.0]],
            k: 7,
        });
        roundtrip_req(ShardRequest::Alg3 { thetas: vec![vec![1.0]], r0: 42 });
        roundtrip_req(ShardRequest::Alg4 { thetas: vec![vec![1.0, 2.0]], r0: 0 });
        roundtrip_req(ShardRequest::ScoreIds { theta: vec![0.5], ids: vec![3, 9, 4_000_000] });
        roundtrip_req(ShardRequest::Metrics);
    }

    #[test]
    fn metrics_response_roundtrips() {
        let text = "# TYPE gmips_requests_total counter\ngmips_requests_total 7\n";
        let r = ShardResponse::Metrics { exposition: text.into() };
        match ShardResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            ShardResponse::Metrics { exposition } => assert_eq!(exposition, text),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn responses_roundtrip_bit_exact() {
        // the conformance contract: f32 scores and f64 partials survive
        // the wire with identical bits
        let score = 0.1f32 + 0.2f32; // not exactly representable in decimal
        let r = ShardResponse::TopK {
            results: vec![TopKResult {
                items: vec![Scored { id: 5, score }, Scored { id: 0, score: -1.25e-30 }],
                scanned: 123,
            }],
        };
        let back =
            ShardResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        match back {
            ShardResponse::TopK { results } => {
                assert_eq!(results[0].items[0].score.to_bits(), score.to_bits());
                assert_eq!(results[0].items[1].score.to_bits(), (-1.25e-30f32).to_bits());
                assert_eq!(results[0].items[0].id, 5);
                assert_eq!(results[0].scanned, 123);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let log_z = (0.1f64 + 0.2).ln();
        let r = ShardResponse::Alg3 {
            partials: vec![
                (log_z, EstimateWork { scanned: 10, k: 3, l: 4 }),
                (f64::NEG_INFINITY, EstimateWork::default()),
            ],
        };
        let back =
            ShardResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        match back {
            ShardResponse::Alg3 { partials } => {
                assert_eq!(partials[0].0.to_bits(), log_z.to_bits());
                assert_eq!(partials[1].0, f64::NEG_INFINITY);
                assert_eq!(partials[0].1.k, 3);
            }
            other => panic!("wrong variant: {other:?}"),
        }

        let mean = vec![0.1 + 0.2, -3.5e-20];
        let r = ShardResponse::Alg4 {
            frags: vec![ShardFragment {
                log_z,
                mean: mean.clone(),
                work: EstimateWork { scanned: 1, k: 2, l: 3 },
            }],
        };
        let back =
            ShardResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        match back {
            ShardResponse::Alg4 { frags } => {
                assert_eq!(frags[0].mean[0].to_bits(), mean[0].to_bits());
                assert_eq!(frags[0].mean[1].to_bits(), mean[1].to_bits());
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn pong_and_error_roundtrip() {
        let r = ShardResponse::Pong { shard: 2, shards: 4, n: 1000, d: 16, coarse_cost: 32, gap: None };
        match ShardResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap() {
            ShardResponse::Pong { shard, shards, n, d, coarse_cost, gap } => {
                assert_eq!((shard, shards, n, d, coarse_cost, gap), (2, 4, 1000, 16, 32, None));
            }
            other => panic!("wrong variant: {other:?}"),
        }
        let r = ShardResponse::Error { message: "boom".into() };
        match ShardResponse::from_json(&r.to_json()).unwrap() {
            ShardResponse::Error { message } => assert_eq!(message, "boom"),
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn unknown_op_is_error() {
        let v = Json::parse(r#"{"op":"frobnicate"}"#).unwrap();
        assert!(ShardRequest::from_json(&v).is_err());
        assert!(ShardResponse::from_json(&Json::parse("{}").unwrap()).is_err());
    }
}
