//! Remote counterparts of the sharded sampler/estimator stack.
//!
//! Each dispatcher drives a [`RemoteStack`] fan-out and then runs the
//! *same* coordinator-side math as the in-process sharded stack:
//!
//! * [`RemoteSampler`] — Algorithm 1: remote top-k fragments → merged
//!   session → per-shard perturbed argmax and lazy tail draws from the
//!   id-keyed frozen streams ([`crate::shard::sampler`]), with tail
//!   candidates scored by their owning shard server over the wire;
//! * [`RemotePartition`] — Algorithm 3: remote per-shard partials merged
//!   by log-sum-exp;
//! * [`RemoteExpectation`] — Algorithm 4: remote per-shard fragments
//!   merged by weighted log-sum-exp.
//!
//! With every shard up the results are **bit-identical** to the
//! in-process sharded stack (same frozen streams, same merges, same
//! round counters). Under faults each op returns the `(ok, total)` shard
//! status so the engine can mark the response degraded; only a total
//! fan-out failure is an `Err`. A tail candidate whose owning shard is
//! down simply drops out of the fold — the draw renormalizes over the
//! rows that remain reachable rather than failing.

use super::stack::RemoteStack;
use crate::error::Result;
use crate::estimator::expectation::FeatureExpectation;
use crate::estimator::partition::PartitionEstimate;
use crate::sampler::{SampleOutcome, SampleWork};
use crate::shard::sampler::{
    build_session, fold_tail, lazy_tail_draws, perturbed_argmax, ShardedSession,
};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Keep the worse of two `(ok, total)` shard statuses.
fn worse(a: (usize, usize), b: (usize, usize)) -> (usize, usize) {
    if b.0 < a.0 {
        b
    } else {
        a
    }
}

/// Algorithm 1 over remote shards.
pub struct RemoteSampler {
    stack: Arc<RemoteStack>,
    /// top-set size (paper: k = Θ(√n))
    pub k: usize,
    /// threshold slack c ≥ sup(gap) for the lazy tail bound
    pub gap_c: f64,
    seed: u64,
    round: AtomicU64,
}

impl RemoteSampler {
    pub fn new(stack: Arc<RemoteStack>, k: usize, gap_c: f64, seed: u64) -> RemoteSampler {
        let k = k.clamp(1, stack.n().max(1));
        RemoteSampler { stack, k, gap_c, seed, round: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        "remote-gumbel"
    }

    /// Draw `count` samples for one θ (one remote retrieval fan-out).
    pub fn sample_many(
        &self,
        q: &[f32],
        count: usize,
    ) -> Result<(Vec<SampleOutcome>, (usize, usize))> {
        let (mut tops, st) = self.stack.top_k_status(&[q], self.k)?;
        let top = tops.pop().expect("one top-k result per query");
        let sess = build_session(self.stack.map(), self.stack.n(), top);
        let r0 = self.round.fetch_add(count as u64, Ordering::Relaxed);
        let mut status = st;
        let mut outs = Vec::with_capacity(count);
        for i in 0..count {
            let (o, s2) = self.sample_at(&sess, q, r0 + i as u64);
            status = worse(status, s2);
            outs.push(o);
        }
        Ok((outs, status))
    }

    /// Batched draws: `counts[i]` samples for `qs[i]`, one fan-out for
    /// the whole batch (same round bookkeeping as the in-process sharded
    /// sampler, so the two are replay-identical).
    pub fn sample_batch(
        &self,
        qs: &[&[f32]],
        counts: &[usize],
    ) -> Result<(Vec<Vec<SampleOutcome>>, (usize, usize))> {
        let (tops, st) = self.stack.top_k_status(qs, self.k)?;
        let mut status = st;
        let mut all = Vec::with_capacity(qs.len());
        for ((&q, &count), top) in qs.iter().zip(counts).zip(tops) {
            let sess = build_session(self.stack.map(), self.stack.n(), top);
            // same clamp as the in-process batch path: an empty request
            // still consumes (and draws) one round
            let count = count.max(1);
            let r0 = self.round.fetch_add(count as u64, Ordering::Relaxed);
            let mut outs = Vec::with_capacity(count);
            for i in 0..count {
                let (o, s2) = self.sample_at(&sess, q, r0 + i as u64);
                status = worse(status, s2);
                outs.push(o);
            }
            all.push(outs);
        }
        Ok((all, status))
    }

    /// One draw at an explicit round: per-shard perturbed argmax over the
    /// merged head, then lazy tail draws scored remotely by their owning
    /// shards. Tail candidates whose shard is down drop out of the fold.
    fn sample_at(
        &self,
        sess: &ShardedSession,
        q: &[f32],
        round: u64,
    ) -> (SampleOutcome, (usize, usize)) {
        let ns = self.stack.shards();
        let (best_id, best) = perturbed_argmax(sess, self.seed, round);
        let b = best - sess.top.s_min() - self.gap_c;
        let (tail_ids, tail_gumbels) = lazy_tail_draws(sess, self.stack.n(), self.seed, round, b);
        let m = tail_ids.len();
        let mut pick = (best_id, best);
        let mut status = (ns, ns);
        if m > 0 {
            let (scores, st) = self.stack.score_ids_status(q, &tail_ids);
            status = st;
            let mut ids = Vec::with_capacity(m);
            let mut gumbels = Vec::with_capacity(m);
            let mut vals = Vec::with_capacity(m);
            for ((&tid, &g), sc) in tail_ids.iter().zip(&tail_gumbels).zip(scores) {
                if let Some(y) = sc {
                    ids.push(tid);
                    gumbels.push(g);
                    vals.push(y);
                }
            }
            pick = fold_tail(pick.0, pick.1, &ids, &gumbels, &vals);
        }
        let work = SampleWork { scanned: sess.top.scanned, k: sess.top.items.len(), m };
        (SampleOutcome { id: pick.0, work }, status)
    }
}

/// Algorithm 3 over remote shards.
pub struct RemotePartition {
    stack: Arc<RemoteStack>,
    round: AtomicU64,
}

impl RemotePartition {
    pub fn new(stack: Arc<RemoteStack>) -> RemotePartition {
        RemotePartition { stack, round: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        "remote-alg3"
    }

    /// One `log Ẑ` estimate (advances the replayable round counter by
    /// one, exactly like the in-process sharded estimator).
    pub fn estimate(&self, q: &[f32]) -> Result<(PartitionEstimate, (usize, usize))> {
        let r = self.round.fetch_add(1, Ordering::Relaxed);
        let (mut v, st) = self.stack.alg3_status(&[q], r)?;
        Ok((v.pop().expect("one estimate per query"), st))
    }

    /// Batched estimates sharing one fan-out; query `i` runs at round
    /// `r0 + i`.
    pub fn estimate_batch(
        &self,
        qs: &[&[f32]],
    ) -> Result<(Vec<PartitionEstimate>, (usize, usize))> {
        let r0 = self.round.fetch_add(qs.len() as u64, Ordering::Relaxed);
        self.stack.alg3_status(qs, r0)
    }
}

/// Algorithm 4 over remote shards.
pub struct RemoteExpectation {
    stack: Arc<RemoteStack>,
    round: AtomicU64,
}

impl RemoteExpectation {
    pub fn new(stack: Arc<RemoteStack>) -> RemoteExpectation {
        RemoteExpectation { stack, round: AtomicU64::new(0) }
    }

    pub fn name(&self) -> &'static str {
        "remote-alg4"
    }

    /// One `E_θ[φ]` estimate.
    pub fn expect_features(&self, q: &[f32]) -> Result<(FeatureExpectation, (usize, usize))> {
        let r = self.round.fetch_add(1, Ordering::Relaxed);
        let (mut v, st) = self.stack.alg4_status(&[q], r)?;
        Ok((v.pop().expect("one expectation per query"), st))
    }

    /// Batched estimates sharing one fan-out; query `i` runs at round
    /// `r0 + i`.
    pub fn expect_features_batch(
        &self,
        qs: &[&[f32]],
    ) -> Result<(Vec<FeatureExpectation>, (usize, usize))> {
        let r0 = self.round.fetch_add(qs.len() as u64, Ordering::Relaxed);
        self.stack.alg4_status(qs, r0)
    }
}
