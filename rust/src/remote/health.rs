//! Per-shard health tracking for the remote fan-out.
//!
//! Health is derived from *observed outcomes* — request results and the
//! background heartbeat both feed the same board — with a
//! consecutive-failure threshold before a shard is declared down:
//!
//! * `Up` — last probe succeeded;
//! * `Degraded` — at least one recent failure, but fewer than
//!   `down_after` in a row (requests still try it, paying the retry
//!   budget);
//! * `Down` — `down_after`+ consecutive failures. The fan-out skips the
//!   shard without burning deadline; only the heartbeat keeps probing,
//!   so one successful ping flips it straight back to `Up` (the
//!   rejoin path of the degraded-then-recovered drill).
//!
//! Everything is atomics — the request path reads one `u8` per shard.

use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};

/// One shard's serving state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardHealth {
    Up,
    Degraded,
    Down,
}

impl ShardHealth {
    pub fn name(&self) -> &'static str {
        match self {
            ShardHealth::Up => "up",
            ShardHealth::Degraded => "degraded",
            ShardHealth::Down => "down",
        }
    }
}

const UP: u8 = 0;
const DEGRADED: u8 = 1;
const DOWN: u8 = 2;

/// Lock-free health states for all shards of one remote stack.
#[derive(Debug)]
pub struct HealthBoard {
    states: Vec<AtomicU8>,
    /// consecutive failures per shard (reset on success)
    fails: Vec<AtomicU32>,
    down_after: u32,
}

impl HealthBoard {
    /// All shards start `Up`; `down_after` consecutive failures demote a
    /// shard to `Down` (clamped to ≥ 1 so a single success/failure is
    /// always decisive when configured that way).
    pub fn new(shards: usize, down_after: u32) -> Self {
        HealthBoard {
            states: (0..shards).map(|_| AtomicU8::new(UP)).collect(),
            fails: (0..shards).map(|_| AtomicU32::new(0)).collect(),
            down_after: down_after.max(1),
        }
    }

    pub fn shards(&self) -> usize {
        self.states.len()
    }

    pub fn state(&self, s: usize) -> ShardHealth {
        match self.states[s].load(Ordering::Relaxed) {
            UP => ShardHealth::Up,
            DEGRADED => ShardHealth::Degraded,
            _ => ShardHealth::Down,
        }
    }

    pub fn is_down(&self, s: usize) -> bool {
        self.states[s].load(Ordering::Relaxed) == DOWN
    }

    /// A successful probe/request: straight back to `Up`.
    pub fn record_success(&self, s: usize) {
        self.fails[s].store(0, Ordering::Relaxed);
        let prev = self.states[s].swap(UP, Ordering::Relaxed);
        if prev != UP {
            crate::obs::registry().health_transitions[UP as usize].inc();
        }
    }

    /// A failed probe/request (after the caller's retry budget):
    /// `Degraded` until `down_after` consecutive failures, then `Down`.
    pub fn record_failure(&self, s: usize) {
        let f = self.fails[s].fetch_add(1, Ordering::Relaxed).saturating_add(1);
        let state = if f >= self.down_after { DOWN } else { DEGRADED };
        let prev = self.states[s].swap(state, Ordering::Relaxed);
        if prev != state {
            crate::obs::registry().health_transitions[state as usize].inc();
        }
    }

    /// Number of shards not currently `Down`.
    pub fn live(&self) -> usize {
        (0..self.shards()).filter(|&s| !self.is_down(s)).count()
    }

    /// `"up up down"`-style summary for stats output.
    pub fn summary(&self) -> String {
        (0..self.shards())
            .map(|s| self.state(s).name())
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demotion_and_recovery() {
        let hb = HealthBoard::new(2, 2);
        assert_eq!(hb.state(0), ShardHealth::Up);
        hb.record_failure(0);
        assert_eq!(hb.state(0), ShardHealth::Degraded);
        assert!(!hb.is_down(0));
        hb.record_failure(0);
        assert_eq!(hb.state(0), ShardHealth::Down);
        assert_eq!(hb.live(), 1);
        hb.record_success(0);
        assert_eq!(hb.state(0), ShardHealth::Up);
        assert_eq!(hb.live(), 2);
        assert_eq!(hb.summary(), "up up");
    }

    #[test]
    fn down_after_clamps_to_one() {
        let hb = HealthBoard::new(1, 0);
        hb.record_failure(0);
        assert_eq!(hb.state(0), ShardHealth::Down);
    }
}
