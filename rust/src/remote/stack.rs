//! [`RemoteStack`] — the coordinator-side fan-out/merge client over a set
//! of shard servers.
//!
//! `connect` performs a validating handshake (every reachable server must
//! agree on its shard id, the shard count, `n`, `d`, the coarse-probe
//! cost, and the gap bound — a mis-wired `remote.addrs` list fails fast
//! instead of silently merging fragments from the wrong partition), then
//! serves the three fan-out ops:
//!
//! * [`top_k_status`](RemoteStack::top_k_status) — per-shard top-k
//!   fragments (already in global id space) merged with
//!   [`crate::util::topk::merge_topk`], the coarse cost accounted once —
//!   the exact merge of the in-process `ShardedIndex`;
//! * [`alg3_status`](RemoteStack::alg3_status) — Algorithm-3 partials
//!   merged by [`crate::shard::estimator::merge_partials_with`];
//! * [`alg4_status`](RemoteStack::alg4_status) — Algorithm-4 fragments
//!   merged by [`crate::shard::expectation::merge_shard_fragments`];
//! * [`score_ids_status`](RemoteStack::score_ids_status) — tail-row
//!   scoring routed to each id's owning shard (the sampler's lazy-tail
//!   unit).
//!
//! Every op fans out in parallel (one thread per shard — the calls are
//! network-bound), skips shards the [`HealthBoard`] marks `Down` without
//! burning deadline, and **renormalizes over the surviving shards** when
//! some fail: the `(ok, total)` status pair the `*_status` methods return
//! is what the engine turns into the response's `degraded` flag. Only
//! when *zero* shards answer does an op return `Err`. A background
//! heartbeat (period `remote.heartbeat_ms`; `0` disables it) keeps
//! probing every shard — including `Down` ones, which request traffic
//! skips — so a restarted shard server rejoins the fan-out without any
//! operator action.

use super::client::ShardClient;
use super::health::HealthBoard;
use super::protocol::{ShardRequest, ShardResponse};
use crate::config::Config;
use crate::error::{Error, Result};
use crate::estimator::expectation::FeatureExpectation;
use crate::estimator::partition::PartitionEstimate;
use crate::estimator::EstimateWork;
use crate::mips::{MipsIndex, TopKResult};
use crate::shard::estimator::merge_partials_with;
use crate::shard::expectation::{merge_shard_fragments, ShardFragment};
use crate::shard::ShardMap;
use crate::util::pool;
use crate::util::topk::merge_topk;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Background health prober; stops and joins on drop.
struct Heartbeat {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Heartbeat {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn spawn_heartbeat(
    clients: Vec<Arc<ShardClient>>,
    health: Arc<HealthBoard>,
    period_ms: u64,
) -> Heartbeat {
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let period = Duration::from_millis(period_ms.max(1));
    let handle = std::thread::spawn(move || {
        while !stop2.load(Ordering::Relaxed) {
            for (s, c) in clients.iter().enumerate() {
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                // one probe bounded by the period, so a dead shard can't
                // stall the loop for the full request deadline
                match c.call_with_deadline(&ShardRequest::Ping, Instant::now() + period) {
                    Ok(_) => health.record_success(s),
                    Err(_) => health.record_failure(s),
                }
            }
            // sleep in small steps so drop/join stays prompt
            let mut slept = Duration::ZERO;
            while slept < period && !stop2.load(Ordering::Relaxed) {
                let step = Duration::from_millis(20).min(period - slept);
                std::thread::sleep(step);
                slept += step;
            }
        }
    });
    Heartbeat { stop, handle: Some(handle) }
}

/// Fan-out/merge client over `N` shard servers.
pub struct RemoteStack {
    clients: Vec<Arc<ShardClient>>,
    health: Arc<HealthBoard>,
    map: ShardMap,
    n: usize,
    d: usize,
    coarse_cost: usize,
    gap: Option<f64>,
    /// kept for its Drop (stops the probe thread)
    _heartbeat: Option<Heartbeat>,
}

impl RemoteStack {
    /// Connect to `remote.addrs` (shard `s` = the `s`-th address) and
    /// validate the handshake. Servers unreachable right now are marked
    /// `Down` (the heartbeat keeps probing them); at least one must
    /// answer, and every answer must agree on the merge parameters.
    pub fn connect(cfg: &Config) -> Result<RemoteStack> {
        let addrs = cfg.remote.addr_list();
        if addrs.is_empty() {
            return Err(Error::config(
                "remote.addrs is empty — set remote.addrs = \"host:port,host:port,...\"",
            ));
        }
        let ns = addrs.len();
        let clients: Vec<Arc<ShardClient>> = addrs
            .iter()
            .enumerate()
            .map(|(s, a)| Arc::new(ShardClient::new(a, s, &cfg.remote)))
            .collect();
        let health = Arc::new(HealthBoard::new(ns, cfg.remote.down_after));
        let mut meta: Option<(usize, usize, usize, Option<f64>)> = None;
        for (s, c) in clients.iter().enumerate() {
            match c.ping() {
                Ok(ShardResponse::Pong { shard, shards, n, d, coarse_cost, gap }) => {
                    if shard != s {
                        return Err(Error::config(format!(
                            "server at {} serves shard {shard}, but it is listed at \
                             position {s} of remote.addrs — fix the address order",
                            c.addr()
                        )));
                    }
                    if shards != ns {
                        return Err(Error::config(format!(
                            "server at {} belongs to a {shards}-shard deployment, but \
                             remote.addrs lists {ns} addresses",
                            c.addr()
                        )));
                    }
                    match meta {
                        None => meta = Some((n, d, coarse_cost, gap)),
                        Some((n0, d0, cc0, g0)) => {
                            if (n, d, coarse_cost, gap) != (n0, d0, cc0, g0) {
                                return Err(Error::config(format!(
                                    "server at {} disagrees on the merge parameters \
                                     (n={n} d={d} coarse_cost={coarse_cost} gap={gap:?} \
                                     vs n={n0} d={d0} coarse_cost={cc0} gap={g0:?}) — \
                                     all shard servers must share one config",
                                    c.addr()
                                )));
                            }
                        }
                    }
                    health.record_success(s);
                }
                Ok(other) => {
                    return Err(Error::serve(format!(
                        "unexpected handshake reply from {}: {other:?}",
                        c.addr()
                    )));
                }
                Err(_) => {
                    // straight to Down: requests skip it, the heartbeat
                    // picks it up when it comes back
                    for _ in 0..cfg.remote.down_after.max(1) {
                        health.record_failure(s);
                    }
                }
            }
        }
        let Some((n, d, coarse_cost, gap)) = meta else {
            return Err(Error::serve(format!(
                "no shard server reachable during handshake ({ns} tried)"
            )));
        };
        let map = ShardMap::new(n, ns, cfg.index.shard_strategy);
        if map.shards() != ns {
            return Err(Error::config(format!(
                "{ns} shard servers over n={n} rows — at most n shards are possible"
            )));
        }
        let heartbeat = if cfg.remote.heartbeat_ms > 0 {
            Some(spawn_heartbeat(clients.clone(), health.clone(), cfg.remote.heartbeat_ms))
        } else {
            None
        };
        Ok(RemoteStack {
            clients,
            health,
            map,
            n,
            d,
            coarse_cost,
            gap,
            _heartbeat: heartbeat,
        })
    }

    pub fn shards(&self) -> usize {
        self.clients.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn gap(&self) -> Option<f64> {
        self.gap
    }

    pub fn coarse_cost(&self) -> usize {
        self.coarse_cost
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    pub fn health(&self) -> &HealthBoard {
        &self.health
    }

    /// Run a per-shard closure across all shards in parallel (one thread
    /// per shard — the work is network-bound), results in shard order.
    fn fan_out<T, F>(&self, f: F) -> Vec<Option<T>>
    where
        T: Send,
        F: Fn(usize) -> Option<T> + Sync,
    {
        let ns = self.clients.len();
        let parts =
            pool::parallel_chunks(ns, ns, |_, s, e| (s..e).map(&f).collect::<Vec<Option<T>>>());
        parts.into_iter().flatten().collect()
    }

    /// One shard call with health bookkeeping: `Down` shards are skipped
    /// without touching the network; failures (after the client's retry
    /// budget) demote the shard.
    fn call_shard(&self, s: usize, req: &ShardRequest) -> Option<ShardResponse> {
        if self.health.is_down(s) {
            return None;
        }
        match self.clients[s].call(req) {
            Ok(resp) => {
                self.health.record_success(s);
                Some(resp)
            }
            Err(_) => {
                self.health.record_failure(s);
                None
            }
        }
    }

    fn owned(qs: &[&[f32]]) -> Vec<Vec<f32>> {
        qs.iter().map(|q| q.to_vec()).collect()
    }

    /// Batched remote top-k: per-shard fragments (global ids) merged with
    /// the deterministic `(score, id)` k-way merge, coarse cost accounted
    /// once — bit-identical to the in-process `ShardedIndex` merge over
    /// the shards that answered. Returns the per-query results plus the
    /// `(ok, total)` shard status.
    pub fn top_k_status(
        &self,
        qs: &[&[f32]],
        k: usize,
    ) -> Result<(Vec<TopKResult>, (usize, usize))> {
        let ns = self.clients.len();
        if qs.is_empty() {
            return Ok((Vec::new(), (ns, ns)));
        }
        let req = ShardRequest::TopK { thetas: Self::owned(qs), k };
        let replies = self.fan_out(|s| match self.call_shard(s, &req) {
            Some(ShardResponse::TopK { results }) if results.len() == qs.len() => Some(results),
            _ => None,
        });
        let ok = replies.iter().filter(|r| r.is_some()).count();
        if ok == 0 {
            return Err(Error::serve(format!(
                "top-k fan-out failed: all {ns} shard servers unreachable"
            )));
        }
        let kk = k.min(self.n).max(1);
        let mut iters: Vec<std::vec::IntoIter<TopKResult>> =
            replies.into_iter().flatten().map(|v| v.into_iter()).collect();
        let merged = (0..qs.len())
            .map(|_| {
                let mut scanned = self.coarse_cost;
                let frags = iters
                    .iter_mut()
                    .map(|it| {
                        let r = it.next().expect("validated: one result per query");
                        scanned += r.scanned;
                        r.items
                    })
                    .collect::<Vec<_>>();
                TopKResult { items: merge_topk(frags, kk).into_sorted(), scanned }
            })
            .collect();
        Ok((merged, (ok, ns)))
    }

    /// Batched remote Algorithm 3 (query `i` at round `r0 + i`):
    /// log-sum-exp merge of the surviving shards' partials — with every
    /// shard up this is bit-identical to the in-process sharded
    /// estimator; under faults it renormalizes over the survivors.
    pub fn alg3_status(
        &self,
        qs: &[&[f32]],
        r0: u64,
    ) -> Result<(Vec<PartitionEstimate>, (usize, usize))> {
        let ns = self.clients.len();
        if qs.is_empty() {
            return Ok((Vec::new(), (ns, ns)));
        }
        let req = ShardRequest::Alg3 { thetas: Self::owned(qs), r0 };
        let replies = self.fan_out(|s| match self.call_shard(s, &req) {
            Some(ShardResponse::Alg3 { partials }) if partials.len() == qs.len() => Some(partials),
            _ => None,
        });
        let ok = replies.iter().filter(|r| r.is_some()).count();
        if ok == 0 {
            return Err(Error::serve(format!(
                "log-partition fan-out failed: all {ns} shard servers unreachable"
            )));
        }
        let survivors: Vec<Vec<(f64, EstimateWork)>> = replies.into_iter().flatten().collect();
        let merged = (0..qs.len())
            .map(|i| {
                merge_partials_with(self.coarse_cost, survivors.iter().map(|p| p[i]).collect())
            })
            .collect();
        Ok((merged, (ok, ns)))
    }

    /// Batched remote Algorithm 4 (query `i` at round `r0 + i`): weighted
    /// log-sum-exp merge of the surviving shards' fragments — the
    /// renormalization over survivors is automatic (`μ̂` divides by the
    /// surviving `Σ_s Ẑ_s`).
    pub fn alg4_status(
        &self,
        qs: &[&[f32]],
        r0: u64,
    ) -> Result<(Vec<FeatureExpectation>, (usize, usize))> {
        let ns = self.clients.len();
        if qs.is_empty() {
            return Ok((Vec::new(), (ns, ns)));
        }
        let req = ShardRequest::Alg4 { thetas: Self::owned(qs), r0 };
        let replies = self.fan_out(|s| match self.call_shard(s, &req) {
            Some(ShardResponse::Alg4 { frags }) if frags.len() == qs.len() => Some(frags),
            _ => None,
        });
        let ok = replies.iter().filter(|r| r.is_some()).count();
        if ok == 0 {
            return Err(Error::serve(format!(
                "expectation fan-out failed: all {ns} shard servers unreachable"
            )));
        }
        let mut iters: Vec<std::vec::IntoIter<ShardFragment>> =
            replies.into_iter().flatten().map(|v| v.into_iter()).collect();
        let merged = (0..qs.len())
            .map(|_| {
                let frags: Vec<ShardFragment> = iters
                    .iter_mut()
                    .map(|it| it.next().expect("validated: one fragment per query"))
                    .collect();
                merge_shard_fragments(self.d, self.coarse_cost, frags)
            })
            .collect();
        Ok((merged, (ok, ns)))
    }

    /// Fan the `metrics` op out to every shard: each answering shard's
    /// Prometheus exposition comes back as `(shard_id, text)` for
    /// [`crate::obs::aggregate`]. Errors only when zero shards answer.
    pub fn metrics_status(&self) -> Result<(Vec<(usize, String)>, (usize, usize))> {
        let ns = self.clients.len();
        let replies = self.fan_out(|s| match self.call_shard(s, &ShardRequest::Metrics) {
            Some(ShardResponse::Metrics { exposition }) => Some((s, exposition)),
            _ => None,
        });
        let shards: Vec<(usize, String)> = replies.into_iter().flatten().collect();
        let ok = shards.len();
        if ok == 0 {
            return Err(Error::serve(format!(
                "metrics fan-out failed: all {ns} shard servers unreachable"
            )));
        }
        Ok((shards, (ok, ns)))
    }

    /// Score global ids for `q`, each id routed to its owning shard.
    /// Ids owned by a shard that fails come back `None` (the caller —
    /// the remote sampler's lazy tail — drops them and degrades instead
    /// of failing the draw), so this op never errors.
    pub fn score_ids_status(&self, q: &[f32], ids: &[u32]) -> (Vec<Option<f32>>, (usize, usize)) {
        let ns = self.clients.len();
        if ids.is_empty() {
            return (Vec::new(), (ns, ns));
        }
        // (positions, ids) per owning shard
        let mut by_shard: Vec<(Vec<usize>, Vec<u32>)> = vec![Default::default(); ns];
        for (pos, &id) in ids.iter().enumerate() {
            let (s, _) = self.map.to_local(id);
            by_shard[s].0.push(pos);
            by_shard[s].1.push(id);
        }
        let replies = self.fan_out(|s| {
            if by_shard[s].1.is_empty() {
                return Some(Vec::new());
            }
            let req = ShardRequest::ScoreIds { theta: q.to_vec(), ids: by_shard[s].1.clone() };
            match self.call_shard(s, &req) {
                Some(ShardResponse::Scores { scores })
                    if scores.len() == by_shard[s].1.len() =>
                {
                    Some(scores)
                }
                _ => None,
            }
        });
        let mut out = vec![None; ids.len()];
        let mut failed = 0usize;
        for (s, reply) in replies.into_iter().enumerate() {
            match reply {
                Some(scores) => {
                    for (&pos, &y) in by_shard[s].0.iter().zip(&scores) {
                        out[pos] = Some(y);
                    }
                }
                None => failed += 1,
            }
        }
        (out, (ns - failed, ns))
    }
}

/// [`MipsIndex`] facade over the remote fan-out, so the engine's plain
/// top-k path (and anything else that only needs an index) works
/// unchanged against remote shards. Total fan-out failure degrades to an
/// empty result here — the engine's TopK arm uses
/// [`RemoteStack::top_k_status`] directly to surface errors and the
/// degraded flag.
pub struct RemoteIndex {
    stack: Arc<RemoteStack>,
}

impl RemoteIndex {
    pub fn new(stack: Arc<RemoteStack>) -> RemoteIndex {
        RemoteIndex { stack }
    }
}

impl MipsIndex for RemoteIndex {
    fn top_k(&self, q: &[f32], k: usize) -> TopKResult {
        match self.stack.top_k_status(&[q], k) {
            Ok((mut v, _)) => v.pop().unwrap_or_default(),
            Err(_) => TopKResult::default(),
        }
    }

    fn top_k_batch(&self, qs: &[&[f32]], k: usize) -> Vec<TopKResult> {
        match self.stack.top_k_status(qs, k) {
            Ok((v, _)) => v,
            Err(_) => vec![TopKResult::default(); qs.len()],
        }
    }

    fn n(&self) -> usize {
        self.stack.n()
    }

    fn d(&self) -> usize {
        self.stack.d()
    }

    fn gap_bound(&self) -> Option<f64> {
        self.stack.gap()
    }

    fn name(&self) -> &'static str {
        "remote"
    }

    fn describe(&self) -> String {
        format!(
            "remote[{} shards, health: {}] n={} d={}",
            self.stack.shards(),
            self.stack.health().summary(),
            self.stack.n(),
            self.stack.d()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    fn cfg_with_addrs(addrs: &str) -> Config {
        let mut cfg = Config::default();
        cfg.remote.addrs = addrs.to_string();
        cfg.remote.deadline_ms = 400;
        cfg.remote.connect_timeout_ms = 50;
        cfg.remote.retries = 0;
        cfg.remote.backoff_ms = 1;
        cfg.remote.heartbeat_ms = 0;
        cfg
    }

    #[test]
    fn empty_addr_list_is_a_config_error() {
        let err = RemoteStack::connect(&cfg_with_addrs("")).unwrap_err();
        assert!(err.to_string().contains("remote.addrs"), "{err}");
    }

    #[test]
    fn unreachable_servers_fail_the_handshake() {
        let err = RemoteStack::connect(&cfg_with_addrs("127.0.0.1:1")).unwrap_err();
        assert!(err.to_string().contains("no shard server reachable"), "{err}");
    }

    #[test]
    fn mismatched_shard_count_is_rejected() {
        // a fake server that claims to be shard 0 of a 3-shard deployment
        // while remote.addrs lists a single address
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            if let Ok((stream, _)) = listener.accept() {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut line = String::new();
                let _ = reader.read_line(&mut line);
                let pong = ShardResponse::Pong {
                    shard: 0,
                    shards: 3,
                    n: 100,
                    d: 4,
                    coarse_cost: 0,
                    gap: Some(0.0),
                };
                let mut stream = stream;
                let _ = writeln!(stream, "{}", pong.to_json());
            }
        });
        let err = RemoteStack::connect(&cfg_with_addrs(&addr.to_string())).unwrap_err();
        assert!(err.to_string().contains("3-shard"), "{err}");
        server.join().unwrap();
    }
}
