//! Networked shard serving — the fault-tolerant distributed tier above
//! [`crate::shard`].
//!
//! PRs 3–4 made every operation **decomposable over a row partition**:
//! per-shard top-k fragments merge by k-way `(score, id)` merge, the
//! Algorithm-3/4 partials `(log Ẑ_s, ·)` merge by (weighted)
//! log-sum-exp, and the keyed Gumbel maxima merge by argmax — all keyed
//! by the monotone global-id bijection ([`crate::shard::ShardMap`]).
//! This module puts a network between the fan-out and the merge, so
//! capacity grows with machines instead of cores:
//!
//! * **shard servers** ([`shard::ShardEngine`] behind the JSON-lines
//!   [`crate::server::Server`]) answer per-shard top-k fragments,
//!   Algorithm-3/4 partials, and tail-row scoring for *their* shard of
//!   the partition (the [`protocol`] ops);
//! * a coordinator-side **fan-out stack** ([`stack::RemoteStack`]) calls
//!   every shard in parallel and merges with the *same* `shard::` merge
//!   code the in-process path uses — with no faults injected, the remote
//!   answers are **bit-identical** to the in-process
//!   [`crate::shard::ShardedIndex`] stack at the same seeds (enforced by
//!   the cross-process conformance suite `tests/remote_serving.rs`);
//! * the [`dispatchers`] wrap the stack in the same round-counter
//!   discipline the sharded sampler/estimators use, so the engine's
//!   `Remote` dispatch variants replay the exact frozen-stream rounds.
//!
//! ## Fault tolerance by construction
//!
//! Every remote call carries a **deadline** (the per-request budget,
//! propagated to connect/read/write timeouts), retries transient
//! connect/IO failures with **bounded exponential backoff plus
//! deterministic jitter**, and reconnects automatically
//! ([`client::ShardClient`]). A background **heartbeat** maintains
//! per-shard health (up/degraded/down — [`health::HealthBoard`]); shards
//! down past the retry budget are skipped without burning the deadline,
//! and the merge **renormalizes over the surviving shards**: the
//! response is the exact same estimator applied to the surviving
//! sub-population, flagged `degraded: true` / `shards_ok: s/N` instead
//! of failing the request. Saturation sheds instead of collapsing (the
//! server front-end's deadline-aware `try_submit` path returns an
//! explicit `overloaded` error), and a deterministic fault-injection
//! harness ([`faults::FaultPlan`]) drives the test suite: dropped
//! connections, delayed responses, corrupted frames, and shards killed
//! mid-stream.

pub mod client;
pub mod dispatchers;
pub mod faults;
pub mod health;
pub mod protocol;
pub mod shard;
pub mod stack;

pub use client::ShardClient;
pub use dispatchers::{RemoteExpectation, RemotePartition, RemoteSampler};
pub use faults::FaultPlan;
pub use health::{HealthBoard, ShardHealth};
pub use protocol::{ShardRequest, ShardResponse};
pub use shard::{ShardEngine, ShardHandler};
pub use stack::{RemoteIndex, RemoteStack};
